"""Render EXPERIMENTS.md tables from benchmarks/dryrun_results.json.

    PYTHONPATH=src python -m benchmarks.render_experiments [--mesh pod]
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results.json")


def render(mesh: str = "pod") -> str:
    with open(RESULTS) as fh:
        rows = json.load(fh)
    out = []
    out.append("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
               "bottleneck | useful | roofline MFU | HBM/dev (GiB) | status |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                       f"skip: {r['reason'][:48]} |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                       f"ERROR |")
            continue
        m = r["memory_per_device"]
        hbm = (m["arguments"] + m["outputs"] + m["temps"] - m["aliased"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | {100*r['mfu']:.1f}% | "
            f"{hbm:.2f} | ok |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod"))
    args = ap.parse_args()
    print(render(args.mesh))


if __name__ == "__main__":
    main()
