"""Shared benchmark utilities: timing, baselines, CSV emission.

Baselines implemented per the paper's comparisons:
  * ``neal_like``  — classic random-scan simulated annealing (the D-Wave Neal
    baseline of Table II/III is exactly this algorithm on CPU).
  * ``sync_all``   — naive synchronous all-spin Glauber updates (§III-B): the
    parallel-update scheme the paper shows oscillates / violates detailed
    balance. Implemented to reproduce that failure mode.
  * Snowball ``rsa`` / ``rwa`` — the paper's dual modes (core.solver).
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ising, rng
from repro.core.pwl import exact_flip_probability
from repro.core.schedules import Schedule


def time_call(fn, *args, repeats: int = 3, **kw):
    """(result, best_seconds). fn must block (we call block_until_ready)."""
    result = None
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, result)
        best = min(best, time.perf_counter() - t0)
    return result, best


@partial(jax.jit, static_argnames=("num_steps", "num_replicas", "schedule"))
def sync_all_spin_anneal(problem: ising.IsingProblem, seed, num_steps: int,
                         num_replicas: int, schedule: Schedule):
    """Naive synchronous all-spin Glauber (paper §III-B / Eq. 4-5).

    Every spin updates simultaneously from the same configuration — the
    transition kernel that violates detailed balance and exhibits period-2
    oscillation. Used as the convergence-failure baseline.
    """
    n = problem.num_spins
    base = jax.random.fold_in(jax.random.key(0), jnp.asarray(seed, jnp.uint32))
    keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(
        jnp.arange(num_replicas))
    spins0 = jax.vmap(lambda k: ising.random_spins(
        rng.stream(k, rng.Salt.INIT), (n,)))(keys)

    def step(carry, t):
        spins, best_e, best_s = carry
        temperature = schedule(t)
        u = jax.vmap(lambda s: ising.local_fields(problem, s))(spins)
        de = 2.0 * spins.astype(jnp.float32) * u
        p = exact_flip_probability(de, temperature)
        draw_keys = jax.vmap(lambda k: rng.stream(k, t, rng.Salt.ACCEPT))(keys)
        us = jax.vmap(lambda k: rng.uniform01(k, (n,)))(draw_keys)
        flip = us < p
        spins = jnp.where(flip, -spins, spins).astype(spins.dtype)
        e = jax.vmap(lambda s: ising.energy(problem, s))(spins)
        better = e < best_e
        best_e = jnp.where(better, e, best_e)
        best_s = jnp.where(better[:, None], spins, best_s)
        return (spins, best_e, best_s), e

    e0 = jax.vmap(lambda s: ising.energy(problem, s))(spins0)
    (spins, best_e, best_s), trace = jax.lax.scan(
        step, (spins0, e0, spins0), jnp.arange(num_steps))
    return best_e + problem.offset, best_s, trace + problem.offset


class CsvEmitter:
    """Accumulates ``name,us_per_call,derived`` rows (benchmark contract)."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.2f},{derived}", flush=True)

    def header(self):
        print("name,us_per_call,derived", flush=True)
