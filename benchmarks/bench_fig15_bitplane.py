"""Fig. 15 + Fig. 8: bit-plane precision scaling.

(1) Fig. 15's reconstruction claim: encode a 16-bit 64×64 coupling field into
    signed bit-planes, decode, and measure pixel-wise agreement (paper: 99.5%;
    the digital codec here is exact ⇒ 100%), plus anneal a planted 16-bit
    instance and report spin agreement with the plant.
(2) Fig. 8's quantization damage: arithmetic right-shift of couplings by k
    bits distorts the landscape; we report ground-state cut degradation vs k
    on an exhaustible instance — the motivation for scalable precision.
"""
from __future__ import annotations

import numpy as np

from repro.configs.snowball import default_solver
from repro.core import bitplane, ising
from repro.core.solver import solve
from repro.graphs.generators import ground_state_planted_grid
from repro.graphs.maxcut import MaxCutInstance, cut_value, maxcut_to_ising

from .common import CsvEmitter, time_call


def reconstruction(emit: CsvEmitter):
    rng = np.random.default_rng(15)
    n = 64
    # 16-bit target "field" (smooth surface, like the paper's 3D landscape).
    xs = np.linspace(-2, 2, n)
    target = (np.sin(xs[:, None] * 2) * np.cos(xs[None, :] * 3)
              + 0.3 * rng.normal(size=(n, n)))
    target = np.rint((target - target.min()) / np.ptp(target) * (2**15 - 1)).astype(np.int64)
    target = np.triu(target, 1)
    target = target + target.T
    planes = bitplane.encode_couplings(target, 16)
    recovered = bitplane.decode_couplings(planes)
    agreement = float(np.mean(recovered == target))
    emit.add("fig15/recon16bit", 0.0, f"pixel_agreement={agreement*100:.2f}%")
    return agreement


def planted_anneal(emit: CsvEmitter):
    inst, plant = ground_state_planted_grid(8, 8, seed=15)
    prob = maxcut_to_ising(inst)
    cfg = default_solver(64, 4000, mode="rwa", num_replicas=8)
    res, secs = time_call(solve, prob, 0, cfg)
    best = np.asarray(res.best_spins)[int(np.argmin(np.asarray(res.best_energy)))]
    agree = max(np.mean(best == plant), np.mean(best == -plant))
    emit.add("fig15/planted_recovery", secs / 4000 * 1e6,
             f"spin_agreement={agree*100:.1f}%")
    return float(agree)


def quantization_damage(emit: CsvEmitter):
    rng = np.random.default_rng(8)
    n = 14
    w = np.triu(rng.integers(1, 2**10, size=(n, n)).astype(np.float64), 1)
    w = w + w.T
    inst = MaxCutInstance(weights=w.astype(np.float32))
    _, s_full, _ = ising.brute_force_ground_state(maxcut_to_ising(inst))
    best_cut = cut_value(inst, s_full)
    out = {}
    for shift in (0, 2, 4, 6, 8):
        wq = np.floor(w / (1 << shift)) * (1 << shift)  # arithmetic right shift
        instq = MaxCutInstance(weights=wq.astype(np.float32))
        _, s_q, _ = ising.brute_force_ground_state(maxcut_to_ising(instq))
        # Evaluate the quantized-problem optimum on the ORIGINAL weights.
        achieved = cut_value(inst, s_q)
        frac = achieved / best_cut
        emit.add(f"fig8/shift{shift}", 0.0, f"cut_fraction={frac:.4f}")
        out[shift] = frac
    return out


def main():
    emit = CsvEmitter()
    agree = reconstruction(emit)
    planted = planted_anneal(emit)
    damage = quantization_damage(emit)
    assert agree == 1.0  # exact digital codec (≥ paper's 99.5%)
    print(f"# fig15: recon={agree:.3f} planted={planted:.3f} "
          f"fig8_monotone={damage[0] >= damage[8]}")
    return {"recon": agree, "planted": planted, "damage": damage}


if __name__ == "__main__":
    main()
