"""§Perf: the spin-sharded plane store (coupling tier 4) past the single-HBM
wall.

N=16384 — the same size as the single-device HBM-streamed anchor — solved by
``repro.distributed.solver_sharded.solve_sharded`` on a forced 2-device host
mesh: each device holds **half** the packed planes (and the matching slice of
the local fields), so the recorded ``plane_bytes_per_device`` must be exactly
half the streamed point's ``j_bytes_hbm_planes`` (``benchmarks.run --check``
gates that identity). Per-step comms are the owner's (B, 1, W) row-tile
broadcast plus the roulette's (R, N/lane) block sums — O(B·N/32) words, never
the O(N²) store.

Runs in a subprocess because XLA's host device count locks at the first jax
init (the same reason ``tests/test_distributed.py`` subprocesses); the parent
bench process stays single-device. Timing is the native-XLA shard_map path
(no interpret-mode Pallas involved), so wall numbers are a relative signal
against this file's own history, not against the interpret-mode tiers.
"""
from __future__ import annotations

import json
import sys

from .bench_solver_perf import merge_bench_results
from .common import CsvEmitter
from .subproc import REPO, run_forced_device_subprocess

SHARDED_N = 16384
SHARDED_STEPS = 48
SHARDED_REPLICAS = 4
SHARDED_DEVICES = 2

#: 2-D mesh cell: the same instance on 4 devices laid out 1-D (4 row
#: shards) vs 2x2 (2 replica groups x 2 row shards) within one subprocess.
SHARDED_2D_GROUPS = 2
SHARDED_2D_ROWS = 2

_SUBPROCESS_CODE = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs.snowball import default_solver
from repro.core.coupling import CouplingStore
from repro.distributed.solver_sharded import solve_sharded
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import maxcut_to_ising

n, steps, reps, devices = {n}, {steps}, {reps}, {devices}
assert jax.device_count() == devices, jax.device_count()
inst = complete_bipolar(n, seed=n)
prob = maxcut_to_ising(inst)
store = CouplingStore.build(prob.couplings, "bitplane_sharded")
mesh = Mesh(np.array(jax.devices()), ("spins",))
cfg = default_solver(n, steps, mode="rsa", num_replicas=reps)
# Pre-packed planes keep the timed region the sharded solve itself, not the
# one-off host-side numpy encode.
secs = float("inf")
best = 0.0
for _ in range(2):
    t0 = time.perf_counter()
    res = solve_sharded(prob, 0, cfg, mesh, coupling=store.planes)
    jax.block_until_ready(res)
    secs = min(secs, time.perf_counter() - t0)
    best = float(np.min(np.asarray(res.best_energy)))
planes = store.planes
print("RESULT " + json.dumps({{
    "n": n,
    "mode": "rsa",
    "num_devices": devices,
    "num_replicas": reps,
    "num_planes": int(planes.num_planes),
    "sharded_us_per_step": secs / steps * 1e6,
    "best_energy": best,
    "plane_bytes_total": int(planes.nbytes),
    "plane_bytes_per_device": int(store.plane_bytes_per_shard(devices)),
    "row_broadcast_words_per_step":
        int(2 * planes.num_planes * planes.num_words * reps),
}}))
"""


_SUBPROCESS_2D_CODE = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs.snowball import default_solver
from repro.core.coupling import CouplingStore
from repro.distributed.solver_sharded import solve_sharded
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import maxcut_to_ising

n, steps, reps = {n}, {steps}, {reps}
groups, rows = {groups}, {rows}
devices = groups * rows
assert jax.device_count() == devices, jax.device_count()
inst = complete_bipolar(n, seed=n)
prob = maxcut_to_ising(inst)
store = CouplingStore.build(prob.couplings, "bitplane_sharded")
mesh_1d = Mesh(np.array(jax.devices()), ("spins",))
mesh_2d = Mesh(np.array(jax.devices()).reshape(groups, rows),
               ("groups", "rows"))
cfg = default_solver(n, steps, mode="rsa", num_replicas=reps)

def timed(mesh):
    secs = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        res = solve_sharded(prob, 0, cfg, mesh, coupling=store.planes)
        jax.block_until_ready(res)
        secs = min(secs, time.perf_counter() - t0)
    return secs, np.asarray(res.best_energy).tolist()

secs_1d, best_1d = timed(mesh_1d)
secs_2d, best_2d = timed(mesh_2d)
planes = store.planes
print("RESULT " + json.dumps({{
    "n": n,
    "mode": "rsa",
    "num_devices": devices,
    "num_groups": groups,
    "rows_per_group": rows,
    "num_replicas": reps,
    "num_steps": steps,
    "num_planes": int(planes.num_planes),
    "plane_bytes_total": int(planes.nbytes),
    "plane_bytes_per_device_1d": int(store.plane_bytes_per_shard(devices)),
    "plane_bytes_per_device_2d":
        int(store.plane_bytes_per_device((groups, rows))),
    "us_per_step_1d": secs_1d / steps * 1e6,
    "us_per_step_2d": secs_2d / steps * 1e6,
    "replica_steps_per_sec_1d": reps * steps / secs_1d,
    "replica_steps_per_sec_2d": reps * steps / secs_2d,
    "best_energy_1d": best_1d,
    "best_energy_2d": best_2d,
}}))
"""


def run_sharded_point(emit: CsvEmitter) -> dict:
    """Time the N=16384 sharded solve on a forced 2-device mesh and return
    the history cell (per-device plane-byte accounting + µs/step anchor)."""
    code = _SUBPROCESS_CODE.format(n=SHARDED_N, steps=SHARDED_STEPS,
                                   reps=SHARDED_REPLICAS,
                                   devices=SHARDED_DEVICES)
    proc = run_forced_device_subprocess(code, n_devices=SHARDED_DEVICES,
                                        timeout=3600, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n"
                           f"{proc.stderr[-4000:]}")
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    point = json.loads(line[len("RESULT "):])
    point["comms"] = ("per step: psum of the owner's (B,1,W) pos/neg row "
                      "tiles per replica + all_gather of (R, N/lane) "
                      "roulette block sums")
    point["dense_path"] = "cannot allocate: 1 GiB f32 J vs 16 MiB VMEM"
    point["single_device_hbm_path"] = (
        "fits, but J capacity capped by one device's HBM; sharding halves "
        "per-device plane bytes and scales capacity with the mesh")
    emit.add(
        f"solver/N{point['n']}/rsa/sharded_d{point['num_devices']}",
        point["sharded_us_per_step"],
        f"best_E={point['best_energy']:.0f};"
        f"plane_bytes_per_device={point['plane_bytes_per_device']};"
        f"plane_bytes_total={point['plane_bytes_total']};"
        f"bcast_words={point['row_broadcast_words_per_step']}")
    return point


def run_sharded_2d_point(emit: CsvEmitter) -> dict:
    """Time the N=16384 solve on 4 forced devices, 1-D (4 row shards) vs
    2x2 (2 groups x 2 rows) in one subprocess, and return the history cell.

    The within-run pair is the tentpole's trade made measurable: the 2-D
    layout holds half the planes per device (capacity: total / rows, not
    total / devices) while running both groups' replica blocks
    concurrently (throughput), and the recorded best-energy vectors must be
    byte-identical between the layouts — the mesh shape is a placement
    choice, never a trajectory change."""
    devices = SHARDED_2D_GROUPS * SHARDED_2D_ROWS
    code = _SUBPROCESS_2D_CODE.format(n=SHARDED_N, steps=SHARDED_STEPS,
                                      reps=SHARDED_REPLICAS,
                                      groups=SHARDED_2D_GROUPS,
                                      rows=SHARDED_2D_ROWS)
    proc = run_forced_device_subprocess(code, n_devices=devices,
                                        timeout=3600, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded 2-D bench subprocess failed:\n"
                           f"{proc.stderr[-4000:]}")
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    point = json.loads(line[len("RESULT "):])
    point["comms"] = ("per step: psum/all_gather scoped to each group's "
                      "rows sub-axis only — no cross-group collective on "
                      "the hot path")
    emit.add(
        f"solver/N{point['n']}/rsa/sharded_g{point['num_groups']}"
        f"r{point['rows_per_group']}",
        point["us_per_step_2d"],
        f"us_per_step_1d={point['us_per_step_1d']:.1f};"
        f"plane_bytes_per_device_2d={point['plane_bytes_per_device_2d']};"
        f"plane_bytes_per_device_1d={point['plane_bytes_per_device_1d']};"
        f"replica_steps_per_sec_2d={point['replica_steps_per_sec_2d']:.1f}")
    return point


def main(run_id: str | None = None):
    emit = CsvEmitter()
    point = run_sharded_point(emit)
    point_2d = run_sharded_2d_point(emit)
    merge_bench_results({f"N{SHARDED_N}_sharded": {"rsa": point},
                         f"N{SHARDED_N}_sharded_2d": {"rsa": point_2d}},
                        run_id=run_id)
    return point, point_2d


if __name__ == "__main__":
    rid = (sys.argv[sys.argv.index("--run-id") + 1]
           if "--run-id" in sys.argv else None)
    main(run_id=rid)
