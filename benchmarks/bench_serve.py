"""§Serving: multi-tenant throughput of the solver-as-a-service front end.

A Poisson request stream (fixed-seed arrival schedule — reproducible, like
every other input here) over a small pool of instances is played twice
through :class:`repro.serve.SolverService`, *within one run*: once with
batching on (same-instance seed-free requests stack into the replica axis
of one fused launch) and once with ``ServeConfig(batching=False)`` (one
launch per request — the sequential baseline). Each variant is played cold
first (traces/compiles + populates the content-hash store cache) and then
warm-timed, so the recorded ratio isolates the batching policy and the
warm pass doubles as the cache measurement: the encoder call count during
the warm pass is recorded and ``--check`` gates it at exactly **zero**
(cache-hit solves must skip the resolve→encode entirely), alongside
``batched_solves_per_sec >= sequential_solves_per_sec`` — both columns
from the same session, so the gate is load-robust like the fused-vs-
baseline one.

Latency is measured against the simulated arrival clock (arrival → result
assembly, including time spent queued behind the drain in flight), so the
p50/p99 capture what a tenant would see, not just kernel wall time.

Cells merge into ``BENCH_solver_perf.json`` under ``N{n}_serve`` via
``merge_bench_results`` (this suite owns a subset of the table).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs.snowball import default_solver
from repro.core import coupling
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import maxcut_to_ising
from repro.serve import ServeConfig, SolveRequest, SolverService

from .common import CsvEmitter

SERVE_N = 48            # bucket-pads to 64; interpret-mode-friendly
SERVE_STEPS = 2048     # enough solve wall that launch count dominates pacing
SERVE_REPLICAS = 2      # per request; stacking fuses these per instance
NUM_INSTANCES = 3
NUM_REQUESTS = 12
MEAN_GAP_S = 0.0005     # bursty offered load: requests pile up within one window
#: Admission window: every request arriving within this span of the first
#: unserved one drains together. Keyed on *arrival* time, not service time,
#: so batch compositions are a pure function of the fixed-seed schedule —
#: identical across the cold/warm passes (shapes traced cold stay warm) and
#: across the batched/sequential variants (only the launch policy differs).
BATCH_WINDOW_S = 0.005


def _instances():
    probs = []
    for i in range(NUM_INSTANCES):
        inst = complete_bipolar(SERVE_N, seed=100 + i)
        probs.append(maxcut_to_ising(inst))
    return probs


def _arrivals():
    """(arrival_time, instance_index) per request — a fixed-seed Poisson
    process round-robined over the instance pool."""
    rng = np.random.default_rng(7)
    gaps = rng.exponential(scale=MEAN_GAP_S, size=NUM_REQUESTS)
    times = np.cumsum(gaps)
    return [(float(times[i]), i % NUM_INSTANCES) for i in range(NUM_REQUESTS)]


def _simulate(service: SolverService, problems, arrivals, cfg) -> dict:
    """Play the arrival schedule through the service against a simulated
    clock: each admission window collects every request arriving within
    ``BATCH_WINDOW_S`` of the first unserved one, one drain's measured wall
    time then moves the clock — so a request's latency includes both the
    window wait and queueing behind the drain in flight."""
    clock = 0.0
    latencies = []
    submitted_at = {}
    launches0 = service.stats["launches"]
    i = 0
    while i < len(arrivals):
        w_end = arrivals[i][0] + BATCH_WINDOW_S
        clock = max(clock, w_end)
        while i < len(arrivals) and arrivals[i][0] <= w_end:
            t_arr, p = arrivals[i]
            ticket = service.submit(SolveRequest(problems[p], cfg))
            submitted_at[ticket] = t_arr
            i += 1
        t0 = time.perf_counter()
        out = service.drain()
        clock += time.perf_counter() - t0
        for ticket in out:
            latencies.append(clock - submitted_at.pop(ticket))
    lat = np.asarray(sorted(latencies))
    span = clock - arrivals[0][0]
    return {
        "solves_per_sec": len(lat) / span,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "launches": service.stats["launches"] - launches0,
        "span_s": span,
    }


def run_serve_point(emit: CsvEmitter) -> dict:
    problems = _instances()
    arrivals = _arrivals()
    # The bit-plane tier makes the encode cost (and its caching) real; the
    # Max-Cut instances have integral couplings so the tier is exact.
    cfg = dataclasses.replace(
        default_solver(SERVE_N, SERVE_STEPS, mode="rsa",
                       num_replicas=SERVE_REPLICAS),
        coupling_format="bitplane")

    encodes = {"n": 0}
    real_encode = coupling.encode_couplings

    def counting(*a, **k):
        encodes["n"] += 1
        return real_encode(*a, **k)

    coupling.encode_couplings = counting
    try:
        batched = SolverService(ServeConfig())
        _simulate(batched, problems, arrivals, cfg)     # cold: trace + fill
        cold_encodes = encodes["n"]
        encodes["n"] = 0
        warm = _simulate(batched, problems, arrivals, cfg)
        warm_encodes = encodes["n"]

        sequential = SolverService(ServeConfig(batching=False))
        _simulate(sequential, problems, arrivals, cfg)  # cold
        seq = _simulate(sequential, problems, arrivals, cfg)
    finally:
        coupling.encode_couplings = real_encode

    speedup = warm["solves_per_sec"] / seq["solves_per_sec"]
    emit.add(f"serve/N{SERVE_N}/batched",
             warm["p50_latency_s"] * 1e6,
             f"solves_per_s={warm['solves_per_sec']:.2f};"
             f"p99_s={warm['p99_latency_s']:.3f};"
             f"launches={warm['launches']};warm_encodes={warm_encodes}")
    emit.add(f"serve/N{SERVE_N}/sequential",
             seq["p50_latency_s"] * 1e6,
             f"solves_per_s={seq['solves_per_sec']:.2f};"
             f"p99_s={seq['p99_latency_s']:.3f};"
             f"launches={seq['launches']};speedup={speedup:.2f}x")
    return {
        "n": SERVE_N,
        "mode": "rsa",
        "num_requests": NUM_REQUESTS,
        "num_instances": NUM_INSTANCES,
        "steps": SERVE_STEPS,
        "replicas_per_request": SERVE_REPLICAS,
        "mean_arrival_gap_s": MEAN_GAP_S,
        "batched_solves_per_sec": warm["solves_per_sec"],
        "batched_p50_latency_s": warm["p50_latency_s"],
        "batched_p99_latency_s": warm["p99_latency_s"],
        "batched_launches": warm["launches"],
        "sequential_solves_per_sec": seq["solves_per_sec"],
        "sequential_p50_latency_s": seq["p50_latency_s"],
        "sequential_p99_latency_s": seq["p99_latency_s"],
        "sequential_launches": seq["launches"],
        "batch_speedup": speedup,
        "cold_encode_calls": cold_encodes,
        "warm_encode_calls": warm_encodes,
        "store_cache": "content-hash LRU; warm pass must re-encode nothing",
        "workload": "fixed-seed Poisson stream, seed-free requests "
                    "round-robined over the instance pool; batching stacks "
                    "same-instance requests into one fused launch",
    }


def main(run_id: str | None = None):
    from .bench_solver_perf import merge_bench_results

    emit = CsvEmitter()
    cell = run_serve_point(emit)
    merge_bench_results({f"N{SERVE_N}_serve": {"rsa": cell}}, run_id=run_id)
    return cell


if __name__ == "__main__":
    import sys

    rid = sys.argv[sys.argv.index("--run-id") + 1] if "--run-id" in sys.argv else None
    main(run_id=rid)
