"""Forced-device-count subprocess harness.

XLA's host platform device count locks at the first jax initialization, so
anything that needs a multi-device CPU mesh — the multi-device tier-1 tests
(``tests/conftest.run_with_forced_devices``) and the spin-sharded benchmark
suite (``benchmarks/bench_solver_sharded.py``) — must run in a fresh
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set
before import. This module is the single copy of that env plumbing
(deliberately dependency-free so test collection never imports jax through
it); callers decide how to handle a non-zero exit.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced_device_subprocess(code: str, n_devices: int = 8,
                                 timeout: int = 420,
                                 cwd: str | None = None
                                 ) -> subprocess.CompletedProcess:
    """Run ``code`` under a forced ``n_devices``-device CPU platform with the
    repo's ``src`` prepended to PYTHONPATH. Returns the completed process
    (stdout/stderr captured as text); does not raise on failure."""
    pythonpath = os.path.join(REPO, "src")
    if os.environ.get("PYTHONPATH"):
        pythonpath = pythonpath + os.pathsep + os.environ["PYTHONPATH"]
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
               PYTHONPATH=pythonpath)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout, cwd=cwd)
