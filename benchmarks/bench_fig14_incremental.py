"""Fig. 14: incremental local-field updates vs naive recompute.

The paper shows the incremental scheme (Eq. 12, Θ(N)/flip) turns the kernel
compute-bound, while the naive Θ(N²)/flip recompute is memory-bound. We
measure wall time per MC step for both on CPU, and — hardware-neutrally —
count the flop/byte cost ratio (N² / N) the architecture eliminates.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snowball import default_solver
from repro.core import ising, mcmc, rng
from repro.core.solver import SolverConfig, solve
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import maxcut_to_ising

from .common import CsvEmitter, time_call

STEPS = 512
REPLICAS = 4


@partial(jax.jit, static_argnames=("num_steps", "num_replicas", "config"))
def naive_anneal(problem, seed, num_steps: int, num_replicas: int,
                 config: SolverConfig):
    """Identical chain to solver.solve but recomputing ALL local fields from
    scratch (dense J @ s) after every step — the paper's 'Naive' baseline."""
    from repro.core.solver import _mcmc_config
    mc = _mcmc_config(config)
    n = problem.num_spins
    base = jax.random.fold_in(jax.random.key(0), jnp.asarray(seed, jnp.uint32))
    keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(
        jnp.arange(num_replicas))
    spins0 = jax.vmap(lambda k: ising.random_spins(
        rng.stream(k, rng.Salt.INIT), (n,)))(keys)
    states = jax.vmap(lambda s: mcmc.init_chain(problem, s))(spins0)

    def one(states, t):
        temperature = config.schedule(t)
        sk = jax.vmap(lambda k: rng.stream(k, t))(keys)
        states, _ = jax.vmap(lambda st, k: mcmc.step(problem, st, k, temperature, mc))(states, sk)
        # naive: throw away the incremental fields, recompute u = J s + h
        fresh = jax.vmap(lambda s: ising.local_fields(problem, s))(states.spins)
        states = states._replace(fields=fresh)
        return states, None

    states = jax.lax.fori_loop(0, num_steps, lambda t, s: one(s, t)[0], states)
    return states.best_energy + problem.offset


def run(emit: CsvEmitter) -> dict:
    out = {}
    for n in (256, 512, 1024):
        inst = complete_bipolar(n, seed=n)
        prob = maxcut_to_ising(inst)
        cfg = default_solver(n, STEPS, mode="rwa", num_replicas=REPLICAS)
        _, t_inc = time_call(solve, prob, 0, cfg)
        _, t_naive = time_call(naive_anneal, prob, 0, STEPS, REPLICAS, cfg)
        us_inc = t_inc / STEPS * 1e6
        us_naive = t_naive / STEPS * 1e6
        emit.add(f"fig14/N{n}/incremental", us_inc, f"speedup_vs_naive={t_naive/t_inc:.2f}x")
        emit.add(f"fig14/N{n}/naive", us_naive, f"bytes_ratio_eliminated={n}x_model")
        out[n] = (us_inc, us_naive)
    return out


def main():
    emit = CsvEmitter()
    out = run(emit)
    ok = all(naive > inc for inc, naive in out.values())
    print(f"# fig14: incremental_faster_everywhere={ok}")
    return out


if __name__ == "__main__":
    main()
