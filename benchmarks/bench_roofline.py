"""§Roofline: render the dry-run results table (reads dryrun_results.json).

The dry-run itself (launch/dryrun.py) is the producer; this benchmark formats
the per-(arch × shape × mesh) three-term roofline and flags the dominant
bottleneck. Run the dry-run first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out benchmarks/dryrun_results.json
"""
from __future__ import annotations

import json
import os

from .common import CsvEmitter

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results.json")


def main():
    emit = CsvEmitter()
    if not os.path.exists(RESULTS):
        print("# roofline: dryrun_results.json missing — run the dry-run first")
        return {}
    with open(RESULTS) as fh:
        rows = json.load(fh)
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        emit.add(name, r["step_time"] * 1e6,
                 f"bottleneck={r['bottleneck']};mfu={100*r['mfu']:.1f}%;"
                 f"useful={r['useful_ratio']:.2f}")
    print(f"# roofline: ok={len(ok)} skipped={len(skipped)} errors={len(errors)}")
    for r in errors:
        print(f"# ERROR {r['arch']}/{r['shape']}/{r['mesh']}: {r.get('error','')[:120]}")
    return {"ok": len(ok), "skipped": len(skipped), "errors": len(errors)}


if __name__ == "__main__":
    main()
