"""Table II: solution quality (cut value) on Gset-family Max-Cut instances.

Synthetic instances statistically matched to Table I (same topology family,
|V|, |E|, ±1 weights) at reduced |V| so the CPU container finishes in minutes;
real Gset files drop in via ``repro.graphs.parse_gset``. Algorithms:

    neal   — classic random-scan SA (the Neal baseline = RSA w/ exact sigmoid)
    sync   — naive synchronous all-spin updates (§III-B failure-mode baseline)
    rsa    — Snowball Mode I  (random-scan, PWL logistic)
    rwa    — Snowball Mode II (roulette-wheel, PWL logistic)

Paper claim validated: RWA ≥ RSA > {neal, sync} on cut value at equal step
budget (Table II shows RWA/RSA dominating all baselines).
"""
from __future__ import annotations

import numpy as np

from repro.configs.snowball import default_solver
from repro.core.solver import SolverConfig, solve
from repro.graphs import erdos_renyi, small_world, torus_grid
from repro.graphs.maxcut import cut_from_energy, maxcut_to_ising

from .common import CsvEmitter, sync_all_spin_anneal, time_call

# Scaled Table I instances (|V|, |E| ÷10, same topology family + ±1 weights).
INSTANCES = [
    ("G6/10", lambda: erdos_renyi(80, 1918, seed=6, name="G6s")),
    ("G18/10", lambda: small_world(80, 12, seed=18, name="G18s")),
    ("G11/10", lambda: torus_grid(8, 10, seed=11, name="G11s")),
]

STEPS = 6000
REPLICAS = 8


def run(emit: CsvEmitter) -> dict:
    results = {}
    for name, make in INSTANCES:
        inst = make()
        prob = maxcut_to_ising(inst)
        n = inst.num_vertices
        cuts = {}
        times = {}
        for algo in ("neal", "rsa", "rwa"):
            cfg = default_solver(n, STEPS, mode="rsa" if algo != "rwa" else "rwa",
                                 num_replicas=REPLICAS)
            if algo == "neal":
                cfg = SolverConfig(**{**cfg.__dict__, "use_pwl": False})
            res, secs = time_call(solve, prob, 0, cfg)
            best = float(np.min(np.asarray(res.best_energy)))
            cuts[algo] = float(cut_from_energy(inst, best))
            times[algo] = secs
        # naive synchronous all-spin baseline
        (be, _, _), secs = time_call(
            sync_all_spin_anneal, prob, 0, STEPS, REPLICAS,
            default_solver(n, STEPS).schedule)
        cuts["sync"] = float(cut_from_energy(inst, float(np.min(np.asarray(be)))))
        times["sync"] = secs
        for algo, cut in cuts.items():
            us = times[algo] / (STEPS * REPLICAS) * 1e6
            emit.add(f"table2/{name}/{algo}", us, f"cut={cut:.0f}")
        results[name] = cuts
    return results


def main():
    emit = CsvEmitter()
    results = run(emit)
    ok = all(c["rwa"] >= c["sync"] and c["rsa"] >= c["sync"] for c in results.values())
    print(f"# table2: snowball_beats_sync={ok}")
    return results


if __name__ == "__main__":
    main()
