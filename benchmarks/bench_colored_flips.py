"""§Graph-colored parallel flips: colored vs single-flip throughput on the
N=16384 sparse anchor (the same ``sparse_bipolar_edges`` instance as the
sparse-ingest cell, HBM-streamed bit-plane tier).

Single-flip async updates do at most one flip per replica per step; the
colored mode flips one whole conflict-graph color class per step (exact
block Gibbs — DESIGN.md §Graph-colored parallel flips), so on this instance
(χ ≈ 11, mean class ≈ N/χ ≈ 1500) each kernel step carries hundreds of
flips. The recorded cell (``N16384_colored``) holds both engines' µs/step,
µs/flip, flips/sec and steps-to-target **measured in the same session**, so
``benchmarks.run --check`` can gate the claim as a within-run inequality
(colored flips/sec strictly above single-flip; per-step flips bounded by
the largest color class), load-robust like the fused gate.
"""
from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.configs.snowball import default_solver
from repro.core.coupling import CouplingStore
from repro.core.ising import IsingProblem
from repro.graphs import sparse_bipolar_edges
from repro.graphs.coloring import greedy_coloring
from repro.kernels import fused_anneal, ops

from .bench_solver_perf import merge_bench_results
from .common import CsvEmitter, time_call

COLORED_N = 16384
COLORED_EDGES = 8 * COLORED_N
COLORED_REPLICAS = 4
#: Single-flip step budget: matches the HBM-streamed anchor point.
SINGLE_STEPS = 48
#: Colored step budget: full class sweeps (multiples of χ) so every spin
#: gets the same number of update opportunities; set after coloring.
SWEEPS = 4


def _steps_to_target(trace, trace_every, target):
    """First step count at which the ensemble best-so-far trace reaches
    ``target`` (the trace is monotone non-increasing per replica)."""
    best = np.min(np.asarray(trace), axis=1)
    hit = np.nonzero(best <= target)[0]
    return int((hit[0] + 1) * trace_every) if hit.size else None


def run_colored_point(emit: CsvEmitter) -> dict:
    n, r = COLORED_N, COLORED_REPLICAS
    edges = sparse_bipolar_edges(n, COLORED_EDGES, seed=n)
    col = greedy_coloring(edges)
    prob = IsingProblem.create_sparse(edges)

    single_cfg = dataclasses.replace(
        default_solver(n, SINGLE_STEPS, mode="rsa", num_replicas=r),
        coupling_format="bitplane_hbm", trace_every=8)
    store = CouplingStore.build(edges, "bitplane_hbm")
    single, s_secs = time_call(fused_anneal, prob, 0, single_cfg,
                               store=store, repeats=2)

    chi = col.num_classes
    colored_steps = SWEEPS * chi
    colored_cfg = dataclasses.replace(
        default_solver(n, colored_steps, mode="rsa", num_replicas=r),
        coupling_format="bitplane_hbm", trace_every=chi,
        flip_mode="colored")
    plan = ops.colored_plan(prob, "bitplane_hbm")
    colored, c_secs = time_call(ops.colored_anneal, prob, 0, colored_cfg,
                                plan=plan, repeats=2)

    s_flips = int(np.asarray(single.num_flips).sum())
    c_flips = int(np.asarray(colored.num_flips).sum())
    # Common quality target: the worse of the two final ensemble bests —
    # both traces reach it by construction, so steps-to-target is defined
    # for both engines.
    target = max(float(np.min(np.asarray(single.best_energy))),
                 float(np.min(np.asarray(colored.best_energy))))
    point = {
        "n": n,
        "mode": "rsa",
        "nnz": edges.nnz,
        "num_replicas": r,
        "num_color_classes": chi,
        "max_class_size": int(col.max_class_size),
        "single_steps": SINGLE_STEPS,
        "colored_steps": colored_steps,
        "single_us_per_step": s_secs / SINGLE_STEPS * 1e6,
        "colored_us_per_step": c_secs / colored_steps * 1e6,
        "single_flips": s_flips,
        "colored_flips": c_flips,
        "single_us_per_flip": s_secs / max(s_flips, 1) * 1e6,
        "colored_us_per_flip": c_secs / max(c_flips, 1) * 1e6,
        "single_flips_per_sec": s_flips / s_secs,
        "colored_flips_per_sec": c_flips / c_secs,
        "colored_flips_per_step_per_replica":
            c_flips / colored_steps / r,
        "target_energy": target,
        "steps_to_target_single":
            _steps_to_target(single.trace_energy, 8, target),
        "steps_to_target_colored":
            _steps_to_target(colored.trace_energy, chi, target),
        "engines": ("single: fused async sweep (1 flip/replica/step); "
                    "colored: one conflict-graph color class per step, "
                    f"{SWEEPS} full sweeps — same instance, same tier, "
                    "same session"),
    }
    emit.add(f"colored/N{n}/rsa/single", point["single_us_per_step"],
             f"flips={s_flips};flips_per_sec={point['single_flips_per_sec']:.0f}")
    emit.add(f"colored/N{n}/rsa/colored", point["colored_us_per_step"],
             f"flips={c_flips};flips_per_sec={point['colored_flips_per_sec']:.0f};"
             f"classes={chi};max_class={point['max_class_size']};"
             f"speedup={point['colored_flips_per_sec'] / point['single_flips_per_sec']:.1f}x")
    return point


def main(run_id: str | None = None):
    emit = CsvEmitter()
    point = run_colored_point(emit)
    merge_bench_results({f"N{COLORED_N}_colored": {"rsa": point}},
                        run_id=run_id)
    return point


if __name__ == "__main__":
    rid = (sys.argv[sys.argv.index("--run-id") + 1]
           if "--run-id" in sys.argv else None)
    main(run_id=rid)
