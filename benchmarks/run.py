# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# ``--run-id <stamp>`` labels this run in BENCH_solver_perf.json's history
# (e.g. ``python -m benchmarks.run --run-id pr2-2026-07-26``). The stamp is a
# CLI argument by design — no in-process clock read — so benchmark output is
# a pure function of code + inputs and reruns stay byte-reproducible.
#
# ``--suite <name>`` runs a single suite (e.g. ``--suite solver_perf`` to
# refresh the perf anchor without the full table sweep).
#
# ``--check`` validates BENCH_solver_perf.json instead of running anything:
# history schema (unique run-id stamps, required fields, latest history entry
# mirroring the top-level results) plus the perf gate — in the latest run the
# fused engine must not be more than ``CHECK_MAX_FUSED_REGRESSION``× slower
# than the paper-faithful baseline at any matched (N, mode). The gate is
# within-run by design: both engines are timed in the same session, so the
# ratio is robust to machine-load noise that makes cross-run wall-clock
# comparisons meaningless (the recorded history shows ~3× swings between
# otherwise identical runs). Exits non-zero on violations; a tier-1 test
# runs the same function, so perf-touching PRs cannot silently regress.
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from functools import partial

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_solver_perf.json")


def bench_checksum(payload: dict) -> str:
    """Content checksum of a bench payload: sha256 over the canonical
    (sorted-keys, compact) JSON of everything except the ``checksum`` field
    itself. Recorded on write, verified by ``--check`` — a hand-edited or
    torn history file fails loudly instead of silently gating on garbage."""
    body = {k: v for k, v in payload.items() if k != "checksum"}
    canon = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()


def write_bench_payload(payload: dict, path: str = BENCH_JSON) -> None:
    """Atomically persist a bench payload: stamp ``checksum``, write to a
    temp file in the same directory, fsync, then ``os.replace`` — a crash
    mid-write leaves the previous file intact, never a truncated JSON."""
    payload = dict(payload)
    payload["checksum"] = bench_checksum(payload)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def verify_checksum(payload: dict) -> list[str]:
    """Checksum violations for a loaded payload (empty = healthy or
    legacy-unstamped). Separate from :func:`check_bench_history` so the
    schema checks stay usable on synthetic in-memory payloads."""
    recorded = payload.get("checksum")
    if recorded is None:
        return []   # pre-checksum file: schema checks still apply
    actual = bench_checksum(payload)
    if recorded != actual:
        return [f"checksum mismatch: file records {recorded[:12]}…, contents "
                f"hash to {actual[:12]}… — the history was edited or torn "
                "outside write_bench_payload"]
    return []

#: --check gate: fused µs/step may be at most this multiple of the baseline's
#: at the same (N, mode) in the same recorded run.
CHECK_MAX_FUSED_REGRESSION = 1.3


def check_bench_history(payload: dict,
                        max_ratio: float = CHECK_MAX_FUSED_REGRESSION) -> list[str]:
    """Validate the solver-perf JSON; returns a list of violations (empty =
    healthy). Pure function of the payload so the tier-1 test can exercise
    both the repo's committed file and synthetic failure cases."""
    errors = []
    for field in ("bench", "units", "results", "history"):
        if field not in payload:
            errors.append(f"missing required top-level field {field!r}")
    history = payload.get("history") or []
    if not isinstance(history, list) or not history:
        errors.append("history must be a non-empty list")
        history = []
    run_ids = []
    for i, entry in enumerate(history):
        if not isinstance(entry, dict):
            errors.append(f"history[{i}] is not an object "
                          f"({type(entry).__name__})")
            continue
        rid = entry.get("run_id")
        if not isinstance(rid, str) or not rid:
            errors.append(f"history[{i}] missing a non-empty run_id stamp")
        else:
            run_ids.append(rid)
        if not isinstance(entry.get("results"), dict) or not entry["results"]:
            errors.append(f"history[{i}] ({rid!r}) missing results")
    if len(set(run_ids)) != len(run_ids):
        dupes = sorted({r for r in run_ids if run_ids.count(r) > 1})
        errors.append(f"duplicate run_id stamps {dupes} — every recorded run "
                      "must be uniquely stamped (append, never overwrite)")
    last = history[-1] if history and isinstance(history[-1], dict) else {}
    if last and isinstance(payload.get("results"), dict):
        if last.get("results") != payload["results"]:
            errors.append("top-level results must mirror the latest history "
                          "entry (the file is append-only)")
    # Perf gate on the latest run: fused vs baseline at matched (N, mode).
    latest = last.get("results") or {}
    for n_key, modes in sorted(latest.items()):
        if not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            base = cell.get("baseline_us_per_step")
            fused = cell.get("fused_us_per_step")
            if base is None or fused is None:
                continue  # single-engine points (e.g. bit-plane-only sizes)
            if base <= 0:
                errors.append(f"{n_key}/{mode}: non-positive baseline timing")
                continue
            if fused > max_ratio * base:
                errors.append(
                    f"{n_key}/{mode}: fused {fused:.1f} µs/step is "
                    f"{fused / base:.2f}x the baseline's {base:.1f} — over "
                    f"the {max_ratio}x regression gate")
    errors.extend(check_sharded_points(latest))
    errors.extend(check_sharded_2d_points(latest))
    errors.extend(check_ingestion_points(latest))
    errors.extend(check_serve_points(latest))
    errors.extend(check_row_traffic_points(latest))
    errors.extend(check_colored_points(latest))
    return errors


def check_colored_points(latest: dict) -> list[str]:
    """Schema + throughput gates for graph-colored cells (``N*_colored``
    keys, written by the ``colored_flips`` suite): colored flips/sec must
    land *strictly* above the single-flip engine's measured in the same run
    (the O(N/χ) flips-per-step claim as a within-run inequality, load-robust
    like the fused gate), and the per-step ensemble flip count may never
    exceed the largest color class — a count above it means the kernel
    flipped spins outside the scheduled class."""
    errors = []
    for n_key, modes in sorted(latest.items()):
        if not n_key.endswith("_colored") or not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            num = ("num_replicas", "num_color_classes", "max_class_size",
                   "single_steps", "colored_steps", "single_flips",
                   "colored_flips", "single_flips_per_sec",
                   "colored_flips_per_sec", "single_us_per_flip",
                   "colored_us_per_flip")
            if not all(isinstance(cell.get(k), (int, float)) and cell[k] > 0
                       for k in num):
                errors.append(f"{n_key}/{mode}: colored point needs positive "
                              f"numeric {num}")
                continue
            if cell["colored_flips_per_sec"] <= cell["single_flips_per_sec"]:
                errors.append(
                    f"{n_key}/{mode}: colored {cell['colored_flips_per_sec']:.0f} "
                    f"flips/sec did not beat the single-flip engine's "
                    f"{cell['single_flips_per_sec']:.0f} in the same run — "
                    "the colored mode exists to multiply flip throughput "
                    "on sparse instances")
            per_step = (cell["colored_flips"]
                        / (cell["colored_steps"] * cell["num_replicas"]))
            if per_step > cell["max_class_size"]:
                errors.append(
                    f"{n_key}/{mode}: {per_step:.1f} flips per replica-step "
                    f"exceeds the largest color class "
                    f"({cell['max_class_size']}) — the kernel flipped spins "
                    "outside the scheduled class")
            if cell["num_color_classes"] < 2:
                errors.append(
                    f"{n_key}/{mode}: num_color_classes "
                    f"{cell['num_color_classes']} < 2 — a one-class "
                    "'coloring' means an edgeless conflict graph; the cell "
                    "proves nothing about colored scheduling")
    return errors


def check_row_traffic_points(latest: dict) -> list[str]:
    """Schema + traffic gates for reuse-aware fetch cells (``N*_row_traffic``
    keys, written by the ``row_traffic`` suite): the coalesced stream may
    never fetch more than one row per replica-step; the iid point must land
    *strictly* under the R·T uncoalesced traffic (birthday-rate reuse
    actually recovered, not a counter that always reads R·T); the collapsed-
    ensemble point must fetch at most one row per group-step; and at R ≥ 8
    the coalesced sweep may not be slower than the uncoalesced one timed in
    the same run — the within-run ratio, load-robust like the fused gate."""
    errors = []
    for n_key, modes in sorted(latest.items()):
        if not n_key.endswith("_row_traffic") or not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            num = ("num_replicas", "num_steps", "replica_steps", "num_groups",
                   "rows_fetched_iid", "rows_fetched_ensemble",
                   "uncoalesced_rows_fetched", "coalesced_us_per_step",
                   "uncoalesced_us_per_step")
            if not all(isinstance(cell.get(k), (int, float)) and cell[k] > 0
                       for k in num):
                errors.append(f"{n_key}/{mode}: row-traffic point needs "
                              f"positive numeric {num}")
                continue
            rt = cell["num_replicas"] * cell["num_steps"]
            if cell["replica_steps"] != rt:
                errors.append(f"{n_key}/{mode}: replica_steps "
                              f"{cell['replica_steps']} != num_replicas x "
                              f"num_steps ({rt})")
                continue
            for k in ("rows_fetched_iid", "rows_fetched_ensemble"):
                if cell[k] > rt:
                    errors.append(
                        f"{n_key}/{mode}: {k} {cell[k]} exceeds the "
                        f"replica-step count {rt} — coalescing can never "
                        "fetch more than one row per replica per step")
            if cell["rows_fetched_iid"] >= rt:
                errors.append(
                    f"{n_key}/{mode}: iid unique-row fetches "
                    f"{cell['rows_fetched_iid']} did not land under the "
                    f"{rt} uncoalesced fetches — no birthday-rate reuse "
                    "recovered")
            gt = cell["num_groups"] * cell["num_steps"]
            if cell["rows_fetched_ensemble"] > gt:
                errors.append(
                    f"{n_key}/{mode}: ensemble unique-row fetches "
                    f"{cell['rows_fetched_ensemble']} exceed one row per "
                    f"group-step ({gt}) — identical replicas must coalesce "
                    "to their group count")
            if (cell["num_replicas"] >= 8
                    and cell["coalesced_us_per_step"]
                    > cell["uncoalesced_us_per_step"]):
                errors.append(
                    f"{n_key}/{mode}: coalesced "
                    f"{cell['coalesced_us_per_step']:.1f} µs/step is slower "
                    f"than the uncoalesced "
                    f"{cell['uncoalesced_us_per_step']:.1f} in the same run "
                    f"at R={cell['num_replicas']} — unique-row fetching must "
                    "not lose to fetch-per-replica where reuse exists")
    return errors


def check_serve_points(latest: dict) -> list[str]:
    """Schema + policy gates for serving cells (``N*_serve`` keys, written
    by the ``serve`` suite): the warm pass must have performed exactly zero
    coupling re-encodes (and the cold pass at least one, so the zero is
    meaningful — the content-hash store cache actually short-circuited the
    resolve→encode), and batched throughput must be at least the sequential
    baseline's *measured in the same run* — the batching claim as an
    inequality on recorded numbers, load-robust like the fused gate."""
    errors = []
    for n_key, modes in sorted(latest.items()):
        if not n_key.endswith("_serve") or not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            num = ("batched_solves_per_sec", "sequential_solves_per_sec",
                   "batched_p50_latency_s", "batched_p99_latency_s",
                   "sequential_p50_latency_s", "sequential_p99_latency_s")
            if not all(isinstance(cell.get(k), (int, float)) and cell[k] > 0
                       for k in num):
                errors.append(f"{n_key}/{mode}: serve point needs positive "
                              f"numeric {num}")
                continue
            cold = cell.get("cold_encode_calls")
            warmed = cell.get("warm_encode_calls")
            if not (isinstance(cold, int) and cold >= 1):
                errors.append(f"{n_key}/{mode}: cold_encode_calls must be a "
                              f"positive int (got {cold!r}) — without a cold "
                              "encode the warm-cache zero proves nothing")
            if warmed != 0:
                errors.append(
                    f"{n_key}/{mode}: warm pass performed "
                    f"{warmed!r} coupling encodes — cache-hit solves must "
                    "skip the resolve→encode entirely (expected exactly 0)")
            if cell["batched_solves_per_sec"] < cell["sequential_solves_per_sec"]:
                errors.append(
                    f"{n_key}/{mode}: batched throughput "
                    f"{cell['batched_solves_per_sec']:.2f} solves/s is below "
                    f"the sequential baseline's "
                    f"{cell['sequential_solves_per_sec']:.2f} in the same "
                    "run — replica-stacking must not lose to one-launch-"
                    "per-request")
    return errors


def check_ingestion_points(latest: dict) -> list[str]:
    """Schema + cost gates for sparse-ingestion cells (``N*_sparse_ingest``
    keys): setup accounting must be present, the sparse→plane encode may not
    cost more wall-time than the dense detour *measured in the same run*
    (both columns come from one session, so the ratio is load-robust like
    the fused gate), and the sparse build's peak host bytes must stay under
    the (N, N) f32 it exists to avoid — the dense-J-free claim as an
    inequality on recorded numbers."""
    errors = []
    for n_key, modes in sorted(latest.items()):
        if not n_key.endswith("_sparse_ingest") or not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            num = ("setup_seconds", "setup_seconds_dense_ingest",
                   "peak_j_build_bytes", "peak_j_build_bytes_dense_ingest",
                   "sparse_solve_us_per_step")
            if not all(isinstance(cell.get(k), (int, float)) and cell[k] > 0
                       for k in num):
                errors.append(f"{n_key}/{mode}: sparse-ingest point needs "
                              f"positive numeric {num}")
                continue
            if not (isinstance(cell.get("nnz"), int)
                    and isinstance(cell.get("j_bytes_dense_f32"), int)):
                errors.append(f"{n_key}/{mode}: sparse-ingest point needs "
                              "integer nnz / j_bytes_dense_f32")
                continue
            if cell["setup_seconds"] > cell["setup_seconds_dense_ingest"]:
                errors.append(
                    f"{n_key}/{mode}: sparse ingestion setup "
                    f"{cell['setup_seconds']:.3f}s exceeds the dense detour's "
                    f"{cell['setup_seconds_dense_ingest']:.3f}s in the same "
                    "run — O(nnz) ingestion must not cost more than the "
                    "O(N^2) path it replaces")
            if cell["peak_j_build_bytes"] >= cell["j_bytes_dense_f32"]:
                errors.append(
                    f"{n_key}/{mode}: sparse build peaked at "
                    f"{cell['peak_j_build_bytes']} B, not under the "
                    f"{cell['j_bytes_dense_f32']} B (N, N) f32 — the "
                    "dense-J-free footprint claim fails")
    return errors


def check_sharded_points(latest: dict) -> list[str]:
    """Schema + memory gate for spin-sharded cells (``N*_sharded`` keys,
    written by the ``solver_sharded`` suite): the per-device plane bytes must
    divide the store evenly across ≥ 2 devices, and when the matching
    single-device HBM-streamed point exists at the same N, the sharded store
    must be *that* store divided across the mesh — the D× capacity claim is
    an identity on recorded bytes, not prose."""
    errors = []
    for n_key, modes in sorted(latest.items()):
        if not n_key.endswith("_sharded") or not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            devices = cell.get("num_devices")
            per_dev = cell.get("plane_bytes_per_device")
            total = cell.get("plane_bytes_total")
            us = cell.get("sharded_us_per_step")
            if not all(isinstance(v, int) for v in (devices, per_dev, total)):
                errors.append(
                    f"{n_key}/{mode}: sharded point needs integer "
                    "num_devices / plane_bytes_per_device / plane_bytes_total")
                continue
            if devices < 2:
                errors.append(f"{n_key}/{mode}: sharded point must span >= 2 "
                              f"devices, got {devices}")
            if per_dev * devices != total:
                errors.append(
                    f"{n_key}/{mode}: plane_bytes_per_device {per_dev} x "
                    f"{devices} devices != plane_bytes_total {total} — "
                    "row-sharding must divide the store evenly")
            if not (isinstance(us, (int, float)) and us > 0):
                errors.append(f"{n_key}/{mode}: missing positive "
                              "sharded_us_per_step")
            single = latest.get(n_key[:-len("_sharded")])
            hbm_cell = single.get(mode) if isinstance(single, dict) else None
            hbm_bytes = (hbm_cell or {}).get("j_bytes_hbm_planes")
            if isinstance(hbm_bytes, int) and per_dev * devices != hbm_bytes:
                errors.append(
                    f"{n_key}/{mode}: sharded per-device bytes x devices = "
                    f"{per_dev * devices} B but the single-device streamed "
                    f"store is {hbm_bytes} B — the shards must be the same "
                    f"planes divided {devices} ways")
    return errors


def check_sharded_2d_points(latest: dict) -> list[str]:
    """Schema + layout gates for 2-D mesh cells (``N*_sharded_2d`` keys,
    written by the ``solver_sharded`` suite): on the (groups, rows) mesh the
    planes are replicated across groups and row-sharded within one, so
    per-device bytes must equal total/rows exactly (and land strictly under
    the unsharded total — capacity still scales, with the rows axis); the
    1-D column recorded in the same run must divide total/devices; and the
    best-energy vectors of the two layouts must be byte-identical — the mesh
    shape is a placement choice, never a trajectory change. Cross-refs the
    plain ``N*_sharded`` cell at the same N: one store, two accountings."""
    errors = []
    for n_key, modes in sorted(latest.items()):
        if not n_key.endswith("_sharded_2d") or not isinstance(modes, dict):
            continue
        for mode, cell in sorted(modes.items()):
            if not isinstance(cell, dict):
                continue
            ints = ("num_devices", "num_groups", "rows_per_group",
                    "plane_bytes_total", "plane_bytes_per_device_1d",
                    "plane_bytes_per_device_2d")
            if not all(isinstance(cell.get(k), int) for k in ints):
                errors.append(f"{n_key}/{mode}: sharded-2d point needs "
                              f"integer {ints}")
                continue
            groups, rows = cell["num_groups"], cell["rows_per_group"]
            total = cell["plane_bytes_total"]
            per_1d = cell["plane_bytes_per_device_1d"]
            per_2d = cell["plane_bytes_per_device_2d"]
            if groups < 2 or rows < 2:
                errors.append(
                    f"{n_key}/{mode}: mesh ({groups} groups x {rows} rows) "
                    "degenerates to 1-D — a 2-D point needs >= 2 on both "
                    "axes")
            if cell["num_devices"] != groups * rows:
                errors.append(
                    f"{n_key}/{mode}: num_devices {cell['num_devices']} != "
                    f"groups x rows ({groups * rows})")
            if per_2d * rows != total:
                errors.append(
                    f"{n_key}/{mode}: 2-D per-device bytes {per_2d} x "
                    f"{rows} row shards != plane_bytes_total {total} — "
                    "within a group the rows axis must divide the store "
                    "evenly (groups replicate it)")
            if per_2d >= total:
                errors.append(
                    f"{n_key}/{mode}: 2-D per-device bytes {per_2d} not "
                    f"under the unsharded store's {total} — the rows axis "
                    "bought no capacity")
            if per_1d * cell["num_devices"] != total:
                errors.append(
                    f"{n_key}/{mode}: 1-D per-device bytes {per_1d} x "
                    f"{cell['num_devices']} devices != plane_bytes_total "
                    f"{total}")
            for k in ("us_per_step_1d", "us_per_step_2d",
                      "replica_steps_per_sec_1d", "replica_steps_per_sec_2d"):
                if not (isinstance(cell.get(k), (int, float))
                        and cell[k] > 0):
                    errors.append(f"{n_key}/{mode}: missing positive {k}")
            b1, b2 = cell.get("best_energy_1d"), cell.get("best_energy_2d")
            if not (isinstance(b1, list) and isinstance(b2, list) and b1):
                errors.append(f"{n_key}/{mode}: best_energy_1d/_2d must be "
                              "non-empty per-replica lists")
            elif b1 != b2:
                errors.append(
                    f"{n_key}/{mode}: best_energy_1d != best_energy_2d — "
                    "the 1-D and 2x2 layouts must produce byte-identical "
                    "energies (mesh shape is placement, not a trajectory "
                    "change)")
            plain = latest.get(n_key[:-len("_2d")])
            plain_cell = plain.get(mode) if isinstance(plain, dict) else None
            plain_total = (plain_cell or {}).get("plane_bytes_total")
            if isinstance(plain_total, int) and plain_total != total:
                errors.append(
                    f"{n_key}/{mode}: plane_bytes_total {total} disagrees "
                    f"with the {n_key[:-len('_2d')]} cell's {plain_total} — "
                    "both points must account the same packed store")
    return errors


def run_check(path: str = BENCH_JSON) -> int:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        print(f"# CHECK-ERROR cannot read {path}: {e}")
        return 1
    errors = verify_checksum(payload) + check_bench_history(payload)
    for err in errors:
        print(f"# CHECK-FAIL {err}")
    if not errors:
        print(f"# CHECK-OK {path} ({len(payload.get('history', []))} history "
              "entries)")
    return 1 if errors else 0


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(prog="benchmarks.run")
    parser.add_argument("--run-id", default=None,
                        help="history stamp for BENCH_solver_perf.json")
    parser.add_argument("--suite", default=None,
                        help="run only the named suite (default: all)")
    parser.add_argument("--check", action="store_true",
                        help="validate BENCH_solver_perf.json and exit")
    args = parser.parse_args(argv)

    if args.check:
        sys.exit(run_check())

    from . import (bench_colored_flips, bench_fig14_incremental,
                   bench_fig15_bitplane, bench_roofline, bench_row_traffic,
                   bench_serve, bench_solver_perf, bench_solver_sharded,
                   bench_table2_gset, bench_table3_tts)

    print("name,us_per_call,derived")
    suites = [
        ("table2_gset", bench_table2_gset.main),       # Table II quality
        ("table3_tts", bench_table3_tts.main),         # Table III TTS(0.99)
        ("fig14_incremental", bench_fig14_incremental.main),  # Fig 14
        ("fig15_bitplane", bench_fig15_bitplane.main),        # Fig 15 + Fig 8
        ("solver_perf",                                 # §Perf solver engines
         partial(bench_solver_perf.main, run_id=args.run_id)),
        ("solver_sharded",                              # spin-sharded tier
         partial(bench_solver_sharded.main, run_id=args.run_id)),
        ("serve",                                       # §Serving throughput
         partial(bench_serve.main, run_id=args.run_id)),
        ("row_traffic",                                 # §Reuse-aware fetch
         partial(bench_row_traffic.main, run_id=args.run_id)),
        ("colored_flips",                               # §Graph-colored flips
         partial(bench_colored_flips.main, run_id=args.run_id)),
        ("roofline", bench_roofline.main),             # §Roofline table
    ]
    if args.suite is not None:
        suites = [s for s in suites if s[0] == args.suite]
        if not suites:
            parser.error(f"unknown suite {args.suite!r}")
    for name, fn in suites:
        t0 = time.time()
        print(f"# ==== {name} ====", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running; report at the end
            print(f"# SUITE-ERROR {name}: {type(e).__name__}: {e}", flush=True)
        print(f"# ==== {name} done in {time.time()-t0:.1f}s ====", flush=True)


if __name__ == "__main__":
    main()
