# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# ``--run-id <stamp>`` labels this run in BENCH_solver_perf.json's history
# (e.g. ``python -m benchmarks.run --run-id pr2-2026-07-26``). The stamp is a
# CLI argument by design — no in-process clock read — so benchmark output is
# a pure function of code + inputs and reruns stay byte-reproducible.
from __future__ import annotations

import argparse
import sys
import time
from functools import partial


def main(argv=None) -> None:
    from . import (bench_fig14_incremental, bench_fig15_bitplane,
                   bench_roofline, bench_solver_perf, bench_table2_gset,
                   bench_table3_tts)

    parser = argparse.ArgumentParser(prog="benchmarks.run")
    parser.add_argument("--run-id", default=None,
                        help="history stamp for BENCH_solver_perf.json")
    args = parser.parse_args(argv)

    print("name,us_per_call,derived")
    suites = [
        ("table2_gset", bench_table2_gset.main),       # Table II quality
        ("table3_tts", bench_table3_tts.main),         # Table III TTS(0.99)
        ("fig14_incremental", bench_fig14_incremental.main),  # Fig 14
        ("fig15_bitplane", bench_fig15_bitplane.main),        # Fig 15 + Fig 8
        ("solver_perf",                                 # §Perf solver engines
         partial(bench_solver_perf.main, run_id=args.run_id)),
        ("roofline", bench_roofline.main),             # §Roofline table
    ]
    for name, fn in suites:
        t0 = time.time()
        print(f"# ==== {name} ====", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running; report at the end
            print(f"# SUITE-ERROR {name}: {type(e).__name__}: {e}", flush=True)
        print(f"# ==== {name} done in {time.time()-t0:.1f}s ====", flush=True)


if __name__ == "__main__":
    main()
