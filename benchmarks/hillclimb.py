import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb harness: lower cell variants, extract roofline terms,
emit before/after rows (hypothesis → change → measure → confirm/refute).

Variants compose:
  rules=...          sharding-rule overrides (e.g. Megatron seq-SP)
  microbatches=N     gradient-accumulation depth
  flash=True         Pallas flash-attention kernel substitution (see
                     roofline.analysis.apply_flash_substitution)
  mesh=(d, m)        alternate 256-chip mesh factorization (serving TP)
  gather_once=True   hoist FSDP weight gather out of the microbatch loop

    PYTHONPATH=src python -m benchmarks.hillclimb --out benchmarks/hillclimb_results.json
"""

import argparse
import dataclasses
import json

import jax

from repro.configs import SHAPES, get_config
from repro.launch import dryrun as dr
from repro.launch.abstracts import rules_for
from repro.roofline import analyze_compiled
from repro.roofline.analysis import apply_flash_substitution

# (arch, shape, variant-name, overrides)
VARIANTS = [
    # Same-code baselines (apples-to-apples "before" for each cell).
    ("nemotron-4-340b", "train_4k", "baseline", {}),
    ("granite-moe-1b-a400m", "train_4k", "baseline", {}),
    ("qwen2-7b", "train_4k", "baseline", {}),
    ("qwen2-7b", "prefill_32k", "baseline", {}),
    ("jamba-1.5-large-398b", "decode_32k", "baseline", {}),
    # Cell 1 — worst roofline fraction: rwkv6 train (chunked WKV is now the
    # code default; its "before" is the recorded sequential-scan baseline).
    ("rwkv6-1.6b", "train_4k", "chunked-wkv", {}),
    # Cell 2b — pure microbatch reduction (keep baseline Megatron rules).
    ("nemotron-4-340b", "train_4k", "mb8", {"microbatches": 8}),
    ("nemotron-4-340b", "train_4k", "mb8+flash",
     {"microbatches": 8, "flash": True}),
    # Cell 2 — most collective-bound: nemotron train.
    ("nemotron-4-340b", "train_4k", "res-seq-sp",
     {"rules": {"res_seq": "model", "embed_act": None}}),
    ("nemotron-4-340b", "train_4k", "res-seq-sp+mb8",
     {"rules": {"res_seq": "model", "embed_act": None}, "microbatches": 8}),
    ("nemotron-4-340b", "train_4k", "res-seq-sp+mb8+flash",
     {"rules": {"res_seq": "model", "embed_act": None}, "microbatches": 8,
      "flash": True}),
    # Cell 3 — paper-representative MoE: granite train.
    ("granite-moe-1b-a400m", "train_4k", "flash", {"flash": True}),
    ("granite-moe-1b-a400m", "train_4k", "flash+seq-sp",
     {"flash": True, "rules": {"res_seq": "model"}}),
    # Bonus — jamba decode (collective-bound serving): TP-heavy mesh.
    ("jamba-1.5-large-398b", "decode_32k", "serve-mesh-4x64",
     {"mesh": (4, 64)}),
    ("jamba-1.5-large-398b", "decode_32k", "serve-mesh-8x32",
     {"mesh": (8, 32)}),
    ("qwen2-7b", "train_4k", "gather-once+flash",
     {"gather_once": True, "flash": True}),
    ("qwen2-7b", "prefill_32k", "flash", {"flash": True}),
    # Narrow-TP hypothesis: d ≤ 4k models over-pay TP activation psums at
    # 16-way; reshape to (64 data, 4 model).
    ("qwen2-7b", "train_4k", "mesh64x4+gather-once+flash",
     {"mesh": (64, 4), "gather_once": True, "flash": True}),
    ("qwen2-7b", "train_4k", "mesh64x4+mb16+gather-once+flash",
     {"mesh": (64, 4), "gather_once": True, "flash": True, "microbatches": 16}),
    ("granite-moe-1b-a400m", "train_4k", "mesh64x4+flash",
     {"mesh": (64, 4), "flash": True}),
]


def run_variant(arch, shape_name, name, ov, verbose=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if ov.get("mesh"):
        d, m = ov["mesh"]
        mesh = jax.make_mesh((d, m), ("data", "model"))
        mesh_name = f"pod-{d}x{m}"
    else:
        mesh = dr.make_production_mesh(multi_pod=False)
        mesh_name = "pod"
    hints = dict(dr.HINTS.get(cfg.name, {}))
    if "rules" in ov:
        hints["rules"] = {**hints.get("rules", {}), **ov["rules"]}
    if "microbatches" in ov:
        hints["train_microbatches"] = ov["microbatches"]
    if ov.get("gather_once"):
        hints["gather_once"] = True
    old_hints = dr.HINTS.get(cfg.name)
    dr.HINTS[cfg.name] = hints
    try:
        lowered, model_flops = dr.build_lowered(cfg, shape, mesh, multi_pod=False)
        compiled = lowered.compile()
        report = analyze_compiled(compiled, arch=arch, shape=shape_name,
                                  mesh_name=mesh_name, num_devices=mesh.devices.size,
                                  model_flops=model_flops, note=name)
        if ov.get("flash"):
            report = apply_flash_substitution(
                report, head_dim=cfg.resolved_head_dim, causal=cfg.causal,
                block_q=cfg.seq_chunk_q, block_k=min(cfg.seq_chunk_kv, 512))
        out = dataclasses.asdict(report)
        out.update(status="ok", variant=name, step_time=report.step_time,
                   mfu=report.mfu)
        mem = compiled.memory_analysis()
        out["hbm_gib"] = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                          + mem.temp_size_in_bytes) / 2**30
        if verbose:
            print(f"== {arch} × {shape_name} [{name}]: "
                  f"tc={report.t_compute*1e3:.1f} tm={report.t_memory*1e3:.1f} "
                  f"tcoll={report.t_collective*1e3:.1f} ms "
                  f"bottleneck={report.bottleneck} mfu={report.mfu*100:.2f}% "
                  f"hbm={out['hbm_gib']:.1f}GiB", flush=True)
        return out
    except Exception as e:
        import traceback
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "variant": name,
                "status": "error", "error": str(e)}
    finally:
        if old_hints is None:
            dr.HINTS.pop(cfg.name, None)
        else:
            dr.HINTS[cfg.name] = old_hints


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/hillclimb_results.json")
    ap.add_argument("--only", default=None, help="substring filter on variant name")
    args = ap.parse_args()
    results = []
    for arch, shape, name, ov in VARIANTS:
        if args.only and args.only not in f"{arch}/{shape}/{name}":
            continue
        results.append(run_variant(arch, shape, name, ov))
    existing = []
    if os.path.exists(args.out):
        existing = json.load(open(args.out))
    key = lambda r: (r["arch"], r["shape"], r.get("variant"))
    merged = {key(r): r for r in existing}
    merged.update({key(r): r for r in results})
    with open(args.out, "w") as fh:
        json.dump(list(merged.values()), fh, indent=1)


if __name__ == "__main__":
    main()
