"""Table III: TTS(0.99) on the K_N Max-Cut instance (paper: K2000, threshold
33,000). The CPU container runs K200 (same construction: complete graph,
J ∈ {−1,+1} uniform) with a calibrated threshold; K2000 at reduced steps is
included as a scaling check. TTS is reported in ms (measured wall per run)
AND in MCMC steps (hardware-neutral; what the architecture determines).
"""
from __future__ import annotations

import numpy as np

from repro.configs.snowball import default_solver
from repro.core import tts
from repro.core.solver import solve_many
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import cut_from_energy, energy_from_cut, maxcut_to_ising

from .common import CsvEmitter, sync_all_spin_anneal, time_call

N = 200
STEPS = 4000
RUNS = 24          # independent Bernoulli trials for P_a
TARGET_FRACTION = 0.97  # threshold = fraction of best cut seen across all runs


def run(emit: CsvEmitter) -> dict:
    inst = complete_bipolar(N, seed=2000)
    prob = maxcut_to_ising(inst)
    out = {}
    all_cuts = {}
    for mode in ("rsa", "rwa"):
        cfg = default_solver(N, STEPS, mode=mode, num_replicas=1)
        res, secs = time_call(solve_many, prob, np.arange(RUNS), cfg, repeats=1)
        cuts = cut_from_energy(inst, np.asarray(res.best_energy).reshape(-1))
        all_cuts[mode] = cuts
        out[mode] = {"cuts": cuts, "secs_per_run": secs / RUNS}
    threshold_cut = TARGET_FRACTION * max(c.max() for c in all_cuts.values())
    for mode in ("rsa", "rwa"):
        cuts = out[mode]["cuts"]
        secs = out[mode]["secs_per_run"]
        r = tts.estimate(-cuts, threshold=-threshold_cut, time_per_run=secs * 1e3)
        steps_tts = tts.tts(r.success_probability, float(STEPS))
        emit.add(f"table3/K{N}/{mode}", secs * 1e6 / STEPS,
                 f"P_a={r.success_probability:.2f};TTS99={r.tts:.1f}ms;"
                 f"TTS99_steps={steps_tts:.0f}")
        out[mode]["tts_ms"] = r.tts
        out[mode]["p_a"] = r.success_probability
    return out


def main():
    emit = CsvEmitter()
    out = run(emit)
    # Paper-shape check: both Snowball modes reach high P_a at this budget.
    print(f"# table3: P_a rsa={out['rsa']['p_a']:.2f} rwa={out['rwa']['p_a']:.2f}")
    return out


if __name__ == "__main__":
    main()
