"""§Reuse-aware row fetch: unique-row HBM traffic vs the R-per-step fetch.

N=512 with R=16 replicas on the HBM-streamed bit-plane tier under a cold rwa
schedule. Two selection regimes, one cell (``N512_row_traffic``):

* **iid** — independently initialized replicas with independent uniform
  streams: reuse is the birthday rate (~C(R,2)/N per step), so the coalesced
  counter lands strictly below the R·T uncoalesced traffic but close to it.
  This is the honest steady-state number for uncorrelated chains.
* **ensemble** — G=4 groups of bit-identical replicas (the collapsed low-T /
  restart-batch regime of DESIGN §Reuse-aware row fetch): every group picks
  one site per step, so the coalesced stream DMAs at most G·T rows instead
  of R·T. The coalesce=True vs coalesce=False timing comparison runs on this
  regime *in the same session* — ``benchmarks.run --check`` gates the
  within-run ratio, load-robust like the fused gate.

Both paths are bit-identical in trajectory (tests/test_row_coalescing.py
proves it); this file records the traffic counters and the wall-time payoff.
"""
from __future__ import annotations

import sys

import numpy as np

from .bench_solver_perf import merge_bench_results
from .common import CsvEmitter, time_call

TRAFFIC_N = 512
TRAFFIC_REPLICAS = 16
TRAFFIC_STEPS = 64
TRAFFIC_GROUPS = 4


def _problem(n: int):
    g = np.random.default_rng(11)
    J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
    J = np.triu(J, 1)
    return J + J.T


def _grouped_inputs(J, groups, steps, seed=0):
    """(u0, s0, e0, uniforms) with replicas in a group sharing spins and
    uniform streams — group structure is the reuse structure."""
    import jax.numpy as jnp

    g = np.random.default_rng(seed)
    idx = np.asarray(groups)
    n_groups = idx.max() + 1
    s_g = np.where(g.random((n_groups, J.shape[0])) < 0.5, 1.0, -1.0)
    s0 = s_g[idx].astype(np.float32)
    u0 = (J @ s0.T).T.astype(np.float32)
    e0 = (-0.5 * np.einsum("rn,rn->r", u0, s0)).astype(np.float32)
    u_g = g.random((steps, n_groups, 4)).astype(np.float32)
    return (jnp.asarray(u0), jnp.asarray(s0), jnp.asarray(e0),
            jnp.asarray(u_g[:, idx, :]))


def run_traffic_point(emit: CsvEmitter) -> dict:
    import jax.numpy as jnp

    from repro.core.bitplane import encode_couplings
    from repro.kernels.sweep import mcmc_sweep

    n, r, steps = TRAFFIC_N, TRAFFIC_REPLICAS, TRAFFIC_STEPS
    J = _problem(n)
    planes = encode_couplings(J, 2, align_words=128)
    # Cold rwa schedule: the roulette concentrates, the regime where reuse
    # matters most.
    temps = jnp.asarray(np.tile(np.linspace(0.5, 0.05, steps,
                                            dtype=np.float32)[:, None], (1, r)))

    def sweep(inputs, coalesce):
        u0, s0, e0, uniforms = inputs
        return mcmc_sweep(planes, u0, s0, e0, uniforms, temps, mode="rwa",
                          coupling="bitplane_hbm", block_r=r,
                          coalesce=coalesce, interpret=True)

    iid = _grouped_inputs(J, list(range(r)), steps)
    rows_iid = int(np.asarray(sweep(iid, True)[6]).sum())
    rows_iid_un = int(np.asarray(sweep(iid, False)[6]).sum())

    groups = [i // (r // TRAFFIC_GROUPS) for i in range(r)]
    ens = _grouped_inputs(J, groups, steps)
    out_c, secs_c = time_call(sweep, ens, True)
    out_u, secs_u = time_call(sweep, ens, False)
    rows_ens = int(np.asarray(out_c[6]).sum())
    rows_ens_un = int(np.asarray(out_u[6]).sum())
    np.testing.assert_array_equal(np.asarray(out_c[4]), np.asarray(out_u[4]))

    point = {
        "n": n,
        "mode": "rwa",
        "num_replicas": r,
        "num_steps": steps,
        "replica_steps": r * steps,
        "num_groups": TRAFFIC_GROUPS,
        "rows_fetched_iid": rows_iid,
        "rows_fetched_ensemble": rows_ens,
        "uncoalesced_rows_fetched": rows_ens_un,
        "coalesced_us_per_step": secs_c / steps * 1e6,
        "uncoalesced_us_per_step": secs_u / steps * 1e6,
        "coalesced_speedup": secs_u / secs_c,
        "regimes": ("iid: independent replicas (birthday-rate reuse); "
                    "ensemble: 4 groups of identical replicas (collapsed "
                    "ensemble), also the timed pair"),
    }
    assert rows_iid_un == r * steps, rows_iid_un
    emit.add(f"rowtraffic/N{n}/rwa/iid_R{r}", 0.0,
             f"rows={rows_iid};uncoalesced={rows_iid_un}")
    emit.add(f"rowtraffic/N{n}/rwa/ensemble_G{TRAFFIC_GROUPS}",
             point["coalesced_us_per_step"],
             f"rows={rows_ens};uncoalesced_rows={rows_ens_un};"
             f"uncoalesced_us={point['uncoalesced_us_per_step']:.2f};"
             f"speedup={point['coalesced_speedup']:.2f}x")
    return point


def main(run_id: str | None = None):
    emit = CsvEmitter()
    point = run_traffic_point(emit)
    merge_bench_results({f"N{TRAFFIC_N}_row_traffic": {"rwa": point}},
                        run_id=run_id)
    return point


if __name__ == "__main__":
    rid = (sys.argv[sys.argv.index("--run-id") + 1]
           if "--run-id" in sys.argv else None)
    main(run_id=rid)
