"""§Perf (paper side): per-step cost of the solver engines.

Paper-faithful baseline (core.solver scan, one flip per XLA step) vs the
production fused Pallas sweep (interpret mode on CPU — wall numbers are the
*relative* signal; the TPU roofline for the fused kernel is derived in
DESIGN.md §Backends from its VMEM-resident design: per-step HBM traffic → 0
for N ≤ ~2800, leaving the O(N) VPU work after the O(N²)→O(N) gather fix).

Emits ``BENCH_solver_perf.json`` at the repo root — µs/step for both
backends at N ∈ {512, 2000} × {rsa, rwa} — so subsequent PRs have a perf
trajectory to regress against.
"""
from __future__ import annotations

import json
import os
import platform

import numpy as np

from repro.configs.snowball import default_solver
from repro.core.solver import solve
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import maxcut_to_ising
from repro.kernels import fused_anneal

from .common import CsvEmitter, time_call

STEPS = 1024
REPLICAS = 8
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_solver_perf.json")


def run(emit: CsvEmitter) -> dict:
    out = {}
    for n in (512, 2000):
        inst = complete_bipolar(n, seed=n)
        prob = maxcut_to_ising(inst)
        steps = STEPS if n <= 1024 else 512
        for mode in ("rsa", "rwa"):
            cfg = default_solver(n, steps, mode=mode, num_replicas=REPLICAS)
            res, secs = time_call(solve, prob, 0, cfg, repeats=2)
            us = secs / steps * 1e6
            best = float(np.min(np.asarray(res.best_energy)))
            emit.add(f"solver/N{n}/{mode}/baseline", us, f"best_E={best:.0f}")
            out[(n, mode, "baseline")] = us
            res, secs = time_call(fused_anneal, prob, 0, cfg, repeats=2)
            us = secs / steps * 1e6
            best = float(np.min(np.asarray(res.best_energy)))
            emit.add(f"solver/N{n}/{mode}/fused_interpret", us, f"best_E={best:.0f}")
            out[(n, mode, "fused")] = us
    return out


def write_bench_json(out: dict) -> None:
    """Persist the backend perf table (the cross-PR regression anchor)."""
    import jax

    results = {}
    for n in (512, 2000):
        results[f"N{n}"] = {}
        for mode in ("rsa", "rwa"):
            base = out.get((n, mode, "baseline"))
            fused = out.get((n, mode, "fused"))
            results[f"N{n}"][mode] = {
                "baseline_us_per_step": base,
                "fused_us_per_step": fused,
                "fused_speedup": (base / fused) if base and fused else None,
            }
    payload = {
        "bench": "solver_perf",
        "units": "us_per_step (R=8 replicas, interpret-mode Pallas on CPU; "
                 "relative signal only)",
        "host": platform.node(),
        "jax_backend": jax.default_backend(),
        "results": results,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {BENCH_JSON}", flush=True)


def run_tempering_comparison(emit: CsvEmitter):
    """Paper §IV-A: SA vs parallel tempering at equal step budget. PT's swap
    acceptance is the paper's scaling concern — reported per size."""
    import jax.numpy as jnp
    from repro.core.tempering import TemperingConfig, solve_tempering

    out = {}
    for n in (128, 512):
        inst = complete_bipolar(n, seed=n + 1)
        prob = maxcut_to_ising(inst)
        steps = 2000
        sa_cfg = default_solver(n, steps, mode="rsa", num_replicas=8)
        sa, sa_secs = time_call(solve, prob, 0, sa_cfg, repeats=1)
        pt_cfg = TemperingConfig(num_steps=steps, t_min=0.05,
                                 t_max=max(n ** 0.5, 4.0), num_replicas=8)
        pt, pt_secs = time_call(solve_tempering, prob, 0, pt_cfg, repeats=1)
        sa_best = float(jnp.min(sa.best_energy))
        pt_best = float(jnp.min(pt.best_energy))
        emit.add(f"tempering/N{n}/sa", sa_secs / steps * 1e6, f"best_E={sa_best:.0f}")
        emit.add(f"tempering/N{n}/pt", pt_secs / steps * 1e6,
                 f"best_E={pt_best:.0f};swap_acc={float(pt.swap_acceptance):.2f}")
        out[n] = (sa_best, pt_best, float(pt.swap_acceptance))
    return out


def main():
    emit = CsvEmitter()
    out = run(emit)
    write_bench_json(out)
    out["tempering"] = run_tempering_comparison(emit)
    return out


if __name__ == "__main__":
    main()
