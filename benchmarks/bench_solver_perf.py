"""§Perf (paper side): per-step cost of the solver engines.

Paper-faithful baseline (core.solver scan, one flip per XLA step) vs the
production fused Pallas sweep (interpret mode on CPU — wall numbers are the
*relative* signal; the TPU roofline for the fused kernel is derived in
DESIGN.md §Backends from its VMEM-resident design: per-step HBM traffic → 0
for N ≤ ~2800, leaving the O(N) VPU work after the O(N²)→O(N) gather fix).

Emits ``BENCH_solver_perf.json`` at the repo root — µs/step for both
backends at N ∈ {512, 2000} × {rsa, rwa}, the N=4096 packed bit-plane point
the dense f32 path cannot hold in VMEM at all, and the N=16384 HBM-streamed
point past even the packed-VMEM wall (DESIGN.md §Backends) — so subsequent
PRs have a perf trajectory to regress against. The JSON keeps a ``history``
list (one entry per recorded run, stamped via the ``--run-id`` CLI arg of
``benchmarks.run`` — never from an in-process clock) alongside the latest
``results``, so the trajectory accrues across PRs instead of being
overwritten wholesale. ``benchmarks.run --check`` validates the file's
schema and gates fused-vs-baseline regressions.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform

import numpy as np

from repro.configs.snowball import default_solver
from repro.core.solver import solve
from repro.graphs import complete_bipolar
from repro.graphs.maxcut import maxcut_to_ising
from repro.kernels import fused_anneal

from .common import CsvEmitter, time_call

STEPS = 1024
REPLICAS = 8
#: The bit-plane-only size: a dense f32 J would need N²·4 = 64 MiB of VMEM —
#: 4× the 16 MiB budget — while the packed ±1-coupling planes need N²/4 B.
BITPLANE_N = 4096
BITPLANE_STEPS = 96
#: The HBM-streamed-only size: at N=16384 even the packed B=1 planes are
#: 64 MiB — 4× VMEM — so neither the dense f32 J (1 GiB) nor the VMEM
#: bit-plane store can run; only ``coupling="bitplane_hbm"`` fits (planes in
#: HBM, selected rows double-buffered through a 2-slot VMEM scratch).
HBM_N = 16384
HBM_STEPS = 48
#: Fewer replicas for the streamed point: each interpret-mode step decodes an
#: O(B·N) row per replica, and the point exists for the per-step trajectory
#: anchor + J-bytes accounting, not replica statistics.
HBM_REPLICAS = 4
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_solver_perf.json")


def run(emit: CsvEmitter) -> dict:
    out = {}
    for n in (512, 2000):
        inst = complete_bipolar(n, seed=n)
        prob = maxcut_to_ising(inst)
        steps = STEPS if n <= 1024 else 512
        for mode in ("rsa", "rwa"):
            cfg = default_solver(n, steps, mode=mode, num_replicas=REPLICAS)
            res, secs = time_call(solve, prob, 0, cfg, repeats=2)
            us = secs / steps * 1e6
            best = float(np.min(np.asarray(res.best_energy)))
            emit.add(f"solver/N{n}/{mode}/baseline", us, f"best_E={best:.0f}")
            out[(n, mode, "baseline")] = us
            res, secs = time_call(fused_anneal, prob, 0, cfg, repeats=2)
            us = secs / steps * 1e6
            best = float(np.min(np.asarray(res.best_energy)))
            emit.add(f"solver/N{n}/{mode}/fused_interpret", us, f"best_E={best:.0f}")
            out[(n, mode, "fused")] = us
    out["bitplane"] = run_bitplane_point(emit)
    out["bitplane_hbm"] = run_bitplane_hbm_point(emit)
    out["sparse_ingest"] = run_sparse_ingest_point(emit)
    return out


def run_bitplane_point(emit: CsvEmitter) -> dict:
    """N=4096 fused sweep off the packed bit-plane J (paper §IV-B1).

    This size exists *only* on the bit-plane path: the dense kernel would
    have to pin a 64 MiB f32 J in 16 MiB of VMEM, so no dense comparison
    column is recorded — the entry's point is the J-bytes accounting (≥8×
    memory reduction is the acceptance gate; ±1 couplings pack to B=1 plane
    for 16×) plus a µs/step trajectory anchor for the decode cost.
    """
    from repro.core.coupling import timed_build

    n = BITPLANE_N
    inst = complete_bipolar(n, seed=n)
    prob = maxcut_to_ising(inst)
    # timed_build records the one-off host-side encode as the entry's
    # setup_seconds / peak_j_build_bytes (dense ingestion: the peak includes
    # the (N, N) f32 input and the encoder's O(N²) temporaries).
    store, build_stats = timed_build(prob.couplings, "bitplane")
    planes = store.planes
    dense_bytes = n * n * 4
    cfg = default_solver(n, BITPLANE_STEPS, mode="rsa", num_replicas=REPLICAS)
    # Pass the pre-built store so the timed region is the sweep itself,
    # not the host-side numpy encode.
    res, secs = time_call(fused_anneal, prob, 0, cfg, store=store,
                          repeats=2)
    us = secs / BITPLANE_STEPS * 1e6
    best = float(np.min(np.asarray(res.best_energy)))
    reduction = dense_bytes / planes.nbytes
    emit.add(f"solver/N{n}/rsa/fused_bitplane", us,
             f"best_E={best:.0f};J_bytes={planes.nbytes};"
             f"dense_J_bytes={dense_bytes};reduction={reduction:.1f}x")
    return {
        "n": n,
        "mode": "rsa",
        "num_planes": planes.num_planes,
        "bitplane_us_per_step": us,
        "setup_seconds": build_stats["seconds"],
        "peak_j_build_bytes": build_stats["peak_bytes"],
        "j_bytes_bitplane": planes.nbytes,
        "j_bytes_dense_f32": dense_bytes,
        "j_memory_reduction_vs_f32": reduction,
        "dense_path": "cannot allocate: 64 MiB f32 J vs 16 MiB VMEM",
    }


def run_bitplane_hbm_point(emit: CsvEmitter) -> dict:
    """N=16384 fused sweep streaming the packed planes from HBM (§IV-B1 +
    the reuse-aware near-memory streaming axis of the related all-digital
    machines).

    This size exists *only* on the HBM-streamed path: the dense f32 J is
    1 GiB and even the B=1 bit-plane store is 64 MiB against 16 MiB of VMEM,
    so neither VMEM-resident tier can run — the entry records the J-bytes
    accounting for all three tiers plus the µs/step anchor for the
    DMA-stream + decode cost (interpret mode; relative signal).
    """
    from repro.core.coupling import timed_build

    n = HBM_N
    inst = complete_bipolar(n, seed=n)
    prob = maxcut_to_ising(inst)
    store, build_stats = timed_build(prob.couplings, "bitplane_hbm")
    planes = store.planes
    dense_bytes = n * n * 4
    # nbytes of an unpadded VMEM store (the tier the wall excludes).
    vmem_plane_bytes = 2 * planes.num_planes * n * (-(-n // 32)) * 4
    cfg = dataclasses.replace(
        default_solver(n, HBM_STEPS, mode="rsa", num_replicas=HBM_REPLICAS),
        coupling_format="bitplane_hbm")
    # The pre-built store keeps the timed region the streamed sweep itself.
    res, secs = time_call(fused_anneal, prob, 0, cfg, store=store,
                          repeats=2)
    us = secs / HBM_STEPS * 1e6
    best = float(np.min(np.asarray(res.best_energy)))
    emit.add(f"solver/N{n}/rsa/fused_bitplane_hbm", us,
             f"best_E={best:.0f};J_bytes={planes.nbytes};"
             f"dense_J_bytes={dense_bytes};vmem_plane_bytes={vmem_plane_bytes}")
    return {
        "n": n,
        "mode": "rsa",
        "num_planes": planes.num_planes,
        "num_replicas": HBM_REPLICAS,
        "bitplane_hbm_us_per_step": us,
        "setup_seconds": build_stats["seconds"],
        "peak_j_build_bytes": build_stats["peak_bytes"],
        "j_bytes_hbm_planes": planes.nbytes,
        "j_bytes_vmem_planes": vmem_plane_bytes,
        "j_bytes_dense_f32": dense_bytes,
        "dense_path": "cannot allocate: 1 GiB f32 J vs 16 MiB VMEM",
        "bitplane_vmem_path": "cannot allocate: 64 MiB B=1 planes vs 16 MiB VMEM",
        "hbm_stream": "planes in HBM; (B,1,W) row tiles double-buffered "
                      "through VMEM scratch via make_async_copy",
    }


#: The sparse-ingestion anchor: a Gset-regime random instance at the
#: HBM-streamed size — nnz = 8·N edges (~0.1% density), the territory real
#: Max-Cut benchmarks live in.
SPARSE_N = HBM_N
SPARSE_EDGES = 8 * HBM_N
SPARSE_STEPS = 48


def run_sparse_ingest_point(emit: CsvEmitter) -> dict:
    """N=16384 dense-J-free time-to-solution: the same sparse instance
    ingested two ways, **within one run** — (a) the dense detour (edges →
    (N, N) f32 → plane encoder: a 1 GiB materialization plus the encoder's
    O(N²) int64 temporaries, the toll every solve used to pay before the
    first flip) and (b) the direct O(nnz) sparse→plane encoder. The recorded
    ``setup_seconds`` / ``peak_j_build_bytes`` are the sparse path's;
    ``--check`` gates them against the dense-ingest columns (sparse must
    cost no more time and must stay under the (N, N) f32 footprint — the
    dense-J-free claim as recorded numbers, not prose). The solve itself
    then runs off the edge-list problem end to end, proving the whole path
    never touches a dense J.
    """
    from repro.core.coupling import CouplingStore, measure_host_build, timed_build
    from repro.core.ising import IsingProblem
    from repro.graphs import sparse_bipolar_edges

    n = SPARSE_N
    edges = sparse_bipolar_edges(n, SPARSE_EDGES, seed=n)
    store, sparse_stats = timed_build(edges, "bitplane_hbm")
    dense_store, dense_stats = measure_host_build(
        lambda: CouplingStore.build(edges.to_dense(np.float32), "bitplane_hbm"))
    del dense_store  # only its cost matters; the solve runs dense-J-free
    prob = IsingProblem.create_sparse(edges)
    cfg = dataclasses.replace(
        default_solver(n, SPARSE_STEPS, mode="rsa", num_replicas=HBM_REPLICAS),
        coupling_format="bitplane_hbm")
    res, secs = time_call(fused_anneal, prob, 0, cfg, store=store, repeats=2)
    us = secs / SPARSE_STEPS * 1e6
    best = float(np.min(np.asarray(res.best_energy)))
    planes = store.planes
    dense_bytes = n * n * 4
    emit.add(f"solver/N{n}/rsa/sparse_ingest", us,
             f"best_E={best:.0f};nnz={edges.nnz};"
             f"setup_s={sparse_stats['seconds']:.3f};"
             f"dense_setup_s={dense_stats['seconds']:.3f};"
             f"peak={sparse_stats['peak_bytes']};"
             f"dense_peak={dense_stats['peak_bytes']}")
    return {
        "n": n,
        "mode": "rsa",
        "nnz": edges.nnz,
        "num_planes": planes.num_planes,
        "num_replicas": HBM_REPLICAS,
        "sparse_solve_us_per_step": us,
        "setup_seconds": sparse_stats["seconds"],
        "peak_j_build_bytes": sparse_stats["peak_bytes"],
        "setup_seconds_dense_ingest": dense_stats["seconds"],
        "peak_j_build_bytes_dense_ingest": dense_stats["peak_bytes"],
        "j_bytes_planes": planes.nbytes,
        "j_bytes_dense_f32": dense_bytes,
        "edge_bytes": edges.nbytes,
        "ingest": "edge list -> O(nnz) plane encoder; the (N, N) f32 and the "
                  "dense encoder's O(N^2) temporaries exist only on the "
                  "dense-detour columns recorded for comparison",
    }


def _load_bench_json() -> dict:
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                return json.load(f)
        except (OSError, ValueError):
            pass
    return {}


def _write_payload(results: dict, run_id: str | None) -> None:
    """Persist a full ``results`` table as the latest run (the append-only
    history machinery shared by :func:`write_bench_json` and
    :func:`merge_bench_results`)."""
    import jax

    prev = _load_bench_json()
    history = prev.get("history", [])
    if not history and prev.get("results"):
        # Legacy single-snapshot file: preserve it as the first entry.
        history = [{"run_id": "pre-history", "results": prev["results"]}]
    # Re-recording a stamp (or another unstamped scratch run) replaces the
    # prior entry instead of appending a duplicate — ``--check`` enforces
    # unique stamps, so a legal rerun must never corrupt the history.
    stamp = run_id or "unstamped"
    history = [h for h in history
               if not (isinstance(h, dict) and h.get("run_id") == stamp)]
    history.append({
        "run_id": stamp,
        "host": platform.node(),
        "jax_backend": jax.default_backend(),
        "results": results,
    })
    payload = {
        "bench": "solver_perf",
        "units": "us_per_step (R=8 replicas, interpret-mode Pallas on CPU; "
                 "relative signal only)",
        "host": platform.node(),
        "jax_backend": jax.default_backend(),
        "results": results,
        "history": history,
    }
    from .run import write_bench_payload
    write_bench_payload(payload, BENCH_JSON)
    print(f"# wrote {BENCH_JSON} (history entries: {len(history)})", flush=True)


def write_bench_json(out: dict, run_id: str | None = None) -> None:
    """Persist the backend perf table (the cross-PR regression anchor).

    The latest ``results`` stay at the top level for regression tooling;
    every recorded run is also appended to ``history`` with the caller's
    ``run_id`` stamp (a CLI argument — deliberately not a clock read, so
    reruns are reproducible and the stamp is auditable in the PR).
    """
    results = {}
    for n in (512, 2000):
        results[f"N{n}"] = {}
        for mode in ("rsa", "rwa"):
            base = out.get((n, mode, "baseline"))
            fused = out.get((n, mode, "fused"))
            results[f"N{n}"][mode] = {
                "baseline_us_per_step": base,
                "fused_us_per_step": fused,
                "fused_speedup": (base / fused) if base and fused else None,
            }
    if out.get("bitplane"):
        results[f"N{BITPLANE_N}"] = {"rsa": out["bitplane"]}
    if out.get("bitplane_hbm"):
        results[f"N{HBM_N}"] = {"rsa": out["bitplane_hbm"]}
    if out.get("sparse_ingest"):
        results[f"N{SPARSE_N}_sparse_ingest"] = {"rsa": out["sparse_ingest"]}
    # A full solver_perf run refreshes its own cells but must not drop cells
    # another suite owns (e.g. solver_sharded's N*_sharded point) from the
    # latest results — merge over the previous top level.
    merged = dict(_load_bench_json().get("results") or {})
    merged.update(results)
    _write_payload(merged, run_id)


def merge_bench_results(partial_results: dict, run_id: str | None = None) -> None:
    """Merge one suite's cells into the latest results (used by suites that
    own a subset of the table, e.g. ``solver_sharded``). Re-using the stamp
    of a run recorded moments earlier folds both suites into one history
    entry; a fresh stamp records a new entry that carries the other cells
    forward unchanged."""
    merged = dict(_load_bench_json().get("results") or {})
    merged.update(partial_results)
    _write_payload(merged, run_id)


def run_tempering_comparison(emit: CsvEmitter):
    """Paper §IV-A: SA vs parallel tempering at equal step budget. PT's swap
    acceptance is the paper's scaling concern — reported per size."""
    import jax.numpy as jnp
    from repro.core.tempering import TemperingConfig, solve_tempering

    out = {}
    for n in (128, 512):
        inst = complete_bipolar(n, seed=n + 1)
        prob = maxcut_to_ising(inst)
        steps = 2000
        sa_cfg = default_solver(n, steps, mode="rsa", num_replicas=8)
        sa, sa_secs = time_call(solve, prob, 0, sa_cfg, repeats=1)
        pt_cfg = TemperingConfig(num_steps=steps, t_min=0.05,
                                 t_max=max(n ** 0.5, 4.0), num_replicas=8)
        pt, pt_secs = time_call(solve_tempering, prob, 0, pt_cfg, repeats=1)
        sa_best = float(jnp.min(sa.best_energy))
        pt_best = float(jnp.min(pt.best_energy))
        emit.add(f"tempering/N{n}/sa", sa_secs / steps * 1e6, f"best_E={sa_best:.0f}")
        emit.add(f"tempering/N{n}/pt", pt_secs / steps * 1e6,
                 f"best_E={pt_best:.0f};swap_acc={float(pt.swap_acceptance):.2f}")
        out[n] = (sa_best, pt_best, float(pt.swap_acceptance))
    return out


def main(run_id: str | None = None):
    emit = CsvEmitter()
    out = run(emit)
    write_bench_json(out, run_id=run_id)
    out["tempering"] = run_tempering_comparison(emit)
    return out


if __name__ == "__main__":
    import sys

    rid = sys.argv[sys.argv.index("--run-id") + 1] if "--run-id" in sys.argv else None
    main(run_id=rid)
