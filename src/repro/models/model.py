"""Composable LM assembly for all 10 assigned architectures.

Layers are grouped by ``cfg.block_pattern`` (one group = one pass over the
pattern); groups are stacked and scanned (`lax.scan`), keeping HLO size
O(|pattern|) for 24–96-layer models. Every block = pre-norm mixer + pre-norm
FFN with residuals. MoE aux losses accumulate through the scan carry.

Public API:
    model_specs(cfg)                  -> ParamSpec tree
    forward(cfg, params, batch)       -> ForwardOut(logits, aux)
    init_decode_cache(cfg, batch, L)  -> cache pytree
    decode_step(cfg, params, cache, tokens/embeddings) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import layers, moe, rwkv, ssm
from .config import ModelConfig
from .params import ParamSpec, stack_specs
from .sharding import logical_constraint


class ForwardOut(NamedTuple):
    logits: jax.Array          # (B, S, V)
    aux_loss: jax.Array        # scalar: MoE load-balance + z losses (0 if dense)
    expert_load: Optional[jax.Array] = None  # (num_moe_blocks_in_pattern, E) mean load


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pd = cfg.param_dtype
    o_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    s = {
        "norm": ParamSpec((d,), (None,), "zeros", pd),
        "wq": ParamSpec((d, hq, hd), ("embed_w", "heads", "head_dim"), "normal", pd),
        "wk": ParamSpec((d, hkv, hd), ("embed_w", "kv_heads", "head_dim"), "normal", pd),
        "wv": ParamSpec((d, hkv, hd), ("embed_w", "kv_heads", "head_dim"), "normal", pd),
        "wo": ParamSpec((hq, hd, d), ("heads", "head_dim", "embed_w"), f"scaled:{o_scale}", pd),
    }
    if cfg.norm == "layernorm":
        s["norm_b"] = ParamSpec((d,), (None,), "zeros", pd)
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((hq, hd), ("heads", "head_dim"), "zeros", pd)
        s["bk"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"), "zeros", pd)
        s["bv"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"), "zeros", pd)
    return s


def _mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    o_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    s = {
        "norm": ParamSpec((d,), (None,), "zeros", pd),
        "wi": ParamSpec((d, f), ("embed_w", "ffn"), "normal", pd),
        "wo": ParamSpec((f, d), ("ffn", "embed_w"), f"scaled:{o_scale}", pd),
    }
    if cfg.norm == "layernorm":
        s["norm_b"] = ParamSpec((d,), (None,), "zeros", pd)
    if cfg.gated_mlp:
        s["wg"] = ParamSpec((d, f), ("embed_w", "ffn"), "normal", pd)
    return s


def _moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.resolved_moe_d_ff
    pd = cfg.param_dtype
    o_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    s = {
        "norm": ParamSpec((d,), (None,), "zeros", pd),
        "router": ParamSpec((d, e), ("embed_w", "experts"), "normal", pd),
        "wi": ParamSpec((e, d, f), ("experts", "embed_w", None), "normal", pd),
        "wo": ParamSpec((e, f, d), ("experts", None, "embed_w"), f"scaled:{o_scale}", pd),
    }
    if cfg.norm == "layernorm":
        s["norm_b"] = ParamSpec((d,), (None,), "zeros", pd)
    if cfg.gated_mlp:
        s["wg"] = ParamSpec((e, d, f), ("experts", "embed_w", None), "normal", pd)
    return s


def _mamba_specs(cfg: ModelConfig) -> dict:
    d, di, n, w = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    r = cfg.resolved_dt_rank
    pd = cfg.param_dtype
    return {
        "norm": ParamSpec((d,), (None,), "zeros", pd),
        "in_proj": ParamSpec((d, 2 * di), ("embed_w", "ssm_inner"), "normal", pd),
        "conv_w": ParamSpec((di, w), ("ssm_inner", "conv"), "uniform_fan", pd),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros", pd),
        "x_proj": ParamSpec((di, r + 2 * n), ("ssm_inner", None), "normal", pd),
        "dt_proj": ParamSpec((r, di), ("dt_rank", "ssm_inner"), "uniform_fan", pd),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), "mamba_dt_bias", pd),
        "a_log": ParamSpec((di, n), ("ssm_inner", "ssm_state"), "mamba_a_log", pd),
        "d_skip": ParamSpec((di,), ("ssm_inner",), "ones", pd),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed_w"),
                              f"scaled:{0.02 / math.sqrt(2 * cfg.num_layers)}", pd),
    }


def _rwkv_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    pd = cfg.param_dtype
    rank = 32
    return {
        "norm": ParamSpec((d,), (None,), "zeros", pd),
        "mix_base": ParamSpec((rwkv.N_MIX, d), (None, "embed_w"), "const:0.5", pd),
        "mix_lora_a": ParamSpec((d, rank), ("embed_w", "lora"), "normal", pd),
        "mix_lora_b": ParamSpec((rank, rwkv.N_MIX, d), ("lora", None, "embed_w"), "zeros", pd),
        "wr": ParamSpec((d, d), ("embed_w", "rwkv_heads"), "normal", pd),
        "wk": ParamSpec((d, d), ("embed_w", "rwkv_heads"), "normal", pd),
        "wv": ParamSpec((d, d), ("embed_w", "rwkv_heads"), "normal", pd),
        "wg": ParamSpec((d, d), ("embed_w", "rwkv_heads"), "normal", pd),
        "decay_base": ParamSpec((d,), ("embed_w",), "const:-4.0", pd),
        "decay_lora_a": ParamSpec((d, 2 * rank), ("embed_w", "lora"), "normal", pd),
        "decay_lora_b": ParamSpec((2 * rank, d), ("lora", "embed_w"), "zeros", pd),
        "bonus": ParamSpec((h, hd), ("rwkv_heads", None), "normal", pd),
        "ln_x": ParamSpec((d,), ("embed_w",), "zeros", pd),
        "wo": ParamSpec((d, d), ("rwkv_heads", "embed_w"),
                        f"scaled:{0.02 / math.sqrt(2 * cfg.num_layers)}", pd),
    }


def _cmix_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    return {
        "norm": ParamSpec((d,), (None,), "zeros", pd),
        "mu_k": ParamSpec((d,), ("embed_w",), "const:0.5", pd),
        "mu_r": ParamSpec((d,), ("embed_w",), "const:0.5", pd),
        "wk": ParamSpec((d, f), ("embed_w", "ffn"), "normal", pd),
        "wv": ParamSpec((f, d), ("ffn", "embed_w"),
                        f"scaled:{0.02 / math.sqrt(2 * cfg.num_layers)}", pd),
        "wr": ParamSpec((d, d), ("embed_w", "rwkv_heads"), "normal", pd),
    }


_MIXER_SPECS = {"attn": _attn_specs, "mamba": _mamba_specs, "rwkv": _rwkv_specs}
_FFN_SPECS = {"mlp": _mlp_specs, "moe": _moe_specs, "cmix": _cmix_specs}


def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    pd = cfg.param_dtype
    tree: dict = {}
    if cfg.uses_token_embedding:
        tree["embed"] = ParamSpec((v, d), ("vocab", "embed_w"), "normal", pd)
    else:
        tree["frontend_in"] = ParamSpec((d, d), ("embed_w", None), "normal", pd)
    groups: dict = {}
    for i, entry in enumerate(cfg.block_pattern):
        mixer, _, ffn = entry.partition(":")
        block = {"mixer": _MIXER_SPECS[mixer](cfg), "ffn": _FFN_SPECS[ffn](cfg)}
        groups[f"b{i}"] = stack_specs(block, cfg.num_groups)
    tree["groups"] = groups
    tree["final_norm"] = ParamSpec((d,), (None,), "zeros", pd)
    if cfg.norm == "layernorm":
        tree["final_norm_b"] = ParamSpec((d,), (None,), "zeros", pd)
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamSpec((d, v), ("embed_w", "vocab"), "normal", pd)
    return tree


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _pre_norm(cfg, p, x):
    return layers.norm(cfg, p["norm"], x, p.get("norm_b"))


def _attn_apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                cache: Optional[dict], pos: Optional[jax.Array]):
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    xn = _pre_norm(cfg, p, x)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    q = logical_constraint(q, "batch", "seq", "heads", None)
    k = logical_constraint(k, "batch", "seq", "kv_heads", None)
    scale = 1.0 / math.sqrt(hd)
    new_cache = None
    if cache is None:
        qh = jnp.swapaxes(q, 1, 2)  # (B,Hq,S,D)
        kh = jnp.swapaxes(k, 1, 2)  # (B,Hkv,S,D)
        vh = jnp.swapaxes(v, 1, 2)
        if cfg.attn_impl == "flash":
            from ..kernels.flash_attention import flash_attention
            out = flash_attention(qh, kh, vh, cfg.causal, scale,
                                  cfg.seq_chunk_q, cfg.seq_chunk_kv,
                                  jax.default_backend() != "tpu")
        else:
            out = layers.chunked_attention(qh, kh, vh, causal=cfg.causal,
                                           q_chunk=cfg.seq_chunk_q,
                                           kv_chunk=cfg.seq_chunk_kv, scale=scale)
        out = jnp.swapaxes(out, 1, 2)  # (B,S,H,D)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], jnp.swapaxes(k, 1, 2).astype(cache["k"].dtype), pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], jnp.swapaxes(v, 1, 2).astype(cache["v"].dtype), pos, axis=2)
        kc = logical_constraint(kc, "batch", "kv_heads", "cache_seq", None)
        vc = logical_constraint(vc, "batch", "kv_heads", "cache_seq", None)
        new_cache = {"k": kc, "v": vc}
        qh = jnp.swapaxes(q, 1, 2)
        out = layers.decode_attention(qh, kc, vc, pos + s, scale=scale)
        out = jnp.swapaxes(out, 1, 2)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return logical_constraint(proj, "batch", "res_seq", "embed_act"), new_cache


def _apply_block(cfg: ModelConfig, entry: str, p: dict, x: jax.Array,
                 positions: jax.Array, cache: Optional[dict], pos):
    """One pattern entry: mixer + ffn, residual around each."""
    mixer, _, ffn = entry.partition(":")
    aux = (jnp.float32(0.0), None)
    new_cache: dict = {}
    if mixer == "attn":
        h, c = _attn_apply(cfg, p["mixer"], x, positions,
                           cache.get("attn") if cache else None, pos)
        if c is not None:
            new_cache["attn"] = c
    elif mixer == "mamba":
        mc = None
        if cache and "mamba" in cache:
            mc = ssm.MambaCache(conv=cache["mamba"]["conv"], ssm=cache["mamba"]["ssm"])
        h, c = ssm.mamba_block(cfg, p["mixer"], _pre_norm(cfg, p["mixer"], x), cache=mc)
        if c is not None:
            new_cache["mamba"] = {"conv": c.conv, "ssm": c.ssm}
    else:  # rwkv time-mix
        rc = None
        if cache and "rwkv" in cache:
            rc = rwkv.RwkvCache(**cache["rwkv"])
        h, c = rwkv.time_mix(cfg, p["mixer"], _pre_norm(cfg, p["mixer"], x), cache=rc)
        if c is not None:
            new_cache["rwkv"] = c._asdict()
    x = x + h

    fp = p["ffn"]
    xn = _pre_norm(cfg, fp, x)
    if ffn == "mlp":
        h = layers.mlp(cfg, fp, xn)
    elif ffn == "moe":
        h, moe_aux = moe.moe_ffn(cfg, fp, xn)
        aux = (moe_aux.load_balance_loss * cfg.router_aux_weight
               + moe_aux.router_z_loss * 1e-3, moe_aux.expert_load)
    else:  # rwkv channel mix
        rc = None
        if cache and "rwkv" in cache:
            rc = rwkv.RwkvCache(**{**new_cache.get("rwkv", cache["rwkv"])})
        h, c = rwkv.channel_mix(cfg, fp, xn, cache=rc)
        if c is not None:
            new_cache["rwkv"] = c._asdict()
    x = x + h
    return x, aux, (new_cache or None)


# ---------------------------------------------------------------------------
# Forward / decode
# ---------------------------------------------------------------------------

def _embed_input(cfg: ModelConfig, params: dict, tokens, embeddings):
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.uses_token_embedding:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    else:
        x = jnp.einsum("bsd,de->bse", embeddings.astype(dtype),
                       params["frontend_in"].astype(dtype))
    return logical_constraint(x, "batch", "res_seq", "embed_act")


@jax.named_scope("_logits")
def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    xn = layers.norm(cfg, params["final_norm"], x, params.get("final_norm_b"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", xn, head.astype(x.dtype))
    return logical_constraint(logits, "batch", "seq", "vocab")


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _run_groups(cfg: ModelConfig, params: dict, x: jax.Array, positions,
                cache: Optional[dict], pos):
    """lax.scan over layer groups; cache (if any) is scanned alongside."""

    def group_fn(carry, xs):
        x, aux = carry
        gp, gc = xs
        new_gc = {}
        loads = []
        for i, entry in enumerate(cfg.block_pattern):
            bc = gc.get(f"b{i}") if gc else None
            x, (a, load), nc = _apply_block(cfg, entry, gp[f"b{i}"], x, positions, bc, pos)
            aux = aux + a
            if load is not None:
                loads.append(load)
            if nc is not None:
                new_gc[f"b{i}"] = nc
        load_arr = jnp.stack(loads) if loads else jnp.zeros((0,), jnp.float32)
        return (x, aux), (new_gc or None, load_arr)

    group_fn = _remat_wrap(cfg, group_fn)
    (x, aux), (new_cache, loads) = jax.lax.scan(
        group_fn, (x, jnp.float32(0.0)), (params["groups"], cache))
    mean_load = loads.mean(axis=0) if loads.size else None
    return x, aux, new_cache, mean_load


def forward(cfg: ModelConfig, params: dict, tokens=None, embeddings=None,
            positions=None) -> ForwardOut:
    """Full-sequence forward (train / prefill-scoring). No cache."""
    ref = tokens if tokens is not None else embeddings
    b, s = ref.shape[0], ref.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _embed_input(cfg, params, tokens, embeddings)
    x, aux, _, load = _run_groups(cfg, params, x, positions, None, None)
    return ForwardOut(logits=_logits(cfg, params, x), aux_loss=aux, expert_load=load)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Cache pytree stacked over groups; dtype = compute dtype."""
    g = cfg.num_groups
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.compute_dtype)
    cache: dict = {}

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (g,) + a.shape), tree)

    for i, entry in enumerate(cfg.block_pattern):
        mixer = entry.partition(":")[0]
        ffn = entry.partition(":")[2]
        blk: dict = {}
        if mixer == "attn":
            blk["attn"] = {
                "k": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
                "v": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
            }
        elif mixer == "mamba":
            mc = ssm.init_cache(cfg, batch)
            blk["mamba"] = {"conv": mc.conv, "ssm": mc.ssm}
        if mixer == "rwkv" or ffn == "cmix":
            rc = rwkv.init_cache(cfg, batch)
            blk["rwkv"] = rc._asdict()
        cache[f"b{i}"] = stack(blk)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, pos: jax.Array,
                tokens=None, embeddings=None) -> tuple[jax.Array, dict]:
    """One-token decode (S may also be >1 for chunked prefill into the cache).

    ``pos``: scalar int32 — write offset into the KV cache (same across batch).
    Returns (logits (B,S,V), new cache).
    """
    ref = tokens if tokens is not None else embeddings
    b, s = ref.shape[0], ref.shape[1]
    positions = pos + jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _embed_input(cfg, params, tokens, embeddings)
    x, _, new_cache, _ = _run_groups(cfg, params, x, positions, cache, pos)
    return _logits(cfg, params, x), new_cache
