"""Parameter-spec trees: one source of truth for shape, init, dtype and sharding.

``ParamSpec`` describes a single tensor; model assembly builds a nested dict of
specs, from which we derive (a) materialized params (`init_params`), (b)
abstract ShapeDtypeStructs with shardings for the dry-run (`abstract_params`),
and (c) NamedShardings for jit in_shardings (`param_shardings`). Keeping these
three views derived from one tree prevents init/sharding drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import ShardingRules, make_sharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | scaled:<f> | const:<v> |
                               # mamba_a_log | mamba_dt_bias | uniform_fan
    dtype: str = "float32"

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _materialize(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    kind, _, arg = spec.init.partition(":")
    if kind == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if kind == "ones":
        return jnp.ones(spec.shape, dtype)
    if kind == "const":
        return jnp.full(spec.shape, float(arg), dtype)
    if kind == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02).astype(dtype)
    if kind == "scaled":
        return (jax.random.normal(key, spec.shape, jnp.float32) * float(arg)).astype(dtype)
    if kind == "uniform_fan":
        fan_in = spec.shape[0] if spec.shape else 1
        bound = 1.0 / math.sqrt(max(fan_in, 1))
        return jax.random.uniform(key, spec.shape, jnp.float32, -bound, bound).astype(dtype)
    if kind == "mamba_a_log":
        # A = -exp(A_log); init A_log = log(1..N) broadcast over channels.
        n = spec.shape[-1]
        a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), spec.shape[:-1] + (1,))
        return a.astype(dtype)
    if kind == "mamba_dt_bias":
        # softplus^{-1}(dt) for dt ~ logU[1e-3, 1e-1].
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree, prefix=()):
    if _is_spec(tree):
        yield prefix, tree
        return
    for k in sorted(tree.keys()):
        yield from tree_paths(tree[k], prefix + (k,))


def init_params(spec_tree, key: jax.Array):
    """Materialize a spec tree; each leaf gets a path-derived stateless key."""
    import zlib

    def build(tree, prefix):
        if _is_spec(tree):
            leaf_key = key
            for part in prefix:
                # crc32 is process-stable (str hash() is randomized per run).
                leaf_key = jax.random.fold_in(
                    leaf_key, np.uint32(zlib.crc32(str(part).encode())))
            return _materialize(tree, leaf_key)
        return {k: build(v, prefix + (k,)) for k, v in tree.items()}

    return build(spec_tree, ())


def abstract_params(spec_tree, mesh=None, rules: Optional[ShardingRules] = None):
    """ShapeDtypeStruct tree (with shardings when a mesh is given) — dry-run input."""
    def build(tree):
        if _is_spec(tree):
            sharding = make_sharding(tree.axes, mesh, rules, shape=tree.shape)
            return jax.ShapeDtypeStruct(tree.shape, jnp.dtype(tree.dtype), sharding=sharding)
        return {k: build(v) for k, v in tree.items()}

    return build(spec_tree)


def param_shardings(spec_tree, mesh, rules: Optional[ShardingRules] = None):
    def build(tree):
        if _is_spec(tree):
            return make_sharding(tree.axes, mesh, rules, shape=tree.shape)
        return {k: build(v) for k, v in tree.items()}

    return build(spec_tree)


def param_count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in tree_paths(spec_tree))


def param_bytes(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for _, s in tree_paths(spec_tree))


def stack_specs(spec_tree, num: int, axis_name: str = "layers"):
    """Add a leading stacked dim (for scan-over-layer-groups)."""
    def build(tree):
        if _is_spec(tree):
            return ParamSpec(shape=(num,) + tree.shape, axes=(axis_name,) + tree.axes,
                             init=tree.init, dtype=tree.dtype)
        return {k: build(v) for k, v in tree.items()}

    return build(spec_tree)
