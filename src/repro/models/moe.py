"""Mixture-of-Experts layer (GShard-style capacity dispatch, top-k routing).

Dispatch/combine are einsum-based so GSPMD can lower them to all-to-alls when
experts are sharded over the `model` mesh axis. Tokens are grouped by the
batch dim (group = one sequence), so the dispatch one-hot is (B, S, E, C) with
per-group capacity C = ceil(k·S/E·cf) — per-device this is modest once batch
is sharded over `data` and experts over `model`.

An auxiliary load-balance loss (Switch-style) and router z-loss are returned
for the train loop. The Ising-based expert placement optimizer
(`repro.core.placement`) consumes `router_probs` statistics to co-locate
co-activated experts across the EP axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import activation
from .sharding import logical_constraint


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array  # scalar
    router_z_loss: jax.Array      # scalar
    expert_load: jax.Array        # (E,) fraction of tokens routed per expert


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.experts_per_token * tokens_per_group * cfg.capacity_factor
            / max(cfg.num_experts, 1))
    return max(c, 1)


@jax.named_scope("moe_ffn")
def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, MoEAux]:
    """x: (B, S, d) -> (B, S, d). Router in fp32 for numerical stability."""
    b, s, d = x.shape
    e = cfg.num_experts
    k = cfg.experts_per_token
    c = _capacity(cfg, s)

    router_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                               p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)

    # Top-k expert choice per token; gates renormalized over the selected k.
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, k) within its expert's capacity buffer.
    sel_onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (B,S,k,E)
    flat_sel = sel_onehot.reshape(b, s * k, e)
    pos_in_expert = (jnp.cumsum(flat_sel, axis=1) - flat_sel).reshape(b, s, k, e)
    pos = jnp.sum(pos_in_expert * sel_onehot, axis=-1)  # (B,S,k)
    keep = pos < c  # overflow tokens dropped (capacity-factor semantics)

    # Dispatch (B,S,E,C) and combine (B,S,E,C) tensors. The k axis is
    # contracted inside one einsum (a (k,E)ᵀ(k,C) batched matmul) so the
    # (B,S,k,E,C) outer product is never materialized.
    pos_onehot = jax.nn.one_hot(pos, c, dtype=jnp.float32)  # (B,S,k,C)
    kept_sel = sel_onehot * keep[..., None].astype(jnp.float32)  # (B,S,k,E)
    dispatch = jnp.einsum("bske,bskc->bsec", kept_sel, pos_onehot)
    combine = jnp.einsum("bske,bskc->bsec", kept_sel * gate_vals[..., None], pos_onehot)

    xin = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)
    xin = logical_constraint(xin, "batch", "experts", None, None)

    wi = p["wi"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = jnp.einsum("becd,edf->becf", xin, wi)
    h = activation(cfg, h)
    if cfg.gated_mlp:
        g = jnp.einsum("becd,edf->becf", xin, p["wg"].astype(x.dtype))
        h = h * g
    out_e = jnp.einsum("becf,efd->becd", h, wo)
    out = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), out_e)
    out = logical_constraint(out, "batch", "res_seq", "embed_act")

    # Switch-transformer load-balance loss: E · Σ_e f_e · P_e.
    top1 = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    frac_tokens = top1.reshape(-1, e).mean(0)
    frac_probs = probs.reshape(-1, e).mean(0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs)
    z = jax.nn.logsumexp(router_logits, axis=-1)
    z_loss = jnp.mean(z * z)
    return out, MoEAux(load_balance_loss=lb_loss, router_z_loss=z_loss,
                       expert_load=frac_tokens)
