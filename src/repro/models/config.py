"""Model configuration for the 10 assigned architectures (+ reduced smoke configs).

One frozen dataclass covers dense GQA transformers, MoE, SSM (Mamba), RWKV6,
hybrid interleaves, and encoder-only backbones. ``block_pattern`` is a cycle of
``"<mixer>:<ffn>"`` entries (mixer ∈ attn|mamba|rwkv, ffn ∈ mlp|moe|cmix);
layers are stacked in groups of ``len(block_pattern)`` and scanned, which keeps
the compiled HLO size O(pattern) instead of O(layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encoder | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free architectures
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // num_heads
    block_pattern: Tuple[str, ...] = ("attn:mlp",)

    # Attention / embedding features
    causal: bool = True              # False ⇒ encoder-only (bidirectional)
    qkv_bias: bool = False           # qwen2
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "silu"         # silu | gelu | relu2 (squared ReLU)
    gated_mlp: bool = True           # SwiGLU-style gate; False ⇒ plain 2-matmul MLP

    # Mixture-of-Experts
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden width (0 ⇒ d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # Mamba (SSM) blocks
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 ⇒ ceil(d_model / 16)

    # RWKV6 blocks
    rwkv_head_dim: int = 64

    # Modality frontend stub: None | "vision_patches" | "audio_frames"
    frontend: Optional[str] = None

    # Numerics / training behaviour
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"              # none | full | dots
    attn_impl: str = "chunked"       # chunked (pure-jnp) | flash (Pallas TPU kernel)
    seq_chunk_q: int = 512           # flash-attention query block
    seq_chunk_kv: int = 1024         # flash-attention kv block
    ssm_chunk: int = 256             # selective-scan chunk length

    def __post_init__(self):
        if self.num_layers % len(self.block_pattern):
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} must be a multiple of "
                f"the block pattern length {len(self.block_pattern)}")
        for entry in self.block_pattern:
            mixer, _, ffn = entry.partition(":")
            if mixer not in ("attn", "mamba", "rwkv") or ffn not in ("mlp", "moe", "cmix"):
                raise ValueError(f"bad block pattern entry {entry!r}")
            if ffn == "moe" and (self.num_experts <= 0 or self.experts_per_token <= 0):
                raise ValueError(f"{self.name}: moe blocks need num_experts/experts_per_token")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return any(e.startswith("attn") for e in self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True when decode state is O(1) in context (SSM/linear-recurrent mixers
        only, or hybrid where attention KV is a bounded fraction)."""
        return any(e.startswith(("mamba", "rwkv")) for e in self.block_pattern)

    @property
    def uses_token_embedding(self) -> bool:
        return self.frontend is None

    def param_count(self) -> int:
        """Exact parameter count (used for MODEL_FLOPS = 6·N·D in §Roofline)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        for entry in self.block_pattern:
            mixer, _, ffn = entry.partition(":")
            if mixer == "attn":
                qkv = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
                if self.qkv_bias:
                    qkv += self.num_heads * hd + 2 * self.num_kv_heads * hd
                total_block = qkv + (self.num_heads * hd) * d
            elif mixer == "mamba":
                di, n, r = self.d_inner, self.ssm_state_dim, self.resolved_dt_rank
                total_block = (d * 2 * di + di * self.ssm_conv_width
                               + di * (r + 2 * n) + r * di + di + di * n + di + di * d)
            else:  # rwkv time-mix
                total_block = 4 * d * d + d * d  # r,k,v,g proj + output
                total_block += 2 * (d * 32 + 32 * d)  # decay/mix LoRA (rank 32)
            total_block += d  # pre-norm
            if ffn == "mlp":
                mult = 3 if self.gated_mlp else 2
                total_block += mult * d * self.d_ff
            elif ffn == "cmix":
                total_block += 2 * d * self.d_ff
            else:
                e, eff = self.num_experts, self.resolved_moe_d_ff
                mult = 3 if self.gated_mlp else 2
                total_block += d * e + e * mult * d * eff
            total_block += d  # post-norm
            total += total_block * self.num_groups
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        e, k, eff, d = self.num_experts, self.experts_per_token, self.resolved_moe_d_ff, self.d_model
        mult = 3 if self.gated_mlp else 2
        num_moe_blocks = sum(1 for x in self.block_pattern if x.endswith(":moe")) * self.num_groups
        inactive = num_moe_blocks * (e - k) * mult * d * eff
        return full - inactive
