"""Mamba selective-SSM block (for the Jamba hybrid; Gu & Dao 2023).

Recurrence: h_t = Ā_t h_{t-1} + B̄_t x_t, y_t = C_t h_t + D x_t with
Ā_t = exp(Δ_t A), B̄_t = Δ_t B_t (ZOH-ish discretization), and input-dependent
Δ, B, C (the "selective" part). Implemented as a *chunked* scan: within a
chunk the (T, d_inner, N) tensors are materialized (parallel), across chunks a
(B, d_inner, N) state is carried (sequential lax.scan) — the standard
TPU-friendly memory/parallelism trade. The chunk width is `cfg.ssm_chunk`;
d_inner is sharded over `model` (tensor parallel) so the per-device chunk
working set is (B·T_c·d_inner/TP·N).

Decode carries (conv_state (B, d_inner, W−1), ssm_state (B, d_inner, N)) —
O(1) per token, which is what makes `long_500k` runnable for jamba.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import logical_constraint


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, d_inner, W-1)
    ssm: jax.Array   # (B, d_inner, N) float32


def init_cache(cfg: ModelConfig, batch: int) -> MambaCache:
    di, n, w = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    dtype = jnp.dtype(cfg.compute_dtype)
    return MambaCache(conv=jnp.zeros((batch, di, w - 1), dtype),
                      ssm=jnp.zeros((batch, di, n), jnp.float32))


def _ssm_scan_chunked(a_disc, bx, chunk: int, h0=None):
    """h_t = a_t * h_{t-1} + bx_t over seq axis 1.

    a_disc, bx: (B, S, d, N). Within a chunk: cumulative products (parallel);
    across chunks: carried state. Returns h: (B, S, d, N) float32, h_last.
    """
    b, s, d, n = a_disc.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by ssm_chunk {chunk}")
    nc = s // chunk
    a_c = a_disc.reshape(b, nc, chunk, d, n)
    bx_c = bx.reshape(b, nc, chunk, d, n)
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def per_chunk(h_in, inputs):
        a, u = inputs  # (B, T, d, N)
        # cumprod of a within chunk: p_t = a_1…a_t
        log_a = jnp.log(jnp.maximum(a, 1e-37))
        cum = jnp.cumsum(log_a, axis=1)
        p = jnp.exp(cum)
        # h_t = p_t (h_0 + Σ_{τ≤t} u_τ / p_τ)
        inv_p = jnp.exp(-cum)
        acc = jnp.cumsum(u * inv_p, axis=1)
        h = p * (h_in[:, None] + acc)
        return h[:, -1], h

    h_last, hs = jax.lax.scan(
        per_chunk, h0.astype(jnp.float32),
        (jnp.moveaxis(a_c, 1, 0).astype(jnp.float32),
         jnp.moveaxis(bx_c, 1, 0).astype(jnp.float32)))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d, n)
    return h, h_last


def _causal_conv(x: jax.Array, w: jax.Array, prev: Optional[jax.Array] = None):
    """Depthwise causal conv along seq. x: (B, S, d); w: (d, W). Returns y and
    the trailing (B, d, W-1) state for decode handoff."""
    b, s, d = x.shape
    width = w.shape[-1]
    xt = jnp.swapaxes(x, 1, 2)  # (B, d, S)
    if prev is None:
        prev = jnp.zeros((b, d, width - 1), x.dtype)
    xp = jnp.concatenate([prev, xt], axis=-1)  # (B, d, S+W-1)
    idx = jnp.arange(s)[:, None] + jnp.arange(width)[None, :]  # (S, W)
    windows = xp[:, :, idx]  # (B, d, S, W)
    y = jnp.einsum("bdsw,dw->bds", windows, w.astype(x.dtype))
    new_state = xp[:, :, -(width - 1):] if width > 1 else jnp.zeros((b, d, 0), x.dtype)
    return jnp.swapaxes(y, 1, 2), new_state


@jax.named_scope("mamba_block")
def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array,
                cache: Optional[MambaCache] = None):
    """x: (B, S, d_model) -> (B, S, d_model)[, new cache when decoding (S=1)]."""
    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state_dim
    r = cfg.resolved_dt_rank
    decode = cache is not None

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))  # (B,S,2di)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = logical_constraint(xin, "batch", "seq", "ssm_inner")

    conv_w = p["conv_w"]  # (di, W)
    if decode:
        y_conv, conv_state = _causal_conv(xin, conv_w, prev=cache.conv)
    else:
        y_conv, conv_state = _causal_conv(xin, conv_w)
    xin = jax.nn.silu(y_conv + p["conv_b"].astype(x.dtype))

    # Input-dependent Δ, B, C.
    dbc = jnp.einsum("bsd,de->bse", xin, p["x_proj"].astype(x.dtype))
    dt, bmat, cmat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"].astype(x.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)
    a_disc = jnp.exp(dt[..., None] * a[None, None])  # (B,S,di,N)
    bx = (dt[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
          * xin.astype(jnp.float32)[..., None])  # (B,S,di,N)

    if decode and s == 1:
        h = cache.ssm * a_disc[:, 0] + bx[:, 0]  # (B,di,N)
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32))[:, None]
        new_ssm = h
    else:
        h0 = cache.ssm if decode else None  # prefill-with-cache continues state
        hs, h_last = _ssm_scan_chunked(a_disc, bx, cfg.ssm_chunk, h0=h0)
        y = jnp.einsum("bsdn,bsn->bsd", hs, cmat.astype(jnp.float32))
        new_ssm = h_last if decode else None

    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    out = logical_constraint(out, "batch", "res_seq", "embed_act")
    if decode:
        return out, MambaCache(conv=conv_state, ssm=new_ssm)
    return out, None
