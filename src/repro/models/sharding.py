"""Logical-axis sharding (MaxText-style) for GSPMD distribution.

Every parameter and major activation is annotated with *logical* axis names;
``ShardingRules`` maps logical names → mesh axes. GSPMD tolerates
non-divisible dims (e.g. starcoder2's 36 heads on 16-way tensor parallelism)
via implicit padding, which is why the model stack uses ``jit`` +
``with_sharding_constraint`` instead of ``shard_map``.

The active (mesh, rules) pair is threaded through a context variable so model
code stays pure and runs unmodified on a single device (constraints become
no-ops when no context is set).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical name -> mesh axis (or tuple of axes, or None = replicate)."""

    # Weights
    embed_w: Axis = "data"        # FSDP: shard the embed dim of every weight
    vocab: Axis = "model"
    heads: Axis = "model"
    kv_heads: Axis = "model"
    ffn: Axis = "model"
    experts: Axis = "model"
    ssm_inner: Axis = "model"
    rwkv_heads: Axis = "model"
    layers: Axis = None
    # Activations
    batch: Axis = ("pod", "data")
    seq: Axis = None              # seq dim of qkv/ffn activations (leave None)
    res_seq: Axis = None          # residual-stream seq dim only: set to
                                  # "model" for Megatron-style sequence
                                  # parallelism (RS/AG around each block)
    embed_act: Axis = None        # residual-stream embed dim (alternative SP)
    cache_seq: Axis = None        # long-context decode: shard KV cache length
    # Misc small dims
    head_dim: Axis = None
    ssm_state: Axis = None
    conv: Axis = None
    capacity: Axis = None
    dt_rank: Axis = None
    lora: Axis = None

    def spec(self, *names: Optional[str], mesh_axes: Optional[tuple] = None) -> P:
        axes = []
        used: set[str] = set()
        for name in names:
            if name is None:
                axes.append(None)
                continue
            ax = getattr(self, name)
            # Drop axes absent from this mesh (e.g. "pod" on the single-pod mesh)
            # and mesh axes already consumed by an earlier dim (GSPMD forbids reuse).
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax
                           if a not in used and (mesh_axes is None or a in mesh_axes))
                ax = ax or None
                if ax is not None and len(ax) == 1:
                    # Normalize 1-tuples to the bare axis name (newer
                    # PartitionSpec does this itself; old JAX keeps the tuple,
                    # which breaks spec equality and dedup bookkeeping).
                    ax = ax[0]
            elif ax in used or (mesh_axes is not None and ax is not None
                                and ax not in mesh_axes):
                ax = None
            if isinstance(ax, tuple):
                used.update(ax)
            elif ax is not None:
                used.add(ax)
            axes.append(ax)
        return P(*axes)


_CTX: contextvars.ContextVar[Optional[tuple[Mesh, ShardingRules]]] = \
    contextvars.ContextVar("sharding_ctx", default=None)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    """Activate (mesh, rules) for logical_constraint / make_sharding below."""
    token = _CTX.set((mesh, rules or ShardingRules()) if mesh is not None else None)
    try:
        yield
    finally:
        _CTX.reset(token)


def current() -> Optional[tuple[Mesh, ShardingRules]]:
    return _CTX.get()


def logical_constraint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active context; no-op otherwise."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    # Trim/pad names to rank.
    names = tuple(names[: x.ndim]) + (None,) * (x.ndim - len(names))
    spec = rules.spec(*names, mesh_axes=tuple(mesh.axis_names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_sharding(names: tuple, mesh: Optional[Mesh] = None,
                  rules: Optional[ShardingRules] = None,
                  shape: Optional[tuple] = None) -> Optional[NamedSharding]:
    """NamedSharding for a logical-axes tuple (for in_shardings / params).

    When ``shape`` is given, dims that the mapped mesh axes do not divide
    evenly are left unsharded — jit input shardings require divisibility
    (internal with_sharding_constraint hints tolerate GSPMD padding instead).
    """
    ctx = _CTX.get()
    if mesh is None and ctx is not None:
        mesh, rules = ctx
    if mesh is None:
        return None
    rules = rules or ShardingRules()
    spec = rules.spec(*names, mesh_axes=tuple(mesh.axis_names))
    if shape is not None:
        fitted = []
        entries = tuple(spec) + (None,) * (len(shape) - len(spec))
        for dim, ax in zip(shape, entries):
            if ax is None:
                fitted.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            fitted.append(ax if size and dim % size == 0 else None)
        spec = P(*fitted)
    return NamedSharding(mesh, spec)
