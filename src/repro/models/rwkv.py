"""RWKV-6 "Finch" blocks (arXiv:2404.05892): data-dependent decay time-mix +
token-shift channel-mix. Attention-free; decode state is O(1) in context,
which is what makes `long_500k` runnable for rwkv6-1.6b.

Time-mix recurrence per head (head dim D):
    wkv_t = diag(w_t) · wkv_{t-1} + k_tᵀ v_t           (D×D state)
    o_t   = r_t · (diag(u) · k_tᵀ v_t + wkv_{t-1})
with w_t = exp(−exp(decay_t)) *data-dependent* via a LoRA on the shifted
input — the v6 hallmark. Token-shift lerp coefficients are likewise
LoRA-modulated. Channel-mix is the classic shifted 2-layer FFN with a
receptance gate.

Train path scans over sequence in chunks carrying the (B, H, D, D) state —
identical math to decode, so the prefill→decode consistency test is exact.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import logical_constraint

N_MIX = 5  # r, k, v, g, w token-shift lerps


class RwkvCache(NamedTuple):
    wkv: jax.Array        # (B, H, D, D) float32
    shift: jax.Array      # (B, d) last token (time-mix shift)
    cmix_shift: jax.Array  # (B, d) last token (channel-mix shift)


def init_cache(cfg: ModelConfig, batch: int) -> RwkvCache:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    dtype = jnp.dtype(cfg.compute_dtype)
    return RwkvCache(wkv=jnp.zeros((batch, h, hd, hd), jnp.float32),
                     shift=jnp.zeros((batch, d), dtype),
                     cmix_shift=jnp.zeros((batch, d), dtype))


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} with x_{-1} = prev (zeros at sequence start)."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


@jax.named_scope("_wkv_scan")
def _wkv_scan(r, k, v, w, u, state):
    """Sequential wkv recurrence. r,k,v: (B,S,H,D); w: (B,S,H,D) decay in (0,1);
    u: (H,D) bonus. state: (B,H,D,D). Returns out (B,S,H,D), final state."""
    def step(wkv, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B,H,D) each
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,D,D)
        out = jnp.einsum("bhd,bhde->bhe", r_t, u[None, :, :, None] * kv + wkv)
        wkv = w_t[..., :, None] * wkv + kv
        return wkv, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), state


@jax.named_scope("_wkv_chunked")
def _wkv_chunked(r, k, v, w, u, state, chunk: int = 16):
    """Chunked-parallel wkv: mathematically identical to ``_wkv_scan`` but
    matmul-shaped (MXU-friendly) — the §Perf fix for rwkv6 train/prefill.

    Within a chunk of C steps (per head, per batch):
        P_t[i]      = Π_{s≤t} w_s[i]           (channel-wise decay cumprod)
        score(t,τ)  = Σ_i r_t[i]·(P_{t-1}/P_τ)[i]·k_τ[i]      (τ < t)
        score(t,t)  = Σ_i r_t[i]·u[i]·k_t[i]
        out_t       = Σ_τ score(t,τ)·v_τ + (r_t⊙P_{t-1})·S_in
        S_out       = P_C⊙S_in + Σ_τ (P_C/P_τ)⊙k_τ v_τ
    computed with the factorization a_t = r_t⊙P_{t-1}, b_τ = k_τ/P_τ, which is
    f32-safe for C·|log w|_max ≤ ~80 (the decay exponent is clipped to ≥ −e in
    time_mix, so C=16 ⇒ bound ≈ 43.5). Sequential work drops from S steps to
    S/C chunk hops; per-step (D×D) state traffic becomes per-chunk.
    """
    b, s, h, d = r.shape
    if s % chunk:
        return _wkv_scan(r, k, v, w, u, state)
    nc = s // chunk
    rc, kc, vc, wc = (jnp.moveaxis(t.astype(jnp.float32).reshape(b, nc, chunk, h, d),
                                   1, 0) for t in (r, k, v, w))

    def per_chunk(s_in, inputs):
        rr, kk, vv, ww = inputs  # (B, C, H, D)
        logw = jnp.log(jnp.maximum(ww, 1e-38))
        logp = jnp.cumsum(logw, axis=1)              # logP_t
        p = jnp.exp(logp)
        p_prev = jnp.exp(logp - logw)                # P_{t-1}
        a = rr * p_prev                              # (B,C,H,D)
        bmat = kk * jnp.exp(-logp)                   # k_τ / P_τ
        scores = jnp.einsum("bthd,bshd->bhts", a, bmat)  # (B,H,C,C)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        scores = scores * tri[None, None]
        diag = jnp.einsum("bthd,hd,bthd->bth", rr, u, kk)  # score(t,t)
        scores = scores + jnp.einsum(
            "bth,ts->bhts", diag, jnp.eye(chunk, dtype=jnp.float32))
        intra = jnp.einsum("bhts,bshe->bthe", scores, vv)
        cross = jnp.einsum("bthd,bhde->bthe", a, s_in)
        out = intra + cross
        # State update: S_out = P_C ⊙ S_in + Σ_τ (P_C/P_τ) k_τ v_τ
        p_last = p[:, -1]                            # (B,H,D)
        carry_k = kk * jnp.exp(logp[:, -1][:, None] - logp)  # (P_C/P_τ)·k_τ
        s_out = p_last[..., None] * s_in + jnp.einsum("bshd,bshe->bhde", carry_k, vv)
        return s_out, out

    state, outs = jax.lax.scan(per_chunk, state.astype(jnp.float32),
                               (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)
    return out, state


def time_mix(cfg: ModelConfig, p: dict, x: jax.Array,
             cache: Optional[RwkvCache] = None):
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev = cache.shift if cache is not None else None
    xs = _token_shift(x, prev)
    delta = xs - x

    # Data-dependent token-shift lerp (v6): mu + LoRA(x) per r/k/v/g/w stream.
    base = p["mix_base"].astype(x.dtype)  # (N_MIX, d)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", x + 0.5 * delta, p["mix_lora_a"].astype(x.dtype)))
    lora = jnp.einsum("bsr,rmd->bsmd", lora, p["mix_lora_b"].astype(x.dtype))  # (B,S,M,d)
    mixed = x[:, :, None, :] + (base[None, None] + lora) * delta[:, :, None, :]
    xr, xk, xv, xg, xw = [mixed[:, :, i] for i in range(N_MIX)]

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype)).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype)).reshape(b, s, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))

    # Data-dependent decay (v6): w_t = exp(-exp(decay_base + LoRA(xw))).
    # Exponent clipped at +1 (w ≥ exp(-e) ≈ 0.066) so the chunked-parallel
    # factorization below stays f32-safe (DESIGN.md §8).
    dec = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_lora_a"].astype(x.dtype)))
    dec = jnp.einsum("bsr,rd->bsd", dec, p["decay_lora_b"].astype(x.dtype))
    log_w = -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32)
                              + dec.astype(jnp.float32), -8.0, 1.0))
    w = jnp.exp(log_w).reshape(b, s, h, hd)  # in (0,1)

    u = p["bonus"].astype(jnp.float32)  # (H, D)
    state = cache.wkv if cache is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    if s > 1 and s % 16 == 0:
        out, new_state = _wkv_chunked(r, k, v, w, u, state, chunk=16)
    else:
        out, new_state = _wkv_scan(r, k, v, w, u, state)

    # Per-head group norm then output projection, gated.
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    out = out * (1.0 + p["ln_x"].astype(jnp.float32).reshape(1, 1, h, hd))
    out = (out.reshape(b, s, d).astype(x.dtype)) * g
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    out = logical_constraint(out, "batch", "res_seq", "embed_act")
    new_cache = None
    if cache is not None:
        new_cache = RwkvCache(wkv=new_state, shift=x[:, -1], cmix_shift=cache.cmix_shift)
    return out, new_cache


def channel_mix(cfg: ModelConfig, p: dict, x: jax.Array,
                cache: Optional[RwkvCache] = None):
    prev = cache.cmix_shift if cache is not None else None
    xs = _token_shift(x, prev)
    delta = xs - x
    xk = x + p["mu_k"].astype(x.dtype) * delta
    xr = x + p["mu_r"].astype(x.dtype) * delta
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    out = logical_constraint(r * kv, "batch", "res_seq", "embed_act")
    new_cache = None
    if cache is not None:
        new_cache = cache._replace(cmix_shift=x[:, -1])
    return out, new_cache
