from .config import ModelConfig  # noqa: F401
from .model import (ForwardOut, decode_step, forward, init_decode_cache,  # noqa: F401
                    model_specs)
from .params import (abstract_params, init_params, param_bytes, param_count,  # noqa: F401
                     param_shardings)
from .sharding import ShardingRules, logical_constraint, use_sharding  # noqa: F401
