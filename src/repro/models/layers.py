"""Shared layers: norms, activations, rotary embeddings, chunked (flash-style)
attention, and the dense/gated MLP. All functions are pure and take params as
plain dicts of arrays (spec trees built in model.py)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import logical_constraint

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def norm(cfg: ModelConfig, scale: jax.Array, x: jax.Array,
         bias: Optional[jax.Array] = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale.astype(jnp.float32))
    else:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale.astype(jnp.float32))
        if bias is not None:
            out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def activation(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.activation == "silu":
        return jax.nn.silu(x)
    if cfg.activation == "gelu":
        return jax.nn.gelu(x)
    if cfg.activation == "relu2":  # squared ReLU (nemotron / Primer)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {cfg.activation!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure jnp, O(S·blk) live memory
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, mask):
    """GQA-grouped block attention. q (B,K,R,Tq,D); k/v (B,K,Tk,D);
    mask (Tq,Tk) or None -> (scores_max, exp_sum, acc). KV is never
    repeated to Hq = K·R heads — the group dim R rides along in the einsum."""
    s = jnp.einsum("bkrqd,bkld->bkrql", q, k, preferred_element_type=jnp.float32)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    if mask is not None:  # fully-masked rows must contribute zero, not exp(0)
        p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkrql,bkld->bkrqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                      q_chunk: int, kv_chunk: int, scale: float) -> jax.Array:
    """Flash-attention in pure jnp: scan over KV blocks with running (m, l, acc).

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) with Hq a multiple of Hkv (GQA
    handled natively — KV is never materialized at Hq width).
    Returns (B, Hq, Sq, D). Live memory O(B·Hq·q_chunk·kv_chunk).
    """
    with jax.named_scope("chunked_attention"):
        return _chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk, scale=scale)


def _chunked_attention(q, k, v, *, causal, q_chunk, kv_chunk, scale):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    q = (q * scale).reshape(b, hkv, rep, sq, d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError(f"seq ({sq},{skv}) not divisible by chunks ({q_chunk},{kv_chunk})")

    qs = q.reshape(b, hkv, rep, nq, q_chunk, d)

    def q_block(qi, q_blk):  # q_blk: (B,K,R,q_chunk,D)
        def kv_block(carry, kj):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, axis=2)
            if causal:
                rows = qi * q_chunk + jnp.arange(q_chunk)
                cols = kj * kv_chunk + jnp.arange(kv_chunk)
                mask = rows[:, None] >= cols[None, :]
            else:
                mask = None
            m2, l2, acc2 = _attend_block(q_blk, k_blk, v_blk, mask)
            m_new = jnp.maximum(m, m2)
            c1 = jnp.exp(m - m_new)
            c2 = jnp.exp(m2 - m_new)
            l_new = l * c1 + l2 * c2
            acc_new = acc * c1[..., None] + acc2 * c2[..., None]
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hkv, rep, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, rep, q_chunk), jnp.float32),
                jnp.zeros((b, hkv, rep, q_chunk, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda args: q_block(args[0], args[1]),
                      (jnp.arange(nq), jnp.moveaxis(qs, 3, 0)))
    # out: (nq, B, K, R, q_chunk, D) -> (B, Hq, Sq, D)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hq, sq, d)
    return out.astype(k.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, scale: float) -> jax.Array:
    """Single-token attention over a (possibly sharded) KV cache, GQA-native.

    q: (B, Hq, S, D); caches: (B, Hkv, L, D) with Hq a multiple of Hkv.
    Positions ≥ cache_len are masked. Softmax over the (sharded) L dim —
    GSPMD inserts the distributed max/sum combine (flash-decoding analogue),
    so sharding the cache length over `model`/`data` parallelizes decode.
    """
    with jax.named_scope("decode_attention"):
        b, hq, s, d = q.shape
        hkv = k_cache.shape[1]
        rep = hq // hkv
        qg = (q * scale).reshape(b, hkv, rep, s, d)
        sc = jnp.einsum("bkrqd,bkld->bkrql", qg, k_cache,
                        preferred_element_type=jnp.float32)
        mask = jnp.arange(k_cache.shape[2])[None, None, None, None, :] < cache_len
        sc = jnp.where(mask, sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bkrql,bkld->bkrqd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, hq, s, d).astype(k_cache.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (gated / plain)
# ---------------------------------------------------------------------------

@jax.named_scope("mlp")
def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
    h = logical_constraint(h, "batch", "seq", "ffn")
    h = activation(cfg, h)
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
        h = h * g
    out = jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))
    return logical_constraint(out, "batch", "res_seq", "embed_act")
