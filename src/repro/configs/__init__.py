"""Architecture registry: ``--arch <id>`` resolution for launchers/benchmarks."""
from __future__ import annotations

from . import (granite_moe_1b, hubert_xlarge, jamba_15_large, llava_next_34b,
               nemotron_4_340b, phi35_moe, qwen2_7b, rwkv6_1b6, stablelm_12b,
               starcoder2_7b)
from .shapes import SHAPES, InputShape, applicable  # noqa: F401

_MODULES = {
    "starcoder2-7b": starcoder2_7b,
    "stablelm-12b": stablelm_12b,
    "nemotron-4-340b": nemotron_4_340b,
    "qwen2-7b": qwen2_7b,
    "llava-next-34b": llava_next_34b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "granite-moe-1b-a400m": granite_moe_1b,
    "hubert-xlarge": hubert_xlarge,
    "rwkv6-1.6b": rwkv6_1b6,
    "jamba-1.5-large-398b": jamba_15_large,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.CONFIG
