"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) expert d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    block_pattern=("attn:moe",),
    num_experts=32, experts_per_token=8, moe_d_ff=512,
    norm="rmsnorm", activation="silu", gated_mlp=True,
    remat="dots",
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=64, vocab_size=512,
    block_pattern=("attn:moe",),
    num_experts=8, experts_per_token=4, moe_d_ff=64, capacity_factor=8.0,
    norm="rmsnorm", activation="silu", gated_mlp=True,
    seq_chunk_q=16, seq_chunk_kv=16,
)
