"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
SwiGLU, LayerNorm, RoPE, QKV bias (StableLM-2 family) [hf:stabilityai/stablelm-2-1_6b]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=13824, vocab_size=100352,
    norm="layernorm", activation="silu", gated_mlp=True, qkv_bias=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=512,
    norm="layernorm", activation="silu", gated_mlp=True, qkv_bias=True,
    seq_chunk_q=16, seq_chunk_kv=16,
)
