"""Snowball solver configurations (the paper's own system).

``K2000`` mirrors §V-A2: complete graph, N=2000, J ∈ {−1,+1}; the TTS target
cut is 33,000 (Table III). ``GSET_TABLE1`` mirrors Table I's instance families
at their published sizes (synthetic — see DESIGN.md §8.4).
"""
from __future__ import annotations

import dataclasses

from repro.core.schedules import Schedule, geometric, linear
from repro.core.solver import SolverConfig


@dataclasses.dataclass(frozen=True)
class BenchmarkInstance:
    name: str
    topology: str
    num_vertices: int
    num_edges: int
    target_cut: float | None = None


# Table I families (|V|, |E| from the paper; synthetic regeneration).
GSET_TABLE1 = (
    BenchmarkInstance("G6", "erdos_renyi", 800, 19176),
    BenchmarkInstance("G61", "erdos_renyi", 7000, 17148),
    BenchmarkInstance("G18", "small_world", 800, 4694),
    BenchmarkInstance("G64", "small_world", 7000, 41459),
    BenchmarkInstance("G11", "torus", 800, 1600),
    BenchmarkInstance("G62", "torus", 7000, 14000),
)

K2000 = BenchmarkInstance("K2000", "complete", 2000, 1_999_000, target_cut=33_000.0)


def default_solver(num_spins: int, num_steps: int, mode: str = "rwa",
                   num_replicas: int = 8, t0: float | None = None,
                   t1: float | None = None, kind: str = "geometric") -> SolverConfig:
    """Reasonable annealing defaults: T0 ~ typical |ΔE| so early acceptance is
    high; T1 small enough that the chain is effectively greedy at the end."""
    t0 = t0 if t0 is not None else max(num_spins ** 0.5, 4.0)
    t1 = t1 if t1 is not None else 0.05
    sched: Schedule = (geometric(t0, t1, num_steps) if kind == "geometric"
                       else linear(t0, t1, num_steps))
    return SolverConfig(num_steps=num_steps, schedule=sched, mode=mode,
                        num_replicas=num_replicas)
