"""rwkv6-1.6b "Finch" [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — data-dependent decay time-mix + channel-mix [arXiv:2404.05892].
Sub-quadratic: O(1)-state decode makes long_500k runnable."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=7168, vocab_size=65536,
    block_pattern=("rwkv:cmix",),
    norm="layernorm", rwkv_head_dim=64,
    remat="dots",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=128, vocab_size=512,
    block_pattern=("rwkv:cmix",),
    norm="layernorm", rwkv_head_dim=16,
)
