"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
GQA, QKV bias, SwiGLU, RMSNorm, RoPE theta=1e6 [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    norm="rmsnorm", activation="silu", gated_mlp=True, qkv_bias=True,
    rope_theta=1_000_000.0, remat="full",
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=512,
    norm="rmsnorm", activation="silu", gated_mlp=True, qkv_bias=True,
    rope_theta=1_000_000.0, seq_chunk_q=16, seq_chunk_kv=16,
)
