"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2. Mamba:attention 7:1 interleave (one attn
per 8-layer period), MoE every other layer [arXiv:2403.19887].
Hybrid ⇒ long_500k runs: Mamba state is O(1) and only 9/72 layers hold KV."""
from repro.models.config import ModelConfig

_PATTERN = tuple(
    f"{'attn' if i == 3 else 'mamba'}:{'moe' if i % 2 == 1 else 'mlp'}"
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    block_pattern=_PATTERN,
    num_experts=16, experts_per_token=2, moe_d_ff=24576,
    norm="rmsnorm", activation="silu", gated_mlp=True,
    ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
    remat="full",
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    block_pattern=_PATTERN,
    num_experts=4, experts_per_token=2, moe_d_ff=128, capacity_factor=4.0,
    norm="rmsnorm", activation="silu", gated_mlp=True,
    ssm_state_dim=4, ssm_conv_width=4, ssm_expand=2, ssm_chunk=8,
    seq_chunk_q=16, seq_chunk_kv=16,
)
