"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
LM backbone only; the anyres vision tower is a STUB — input_specs() supplies
precomputed patch embeddings of backbone width [hf:llava-hf/llava-v1.6]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    norm="rmsnorm", activation="silu", gated_mlp=True,
    frontend="vision_patches", remat="full",
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=512,
    norm="rmsnorm", activation="silu", gated_mlp=True,
    frontend="vision_patches", seq_chunk_q=16, seq_chunk_kv=16,
)
