"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
GQA + RoPE; non-gated GELU MLP; LayerNorm [arXiv:2402.19173; hf].
Note: the released model uses a 4k sliding window; full causal attention is
used here (the assigned shapes stop at 32k prefill; long_500k is skipped)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    norm="layernorm", activation="gelu", gated_mlp=False,
    remat="full",
)

SMOKE = ModelConfig(
    name="starcoder2-7b-smoke", family="dense",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    norm="layernorm", activation="gelu", gated_mlp=False,
    seq_chunk_q=16, seq_chunk_kv=16,
)
