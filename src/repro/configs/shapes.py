"""Assigned input shapes (one set, shared by all 10 LM-family architectures).

    train_4k     seq 4,096   global_batch 256   (training, lowers train_step)
    prefill_32k  seq 32,768  global_batch 32    (inference prefill, forward)
    decode_32k   seq 32,768  global_batch 128   (decode: 1 new token, KV cache = seq)
    long_500k    seq 524,288 global_batch 1     (long-context decode; sub-quadratic only)

Skips (documented in DESIGN.md §Arch-applicability): ``long_500k`` is skipped
for pure full-attention architectures; encoder-only (hubert) has no decode.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the DESIGN.md skip matrix."""
    if shape.kind == "decode" and not cfg.causal:
        return False, "encoder-only architecture: no autoregressive decode step"
    if shape.name == "long_500k":
        if not cfg.is_subquadratic:
            return False, "pure full-attention O(L^2): 500k context not runnable"
    if shape.name == "prefill_32k" and not cfg.causal:
        return True, "encoder forward (no causal mask)"
    return True, ""
