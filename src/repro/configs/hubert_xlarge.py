"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA, kv=16) d_ff=5120 vocab=504.
Encoder-only (bidirectional); the wav2vec2-style conv frontend is a STUB —
input_specs() supplies precomputed frame embeddings. Train = masked-frame
prediction over the 504-unit codebook [arXiv:2106.07447]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False, norm="layernorm", activation="gelu", gated_mlp=False,
    frontend="audio_frames", remat="dots",
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=64,
    causal=False, norm="layernorm", activation="gelu", gated_mlp=False,
    frontend="audio_frames", seq_chunk_q=16, seq_chunk_kv=16,
)
