"""Deterministic synthetic LM data pipeline with skip-ahead resume.

Batches are pure functions of (seed, step) — threefry counters again, like the
solver's stateless RNG — so (a) every host computes exactly its own shard with
no data service, (b) restart-after-failure resumes mid-epoch by just setting
the step counter (no state to replay), and (c) elastic re-sharding is a
reindex. The token stream is a Zipf-ish categorical with a Markov flavour so
the LM loss has learnable structure (tests assert loss decreases).

For frontend-stub architectures (audio/vlm) the pipeline emits embeddings of
backbone width plus labels (masked-prediction labels for encoder models).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    mask_fraction: float = 0.3   # encoder masked-prediction
    zipf_alpha: float = 1.2


class SyntheticLMData:
    """batch(step) -> dict of arrays; deterministic in (seed, step)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        self._base = jax.random.key(data.seed)
        # Zipf-ish unigram over the vocab, fixed by seed.
        v = cfg.vocab_size
        ranks = jnp.arange(1, v + 1, dtype=jnp.float32)
        self._logits = -data.zipf_alpha * jnp.log(ranks)

    def _key(self, step: int, salt: int):
        k = jax.random.fold_in(self._base, jnp.uint32(step))
        return jax.random.fold_in(k, jnp.uint32(salt))

    def batch(self, step) -> dict:
        cfg, d = self.cfg, self.data
        b, s = d.global_batch, d.seq_len
        tok_key = self._key(step, 0)
        # Markov flavour: token_t depends on a shared drift + fresh noise.
        base = jax.random.categorical(tok_key, self._logits, shape=(b, s + 1))
        drift = jnp.cumsum(jnp.ones((b, s + 1), jnp.int32), axis=1)
        tokens = (base + drift) % self.cfg.vocab_size
        if cfg.uses_token_embedding:
            return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        emb_key = self._key(step, 1)
        emb = jax.random.normal(emb_key, (b, s, cfg.d_model), jnp.bfloat16) * 0.1
        if cfg.causal:  # vlm backbone: next-token objective on paired labels
            return {"embeddings": emb, "labels": tokens[:, 1:]}
        # encoder (hubert): masked-frame prediction; -1 marks unmasked positions.
        mask_key = self._key(step, 2)
        masked = jax.random.bernoulli(mask_key, d.mask_fraction, (b, s))
        labels = jnp.where(masked, tokens[:, :-1], -1)
        return {"embeddings": emb, "labels": labels}

    def host_shard(self, batch: dict, host_index: int, num_hosts: int) -> dict:
        """Per-host slice of the global batch (data-parallel input loading)."""
        def slice_one(x):
            per = x.shape[0] // num_hosts
            return x[host_index * per:(host_index + 1) * per]

        return {k: slice_one(v) for k, v in batch.items()}
