"""Atomic, mesh-agnostic checkpointing with retention and async save.

Fault-tolerance contract (DESIGN.md §5, §Resilient solves):
  * **Atomicity** — state is written to ``step_<N>.tmp/`` then ``os.replace``d
    into place; a crash mid-write can never corrupt the latest checkpoint.
  * **Integrity** — the manifest records a sha256 of ``arrays.npz``; restore
    re-hashes before consuming values and raises ``SnapshotCorruptError`` on
    any mismatch / unreadable file / missing leaf, so callers holding older
    snapshots (``core.resilience``) can fall back newest-first.
  * **Mesh-agnostic** — arrays are saved as logical (unsharded) numpy values
    keyed by pytree path, so a restart may use a different mesh/topology
    (elastic rescale) and simply reshards on load.
  * **Resume** — ``latest_step`` scans the directory; the train loop restores
    params/opt-state/step and the data pipeline skip-ahead does the rest.
  * **Async** — ``CheckpointManager(async_save=True)`` moves file IO off the
    training thread (device→host transfer happens synchronously, IO doesn't).
  * **Retention** — keep the most recent K checkpoints (default 3).

On a real multi-host pod each host writes only its addressable shards; here
(single-process) the full value is written. The format is plain ``.npz`` +
a JSON manifest — no external checkpoint dependency.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import struct
import threading
import zipfile
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class SnapshotCorruptError(RuntimeError):
    """A snapshot directory exists but cannot be trusted: unreadable manifest
    or array archive, checksum mismatch, or a leaf the template expects is
    missing (truncated write). Callers with older snapshots on disk (the
    resilient solve supervisor) catch this and fall back newest-first."""


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        for block in iter(lambda: fh.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_part(p) for p in path)
        flat[key] = leaf
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Atomically write checkpoint ``step`` of ``tree`` (any pytree)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    arrays = {}
    scalars = {}
    for key, leaf in flat.items():
        if isinstance(leaf, (int, float, str, bool)):
            scalars[key] = leaf
        else:
            arrays[key] = np.asarray(jax.device_get(leaf))
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, **arrays)
    with open(arrays_path, "rb") as fh:
        os.fsync(fh.fileno())
    manifest = {"step": step, "scalars": scalars, "extra": extra or {},
                "num_arrays": len(arrays),
                "arrays_sha256": _sha256_file(arrays_path)}
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
        fh.flush()
        os.fsync(fh.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    steps = snapshot_steps(directory)
    return steps[-1] if steps else None


def snapshot_steps(directory: str) -> list[int]:
    """All snapshot step numbers present on disk, ascending (corrupt or not —
    validation happens at restore time so callers can walk newest-first)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for name in os.listdir(directory)
                  if (m := _STEP_RE.match(name)))


def read_manifest(directory: str, step: int) -> dict:
    """The snapshot's manifest (step / scalars / extra / checksum), raising
    :class:`SnapshotCorruptError` if it cannot be read or parsed."""
    path = os.path.join(directory, f"step_{step}", "manifest.json")
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as e:
        raise SnapshotCorruptError(
            f"unreadable manifest for snapshot step_{step}: {e}") from e


def restore(directory: str, step: int, like):
    """Restore into the structure of ``like`` (a template pytree, e.g. freshly
    initialized state). Arrays are resharded to the template's shardings.

    Integrity: when the manifest carries ``arrays_sha256`` (every snapshot
    written since the field existed), the archive is re-hashed before any
    value is consumed — a flipped bit or truncated write raises
    :class:`SnapshotCorruptError` instead of silently restoring garbage.
    Unreadable archives and template leaves missing from the snapshot raise
    the same error, so one except-clause covers every corruption mode.
    """
    path = os.path.join(directory, f"step_{step}")
    manifest = read_manifest(directory, step)
    arrays_path = os.path.join(path, "arrays.npz")
    expect = manifest.get("arrays_sha256")
    if expect is not None:
        try:
            got = _sha256_file(arrays_path)
        except OSError as e:
            raise SnapshotCorruptError(
                f"unreadable arrays.npz for snapshot step_{step}: {e}") from e
        if got != expect:
            raise SnapshotCorruptError(
                f"checksum mismatch for snapshot step_{step}: arrays.npz "
                f"hashes to {got[:12]}…, manifest records {expect[:12]}…")
    try:
        with np.load(arrays_path) as data:
            arrays = {k: data[k] for k in data.files}
    # np.load's failure surface is wide: zero-byte files raise EOFError
    # ("No data left in file") and mangled zip/npy headers can raise
    # struct.error — neither is an OSError/ValueError subclass, and a legacy
    # manifest without arrays_sha256 reaches this load unchecked, so missing
    # them here would crash the newest-first fallback walk instead of
    # falling back to the next-older snapshot.
    except (OSError, ValueError, zipfile.BadZipFile, KeyError, EOFError,
            struct.error) as e:
        raise SnapshotCorruptError(
            f"unreadable arrays.npz for snapshot step_{step}: {e}") from e
    flat_like = _flatten_with_paths(like)
    out = {}
    for key, leaf in flat_like.items():
        if key in arrays:
            val = arrays[key]
            if hasattr(leaf, "sharding") and leaf.sharding is not None and hasattr(leaf, "shape"):
                try:
                    out[key] = jax.device_put(val.astype(leaf.dtype), leaf.sharding)
                    continue
                except Exception:
                    pass
            out[key] = jax.numpy.asarray(val, dtype=getattr(leaf, "dtype", None))
        elif key in manifest["scalars"]:
            out[key] = manifest["scalars"][key]
        else:
            raise SnapshotCorruptError(
                f"snapshot step_{step} missing leaf {key!r}")
    # Rebuild in template order.
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = [k for k, _ in _flatten_with_paths(like).items()]
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


class CheckpointManager:
    """Retention + optional async IO around save/restore."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x))
                                 if hasattr(x, "dtype") else x, tree)

        def do_save():
            save(self.directory, step, host_tree, extra)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=do_save, daemon=True)
            self._thread.start()
        else:
            do_save()

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(m.group(1)) for name in os.listdir(self.directory)
                       if (m := _STEP_RE.match(name)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def restore(self, like, step: Optional[int] = None):
        self.wait()
        step = step if step is not None else self.latest()
        if step is None:
            return None, None
        return restore(self.directory, step, like), step
