"""Atomic, mesh-agnostic checkpointing with retention and async save.

Fault-tolerance contract (DESIGN.md §5):
  * **Atomicity** — state is written to ``step_<N>.tmp/`` then ``os.replace``d
    into place; a crash mid-write can never corrupt the latest checkpoint.
  * **Mesh-agnostic** — arrays are saved as logical (unsharded) numpy values
    keyed by pytree path, so a restart may use a different mesh/topology
    (elastic rescale) and simply reshards on load.
  * **Resume** — ``latest_step`` scans the directory; the train loop restores
    params/opt-state/step and the data pipeline skip-ahead does the rest.
  * **Async** — ``CheckpointManager(async_save=True)`` moves file IO off the
    training thread (device→host transfer happens synchronously, IO doesn't).
  * **Retention** — keep the most recent K checkpoints (default 3).

On a real multi-host pod each host writes only its addressable shards; here
(single-process) the full value is written. The format is plain ``.npz`` +
a JSON manifest — no external checkpoint dependency.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_part(p) for p in path)
        flat[key] = leaf
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Atomically write checkpoint ``step`` of ``tree`` (any pytree)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    arrays = {}
    scalars = {}
    for key, leaf in flat.items():
        if isinstance(leaf, (int, float, str, bool)):
            scalars[key] = leaf
        else:
            arrays[key] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "scalars": scalars, "extra": extra or {},
                "num_arrays": len(arrays)}
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
        fh.flush()
        os.fsync(fh.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := _STEP_RE.match(name))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like):
    """Restore into the structure of ``like`` (a template pytree, e.g. freshly
    initialized state). Arrays are resharded to the template's shardings."""
    path = os.path.join(directory, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    flat_like = _flatten_with_paths(like)
    out = {}
    for key, leaf in flat_like.items():
        if key in arrays:
            val = arrays[key]
            if hasattr(leaf, "sharding") and leaf.sharding is not None and hasattr(leaf, "shape"):
                try:
                    out[key] = jax.device_put(val.astype(leaf.dtype), leaf.sharding)
                    continue
                except Exception:
                    pass
            out[key] = jax.numpy.asarray(val, dtype=getattr(leaf, "dtype", None))
        elif key in manifest["scalars"]:
            out[key] = manifest["scalars"][key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
    # Rebuild in template order.
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = [k for k, _ in _flatten_with_paths(like).items()]
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


class CheckpointManager:
    """Retention + optional async IO around save/restore."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x))
                                 if hasattr(x, "dtype") else x, tree)

        def do_save():
            save(self.directory, step, host_tree, extra)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=do_save, daemon=True)
            self._thread.start()
        else:
            do_save()

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(m.group(1)) for name in os.listdir(self.directory)
                       if (m := _STEP_RE.match(name)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def restore(self, like, step: Optional[int] = None):
        self.wait()
        step = step if step is not None else self.latest()
        if step is None:
            return None, None
        return restore(self.directory, step, like), step
