from .manager import (CheckpointManager, SnapshotCorruptError,  # noqa: F401
                      latest_step, read_manifest, restore, save,
                      snapshot_steps)
