"""Selection math shared by the fused Pallas sweep kernel and its jnp oracle.

Backend-parity tests require *exact* trajectory agreement between
``kernels.sweep.mcmc_sweep`` and ``kernels.ref.mcmc_sweep``, so every piece of
per-step arithmetic whose floating-point association matters — flip
probability (exact or PWL LUT), the hierarchical roulette scan, and the
site-index rescaling — lives here as pure jnp functions on values. The kernel
reads its VMEM refs into values and calls these; the oracle calls the same
functions from a ``lax.scan``. Both therefore trace to identical op sequences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import rng
from ..core.bitplane import WORD_BITS

#: Widest lane block considered for the hierarchical roulette scan. 128 is the
#: TPU lane count — a within-block cumsum over ≤128 lanes stays in-register.
MAX_LANE = 128


def fit_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ target (BlockSpec grids need exact
    tiling, so block knobs clamp to the nearest feasible size instead of
    erroring on e.g. R=12 with block_r=8)."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def decode_bitplane_rows(pos: jax.Array, neg: jax.Array, n: int) -> jax.Array:
    """Decode packed signed bit-plane words into f32 coupling rows (Eq. 13).

    ``pos``/``neg``: (B, ..., W) uint32 — the W packed words of one J row per
    plane (kernel: a (B, 1, W) ``pl.ds`` slice of the VMEM-resident planes;
    oracle: a (B, R, W) ``jnp.take`` gather). Returns (..., n) float32 via
    J_row = Σ_b 2^b (bits(pos_b) − bits(neg_b)). The expansion is a plain
    shift-and-mask over the 32 bit positions plus an unrolled weighted sum
    over the B planes — O(B·N) VPU work, no ``dot_general`` (the fused
    sweep's jaxpr pin covers this path too) and no ``population_count``
    (the row update needs the individual coupler bits, not their weight).
    Plane values are small integers, so the f32 row is exact.
    """
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)

    def expand(words):  # (..., W) uint32 -> (..., W·32) {0,1} int32, LSB-first
        bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
        return bits.reshape(words.shape[:-1] + (-1,)).astype(jnp.int32)

    num_planes = pos.shape[0]
    row = jnp.zeros(pos.shape[1:-1] + (pos.shape[-1] * WORD_BITS,), jnp.float32)
    for b in range(num_planes):  # static unroll: B is small (≤ 16)
        diff = expand(pos[b]) - expand(neg[b])
        row = row + jnp.float32(1 << b) * diff.astype(jnp.float32)
    return row[..., :n]


def default_lane(n: int) -> int:
    """Largest divisor of ``n`` that is ≤ MAX_LANE (BlockSpec-exact tiling).

    The roulette wheel over N sites is scanned as G = N/L block sums followed
    by one L-wide within-block scan, replacing the O(N)-deep flat cumsum with
    two short, lane-parallel scans."""
    for lane in range(min(MAX_LANE, n), 0, -1):
        if n % lane == 0:
            return lane
    return 1


def default_pwl_select() -> str:
    """How the PWL LUT segment is evaluated when the caller does not say:
    "select" (the lane-friendly compare-and-select sweep) on real TPUs, where
    a per-element gather serializes lane-by-lane on the VPU; "gather" (two
    ``jnp.take``s) everywhere else, where gathers are cheap and the S-deep
    select sweep is pure overhead. Resolved identically by the kernel and the
    oracle (both call :func:`flip_probability` with the default), so the
    choice can never split backend parity."""
    return "select" if jax.default_backend() == "tpu" else "gather"


def flip_probability(delta_e: jax.Array, temperature: jax.Array,
                     pwl_table: jax.Array | None = None,
                     pwl_select: str | None = None) -> jax.Array:
    """Glauber flip probability σ(-ΔE/T) (exact or PWL LUT).

    ``pwl_table`` is the ``(S+1, 3)`` ``[knot, value, slope]`` LUT from
    :func:`repro.core.pwl.pwl_table` (None = exact sigmoid) — the same
    construction as ``core.pwl.make_pwl_sigmoid``, evaluated in intercept
    form (agrees with the reference PWL to float ulps; kernel and oracle
    share THIS function, so backend parity stays exact). T ≤ 0 uses the
    greedy limit (1 downhill / 0.5 flat / 0 uphill). Broadcasts over any
    leading shape.

    ``pwl_select`` picks the LUT evaluation: "gather" reads
    ``icpt[seg]``/``slopes[seg]`` with two per-element ``jnp.take``s;
    "select" sweeps the S segments with branch-free compare-and-select
    (``where(seg == k, icpt_k + slope_k·z, …)``), trading O(S·N) VPU selects
    for zero gathers — the lane-friendly formulation for real TPUs whose VPU
    serializes per-element gathers. The two are **bit-identical** by
    construction: exactly one segment matches per element and the selected
    lane computes the same ``icpt + slope·z`` FMA the gather path computes
    (asserted exactly by ``tests/test_kernels.py``). None resolves via
    :func:`default_pwl_select`.
    """
    de = delta_e.astype(jnp.float32)
    t = jnp.asarray(temperature, jnp.float32)
    safe_t = jnp.where(t > 0, t, 1.0)
    z = -de / safe_t
    if pwl_table is None:
        warm = jax.nn.sigmoid(z)
    else:
        if pwl_select is None:
            pwl_select = default_pwl_select()
        if pwl_select not in ("gather", "select"):
            raise ValueError(f"pwl_select must be 'gather' or 'select', "
                             f"got {pwl_select!r}")
        knots = pwl_table[:, 0]
        values = pwl_table[:, 1]
        slopes = pwl_table[:-1, 2]     # last row is zero padding
        num_segments = pwl_table.shape[0] - 1
        z_lo = knots[0]
        z_hi = knots[num_segments]
        inv_step = jnp.float32(1.0) / (knots[1] - knots[0])
        # Intercept form y = icpt[seg] + slope[seg]·z: two gathers per element
        # instead of three (the hot cost of the LUT on wide (R, N) inputs).
        # icpt is loop-invariant — hoisted out of the sweep's step loop.
        icpt = values[:-1] - slopes * knots[:-1]
        zc = jnp.clip(z, z_lo, z_hi)  # tails collapse into the end segments
        seg = jnp.clip(((zc - z_lo) * inv_step).astype(jnp.int32),
                       0, num_segments - 1)
        if pwl_select == "gather":
            seg_icpt = jnp.take(icpt, seg)
            seg_slope = jnp.take(slopes, seg)
        else:
            # The sweep only *moves* coefficients (branch-free selects, no
            # arithmetic), so it is value-exact vs the gather; the y = icpt +
            # slope·z FMA below is then the structurally identical array
            # expression in both formulations — were it computed inside the
            # loop on scalar coefficients, the compiler could contract it to
            # an fma there but not in the gather path, splitting last-ulp
            # parity (observed on XLA CPU).
            def select_one(k, acc):
                ic_acc, sl_acc = acc
                ic = jax.lax.dynamic_index_in_dim(icpt, k, keepdims=False)
                sl = jax.lax.dynamic_index_in_dim(slopes, k, keepdims=False)
                hit = seg == k
                return jnp.where(hit, ic, ic_acc), jnp.where(hit, sl, sl_acc)
            seg_icpt, seg_slope = jax.lax.fori_loop(
                0, num_segments, select_one,
                (jnp.zeros_like(zc), jnp.zeros_like(zc)))
        warm = seg_icpt + seg_slope * zc
    cold = jnp.where(de < 0, 1.0, jnp.where(de == 0, 0.5, 0.0))
    return jnp.where(t > 0, warm, cold).astype(jnp.float32)


def roulette_block_pick(blk: jax.Array, u_roulette: jax.Array):
    """Level-1 of the hierarchical roulette: pick the winning block from the
    (R, G) block-weight sums. Returns ``(g, residual, total, degenerate)``.

    Split out of :func:`roulette_pick` so the spin-sharded driver can run the
    identical arithmetic on an all-gathered ``blk`` — the block pick is a
    pure function of the block sums, so sharded and single-device trajectories
    stay exactly equal (the parity contract of this module's docstring).
    """
    num_blocks = blk.shape[1]
    cb = jnp.cumsum(blk, axis=1)                   # (R, G) short scan
    total = cb[:, -1]                              # W (Eq. 28)
    degenerate = (total <= 0) | ~jnp.isfinite(total)
    radius = u_roulette * jnp.where(degenerate, 1.0, total)
    g = jnp.minimum(
        jnp.sum((cb <= radius[:, None]).astype(jnp.int32), axis=1),
        num_blocks - 1)                            # block index (R,)
    iota_g = jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)
    base = jnp.sum(jnp.where(iota_g < g[:, None], blk, 0.0), axis=1)
    residual = radius - base
    return g, residual, total, degenerate


def roulette_lane_pick(sel: jax.Array, residual: jax.Array, lane: int):
    """Level-2 of the hierarchical roulette: the within-block lane pick from
    the (R, lane) selected-block weights (sharded callers psum-combine
    ``sel`` from the block owner; the arithmetic is shared either way)."""
    cl = jnp.cumsum(sel, axis=1)
    return jnp.minimum(
        jnp.sum((cl <= residual[:, None]).astype(jnp.int32), axis=1),
        lane - 1)


def roulette_pick(p_all: jax.Array, u_roulette: jax.Array, lane: int):
    """Hierarchical roulette-wheel selection (paper Eq. 28-29).

    ``p_all`` is (R, N); ``u_roulette`` (R,) in [0,1). Returns
    ``(site, total, degenerate)``. Site ``j`` is drawn with probability
    ``p_j / W`` via a two-level scan: cumsum over the G = N/lane block sums
    picks the block, a lane-wide cumsum inside the selected block picks the
    site — O(G + lane) scan depth instead of O(N), and every reduction is a
    lane-parallel segment sum. The ≤-count form keeps the pick branch-free.
    """
    r_, n = p_all.shape
    num_blocks = n // lane
    pb = p_all.reshape(r_, num_blocks, lane)
    blk = jnp.sum(pb, axis=2)                      # (R, G) block weights
    g, residual, total, degenerate = roulette_block_pick(blk, u_roulette)
    iota_g = jax.lax.broadcasted_iota(jnp.int32, (r_, num_blocks), 1)
    sel = jnp.sum(jnp.where((iota_g == g[:, None])[:, :, None], pb, 0.0),
                  axis=1)                          # (R, lane) selected block
    l = roulette_lane_pick(sel, residual, lane)
    return (g * lane + l).astype(jnp.int32), total, degenerate


def site_from_uniform(u01: jax.Array, n: int) -> jax.Array:
    """Random-scan site pick — the canonical ``core.rng`` rescaling (Eq. 22)."""
    return rng.index_from_uniform(u01, n)


def coalesce_rows(j: jax.Array):
    """Duplicate structure of one step's (R,) selected sites — the reuse-aware
    row-fetch plan shared by the HBM-streamed kernel and the spin-sharded
    driver (ROADMAP item 4: R fetches/step → unique(R) fetches/step).

    Returns ``(nu, usite, uo, fetched)``:

    * ``nu``      — scalar int32, the number of *unique* sites in ``j``
                    (1 ≤ nu ≤ R; nu row fetches replace R).
    * ``usite``   — (R,) int32, the m-th unique site in first-occurrence
                    order for m < nu (entries at m ≥ nu repeat site 0's
                    value harmlessly — fetch loops run ``nu`` iterations).
    * ``uo``      — (R,) int32, each replica's index into the unique list
                    (``usite[uo[r]] == j[r]`` for every r), so the decoded
                    unique rows broadcast back to every replica that
                    selected them.
    * ``fetched`` — (R,) int32 one-hot-per-group fetch attribution: 1 on the
                    lowest-index replica of each duplicate group, 0 on the
                    replicas reusing its row (``sum(fetched) == nu`` — the
                    per-step unique-rows-fetched counter).

    The decoded row is a deterministic function of the site alone, so
    fetch-once-broadcast is byte-identical to fetch-per-replica — coalescing
    can never move a trajectory (the five-way parity gate). Everything is
    O(R²) masked reductions over 2-D ``broadcasted_iota`` — no ``sort``, no
    1-D iota, no ``dot_general`` — so the identical code runs inside the
    Pallas kernel (Mosaic-safe) and in the shard_map'd jnp driver.
    """
    r = j.shape[0]
    rr = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)   # row ids
    cc = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)   # column ids
    eq = j[:, None] == j[None, :]                          # (R, R)
    # first_idx[r]: lowest replica index selecting the same site as r.
    first_idx = jnp.min(jnp.where(eq, cc, r), axis=1)
    rid = rr[:, 0]                                         # (R,) 0..R-1, 2-D born
    is_first = first_idx == rid
    fetched = is_first.astype(jnp.int32)
    # Position of each first occurrence in the compacted unique list
    # (inclusive prefix count of firsts, minus one), via a masked 2-D sum —
    # the Pallas-safe cumsum.
    uo_first = jnp.sum(jnp.where((cc <= rr) & is_first[None, :], 1, 0),
                       axis=1) - 1
    uo = jnp.sum(jnp.where(cc == first_idx[:, None], uo_first[None, :], 0),
                 axis=1)
    nu = jnp.sum(fetched)
    usite = jnp.sum(jnp.where((rr == uo_first[None, :]) & is_first[None, :],
                              j[None, :], 0), axis=1)
    # Fetch loops index usite at m < nu only; park the tail on a valid site
    # so a clamped prefetch can never read out of range.
    usite = jnp.where(rid < nu, usite, usite[0])
    return nu, usite, uo, fetched
