"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and ``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def local_field_init(spins: jax.Array, couplings: jax.Array, bias: jax.Array) -> jax.Array:
    """u[r, i] = Σ_j J_ij s[r, j] + h_i  (paper Eq. 11 batched over replicas)."""
    s = spins.astype(jnp.float32)
    J = couplings.astype(jnp.float32)
    return s @ J.T + bias.astype(jnp.float32)[None, :]


def bitplane_field_init(pos: jax.Array, neg: jax.Array, spin_words: jax.Array,
                        num_spins: int) -> jax.Array:
    """Hamming-weight accumulation (paper Eq. 14-16) over packed planes.

    pos/neg: (B, N, W) uint32; spin_words: (R, W) uint32; -> (R, N) f32.
    """
    popc = jax.lax.population_count
    x = spin_words[:, None, None, :]  # (R, 1, 1, W)
    m_p = popc(pos).astype(jnp.int32).sum(-1)  # (B, N)
    m_n = popc(neg).astype(jnp.int32).sum(-1)
    o_p = popc(pos[None] & x).astype(jnp.int32).sum(-1)  # (R, B, N)
    o_n = popc(neg[None] & x).astype(jnp.int32).sum(-1)
    contrib = (2 * o_p - m_p[None]) - (2 * o_n - m_n[None])  # (R, B, N)
    w = jnp.float32(2.0) ** jnp.arange(pos.shape[0], dtype=jnp.float32)
    return jnp.einsum("b,rbn->rn", w, contrib.astype(jnp.float32))


def mcmc_sweep(couplings: jax.Array, fields0: jax.Array, spins0: jax.Array,
               energy0: jax.Array, uniforms: jax.Array, temps: jax.Array,
               mode: str = "rsa") -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """T-step dual-mode sweep over R replicas (paper Alg. 1 inner loop).

    couplings: (N, N); fields0/spins0: (R, N); energy0: (R,);
    uniforms: (T, R, 3) f32 in [0,1) — (site, accept, roulette) streams;
    temps: (T,) f32. Returns (fields, spins, energy, best_energy, best_spins).
    mode 'rsa': stochastic Glauber accept at a uniform site;
    mode 'rwa': roulette-wheel (degenerate-W fallback to the site/accept draws).
    """
    n = couplings.shape[0]
    J = couplings.astype(jnp.float32)

    def body(carry, xs):
        u, s, e, be, bs = carry
        u01, temp = xs
        sf = s.astype(jnp.float32)
        de_all = 2.0 * sf * u  # (R, N)
        safe_t = jnp.where(temp > 0, temp, 1.0)
        p_all = jax.nn.sigmoid(-de_all / safe_t)
        p_all = jnp.where(temp > 0, p_all,
                          jnp.where(de_all < 0, 1.0, jnp.where(de_all == 0, 0.5, 0.0)))
        if mode == "rsa":
            j = jnp.minimum((u01[:, 0] * n).astype(jnp.int32), n - 1)
            p_j = jnp.take_along_axis(p_all, j[:, None], axis=1)[:, 0]
            accept = u01[:, 1] < p_j
        else:
            wheel = jnp.cumsum(p_all, axis=1)
            total = wheel[:, -1]
            degenerate = (total <= 0) | ~jnp.isfinite(total)
            r = u01[:, 2] * jnp.where(degenerate, 1.0, total)
            j_rw = jnp.minimum(jnp.sum(wheel <= r[:, None], axis=1), n - 1).astype(jnp.int32)
            j_fb = jnp.minimum((u01[:, 0] * n).astype(jnp.int32), n - 1)
            p_fb = jnp.take_along_axis(p_all, j_fb[:, None], axis=1)[:, 0]
            accept_fb = u01[:, 1] < p_fb
            j = jnp.where(degenerate, j_fb, j_rw)
            accept = jnp.where(degenerate, accept_fb, True)
        s_old = jnp.take_along_axis(s, j[:, None], axis=1)[:, 0].astype(jnp.float32)
        de = jnp.take_along_axis(de_all, j[:, None], axis=1)[:, 0]
        acc_f = accept.astype(jnp.float32)
        rows = jnp.take(J, j, axis=0)  # (R, N)
        u = u - (2.0 * acc_f * s_old)[:, None] * rows
        onehot = jax.nn.one_hot(j, n, dtype=s.dtype)
        s = jnp.where(accept[:, None], (s * (1 - 2 * onehot)).astype(s.dtype), s)
        e = e + acc_f * de
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs), None

    init = (fields0.astype(jnp.float32), spins0, energy0.astype(jnp.float32),
            energy0.astype(jnp.float32), spins0)
    (u, s, e, be, bs), _ = jax.lax.scan(body, init, (uniforms, temps))
    return u, s, e, be, bs
