"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and ``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def local_field_init(spins: jax.Array, couplings: jax.Array, bias: jax.Array) -> jax.Array:
    """u[r, i] = Σ_j J_ij s[r, j] + h_i  (paper Eq. 11 batched over replicas)."""
    s = spins.astype(jnp.float32)
    J = couplings.astype(jnp.float32)
    return s @ J.T + bias.astype(jnp.float32)[None, :]


def bitplane_field_init(pos: jax.Array, neg: jax.Array, spin_words: jax.Array,
                        num_spins: int) -> jax.Array:
    """Hamming-weight accumulation (paper Eq. 14-16) over packed planes.

    pos/neg: (B, N, W) uint32; spin_words: (R, W) uint32; -> (R, N) f32.
    """
    popc = jax.lax.population_count
    x = spin_words[:, None, None, :]  # (R, 1, 1, W)
    m_p = popc(pos).astype(jnp.int32).sum(-1)  # (B, N)
    m_n = popc(neg).astype(jnp.int32).sum(-1)
    o_p = popc(pos[None] & x).astype(jnp.int32).sum(-1)  # (R, B, N)
    o_n = popc(neg[None] & x).astype(jnp.int32).sum(-1)
    contrib = (2 * o_p - m_p[None]) - (2 * o_n - m_n[None])  # (R, B, N)
    w = jnp.float32(2.0) ** jnp.arange(pos.shape[0], dtype=jnp.float32)
    return jnp.einsum("b,rbn->rn", w, contrib.astype(jnp.float32))


def colored_sweep(couplings, fields0: jax.Array, spins0: jax.Array,
                  energy0: jax.Array, uniforms: jax.Array, temps: jax.Array,
                  sched: jax.Array, pwl_table: jax.Array | None = None, *,
                  block_r: int = 8):
    """Exact-semantics oracle for ``kernels.sweep.colored_sweep``.

    Same contract: spins in color-sorted order, ``sched`` (T, 3) int32 rows of
    (window_start, class_offset, class_size), ``uniforms`` (T, R, S) accept
    streams over the static class window S. Per step every member of the
    scheduled class takes an independent heat-bath flip off the live local
    fields (exact block Gibbs — same-color spins share no coupling), then the
    accepted subset's rank-1 row updates are applied slot by slot through the
    same row decode as the single-flip oracle. The kernel gates each slot's
    fetch+FMA on "any replica in the *block* accepted", so the oracle takes
    ``block_r`` and reproduces the identical block-shaped select — parity
    tests require trajectory-exact agreement on all 7 outputs, including the
    coalesced ``rows_fetched`` attribution (one count per fetched row, on the
    block's lowest-index accepting replica). Returns (fields, spins, energy,
    best_energy, best_spins, num_flips, rows_fetched).
    """
    from . import common  # local import: ref stays importable standalone
    from ..core.bitplane import BitPlanes

    if isinstance(couplings, BitPlanes):
        n = couplings.num_spins
        pos, neg = couplings.pos, couplings.neg

        def fetch_row(jr):  # scalar site -> (1, N) f32 decoded coupling row
            return common.decode_bitplane_rows(
                jax.lax.dynamic_slice_in_dim(pos, jr, 1, axis=1),
                jax.lax.dynamic_slice_in_dim(neg, jr, 1, axis=1), n)
    else:
        n = couplings.shape[0]
        J = couplings.astype(jnp.float32)

        def fetch_row(jr):
            return jax.lax.dynamic_slice_in_dim(J, jr, 1, axis=0)

    r = fields0.shape[0]
    br = common.fit_block(r, block_r)
    g = r // br
    win = uniforms.shape[2]
    ids = jnp.arange(br, dtype=jnp.int32)

    def body(carry, xs):
        u, s, e, be, bs, nf, rf = carry
        u01, temp, row_sched = xs            # (R, S), (R,), (3,)
        w, off, size = row_sched[0], row_sched[1], row_sched[2]
        u_win = jax.lax.dynamic_slice(u, (0, w), (r, win))
        s_win = jax.lax.dynamic_slice(s, (0, w), (r, win))
        de = 2.0 * s_win * u_win
        p = common.flip_probability(de, temp[:, None], pwl_table)
        idx = jax.lax.broadcasted_iota(jnp.int32, (r, win), 1) + w
        valid = (idx >= off) & (idx < off + size)
        accept = (u01 < p) & valid
        acc_f = accept.astype(jnp.float32)
        e = e + jnp.sum(acc_f * de, axis=1)
        nf = nf + jnp.sum(accept.astype(jnp.int32), axis=1)
        s = jax.lax.dynamic_update_slice(s, s_win * (1.0 - 2.0 * acc_f),
                                         (0, w))

        def apply_slot(k, carry):
            u, rf = carry
            acc_k = jax.lax.dynamic_slice(acc_f, (0, k), (r, 1))   # (R, 1)
            s_old_k = jax.lax.dynamic_slice(s_win, (0, k), (r, 1))
            acc_b = acc_k.reshape(g, br)
            anyacc = jnp.sum(acc_b, axis=1) > 0.0                  # (G,)
            row = fetch_row(w + k)                                 # (1, N)
            gate = jnp.repeat(anyacc, br)[:, None]
            u = jnp.where(gate, u - (2.0 * acc_k * s_old_k) * row, u)
            first = jnp.min(jnp.where(acc_b > 0.0, ids[None, :], br), axis=1)
            hit = anyacc[:, None] & (ids[None, :] == first[:, None])
            return u, rf + hit.reshape(r).astype(jnp.int32)

        lo = off - w
        u, rf = jax.lax.fori_loop(lo, lo + size, apply_slot, (u, rf))
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs, nf, rf), None

    init = (fields0.astype(jnp.float32), spins0.astype(jnp.float32),
            energy0.astype(jnp.float32), energy0.astype(jnp.float32),
            spins0.astype(jnp.float32), jnp.zeros((r,), jnp.int32),
            jnp.zeros((r,), jnp.int32))
    (u, s, e, be, bs, nf, rf), _ = jax.lax.scan(
        body, init, (uniforms, temps, sched.astype(jnp.int32)))
    return (u, s.astype(spins0.dtype), e, be, bs.astype(spins0.dtype),
            nf, rf)


def mcmc_sweep(couplings, fields0: jax.Array, spins0: jax.Array,
               energy0: jax.Array, uniforms: jax.Array, temps: jax.Array,
               pwl_table: jax.Array | None = None, *, mode: str = "rsa",
               uniformized: bool = False, lane: int | None = None):
    """T-step dual-mode sweep over R replicas (paper Alg. 1 inner loop).

    Exact-semantics oracle for ``kernels.sweep.mcmc_sweep``: identical
    signature (minus blocking knobs) and identical per-step arithmetic via the
    shared ``kernels.common`` selection math, so parity tests can require
    trajectory-exact agreement. couplings (N, N) dense — or a packed
    ``core.bitplane.BitPlanes``, mirroring the kernel's
    ``coupling="bitplane"`` path: rows are gathered from the planes and
    decoded through the same ``common.decode_bitplane_rows`` bit expansion,
    so the bit-plane trajectories are exact too. fields0/spins0 (R, N);
    energy0 (R,); uniforms (T, R, 4) f32 in [0,1) — (site, accept, roulette,
    uniformize) streams; temps (T, R) f32 per-replica temperatures;
    ``pwl_table`` optional (S+1, 3) LUT (None = exact sigmoid). Returns
    (fields, spins, energy, best_energy, best_spins, num_flips).
    """
    from . import common  # local import: ref stays importable standalone
    from ..core.bitplane import BitPlanes

    if isinstance(couplings, BitPlanes):
        n = couplings.num_spins
        pos, neg = couplings.pos, couplings.neg

        def fetch_rows(j):  # (R,) sites -> (R, N) f32 decoded coupling rows
            return common.decode_bitplane_rows(
                jnp.take(pos, j, axis=1), jnp.take(neg, j, axis=1), n)
    else:
        n = couplings.shape[0]
        J = couplings.astype(jnp.float32)

        def fetch_rows(j):
            return jnp.take(J, j, axis=0)
    lane = common.default_lane(n) if lane is None else lane

    def body(carry, xs):
        u, s, e, be, bs, nf = carry
        u01, temp = xs                       # (R, 4), (R,)
        sf = s.astype(jnp.float32)
        if mode == "rsa":
            j = common.site_from_uniform(u01[:, 0], n)
            u_j = jnp.take_along_axis(u, j[:, None], axis=1)[:, 0]
            s_j = jnp.take_along_axis(sf, j[:, None], axis=1)[:, 0]
            de = 2.0 * s_j * u_j
            p_j = common.flip_probability(de, temp, pwl_table)
            accept = u01[:, 1] < p_j
        else:
            de_all = 2.0 * sf * u            # (R, N)
            p_all = common.flip_probability(de_all, temp[:, None], pwl_table)
            j_rw, total, degenerate = common.roulette_pick(p_all, u01[:, 2], lane)
            if uniformized:
                accept = jnp.where(degenerate, False,
                                   u01[:, 3] * jnp.float32(n) < total)
                j = j_rw
            else:
                j_fb = common.site_from_uniform(u01[:, 0], n)
                p_fb = jnp.take_along_axis(p_all, j_fb[:, None], axis=1)[:, 0]
                accept = jnp.where(degenerate, u01[:, 1] < p_fb, True)
                j = jnp.where(degenerate, j_fb, j_rw)
            de = jnp.take_along_axis(de_all, j[:, None], axis=1)[:, 0]
        s_old = jnp.take_along_axis(sf, j[:, None], axis=1)[:, 0]
        acc_f = accept.astype(jnp.float32)
        rows = fetch_rows(j)  # (R, N)
        u = u - (2.0 * acc_f * s_old)[:, None] * rows
        onehot = jax.nn.one_hot(j, n, dtype=s.dtype)
        s = jnp.where(accept[:, None], (s * (1 - 2 * onehot)).astype(s.dtype), s)
        e = e + acc_f * de
        nf = nf + accept.astype(jnp.int32)
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs, nf), None

    r = fields0.shape[0]
    init = (fields0.astype(jnp.float32), spins0, energy0.astype(jnp.float32),
            energy0.astype(jnp.float32), spins0, jnp.zeros((r,), jnp.int32))
    (u, s, e, be, bs, nf), _ = jax.lax.scan(body, init, (uniforms, temps))
    return u, s, e, be, bs, nf
