"""Pallas TPU kernel: fused multi-step dual-mode MCMC sweep (production backend).

TPU analogue of the paper's on-chip local-field memory (§IV-B2b): the FPGA
keeps u in BRAM and read-modify-writes it after every flip. A literal
one-flip-per-XLA-op loop would round-trip u, s through HBM every step; this
kernel keeps the coupling tile J, the local fields u, and the spins s resident
in VMEM across ``T`` consecutive MCMC steps, so per-step HBM traffic drops to
zero for N ≤ ~2800 (f32 J; 16 MiB VMEM) — the same "compute-bound, not
memory-bound" crossover the paper demonstrates in Fig. 14.

Per-step work is O(br·N) (DESIGN.md §Backends): the incremental update
u ← u − 2 J[j,:] s_j_old (Eq. 27/31) fetches row J[j] with one per-replica
``pl.ds`` dynamic slice of the VMEM-resident J. The historical one-hot × J
MXU gather — an O(br·N²) contraction per step — survives only as the opt-in
``gather="onehot"`` heuristic for tiny N, where a single small matmul beats
``br`` sequential DMA-issued row reads.

Coupling storage is selectable (``coupling="dense"|"bitplane"|"bitplane_hbm"``):
the dense path holds J as (N, N) f32 — 16 MiB of VMEM at N=2048, the f32 wall
— while the bit-plane path (paper §IV-B1, Eq. 13) holds the (B, N, W) uint32
``pos``/``neg`` planes of an integer J, 2·B bits per coupler instead of 32.
At the paper's B=2 that is 8× smaller, moving the VMEM wall from N≈2000 to
N≈5–11k (DESIGN.md §Backends). Row j is fetched as a (B, 1, W) ``pl.ds``
slice per sign — O(B·N/32) word reads — and decoded in-register by
``common.decode_bitplane_rows`` (shift-and-mask expansion + unrolled plane
sum, O(B·N) VPU work, no ``dot_general``); the O(N) FMA into u is unchanged,
so the O(N)/step contract and the no-``dot_general`` jaxpr pin both hold.
Local-field *initialization* from planes is the separate popcount kernel
(``kernels/bitplane_field.py``); this kernel only consumes u₀.

``coupling="bitplane_hbm"`` breaks even the packed-VMEM wall (N ≈ 8–11k):
the planes stay in HBM (``memory_space=ANY`` — never blocked into the
pipeline) and each step's selected row streams into a 2-slot VMEM scratch via
``pltpu.make_async_copy`` DMAs, double-buffered across the replica apply
loop — while replica r's (B, 1, W) row tile is decoded and FMA'd, the DMA
for replica r+1's row is already in flight. VMEM then holds only the sweep
state plus two row tiles (O(B·N/32) words), so the N-ceiling is set by HBM
capacity, not VMEM: N=16384 at B=1 is a 64 MiB plane store streamed at
~2·B·N/32 words/step against the same O(N) VPU work. The decoded row goes
through the identical ``common.decode_bitplane_rows`` expansion, so streamed
trajectories are exactly equal to the VMEM-bitplane and dense paths (the
parity tier asserts ``assert_array_equal``). The DMA pattern runs under
interpret mode too (jax 0.4.37 emulates ``make_async_copy`` + semaphores),
so the tested path on CPU is the compiled path on TPU.

Feature parity with ``core.mcmc``: both modes (RSA random-scan, RWA
roulette-wheel with hierarchical lane-scan selection), the uniformized-RWA
null-transition variant, the PWL LUT flip probability (passed as a small VMEM
table), per-replica temperature ladders (``temps`` is (T, R) — parallel
tempering runs a constant ladder, annealing a broadcast schedule), and
``num_flips`` tracking.

Asynchronous single-spin semantics are preserved exactly: each step selects at
most one spin per replica, flips it, and applies the incremental update before
the next selection. Randomness is supplied as a precomputed (T, R, 4) tensor
of uniforms — (site, accept, roulette, uniformize) streams — from the
stateless threefry RNG, so the kernel stays deterministic and replayable.

Grid: replica blocks; J is broadcast (index_map pins block 0) so the pipeline
loads it once per program.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import coupling as coupling_store
from . import common

#: Coupling-store modes of the fused sweep (the single-device slice of the
#: ``core.coupling`` format registry; the sharded tier has its own driver).
COUPLING_MODES = coupling_store.KERNEL_COUPLING_MODES
#: Modes that consume a packed ``BitPlanes`` instead of a dense (N, N) J.
PLANE_MODES = coupling_store.KERNEL_PLANE_MODES


def _dense_layout(couplings, n, br, coalesce):
    """VMEM-resident (N, N) f32 J, broadcast to every replica block."""
    return [pl.BlockSpec((n, n), lambda i: (0, 0))], [couplings], []


def _bitplane_layout(couplings, n, br, coalesce):
    """VMEM-resident packed planes: pos/neg (B, N, W) broadcast."""
    bp, _, w = couplings.pos.shape
    return ([pl.BlockSpec((bp, n, w), lambda i: (0, 0, 0)),
             pl.BlockSpec((bp, n, w), lambda i: (0, 0, 0))],
            [couplings.pos, couplings.neg], [])


def _bitplane_hbm_layout(couplings, n, br, coalesce):
    """HBM-resident planes: never enter the block pipeline (ANY pins them to
    HBM); the kernel streams (B, 1, W) row tiles into a 2-slot VMEM scratch
    double-buffer with one DMA semaphore per (slot, sign) in-flight copy.
    With coalescing, a (br, N) f32 row cache holds the step's decoded unique
    rows so duplicate selections replay a VMEM read instead of a second DMA."""
    bp, _, w = couplings.pos.shape
    scratch = [pltpu.VMEM((2, bp, 1, w), jnp.uint32),  # pos row tiles
               pltpu.VMEM((2, bp, 1, w), jnp.uint32),  # neg row tiles
               pltpu.SemaphoreType.DMA((2, 2))]        # (slot, sign) DMAs
    if coalesce:
        scratch.append(pltpu.VMEM((br, n), jnp.float32))  # decoded row cache
    return ([pl.BlockSpec(memory_space=pltpu.ANY),
             pl.BlockSpec(memory_space=pltpu.ANY)],
            [couplings.pos, couplings.neg], scratch)


#: Kernel-side half of the coupling-store contract: resolved format name →
#: (in_specs, operands, scratch_shapes) for the J store. The host-side half
#: is ``core.coupling.CouplingStore.build``.
_STORE_LAYOUTS = {
    "dense": _dense_layout,
    "bitplane": _bitplane_layout,
    "bitplane_hbm": _bitplane_hbm_layout,
}


def _gather_scalars(x: jax.Array, sites: jax.Array, br: int) -> jax.Array:
    """vals[r] = x[r, sites[r]] via per-replica (1, 1) dynamic slices — O(br)
    work in place of a (br, N) one-hot masked reduction."""

    def body(rix, vals):
        v = jax.lax.dynamic_slice(x, (rix, sites[rix]), (1, 1))
        return jax.lax.dynamic_update_slice(vals, v[0], (rix,))

    return jax.lax.fori_loop(0, br, body, jnp.zeros((br,), x.dtype))


def _gather_scalar_pair(a: jax.Array, b: jax.Array, sites: jax.Array,
                        br: int) -> tuple[jax.Array, jax.Array]:
    """(a[r, sites[r]], b[r, sites[r]]) for every replica in one loop."""

    def body(rix, carry):
        va, vb = carry
        av = jax.lax.dynamic_slice(a, (rix, sites[rix]), (1, 1))
        bv = jax.lax.dynamic_slice(b, (rix, sites[rix]), (1, 1))
        return (jax.lax.dynamic_update_slice(va, av[0], (rix,)),
                jax.lax.dynamic_update_slice(vb, bv[0], (rix,)))

    init = (jnp.zeros((br,), a.dtype), jnp.zeros((br,), b.dtype))
    return jax.lax.fori_loop(0, br, body, init)


def _kernel(*refs, num_steps: int, mode: str, uniformized: bool,
            gather: str, lane: int, has_pwl: bool, coupling: str,
            coalesce: bool):
    streamed = coupling == "bitplane_hbm"
    cache_scr = None
    if streamed:
        # HBM-streaming scratch: 2-slot (double-buffered) row tiles per sign
        # plane plus one DMA semaphore per (slot, sign) in-flight copy; the
        # coalesced path adds the (br, N) decoded-row cache.
        if coalesce:
            pos_scr, neg_scr, row_sems, cache_scr = refs[-4:]
            refs = refs[:-4]
        else:
            pos_scr, neg_scr, row_sems = refs[-3:]
            refs = refs[:-3]
    num_j = 2 if coupling in PLANE_MODES else 1
    j_refs = refs[:num_j]
    (u0_ref, s0_ref, e0_ref, unif_ref, temp_ref) = refs[num_j:num_j + 5]
    if has_pwl:
        pwl_ref = refs[num_j + 5]
        tbl = pwl_ref[...].astype(jnp.float32)
    else:
        tbl = None
    (u_out, s_out, e_out, be_out, bs_out, nf_out,
     rf_out) = refs[num_j + 5 + int(has_pwl):]
    n = u0_ref.shape[1]
    br = u0_ref.shape[0]
    # Only the opt-in MXU path materializes J as a value; the default O(N)
    # path reads single rows straight off the ref(s).
    J = j_refs[0][...].astype(jnp.float32) if gather == "onehot" else None

    def fetch_row(jr):
        """(1, N) f32 coupling row jr — `pl.ds` off the VMEM-resident store."""
        if coupling == "bitplane":
            pos_ref, neg_ref = j_refs
            pr = pos_ref[:, pl.ds(jr, 1), :]  # (B, 1, W) packed words
            nr = neg_ref[:, pl.ds(jr, 1), :]
            return common.decode_bitplane_rows(pr, nr, n)
        return j_refs[0][pl.ds(jr, 1), :].astype(jnp.float32)

    def stream_dmas(slot, jr):
        """The two (B, 1, W) HBM→VMEM row-tile copies for site jr into
        double-buffer ``slot`` (descriptors are rebuilt for wait() — the
        canonical make_async_copy pattern)."""
        pos_ref, neg_ref = j_refs
        return (pltpu.make_async_copy(pos_ref.at[:, pl.ds(jr, 1), :],
                                      pos_scr.at[slot], row_sems.at[slot, 0]),
                pltpu.make_async_copy(neg_ref.at[:, pl.ds(jr, 1), :],
                                      neg_scr.at[slot], row_sems.at[slot, 1]))

    def stream_start(slot, jr):
        for dma in stream_dmas(slot, jr):
            dma.start()

    def stream_wait_decode(slot, jr):
        """Block on slot's row DMAs, then the same in-register bit expansion
        as the VMEM path — identical decode ⇒ identical trajectories."""
        for dma in stream_dmas(slot, jr):
            dma.wait()
        return common.decode_bitplane_rows(pos_scr[slot], neg_scr[slot], n)
    u = u0_ref[...].astype(jnp.float32)     # (br, N)
    s = s0_ref[...].astype(jnp.float32)     # (br, N) ±1
    e = e0_ref[...].astype(jnp.float32)[:, 0]  # (br,)

    def step(t, carry):
        u, s, e, be, bs, nf, rf = carry
        temp = temp_ref[t]                  # (br,) per-replica ladder rung
        u_site = unif_ref[t, :, 0]
        u_acc = unif_ref[t, :, 1]
        u_rou = unif_ref[t, :, 2]
        u_uni = unif_ref[t, :, 3]
        if mode == "rsa":
            j = common.site_from_uniform(u_site, n)
            s_old, u_j = _gather_scalar_pair(s, u, j, br)
            de = 2.0 * s_old * u_j
            p_j = common.flip_probability(de, temp, tbl)
            accept_b = u_acc < p_j
        else:
            de_all = 2.0 * s * u
            p_all = common.flip_probability(de_all, temp[:, None], tbl)
            j_rw, total, degenerate = common.roulette_pick(p_all, u_rou, lane)
            if uniformized:
                # Null transition with prob 1 − W/W*, W* = N (§IV-B3c).
                accept_b = jnp.where(degenerate, False,
                                     u_uni * jnp.float32(n) < total)
                j = j_rw
            else:
                # Degenerate-W fallback: one random-scan update (Alg. 1 l. 10-14).
                j_fb = common.site_from_uniform(u_site, n)
                p_fb = _gather_scalars(p_all, j_fb, br)
                accept_b = jnp.where(degenerate, u_acc < p_fb, True)
                j = jnp.where(degenerate, j_fb, j_rw)
            de, s_old = _gather_scalar_pair(de_all, s, j, br)
        accept = accept_b.astype(jnp.float32)
        e = e + accept * de
        nf = nf + accept_b.astype(jnp.int32)
        better = e < be
        be = jnp.where(better, e, be)
        if gather == "onehot":
            rf = rf + 1                      # one row materialized per replica
            iota = jax.lax.broadcasted_iota(jnp.int32, (br, n), 1)
            onehot = (iota == j[:, None]).astype(jnp.float32)
            rows = jax.lax.dot_general(onehot, J, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
            u = u - (2.0 * accept * s_old)[:, None] * rows
            s = s * (1.0 - 2.0 * accept[:, None] * onehot)
            bs = jnp.where(better[:, None], s, bs)
        else:
            # Asynchronous apply, one replica at a time: an O(N) row FMA
            # straight off the J ref, a scalar spin flip, and a
            # copy-on-improve of best_spins (lax.cond so the (1, N) copy is
            # only paid when the replica actually improved).
            def apply_row(rix, jr, row, u, s, bs):
                """Consume replica rix's (1, N) coupling row — the arithmetic
                shared verbatim by the VMEM-fetch and HBM-streamed drivers."""
                coef = 2.0 * accept[rix] * s_old[rix]
                u_row = jax.lax.dynamic_slice(u, (rix, 0), (1, n))
                u = jax.lax.dynamic_update_slice(u, u_row - coef * row,
                                                 (rix, 0))
                new_sj = (s_old[rix] * (1.0 - 2.0 * accept[rix])).reshape(1, 1)
                s = jax.lax.dynamic_update_slice(s, new_sj, (rix, jr))
                bs = jax.lax.cond(
                    better[rix],
                    lambda b, s=s: jax.lax.dynamic_update_slice(
                        b, jax.lax.dynamic_slice(s, (rix, 0), (1, n)),
                        (rix, 0)),
                    lambda b: b, bs)
                return (u, s, bs)

            if streamed and coalesce:
                # Reuse-aware streaming (ROADMAP item 4): DMA each *unique*
                # selected row exactly once — still double-buffered across
                # the dynamic-trip fetch loop — into the (br, N) decoded-row
                # cache, then apply replicas in their original order reading
                # the cache. The decoded row depends only on the site, so
                # fetch-once-broadcast is byte-identical to fetch-per-replica
                # and the trajectory cannot move; only rf (rows fetched)
                # drops from br to nu per step.
                nu, usite, uo, fetched = common.coalesce_rows(j)
                rf = rf + fetched

                def fetch_one(m, c):
                    slot = jax.lax.rem(m, 2)

                    @pl.when(m + 1 < nu)
                    def _():
                        nxt = jnp.minimum(m + 1, br - 1)
                        stream_start(jax.lax.rem(m + 1, 2), usite[nxt])

                    cache_scr[pl.ds(m, 1), :] = stream_wait_decode(
                        slot, usite[m])
                    return c

                stream_start(0, usite[0])
                jax.lax.fori_loop(0, nu, fetch_one, 0)

                def apply_one(rix, carry):
                    u, s, bs = carry
                    row = cache_scr[pl.ds(uo[rix], 1), :]  # (1, N)
                    return apply_row(rix, j[rix], row, u, s, bs)
            elif streamed:
                rf = rf + 1
                # Double-buffered HBM streaming: replica r+1's row tiles are
                # DMA'd into the other scratch slot while replica r's row is
                # decoded and applied (sites j are all known before the apply
                # loop, and replicas are independent, so the prefetch can
                # never read a stale site).
                def apply_one(rix, carry):
                    u, s, bs = carry
                    jr = j[rix]
                    slot = jax.lax.rem(rix, 2)

                    @pl.when(rix + 1 < br)
                    def _():
                        nxt = jnp.minimum(rix + 1, br - 1)
                        stream_start(jax.lax.rem(rix + 1, 2), j[nxt])

                    row = stream_wait_decode(slot, jr)  # (1, N)
                    return apply_row(rix, jr, row, u, s, bs)

                stream_start(0, j[0])
            else:
                rf = rf + 1

                def apply_one(rix, carry):
                    u, s, bs = carry
                    jr = j[rix]
                    row = fetch_row(jr)  # (1, N)
                    return apply_row(rix, jr, row, u, s, bs)

            u, s, bs = jax.lax.fori_loop(0, br, apply_one, (u, s, bs))
        return (u, s, e, be, bs, nf, rf)

    init = (u, s, e, e, s, jnp.zeros((br,), jnp.int32),
            jnp.zeros((br,), jnp.int32))
    u, s, e, be, bs, nf, rf = jax.lax.fori_loop(0, num_steps, step, init)
    u_out[...] = u
    s_out[...] = s.astype(s_out.dtype)
    e_out[...] = e[:, None]
    be_out[...] = be[:, None]
    bs_out[...] = bs.astype(bs_out.dtype)
    nf_out[...] = nf[:, None]
    rf_out[...] = rf[:, None]


def _colored_kernel(*refs, num_steps: int, has_pwl: bool, coupling: str):
    """Graph-colored block sweep: per step, every spin of the scheduled color
    class accepts an independent heat-bath flip off the live local fields,
    then the accepted subset's rank-1 field updates are applied through the
    same per-row fetch/decode the single-flip kernel uses. Same-color spins
    share no coupling, so the ΔE computed at step start stays valid at every
    member site regardless of apply order — exact block Gibbs (DESIGN.md
    §Graph-colored parallel flips). The selection-mode knob (rsa/rwa/
    uniformized) does not enter: class membership replaces spin selection,
    so colored trajectories are mode-independent by construction.

    The driver hands the class schedule as a (T, 3) int32 ``sched`` tensor —
    per step the lane-aligned window start ``w``, the class offset, and the
    class size in the color-sorted (permuted) spin order — so the kernel
    slices one static-width window per step and masks to the live class.
    """
    streamed = coupling == "bitplane_hbm"
    if streamed:
        pos_scr, neg_scr, row_sems = refs[-3:]
        refs = refs[:-3]
    num_j = 2 if coupling in PLANE_MODES else 1
    j_refs = refs[:num_j]
    (u0_ref, s0_ref, e0_ref, unif_ref, temp_ref,
     sched_ref) = refs[num_j:num_j + 6]
    if has_pwl:
        pwl_ref = refs[num_j + 6]
        tbl = pwl_ref[...].astype(jnp.float32)
    else:
        tbl = None
    (u_out, s_out, e_out, be_out, bs_out, nf_out,
     rf_out) = refs[num_j + 6 + int(has_pwl):]
    n = u0_ref.shape[1]
    br = u0_ref.shape[0]
    win = unif_ref.shape[2]

    def fetch_row(jr):
        """(1, N) f32 coupling row jr — identical decode to the single-flip
        kernel, so the colored oracle can require bit-exact trajectories."""
        if coupling == "bitplane":
            pos_ref, neg_ref = j_refs
            return common.decode_bitplane_rows(
                pos_ref[:, pl.ds(jr, 1), :], neg_ref[:, pl.ds(jr, 1), :], n)
        if streamed:
            pos_ref, neg_ref = j_refs
            dmas = (pltpu.make_async_copy(pos_ref.at[:, pl.ds(jr, 1), :],
                                          pos_scr.at[0], row_sems.at[0, 0]),
                    pltpu.make_async_copy(neg_ref.at[:, pl.ds(jr, 1), :],
                                          neg_scr.at[0], row_sems.at[0, 1]))
            for dma in dmas:
                dma.start()
            for dma in dmas:
                dma.wait()
            return common.decode_bitplane_rows(pos_scr[0], neg_scr[0], n)
        return j_refs[0][pl.ds(jr, 1), :].astype(jnp.float32)

    u = u0_ref[...].astype(jnp.float32)
    s = s0_ref[...].astype(jnp.float32)
    e = e0_ref[...].astype(jnp.float32)[:, 0]

    def step(t, carry):
        u, s, e, be, bs, nf, rf = carry
        temp = temp_ref[t]                       # (br,)
        w = sched_ref[t, 0]
        off = sched_ref[t, 1]
        size = sched_ref[t, 2]
        u_win = jax.lax.dynamic_slice(u, (0, w), (br, win))
        s_win = jax.lax.dynamic_slice(s, (0, w), (br, win))
        de = 2.0 * s_win * u_win
        p = common.flip_probability(de, temp[:, None], tbl)
        idx = jax.lax.broadcasted_iota(jnp.int32, (br, win), 1) + w
        valid = (idx >= off) & (idx < off + size)
        accept = (unif_ref[t] < p) & valid
        acc_f = accept.astype(jnp.float32)
        e = e + jnp.sum(acc_f * de, axis=1)
        nf = nf + jnp.sum(accept.astype(jnp.int32), axis=1)
        s = jax.lax.dynamic_update_slice(s, s_win * (1.0 - 2.0 * acc_f),
                                         (0, w))

        def apply_slot(k, carry):
            # One class member per iteration: fetch its row once — the fetch
            # is shared by every replica, cross-replica coalescing for free —
            # and FMA it into all br field rows, gated so idle slots cost
            # nothing (and the streamed tier skips the DMA entirely).
            u, rf = carry
            acc_k = jax.lax.dynamic_slice(acc_f, (0, k), (br, 1))  # (br, 1)
            s_old_k = jax.lax.dynamic_slice(s_win, (0, k), (br, 1))
            anyacc = jnp.sum(acc_k) > 0.0

            def do(carry):
                u, rf = carry
                row = fetch_row(w + k)                 # (1, N)
                u = u - (2.0 * acc_k * s_old_k) * row
                # Attribute the single shared fetch to the lowest-index
                # accepting replica (the coalesce_rows convention), so the
                # block sum of rf is the true unique-row traffic.
                ids = jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
                first = jnp.min(jnp.where(acc_k > 0.0, ids, br))
                return u, rf + (ids[:, 0] == first).astype(jnp.int32)

            return jax.lax.cond(anyacc, do, lambda c: c, (u, rf))

        lo = off - w
        u, rf = jax.lax.fori_loop(lo, lo + size, apply_slot, (u, rf))
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs, nf, rf)

    init = (u, s, e, e, s, jnp.zeros((br,), jnp.int32),
            jnp.zeros((br,), jnp.int32))
    u, s, e, be, bs, nf, rf = jax.lax.fori_loop(0, num_steps, step, init)
    u_out[...] = u
    s_out[...] = s.astype(s_out.dtype)
    e_out[...] = e[:, None]
    be_out[...] = be[:, None]
    bs_out[...] = bs.astype(bs_out.dtype)
    nf_out[...] = nf[:, None]
    rf_out[...] = rf[:, None]


@functools.partial(jax.jit, static_argnames=("coupling", "block_r",
                                             "interpret"))
def colored_sweep(couplings, fields0: jax.Array, spins0: jax.Array,
                  energy0: jax.Array, uniforms: jax.Array, temps: jax.Array,
                  sched: jax.Array, pwl_table: Optional[jax.Array] = None, *,
                  coupling: str = "dense", block_r: int = 8,
                  interpret: bool = False):
    """T graph-colored block-update steps for R replicas.

    The colored counterpart of :func:`mcmc_sweep`: state and coupling-store
    contracts are identical (same 7 outputs, same ``_STORE_LAYOUTS`` tiers,
    same decode, no ``dot_general``), but each step updates the whole
    scheduled color class instead of selecting one spin. Spins must already
    be in color-sorted (permuted) order — ``kernels.ops.colored_anneal``
    owns the permutation. ``uniforms`` is (T, R, S) with S the static
    lane-aligned class window; ``sched`` is (T, 3) int32 rows of
    ``(window_start, class_offset, class_size)`` per step. ``rows_fetched``
    counts each fetched coupling row once, attributed to the lowest-index
    accepting replica (the row fetch is shared across replicas — colored
    mode is coalesced by construction on every tier).
    """
    r, n = fields0.shape
    t = uniforms.shape[0]
    win = uniforms.shape[2]
    assert spins0.shape == (r, n)
    assert uniforms.shape == (t, r, win) and temps.shape == (t, r)
    assert sched.shape == (t, 3)
    coupling_store.validate_kernel_operand(coupling, couplings, n, "dynamic")
    br = common.fit_block(r, block_r)
    grid = (r // br,)
    in_specs, j_args, scratch_shapes = _STORE_LAYOUTS[coupling](
        couplings, n, br, False)
    if coupling == "bitplane_hbm":
        # The colored fetch is cond-gated (no double-buffer overlap), so only
        # the 2-slot tile scratch + semaphores of the layout are consumed.
        scratch_shapes = scratch_shapes[:3]
    in_specs = in_specs + [
        pl.BlockSpec((br, n), lambda i: (i, 0)),         # u0
        pl.BlockSpec((br, n), lambda i: (i, 0)),         # s0
        pl.BlockSpec((br, 1), lambda i: (i, 0)),         # e0
        pl.BlockSpec((t, br, win), lambda i: (0, i, 0)),  # uniforms
        pl.BlockSpec((t, br), lambda i: (0, i)),         # temps
        pl.BlockSpec((t, 3), lambda i: (0, 0)),          # class schedule
    ]
    args = j_args + [fields0, spins0, energy0.reshape(r, 1), uniforms, temps,
                     sched.astype(jnp.int32)]
    if pwl_table is not None:
        in_specs.append(pl.BlockSpec(pwl_table.shape, lambda i: (0, 0)))
        args.append(pwl_table)
    outs = pl.pallas_call(
        functools.partial(_colored_kernel, num_steps=t,
                          has_pwl=pwl_table is not None, coupling=coupling),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.float32),
            jax.ShapeDtypeStruct((r, n), spins0.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, n), spins0.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*args)
    u, s, e, be, bs, nf, rf = outs
    return u, s, e[:, 0], be[:, 0], bs, nf[:, 0], rf[:, 0]


@functools.partial(jax.jit, static_argnames=(
    "mode", "uniformized", "gather", "coupling", "block_r", "lane",
    "coalesce", "interpret"))
def mcmc_sweep(couplings, fields0: jax.Array, spins0: jax.Array,
               energy0: jax.Array, uniforms: jax.Array, temps: jax.Array,
               pwl_table: Optional[jax.Array] = None, *, mode: str = "rsa",
               uniformized: bool = False, gather: str = "dynamic",
               coupling: str = "dense", block_r: int = 8,
               lane: Optional[int] = None, coalesce: bool = True,
               interpret: bool = False):
    """T fused MCMC steps for R replicas.

    couplings: (N, N) f32 with ``coupling="dense"``, or a packed
    ``core.bitplane.BitPlanes`` of an integer J with ``coupling="bitplane"``
    (2·B bits per coupler in VMEM instead of 32 — the N≈2000 → N≈11k wall
    move) or ``coupling="bitplane_hbm"`` (planes stay in HBM, selected rows
    stream through a double-buffered VMEM scratch — the past-the-packed-wall
    tier, DESIGN.md §Backends). fields0/spins0 (R, N); energy0 (R,);
    uniforms (T, R, 4) [site, accept, roulette, uniformize] in [0,1); temps
    (T, R) per-replica temperatures; pwl_table optional (S+1, 3) LUT from
    ``core.pwl.pwl_table`` (None = exact sigmoid). ``gather``: "dynamic"
    (default, O(N)/step row fetch) or "onehot" (opt-in O(N²)/step MXU
    contraction for tiny N; dense-only). ``block_r`` clamps to the largest
    divisor of R. ``coalesce`` (default on; only the HBM-streamed tier is
    affected — VMEM-resident fetches are free) DMAs each step's *unique*
    selected rows once and broadcasts the decoded row to every replica that
    picked it (``common.coalesce_rows``) — bit-identical trajectories, up to
    br× less row traffic. Returns (fields, spins, energy, best_energy,
    best_spins, num_flips, rows_fetched) where rows_fetched is the (R,)
    int32 count of coupling-row fetches each replica block attributed to
    that replica (uncoalesced paths count one per replica per step; the
    coalesced stream attributes each unique row to the lowest-index replica
    selecting it, so the block sum is the unique-row traffic); see
    ``ref.mcmc_sweep`` for the exact-semantics oracle.
    """
    r, n = fields0.shape
    t = uniforms.shape[0]
    assert spins0.shape == (r, n)
    assert uniforms.shape == (t, r, 4) and temps.shape == (t, r)
    if gather not in ("dynamic", "onehot"):
        raise ValueError(f"gather must be 'dynamic' or 'onehot', got {gather!r}")
    coupling_store.validate_kernel_operand(coupling, couplings, n, gather)
    br = common.fit_block(r, block_r)
    lane = common.default_lane(n) if lane is None else lane
    if n % lane:
        raise ValueError(f"N={n} not divisible by lane={lane}")
    grid = (r // br,)
    # Coalescing only changes behavior where the row fetch is real data
    # movement (the registry's coalescable tiers); VMEM-resident stores keep
    # their direct per-replica reads so the flag never perturbs their layout.
    coalesce = coalesce and coupling_store.FORMATS[coupling].coalescable
    in_specs, j_args, scratch_shapes = _STORE_LAYOUTS[coupling](
        couplings, n, br, coalesce)
    in_specs = in_specs + [
        pl.BlockSpec((br, n), lambda i: (i, 0)),       # u0
        pl.BlockSpec((br, n), lambda i: (i, 0)),       # s0
        pl.BlockSpec((br, 1), lambda i: (i, 0)),       # e0
        pl.BlockSpec((t, br, 4), lambda i: (0, i, 0)),  # uniforms
        pl.BlockSpec((t, br), lambda i: (0, i)),       # temps
    ]
    args = j_args + [fields0, spins0, energy0.reshape(r, 1), uniforms, temps]
    if pwl_table is not None:
        in_specs.append(pl.BlockSpec(pwl_table.shape, lambda i: (0, 0)))
        args.append(pwl_table)
    outs = pl.pallas_call(
        functools.partial(_kernel, num_steps=t, mode=mode,
                          uniformized=uniformized, gather=gather, lane=lane,
                          has_pwl=pwl_table is not None, coupling=coupling,
                          coalesce=coalesce),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.float32),
            jax.ShapeDtypeStruct((r, n), spins0.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, n), spins0.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*args)
    u, s, e, be, bs, nf, rf = outs
    return u, s, e[:, 0], be[:, 0], bs, nf[:, 0], rf[:, 0]
