"""Pallas TPU kernel: fused multi-step dual-mode MCMC sweep.

TPU analogue of the paper's on-chip local-field memory (§IV-B2b): the FPGA
keeps u in BRAM and read-modify-writes it after every flip. A literal
one-flip-per-XLA-op loop would round-trip u, s through HBM every step; this
kernel keeps the coupling tile J, the local fields u, and the spins s resident
in VMEM across ``T`` consecutive MCMC steps, so per-step HBM traffic drops to
zero for N ≤ ~2800 (f32 J; 16 MiB VMEM) — the same "compute-bound, not
memory-bound" crossover the paper demonstrates in Fig. 14.

Asynchronous single-spin semantics are preserved exactly: each step selects at
most one spin per replica, flips it, and applies the incremental update
u ← u − 2 J[j,:] s_j_old before the next selection (Eq. 27/31).

Randomness is supplied as a precomputed (T, R, 3) tensor of uniforms from the
stateless threefry streams (site, accept, roulette) — the kernel itself stays
deterministic and replayable, mirroring the paper's stateless-RNG design.

Grid: replica blocks; J is broadcast (index_map pins block 0) so the pipeline
loads it once per program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flip_prob(de, temp):
    safe_t = jnp.where(temp > 0, temp, 1.0)
    warm = jax.nn.sigmoid(-de / safe_t)
    cold = jnp.where(de < 0, 1.0, jnp.where(de == 0, 0.5, 0.0))
    return jnp.where(temp > 0, warm, cold).astype(jnp.float32)


def _kernel(j_ref, u0_ref, s0_ref, e0_ref, unif_ref, temp_ref,
            u_out, s_out, e_out, be_out, bs_out, *, num_steps: int, mode: str):
    n = j_ref.shape[0]
    J = j_ref[...].astype(jnp.float32)  # (N, N) VMEM-resident
    u = u0_ref[...].astype(jnp.float32)  # (br, N)
    s = s0_ref[...].astype(jnp.float32)  # (br, N) ±1
    e = e0_ref[...].astype(jnp.float32)[:, 0]  # (br,)
    be = e
    bs = s

    def step(t, carry):
        u, s, e, be, bs = carry
        u01 = unif_ref[t]  # (br, 3)... sliced below
        temp = temp_ref[t, 0]
        de_all = 2.0 * s * u
        p_all = _flip_prob(de_all, temp)
        u_site = unif_ref[t, :, 0]
        u_acc = unif_ref[t, :, 1]
        u_rou = unif_ref[t, :, 2]
        if mode == "rsa":
            j = jnp.minimum((u_site * n).astype(jnp.int32), n - 1)  # (br,)
            onehot = (jax.lax.broadcasted_iota(jnp.int32, p_all.shape, 1)
                      == j[:, None]).astype(jnp.float32)
            p_j = jnp.sum(p_all * onehot, axis=1)
            accept = (u_acc < p_j).astype(jnp.float32)
        else:
            wheel = jnp.cumsum(p_all, axis=1)
            total = wheel[:, -1]
            degenerate = (total <= 0) | ~jnp.isfinite(total)
            r = u_rou * jnp.where(degenerate, 1.0, total)
            j_rw = jnp.minimum(jnp.sum((wheel <= r[:, None]).astype(jnp.int32), axis=1),
                               n - 1)
            j_fb = jnp.minimum((u_site * n).astype(jnp.int32), n - 1)
            onehot_fb = (jax.lax.broadcasted_iota(jnp.int32, p_all.shape, 1)
                         == j_fb[:, None]).astype(jnp.float32)
            p_fb = jnp.sum(p_all * onehot_fb, axis=1)
            accept_fb = u_acc < p_fb
            j = jnp.where(degenerate, j_fb, j_rw)
            accept = jnp.where(degenerate, accept_fb, True).astype(jnp.float32)
            onehot = (jax.lax.broadcasted_iota(jnp.int32, p_all.shape, 1)
                      == j[:, None]).astype(jnp.float32)
        s_old = jnp.sum(s * onehot, axis=1)  # (br,)
        de = jnp.sum(de_all * onehot, axis=1)
        # Incremental update: rows J[j] gathered via one-hot matmul (MXU-friendly,
        # avoids per-replica dynamic gathers from VMEM).
        rows = jax.lax.dot_general(onehot, J, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)  # (br, N)
        u = u - (2.0 * accept * s_old)[:, None] * rows
        s = s * (1.0 - 2.0 * accept[:, None] * onehot)
        e = e + accept * de
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs)

    u, s, e, be, bs = jax.lax.fori_loop(0, num_steps, step, (u, s, e, be, bs))
    u_out[...] = u
    s_out[...] = s.astype(s_out.dtype)
    e_out[...] = e[:, None]
    be_out[...] = be[:, None]
    bs_out[...] = bs.astype(bs_out.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "block_r", "interpret"))
def mcmc_sweep(couplings: jax.Array, fields0: jax.Array, spins0: jax.Array,
               energy0: jax.Array, uniforms: jax.Array, temps: jax.Array,
               *, mode: str = "rsa", block_r: int = 8, interpret: bool = False):
    """T fused MCMC steps for R replicas. Returns (fields, spins, energy,
    best_energy, best_spins); see ``ref.mcmc_sweep`` for exact semantics."""
    r, n = fields0.shape
    t = uniforms.shape[0]
    assert couplings.shape == (n, n) and spins0.shape == (r, n)
    assert uniforms.shape == (t, r, 3) and temps.shape == (t,)
    br = min(block_r, r)
    if r % br:
        raise ValueError(f"R={r} not divisible by block_r={br}")
    grid = (r // br,)
    outs = pl.pallas_call(
        functools.partial(_kernel, num_steps=t, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),        # J broadcast
            pl.BlockSpec((br, n), lambda i: (i, 0)),       # u0
            pl.BlockSpec((br, n), lambda i: (i, 0)),       # s0
            pl.BlockSpec((br, 1), lambda i: (i, 0)),       # e0
            pl.BlockSpec((t, br, 3), lambda i: (0, i, 0)),  # uniforms
            pl.BlockSpec((t, 1), lambda i: (0, 0)),        # temps
        ],
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.float32),
            jax.ShapeDtypeStruct((r, n), spins0.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, n), spins0.dtype),
        ],
        interpret=interpret,
    )(couplings, fields0, spins0, energy0.reshape(r, 1), uniforms,
      temps.reshape(t, 1))
    u, s, e, be, bs = outs
    return u, s, e[:, 0], be[:, 0], bs
