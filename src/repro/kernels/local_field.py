"""Pallas TPU kernel: batched local-field initialization u = s Jᵀ + h.

TPU adaptation of the paper's row-major streaming init (§IV-B2a): on an FPGA
the dense init is a popcount pipeline; on TPU the roofline-optimal engine for
a dense (R, N) × (N, N) contraction is the MXU, so the init is a tiled matmul
with f32 accumulation. Tiles are chosen MXU-aligned (multiples of 128 on the
contracting/lane dims, 8 on sublanes) and triple-buffered through VMEM by the
Pallas pipeline.

Grid: (R/br, N/bn, K/bk) with the K axis innermost ("arbitrary") so each
(br × bn) output tile accumulates in a VMEM scratch across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, j_ref, h_ref, out_ref, acc_ref, *, num_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s_blk = s_ref[...].astype(jnp.float32)  # (br, bk)
    j_blk = j_ref[...].astype(jnp.float32)  # (bn, bk) — row-block of J
    acc_ref[...] += jax.lax.dot_general(
        s_blk, j_blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == num_k - 1)
    def _done():
        out_ref[...] = acc_ref[...] + h_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_r", "block_n", "block_k", "interpret"))
def local_field_init(spins: jax.Array, couplings: jax.Array, bias: jax.Array,
                     *, block_r: int = 8, block_n: int = 256, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """u[r] = J @ s[r] + h for a replica batch. spins (R,N) ±1 (any int/float
    dtype), couplings (N,N), bias (N,). Returns (R,N) f32."""
    r, n = spins.shape
    assert couplings.shape == (n, n) and bias.shape == (n,)
    br = min(block_r, r)
    bn = min(block_n, n)
    bk = min(block_k, n)
    if r % br or n % bn or n % bk:
        raise ValueError(f"shape ({r},{n}) not divisible by blocks ({br},{bn},{bk})")
    num_k = n // bk
    grid = (r // br, n // bn, num_k)
    return pl.pallas_call(
        functools.partial(_kernel, num_k=num_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bk), lambda i, j, k: (i, k)),     # spins
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),     # J row-block
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),      # bias (2D for TPU layout)
        ],
        out_specs=pl.BlockSpec((br, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, bn), jnp.float32)],
        interpret=interpret,
    )(spins, couplings, bias.reshape(1, n))
