"""Pallas TPU kernels for Snowball's compute hot-spots.

- ``local_field``   — MXU tiled matmul init  u = J s + h      (paper §IV-B2a)
- ``bitplane_field``— VPU popcount init from packed bit-planes (paper Eq. 14-16)
- ``sweep``         — fused VMEM-resident multi-step MCMC sweep (paper §IV-B2b/3),
                      the production solver backend (DESIGN.md §Backends):
                      O(N)/step row gather, dual-mode + uniformized RWA + PWL
                      LUT parity with ``core.mcmc``, per-replica temp ladders

``ops`` holds the jit'd wrappers; ``ref`` the pure-jnp oracles; ``common``
the selection math shared by kernel and oracle (exact backend parity).
"""
from . import common, ops, ref  # noqa: F401
from .ops import bitplane_field_init, fused_anneal, local_field_init  # noqa: F401
