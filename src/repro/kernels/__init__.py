"""Pallas TPU kernels for Snowball's compute hot-spots.

- ``local_field``   — MXU tiled matmul init  u = J s + h      (paper §IV-B2a)
- ``bitplane_field``— VPU popcount init from packed bit-planes (paper Eq. 14-16)
- ``sweep``         — fused VMEM-resident multi-step MCMC sweep (paper §IV-B2b/3)

``ops`` holds the jit'd wrappers; ``ref`` the pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
from .ops import bitplane_field_init, fused_anneal, local_field_init  # noqa: F401
