"""Pallas TPU kernel: local-field init from packed signed bit-planes.

TPU-native analogue of the paper's Hamming-weight accumulator (§IV-B2a): the
FPGA's 64-bit popcount trees become `lax.population_count` on the VPU over
`uint32` lanes. For B planes the couplings cost 2·B bits each — at the paper's
B=2 that is 8× less HBM traffic than an int8 J and 16× less than f32, which
directly scales the memory-roofline term of the init (see EXPERIMENTS.md §Perf).

Layout: planes (B, N, W) uint32 packed 32 couplers/word; spin words (R, W).
Grid: (N/bn, R/br); each program produces a (br × bn) tile of u by looping
planes in-register. The plane tile (B, bn, W) streams once per N-block and is
reused across the replica axis by the pipeline (index_map ignores r).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(pos_ref, neg_ref, x_ref, out_ref, *, num_planes: int):
    x = x_ref[...]  # (br, W) uint32
    popc = jax.lax.population_count
    acc = jnp.zeros(out_ref.shape, jnp.float32)  # (br, bn)
    for b in range(num_planes):  # static unroll: B is small (≤ 16)
        pos = pos_ref[b]  # (bn, W)
        neg = neg_ref[b]
        m_p = popc(pos).astype(jnp.int32).sum(-1)  # (bn,)
        m_n = popc(neg).astype(jnp.int32).sum(-1)
        o_p = popc(pos[None, :, :] & x[:, None, :]).astype(jnp.int32).sum(-1)  # (br, bn)
        o_n = popc(neg[None, :, :] & x[:, None, :]).astype(jnp.int32).sum(-1)
        contrib = (2 * o_p - m_p[None, :]) - (2 * o_n - m_n[None, :])
        acc = acc + jnp.float32(1 << b) * contrib.astype(jnp.float32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_r", "block_n", "interpret"))
def bitplane_field_init(pos: jax.Array, neg: jax.Array, spin_words: jax.Array,
                        *, block_r: int = 8, block_n: int = 256,
                        interpret: bool = False) -> jax.Array:
    """u^(J)[r, i] from packed planes (Eq. 14-16). Returns (R, N) f32.

    ``block_r``/``block_n`` clamp to the largest divisors of R/N ≤ the
    requested sizes (BlockSpec grids need exact tiling; a non-dividing
    request falls back instead of erroring).
    """
    num_planes, n, w = pos.shape
    assert neg.shape == pos.shape
    r = spin_words.shape[0]
    assert spin_words.shape == (r, w)
    br = common.fit_block(r, block_r)
    bn = common.fit_block(n, block_n)
    grid = (n // bn, r // br)
    return pl.pallas_call(
        functools.partial(_kernel, num_planes=num_planes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((num_planes, bn, w), lambda i, j: (0, i, 0)),
            pl.BlockSpec((num_planes, bn, w), lambda i, j: (0, i, 0)),
            pl.BlockSpec((br, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, bn), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(pos, neg, spin_words)
