"""Jit'd public wrappers around the Pallas kernels.

``fused_anneal`` is the *optimized* solver backend (beyond-paper, DESIGN.md §2):
it runs the annealing loop in chunks of the VMEM-resident sweep kernel, with
uniforms drawn from the same stateless threefry streams as the reference
engine. ``repro.core.solver.solve`` remains the paper-faithful baseline; both
are benchmarked side by side in EXPERIMENTS.md §Perf.

On this CPU container kernels run in interpret mode (the Mosaic TPU backend is
the target); ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import ising, rng
from ..core.bitplane import BitPlanes, pack_spins
from ..core.solver import SolverConfig, SolveResult
from . import bitplane_field as _bitplane_field
from . import local_field as _local_field
from . import sweep as _sweep


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _fit_block(n: int, target: int) -> int:
    """Largest divisor of n that is ≤ target (BlockSpec grids need exact tiling)."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def local_field_init(spins: jax.Array, couplings: jax.Array, bias: jax.Array,
                     *, interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Batched u = J s + h via the MXU matmul kernel."""
    r, n = spins.shape
    kw.setdefault("block_r", _fit_block(r, 8))
    kw.setdefault("block_n", _fit_block(n, 256))
    kw.setdefault("block_k", _fit_block(n, 512))
    return _local_field.local_field_init(
        spins, couplings, bias, interpret=_auto_interpret(interpret), **kw)


def bitplane_field_init(planes: BitPlanes, spins: jax.Array,
                        *, interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Batched u^(J) from packed bit-planes via the popcount kernel."""
    words = pack_spins(spins)
    return _bitplane_field.bitplane_field_init(
        planes.pos, planes.neg, words, interpret=_auto_interpret(interpret), **kw)


@partial(jax.jit, static_argnames=("config", "chunk_steps", "block_r", "interpret"))
def _fused_anneal_impl(problem: ising.IsingProblem, seed: jax.Array,
                       config: SolverConfig, chunk_steps: int, block_r: int,
                       interpret: bool) -> SolveResult:
    n = problem.num_spins
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0), seed)
    replica_keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(jnp.arange(r))
    spins0 = jax.vmap(lambda k: ising.random_spins(rng.stream(k, rng.Salt.INIT), (n,)))(replica_keys)
    spins0 = spins0.astype(jnp.float32)
    u0 = local_field_init(spins0, problem.couplings, problem.fields,
                          interpret=interpret, block_r=_fit_block(r, block_r))
    e0 = ising.energy(problem, spins0)

    num_chunks = max(config.num_steps // chunk_steps, 1)

    def chunk(carry, c):
        u, s, e, be, bs = carry
        ck = rng.stream(base, rng.Salt.ROULETTE, c)
        uniforms = rng.uniform01(ck, (chunk_steps, r, 3))
        steps = c * chunk_steps + jnp.arange(chunk_steps)
        temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
        u, s, e, ce, cs = _sweep.mcmc_sweep(
            problem.couplings, u, s, e, uniforms, temps,
            mode=config.mode, block_r=min(block_r, r), interpret=interpret)
        better = ce < be
        be = jnp.where(better, ce, be)
        bs = jnp.where(better[:, None], cs, bs)
        return (u, s, e, be, bs), be

    init = (u0, spins0, e0, e0, spins0)
    (u, s, e, be, bs), trace = jax.lax.scan(chunk, init, jnp.arange(num_chunks))
    return SolveResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(jnp.int8),
        final_energy=e + problem.offset,
        num_flips=jnp.zeros((r,), jnp.int32),  # not tracked by the fused path
        trace_energy=(trace + problem.offset) if config.trace_every else jnp.zeros((0, r)),
    )


def fused_anneal(problem: ising.IsingProblem, seed, config: SolverConfig,
                 *, chunk_steps: int = 256, block_r: int = 8,
                 interpret: Optional[bool] = None) -> SolveResult:
    """Optimized annealing driver on the fused sweep kernel.

    Matches ``core.solver.solve`` semantics (same modes, schedule, TTS usage)
    up to RNG stream layout; the exact flip-probability (not the PWL) is used
    inside the kernel. Fallback path for degenerate W follows Alg. 1.
    """
    if config.uniformized:
        raise NotImplementedError("fused path implements plain RSA/RWA (paper's default)")
    return _fused_anneal_impl(problem, jnp.asarray(seed, jnp.uint32), config,
                              chunk_steps, block_r, _auto_interpret(interpret))
