"""Jit'd public wrappers around the Pallas kernels.

``fused_anneal`` is the *production* solver backend (DESIGN.md §Backends): it
runs the annealing loop in chunks of the VMEM-resident sweep kernel, with
uniforms drawn from the dedicated ``Salt.SWEEP`` stateless threefry stream
(disjoint by construction from every stream the reference engine consumes).
``repro.core.solver.solve`` with ``backend="reference"`` remains the
paper-faithful oracle; ``backend="fused"`` routes through this module. Both
are benchmarked side by side in ``BENCH_solver_perf.json``.

On this CPU container kernels run in interpret mode (the Mosaic TPU backend is
the target); ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import ising, rng
from ..core.bitplane import BitPlanes, pack_spins
from ..core.pwl import pwl_table as _pwl_table
from ..core.solver import SolverConfig, SolveResult
from . import bitplane_field as _bitplane_field
from . import local_field as _local_field
from . import sweep as _sweep

#: N at or below which the one-hot MXU row gather beats per-replica dynamic
#: slices (one small matmul vs br sequential row DMAs) — the opt-in heuristic
#: resolved by ``gather="auto"``.
ONEHOT_GATHER_MAX_N = 128


def auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def fit_block(n: int, target: int) -> int:
    """Largest divisor of n that is ≤ target (BlockSpec grids need exact tiling)."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def local_field_init(spins: jax.Array, couplings: jax.Array, bias: jax.Array,
                     *, interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Batched u = J s + h via the MXU matmul kernel."""
    r, n = spins.shape
    kw.setdefault("block_r", fit_block(r, 8))
    kw.setdefault("block_n", fit_block(n, 256))
    kw.setdefault("block_k", fit_block(n, 512))
    return _local_field.local_field_init(
        spins, couplings, bias, interpret=auto_interpret(interpret), **kw)


def bitplane_field_init(planes: BitPlanes, spins: jax.Array,
                        *, interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Batched u^(J) from packed bit-planes via the popcount kernel."""
    words = pack_spins(spins)
    return _bitplane_field.bitplane_field_init(
        planes.pos, planes.neg, words, interpret=auto_interpret(interpret), **kw)


def _resolve_gather(gather: str, n: int) -> str:
    if gather == "auto":
        return "onehot" if n <= ONEHOT_GATHER_MAX_N else "dynamic"
    return gather


def init_fields(problem: ising.IsingProblem, spins0: jax.Array, *,
                interpret: bool, block_r: int = 8) -> jax.Array:
    """One-time u₀ = J s + h init for the fused drivers. The tiled Pallas MXU
    kernel only wins on real TPUs; interpret mode emulates it tile-by-tile at
    a huge constant factor, so there the init goes through XLA's native
    matmul instead."""
    if interpret:
        return ising.local_fields(problem, spins0).astype(jnp.float32)
    r = spins0.shape[0]
    return local_field_init(spins0, problem.couplings, problem.fields,
                            interpret=False, block_r=fit_block(r, block_r))


def fused_init_state(problem: ising.IsingProblem, base: jax.Array, r: int, *,
                     interpret: bool, block_r: int = 8):
    """Replica init for the fused drivers: the ``(u, s, e, best_e, best_s,
    num_flips)`` state tuple. Key derivation (``Salt.REPLICA`` → ``Salt.INIT``)
    is exactly the reference engine's, so both backends start every replica
    from the identical spin configuration — a single definition keeps that
    parity contract in one place."""
    n = problem.num_spins
    replica_keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(jnp.arange(r))
    spins0 = jax.vmap(lambda k: ising.random_spins(
        rng.stream(k, rng.Salt.INIT), (n,)))(replica_keys)
    spins0 = spins0.astype(jnp.float32)
    u0 = init_fields(problem, spins0, interpret=interpret, block_r=block_r)
    e0 = ising.energy(problem, spins0)
    return (u0, spins0, e0, e0, spins0, jnp.zeros((r,), jnp.int32))


def solver_pwl_table(config: SolverConfig) -> Optional[jax.Array]:
    """The (S+1, 3) VMEM LUT for ``config``, or None for the exact sigmoid."""
    if not config.use_pwl:
        return None
    return _pwl_table(config.pwl_segments, config.pwl_zmax)


def fused_sweep_chunk(couplings: jax.Array, state, chunk_key: jax.Array,
                      num_steps: int, temps: jax.Array, *, mode: str,
                      uniformized: bool = False,
                      pwl_table: Optional[jax.Array] = None,
                      gather: str = "dynamic", block_r: int = 8,
                      interpret: bool = False):
    """One fused sweep chunk + best-so-far merge — the single chunk driver
    shared by ``fused_anneal``, fused tempering, and the fused distributed
    runner, so kernel-signature changes happen in exactly one place.

    ``state`` is the 6-tuple ``(u, s, e, best_e, best_s, num_flips)`` with a
    leading replica axis; ``chunk_key`` is the chunk's ``Salt.SWEEP`` stream;
    ``temps`` is the (num_steps, R) per-replica temperature tensor. Returns
    the updated state tuple.
    """
    u, s, e, be, bs, nf = state
    r = e.shape[0]
    uniforms = rng.uniform01(chunk_key, (num_steps, r, 4))
    u, s, e, ce, cs, cf = _sweep.mcmc_sweep(
        couplings, u, s, e, uniforms, temps, pwl_table, mode=mode,
        uniformized=uniformized, gather=gather, block_r=block_r,
        interpret=interpret)
    better = ce < be
    return (u, s, e, jnp.where(better, ce, be),
            jnp.where(better[:, None], cs, bs), nf + cf)


@partial(jax.jit, static_argnames=("config", "chunk_steps", "block_r",
                                   "gather", "interpret"))
def _fused_anneal_impl(problem: ising.IsingProblem, seed: jax.Array,
                       config: SolverConfig, chunk_steps: int, block_r: int,
                       gather: str, interpret: bool) -> SolveResult:
    n = problem.num_spins
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0), seed)
    init = fused_init_state(problem, base, r, interpret=interpret,
                            block_r=block_r)
    tbl = solver_pwl_table(config)
    gather = _resolve_gather(gather, n)

    # Trace cadence is identical to the reference backend: with tracing on,
    # kernel chunks are exactly ``trace_every`` steps and the trace records
    # best-so-far energy at every chunk end (both backends then run
    # num_chunks·trace_every steps); ``chunk_steps`` is only the perf knob
    # for untraced runs, where a remainder sweep keeps the total at exactly
    # ``num_steps`` like the reference scan.
    if config.trace_every:
        chunk_len = config.trace_every
        num_chunks = max(config.num_steps // chunk_len, 1)
        rem_steps = 0
    else:
        chunk_len = max(min(chunk_steps, config.num_steps), 1)
        num_chunks = config.num_steps // chunk_len
        rem_steps = config.num_steps - num_chunks * chunk_len

    def chunk(carry, c, clen):
        steps = c * chunk_len + jnp.arange(clen)
        temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
        temps = jnp.broadcast_to(temps[:, None], (clen, r))
        state = fused_sweep_chunk(
            problem.couplings, carry, rng.stream(base, rng.Salt.SWEEP, c),
            clen, temps, mode=config.mode, uniformized=config.uniformized,
            pwl_table=tbl, gather=gather, block_r=fit_block(r, block_r),
            interpret=interpret)
        return state, state[3]  # best-so-far energy at chunk end

    (u, s, e, be, bs, nf), trace = jax.lax.scan(
        partial(chunk, clen=chunk_len), init, jnp.arange(num_chunks))
    if rem_steps:
        (u, s, e, be, bs, nf), _ = chunk((u, s, e, be, bs, nf),
                                         jnp.int32(num_chunks), clen=rem_steps)
    return SolveResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(jnp.int8),
        final_energy=e + problem.offset,
        num_flips=nf,
        trace_energy=((trace + problem.offset).astype(jnp.float32)
                      if config.trace_every else jnp.zeros((0, r), jnp.float32)),
    )


def fused_anneal(problem: ising.IsingProblem, seed, config: SolverConfig,
                 *, chunk_steps: int = 256, block_r: int = 8,
                 gather: str = "dynamic",
                 interpret: Optional[bool] = None) -> SolveResult:
    """Production annealing driver on the fused sweep kernel.

    Full ``core.solver.solve`` feature parity — both modes, uniformized RWA,
    PWL LUT vs exact flip probability, ``num_flips``, and reference-identical
    trace shape/dtype/cadence — up to RNG stream layout (the fused path draws
    its chunk uniforms from the dedicated ``Salt.SWEEP`` stream). ``gather``
    is "dynamic" (O(N)/step), "onehot" (O(N²)/step MXU contraction), or
    "auto" (onehot only for N ≤ ONEHOT_GATHER_MAX_N, i.e. 128).
    """
    return _fused_anneal_impl(problem, jnp.asarray(seed, jnp.uint32), config,
                              chunk_steps, block_r, gather,
                              auto_interpret(interpret))
