"""Jit'd public wrappers around the Pallas kernels.

``fused_anneal`` is the *production* solver backend (DESIGN.md §Backends): it
runs the annealing loop in chunks of the VMEM-resident sweep kernel, with
uniforms drawn from the dedicated ``Salt.SWEEP`` stateless threefry stream
(disjoint by construction from every stream the reference engine consumes).
``repro.core.solver.solve`` with ``backend="reference"`` remains the
paper-faithful oracle; ``backend="fused"`` routes through this module. Both
are benchmarked side by side in ``BENCH_solver_perf.json``.

On this CPU container kernels run in interpret mode (the Mosaic TPU backend is
the target); ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ..core import ising, rng
from ..core.bitplane import BitPlanes, local_fields_from_planes, pack_spins
# The coupling-store subsystem (format registry, resolve/encode, the VMEM/HBM
# wall constants) is first-class in ``core.coupling``; this module re-exports
# the long-standing names so kernel-level callers keep working.
from ..core.coupling import (  # noqa: F401  (re-exported API)
    BITPLANE_VMEM_MAX_N, COUPLING_FORMATS, DENSE_COUPLING_BITS,
    DENSE_COUPLING_MAX_N, KERNEL_COUPLING_MODES, PLANE_FORMATS,
    STREAM_ALIGN_WORDS, CouplingStore)
from ..core.coupling import encode_planes as encode_for_sweep  # noqa: F401
from ..core.coupling import resolve_format as resolve_coupling_format  # noqa: F401
from ..core.pwl import pwl_table as _pwl_table
from ..core.solver import SolverConfig, SolveResult
from . import bitplane_field as _bitplane_field
from . import local_field as _local_field
from . import sweep as _sweep
from .common import fit_block  # noqa: F401  (canonical home is kernels.common)

#: N at or below which the one-hot MXU row gather beats per-replica dynamic
#: slices (one small matmul vs br sequential row DMAs) — the opt-in heuristic
#: resolved by ``gather="auto"``.
ONEHOT_GATHER_MAX_N = 128


def auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def local_field_init(spins: jax.Array, couplings: jax.Array, bias: jax.Array,
                     *, interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Batched u = J s + h via the MXU matmul kernel."""
    r, n = spins.shape
    kw.setdefault("block_r", fit_block(r, 8))
    kw.setdefault("block_n", fit_block(n, 256))
    kw.setdefault("block_k", fit_block(n, 512))
    return _local_field.local_field_init(
        spins, couplings, bias, interpret=auto_interpret(interpret), **kw)


def bitplane_field_init(planes: BitPlanes, spins: jax.Array,
                        *, interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Batched u^(J) from packed bit-planes via the popcount kernel.

    Spin words are packed to the planes' word count so tile-padded (HBM-
    streamed) plane stores line up — padding words are zero on both sides.
    """
    words = pack_spins(spins, planes.num_words)
    return _bitplane_field.bitplane_field_init(
        planes.pos, planes.neg, words, interpret=auto_interpret(interpret), **kw)


def _resolve_gather(gather: str, n: int) -> str:
    if gather == "auto":
        return "onehot" if n <= ONEHOT_GATHER_MAX_N else "dynamic"
    return gather


def plane_local_fields(planes: BitPlanes, spins0: jax.Array, *,
                       interpret: bool, block_r: int = 8) -> jax.Array:
    """u^(J) = J s from the packed planes via the Hamming-weight accumulation
    (Eq. 14-16) — the popcount Pallas kernel on real TPUs, its jnp oracle in
    interpret mode (tile-by-tile interpret emulation has a huge constant
    factor; same reason the dense init uses XLA's native matmul there). For
    integer J both are the exact integer result in f32, so everything built
    on this value (u₀, the plane-native e₀) is bit-identical to the dense
    matmul path."""
    if interpret:
        return local_fields_from_planes(planes, spins0)
    r, n = spins0.shape
    return bitplane_field_init(planes, spins0, interpret=False,
                               block_r=fit_block(r, block_r),
                               block_n=fit_block(n, 256))


def init_fields(problem: ising.IsingProblem, spins0: jax.Array, *,
                interpret: bool, block_r: int = 8,
                planes: Optional[BitPlanes] = None) -> jax.Array:
    """One-time u₀ = J s + h init for the fused drivers (plane-backed or
    dense; see :func:`plane_local_fields` for the packed path)."""
    if planes is not None:
        u_j = plane_local_fields(planes, spins0, interpret=interpret,
                                 block_r=block_r)
        return (u_j + problem.fields[None, :]).astype(jnp.float32)
    if interpret:
        return ising.local_fields(problem, spins0).astype(jnp.float32)
    r = spins0.shape[0]
    return local_field_init(spins0, problem.couplings, problem.fields,
                            interpret=False, block_r=fit_block(r, block_r))


def fused_init_state(problem: ising.IsingProblem, base: jax.Array, r: int, *,
                     interpret: bool, block_r: int = 8,
                     planes: Optional[BitPlanes] = None):
    """Replica init for the fused drivers: the ``(u, s, e, best_e, best_s,
    num_flips)`` state tuple. Key derivation (``Salt.REPLICA`` → ``Salt.INIT``)
    is exactly the reference engine's, so both backends start every replica
    from the identical spin configuration — a single definition keeps that
    parity contract in one place.

    With ``planes`` the init is fully **dense-J-free**: u₀ comes from the
    packed store and e₀ is assembled by ``ising.energy_from_fields`` on the
    same u^(J) — the identical einsum contractions ``ising.energy`` runs on
    ``J s``, fed a bit-identical u^(J) (integer J ⇒ the Hamming-weight sum
    equals the f32 matmul exactly), so plane-fed and dense-fed replicas
    start from bitwise-equal energies for any h. Edge-list problems
    (``problem.couplings is None``) therefore never touch a dense matrix
    here.
    """
    n = problem.num_spins
    replica_keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(jnp.arange(r))
    spins0 = jax.vmap(lambda k: ising.random_spins(
        rng.stream(k, rng.Salt.INIT), (n,)))(replica_keys)
    spins0 = spins0.astype(jnp.float32)
    if planes is not None:
        u_j = plane_local_fields(planes, spins0, interpret=interpret,
                                 block_r=block_r)
        u0 = (u_j + problem.fields[None, :]).astype(jnp.float32)
        e0 = ising.energy_from_fields(u_j, spins0, problem.fields)
    else:
        u0 = init_fields(problem, spins0, interpret=interpret, block_r=block_r)
        e0 = ising.energy(problem, spins0)
    return (u0, spins0, e0, e0, spins0, jnp.zeros((r,), jnp.int32))


def solver_pwl_table(config: SolverConfig) -> Optional[jax.Array]:
    """The (S+1, 3) VMEM LUT for ``config``, or None for the exact sigmoid."""
    if not config.use_pwl:
        return None
    return _pwl_table(config.pwl_segments, config.pwl_zmax)


def fused_sweep_chunk(couplings: Union[jax.Array, BitPlanes], state,
                      chunk_key: jax.Array, num_steps: int, temps: jax.Array,
                      *, mode: str, uniformized: bool = False,
                      pwl_table: Optional[jax.Array] = None,
                      gather: str = "dynamic", block_r: int = 8,
                      coupling: Optional[str] = None, coalesce: bool = True,
                      with_rows_fetched: bool = False,
                      interpret: bool = False):
    """One fused sweep chunk + best-so-far merge — the single chunk driver
    shared by ``fused_anneal``, fused tempering, and the fused distributed
    runner, so kernel-signature changes happen in exactly one place.

    ``couplings`` is the dense (N, N) J or a packed ``BitPlanes``.
    ``coupling`` selects the kernel's J store ("dense" | "bitplane" |
    "bitplane_hbm"); None infers from the type — a ``BitPlanes`` defaults to
    the VMEM-resident "bitplane" path, so the HBM-streamed tier must be
    requested explicitly (the drivers pass their resolved format through).
    ``state`` is the 6-tuple ``(u, s, e, best_e, best_s, num_flips)`` with a
    leading replica axis; ``chunk_key`` is the chunk's ``Salt.SWEEP`` stream;
    ``temps`` is the (num_steps, R) per-replica temperature tensor.
    ``coalesce`` flows to the kernel's reuse-aware unique-row fetch (only the
    HBM-streamed tier reacts; trajectories are bit-identical either way).
    Returns the updated state tuple — the 6-tuple is the snapshot/resume
    contract, so the kernel's rows-fetched counter is only surfaced when
    ``with_rows_fetched`` asks for it, as a second ``(state, rf)`` element.
    """
    u, s, e, be, bs, nf = state
    r = e.shape[0]
    if coupling is None:
        coupling = "bitplane" if isinstance(couplings, BitPlanes) else "dense"
    uniforms = rng.uniform01(chunk_key, (num_steps, r, 4))
    u, s, e, ce, cs, cf, rf = _sweep.mcmc_sweep(
        couplings, u, s, e, uniforms, temps, pwl_table, mode=mode,
        uniformized=uniformized, gather=gather, coupling=coupling,
        block_r=block_r, coalesce=coalesce, interpret=interpret)
    better = ce < be
    state = (u, s, e, jnp.where(better, ce, be),
             jnp.where(better[:, None], cs, bs), nf + cf)
    return (state, rf) if with_rows_fetched else state


def anneal_chunk_plan(config: SolverConfig, chunk_steps: int):
    """(chunk_len, num_chunks, rem_steps) for a fused-trajectory anneal.

    Trace cadence is identical to the reference backend: with tracing on,
    chunks are exactly ``trace_every`` steps and the trace records
    best-so-far energy at every chunk end (both backends then run
    num_chunks·trace_every steps); ``chunk_steps`` is only the perf knob
    for untraced runs, where a remainder sweep keeps the total at exactly
    ``num_steps`` like the reference scan. Shared by the Pallas anneal and
    the spin-sharded anneal — identical chunking (hence identical per-chunk
    ``Salt.SWEEP`` streams) is a precondition for their exact parity.
    """
    if config.trace_every:
        chunk_len = config.trace_every
        num_chunks = max(config.num_steps // chunk_len, 1)
        rem_steps = 0
    else:
        chunk_len = max(min(chunk_steps, config.num_steps), 1)
        num_chunks = config.num_steps // chunk_len
        rem_steps = config.num_steps - num_chunks * chunk_len
    return chunk_len, num_chunks, rem_steps


def anneal_gather(store: CouplingStore, gather: str, n: int) -> str:
    """Resolve the row-fetch strategy for a resolved store: plane tiers take
    the O(N) dynamic fetch ("auto"/"dynamic" — an explicit "onehot" flows
    through so the kernel raises its dense-only error rather than being
    silently overridden), the dense tier applies the N-crossover heuristic.
    Shared by ``_fused_anneal_impl`` and the resilient chunked driver so both
    feed the kernel identically."""
    if store.planes is not None:
        return gather if gather == "onehot" else "dynamic"
    return _resolve_gather(gather, n)


def anneal_chunk_step(store: CouplingStore, state, base: jax.Array,
                      c: jax.Array, *, clen: int, chunk_len: int,
                      config: SolverConfig, gather: str, block_r: int,
                      interpret: bool, with_rows_fetched: bool = False):
    """One annealing chunk of the fused trajectory: the temps tensor for
    global steps ``[c·chunk_len, c·chunk_len + clen)``, the chunk's
    ``Salt.SWEEP`` stream, and the sweep+merge of :func:`fused_sweep_chunk`.
    This is the single chunk body under ``_fused_anneal_impl``'s scan AND the
    resilient supervisor's per-chunk jit (``core.resilience``) — one
    definition is what makes the resumed trajectory bit-identical to the
    uninterrupted scan. ``with_rows_fetched`` surfaces the kernel's
    rows-fetched counter as a second return (the resilient path keeps the
    bare 6-tuple — its snapshot contract)."""
    r = config.num_replicas
    steps = c * chunk_len + jnp.arange(clen)
    temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
    temps = jnp.broadcast_to(temps[:, None], (clen, r))
    return fused_sweep_chunk(
        store.kernel_operand, state, rng.stream(base, rng.Salt.SWEEP, c),
        clen, temps, mode=config.mode, uniformized=config.uniformized,
        pwl_table=solver_pwl_table(config), gather=gather,
        block_r=fit_block(r, block_r), coupling=store.fmt,
        with_rows_fetched=with_rows_fetched, interpret=interpret)


@partial(jax.jit, static_argnames=("config", "chunk_steps", "block_r",
                                   "gather", "interpret"))
def _fused_anneal_impl(problem: ising.IsingProblem, seed: jax.Array,
                       config: SolverConfig, chunk_steps: int, block_r: int,
                       gather: str, interpret: bool,
                       store: CouplingStore) -> SolveResult:
    n = problem.num_spins
    r = config.num_replicas
    planes = store.planes
    base = jax.random.fold_in(jax.random.key(0), seed)
    init = fused_init_state(problem, base, r, interpret=interpret,
                            block_r=block_r, planes=planes)
    gather = anneal_gather(store, gather, n)

    chunk_len, num_chunks, rem_steps = anneal_chunk_plan(config, chunk_steps)

    def chunk(carry, c, clen):
        state, rows = carry
        state, rf = anneal_chunk_step(store, state, base, c, clen=clen,
                                      chunk_len=chunk_len, config=config,
                                      gather=gather, block_r=block_r,
                                      interpret=interpret,
                                      with_rows_fetched=True)
        return (state, rows + rf), state[3]  # best-so-far energy at chunk end

    init = (init, jnp.zeros((r,), jnp.int32))
    ((u, s, e, be, bs, nf), rows), trace = jax.lax.scan(
        partial(chunk, clen=chunk_len), init, jnp.arange(num_chunks))
    if rem_steps:
        ((u, s, e, be, bs, nf), rows), _ = chunk(
            ((u, s, e, be, bs, nf), rows), jnp.int32(num_chunks),
            clen=rem_steps)
    return SolveResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(jnp.int8),
        final_energy=e + problem.offset,
        num_flips=nf,
        trace_energy=((trace + problem.offset).astype(jnp.float32)
                      if config.trace_every else jnp.zeros((0, r), jnp.float32)),
        rows_fetched=rows,
    )


class ColoredPlan:
    """Host-side execution plan for the colored sweep: the coloring, the
    color-permuted problem, and the static window math the kernel schedule is
    built from. Built once per (problem, format) by :func:`colored_plan`;
    the permuted spin order is ``coloring.perm`` and results map back through
    ``coloring.inverse_perm``.

    Window math: with ``lane = common.default_lane(n)`` the static class
    window is ``S = min(n, roundup(max_class_size + lane - 1, lane))`` and
    class c starts its window at ``w_c = min((offsets[c] // lane)·lane,
    n - S)``. Coverage: ``w_c ≤ offsets[c]`` (floor) and ``w_c + S ≥
    offsets[c] - (lane-1) + (size_c + lane - 1) = offsets[c] + size_c``, so
    every class fits its lane-aligned window.
    """

    def __init__(self, coloring, problem: ising.IsingProblem, fmt,
                 num_planes: Optional[int] = None):
        from .common import default_lane

        n = problem.num_spins
        self.coloring = coloring
        perm = coloring.perm
        inv = coloring.inverse_perm
        if problem.edges is not None:
            pedges = ising.EdgeList.create(
                inv[problem.edges.rows], inv[problem.edges.cols],
                problem.edges.weights, n)
            self.problem = ising.IsingProblem.create_sparse(
                pedges, h=problem.fields[jnp.asarray(perm)],
                offset=problem.offset)
        else:
            p = jnp.asarray(perm)
            self.problem = ising.IsingProblem.create(
                problem.couplings[p][:, p], h=problem.fields[p],
                offset=problem.offset, check=False)
        self.store = CouplingStore.build(self.problem.coupling_source, fmt,
                                         num_planes=num_planes)
        self.store.require(KERNEL_COUPLING_MODES, "colored_anneal")
        lane = default_lane(n)
        import numpy as _np

        max_class = coloring.max_class_size
        self.window = min(n, -(-(max_class + lane - 1) // lane) * lane)
        offs = coloring.offsets[:-1]
        w = _np.minimum((offs // lane) * lane, n - self.window)
        self.wstarts = jnp.asarray(w, jnp.int32)
        self.offsets = jnp.asarray(offs, jnp.int32)
        self.sizes = jnp.asarray(coloring.class_sizes, jnp.int32)

    # Registered as a pytree (coloring + static window in aux — Coloring is
    # content-hashed, so jit caches key on coloring identity) so the jitted
    # anneal impl takes the plan whole.
    def tree_flatten(self):
        return ((self.problem, self.store, self.wstarts, self.offsets,
                 self.sizes), (self.coloring, self.window))

    @classmethod
    def tree_unflatten(cls, aux, children):
        plan = cls.__new__(cls)
        (plan.problem, plan.store, plan.wstarts, plan.offsets,
         plan.sizes) = children
        plan.coloring, plan.window = aux
        return plan


jax.tree_util.register_pytree_node_class(ColoredPlan)


def colored_plan(problem: ising.IsingProblem, fmt: str = "auto",
                 num_planes: Optional[int] = None) -> ColoredPlan:
    """Coloring + permutation + store for a colored solve of ``problem``.

    The greedy coloring runs on the conflict graph of
    ``problem.coupling_source`` (memoized per edge-list digest), the problem
    and its coupling store are rebuilt in color-sorted spin order (classes
    contiguous — the kernel schedules one contiguous window per step), and
    the lane-aligned window schedule is precomputed. Dense-J-free for
    edge-list problems end to end: coloring is O(N + nnz) over the COO
    edges and the permuted store runs the O(nnz) sparse encoder.
    """
    from ..graphs.coloring import greedy_coloring

    return ColoredPlan(greedy_coloring(problem.coupling_source), problem, fmt,
                       num_planes=num_planes)


def colored_sweep_chunk(couplings, state, chunk_key: jax.Array,
                        num_steps: int, temps: jax.Array, sched: jax.Array, *,
                        window: int, pwl_table: Optional[jax.Array] = None,
                        block_r: int = 8, coupling: str = "dense",
                        with_rows_fetched: bool = False,
                        interpret: bool = False):
    """One colored sweep chunk + best-so-far merge — the colored counterpart
    of :func:`fused_sweep_chunk`, with the identical 6-tuple state contract
    (snapshot/resume) and per-chunk ``Salt.SWEEP`` uniform stream. The chunk
    draws ``(num_steps, R, window)`` accept uniforms (one per window slot —
    the colored analogue of the single-flip path's 4 streams/step); ``sched``
    is the (num_steps, 3) class schedule from the plan arrays."""
    u, s, e, be, bs, nf = state
    r = e.shape[0]
    uniforms = rng.uniform01(chunk_key, (num_steps, r, window))
    u, s, e, ce, cs, cf, rf = _sweep.colored_sweep(
        couplings, u, s, e, uniforms, temps, sched, pwl_table,
        coupling=coupling, block_r=block_r, interpret=interpret)
    better = ce < be
    state = (u, s, e, jnp.where(better, ce, be),
             jnp.where(better[:, None], cs, bs), nf + cf)
    return (state, rf) if with_rows_fetched else state


def colored_class_schedule(wstarts: jax.Array, offsets: jax.Array,
                           sizes: jax.Array, steps: jax.Array) -> jax.Array:
    """(T, 3) int32 kernel schedule for absolute step indices ``steps``:
    round-robin over the χ color classes keyed on the *global* step, so a
    chunked/resumed trajectory visits the identical class sequence as one
    monolithic run (the colored leg of the resume-parity contract)."""
    cls = (steps % wstarts.shape[0]).astype(jnp.int32)
    return jnp.stack([jnp.take(wstarts, cls), jnp.take(offsets, cls),
                      jnp.take(sizes, cls)], axis=1)


def colored_chunk_step(plan: ColoredPlan, state, base: jax.Array,
                       c: jax.Array, *, clen: int, chunk_len: int,
                       config: SolverConfig, block_r: int, interpret: bool,
                       with_rows_fetched: bool = False):
    """One annealing chunk of the colored trajectory — the single chunk body
    under ``_colored_anneal_impl``'s scan AND the resilient supervisor's
    per-chunk jit, mirroring :func:`anneal_chunk_step` (same temps tensor,
    same per-chunk ``Salt.SWEEP`` stream), so chunked resume is bit-identical
    to the uninterrupted scan."""
    r = config.num_replicas
    steps = c * chunk_len + jnp.arange(clen)
    temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
    temps = jnp.broadcast_to(temps[:, None], (clen, r))
    sched = colored_class_schedule(plan.wstarts, plan.offsets, plan.sizes,
                                   steps)
    return colored_sweep_chunk(
        plan.store.kernel_operand, state,
        rng.stream(base, rng.Salt.SWEEP, c), clen, temps, sched,
        window=plan.window, pwl_table=solver_pwl_table(config),
        block_r=fit_block(r, block_r), coupling=plan.store.fmt,
        with_rows_fetched=with_rows_fetched, interpret=interpret)


@partial(jax.jit, static_argnames=("config", "chunk_steps", "block_r",
                                   "interpret"))
def _colored_anneal_run(plan: ColoredPlan, seed: jax.Array,
                        config: SolverConfig, chunk_steps: int, block_r: int,
                        interpret: bool) -> SolveResult:
    problem = plan.problem
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0), seed)
    init = fused_init_state(problem, base, r, interpret=interpret,
                            block_r=block_r, planes=plan.store.planes)
    chunk_len, num_chunks, rem_steps = anneal_chunk_plan(config, chunk_steps)

    def chunk(carry, c, clen):
        state, rows = carry
        state, rf = colored_chunk_step(plan, state, base, c, clen=clen,
                                       chunk_len=chunk_len, config=config,
                                       block_r=block_r, interpret=interpret,
                                       with_rows_fetched=True)
        return (state, rows + rf), state[3]

    init = (init, jnp.zeros((r,), jnp.int32))
    ((u, s, e, be, bs, nf), rows), trace = jax.lax.scan(
        partial(chunk, clen=chunk_len), init, jnp.arange(num_chunks))
    if rem_steps:
        ((u, s, e, be, bs, nf), rows), _ = chunk(
            ((u, s, e, be, bs, nf), rows), jnp.int32(num_chunks),
            clen=rem_steps)
    return SolveResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(jnp.int8),
        final_energy=e + problem.offset,
        num_flips=nf,
        trace_energy=((trace + problem.offset).astype(jnp.float32)
                      if config.trace_every else jnp.zeros((0, r), jnp.float32)),
        rows_fetched=rows,
    )


def unpermute_spins(plan: ColoredPlan, spins: jax.Array) -> jax.Array:
    """Map (..., N) permuted-order spins back to original vertex order
    (``s_orig[..., i] = s_perm[..., inverse_perm[i]]``)."""
    return spins[..., jnp.asarray(plan.coloring.inverse_perm)]


def colored_anneal(problem: ising.IsingProblem, seed, config: SolverConfig,
                   *, chunk_steps: int = 256, block_r: int = 8,
                   coupling: Optional[str] = None,
                   num_planes: Optional[int] = None,
                   interpret: Optional[bool] = None,
                   plan: Optional[ColoredPlan] = None) -> SolveResult:
    """Graph-colored annealing driver (``SolverConfig(flip_mode="colored")``).

    Flips one conflict-graph color class per step — every class member takes
    an independent heat-bath flip off the live local fields, exact block
    Gibbs because same-color spins share no coupling — so sparse instances
    do O(N/χ) flips per kernel step instead of 1 (ROADMAP item 3, DESIGN.md
    §Graph-colored parallel flips). The selection-mode knobs
    (``config.mode``/``uniformized``) do not enter colored semantics; PWL vs
    exact flip probability, the schedule, trace cadence, ``num_flips`` and
    ``rows_fetched`` telemetry all behave as in :func:`fused_anneal`.

    ``plan`` takes a prebuilt :func:`colored_plan` so repeated solves of one
    instance (TTS sweeps, benchmarks) skip the coloring + permutation +
    store encode; ``coupling`` overrides ``config.coupling_format`` when no
    plan is passed. Results are reported in the original vertex order — the
    color-sorted permutation is internal.
    """
    if config.flip_mode != "colored":
        raise ValueError(
            f"colored_anneal serves flip_mode='colored' configs, got "
            f"{config.flip_mode!r} — use fused_anneal / solve()")
    if plan is None:
        plan = colored_plan(
            problem, coupling if coupling is not None
            else config.coupling_format, num_planes=num_planes)
    elif coupling is not None:
        raise ValueError("pass a prebuilt plan= or a coupling= override, "
                         "not both")
    result = _colored_anneal_run(plan, jnp.asarray(seed, jnp.uint32), config,
                                 chunk_steps, block_r,
                                 auto_interpret(interpret))
    return result._replace(best_spins=unpermute_spins(plan,
                                                      result.best_spins))


def fused_anneal(problem: ising.IsingProblem, seed, config: SolverConfig,
                 *, chunk_steps: int = 256, block_r: int = 8,
                 gather: str = "dynamic",
                 coupling: Union[str, BitPlanes, None] = None,
                 num_planes: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 store: Optional[CouplingStore] = None) -> SolveResult:
    """Production annealing driver on the fused sweep kernel.

    Full ``core.solver.solve`` feature parity — both modes, uniformized RWA,
    PWL LUT vs exact flip probability, ``num_flips``, and reference-identical
    trace shape/dtype/cadence — up to RNG stream layout (the fused path draws
    its chunk uniforms from the dedicated ``Salt.SWEEP`` stream). ``gather``
    is "dynamic" (O(N)/step), "onehot" (O(N²)/step MXU contraction), or
    "auto" (onehot only for N ≤ ONEHOT_GATHER_MAX_N, i.e. 128).

    ``coupling`` overrides ``config.coupling_format`` ("auto" picks the
    packed bit-plane store when J is integral, N is past the f32 VMEM
    crossover, and packing actually shrinks J — escalating to the
    HBM-streamed store past the packed-VMEM wall); the
    ``CouplingStore.build`` packing happens here, on the host, so the jitted
    impl only ever sees ready arrays. Callers that already hold packed
    planes (benchmarks, repeated solves of one instance) pass the
    ``BitPlanes`` itself as ``coupling`` to skip the O(N²·B) re-encode —
    the store tier then follows ``config.coupling_format`` when it names a
    single-device plane format, else the VMEM-resident "bitplane" path.
    ``num_planes`` forces the precision B (default: fewest planes covering
    |J|max). The "bitplane_sharded" tier is rejected here — it is served by
    the spin-parallel ``repro.distributed.solver_sharded.solve_sharded``.

    ``store`` takes a prebuilt ``CouplingStore`` and skips the resolve→encode
    entirely (the memoization contract for repeated solves — TTS sweeps,
    tempering restarts — of one instance); it is mutually exclusive with
    ``coupling``, and its tier wins over ``config.coupling_format`` (the
    store *is* the resolved format). It must have been built from this
    problem's couplings: a dense store is identity-checked against
    ``problem.couplings`` (the init derives u₀/e₀ from the problem while
    the sweep consumes the store — feeding a different same-N matrix would
    silently corrupt trajectories); a plane store cannot be re-verified
    without re-encoding, so that half of the contract is the caller's.
    With an edge-list problem and no prebuilt store the build runs the
    O(nnz) sparse encoder — the dense (N, N) matrix is never materialized
    anywhere on this path.
    """
    if config.flip_mode != "single":
        raise ValueError(
            f"fused_anneal runs single-flip sweeps (flip_mode="
            f"{config.flip_mode!r}); colored block updates are served by "
            "colored_anneal / the 'colored' backend")
    if store is not None:
        if coupling is not None:
            raise ValueError("pass a prebuilt store= or a coupling= override, "
                             "not both")
        store.require_num_spins(problem.num_spins, "fused_anneal")
        if store.dense is not None and store.dense is not problem.couplings:
            raise ValueError(
                "prebuilt dense CouplingStore does not hold this problem's "
                "couplings array — the init would run on one J and the sweep "
                "on another; rebuild the store from problem.couplings")
    elif isinstance(coupling, BitPlanes):
        # Any plane format on the config flows into the store so require()
        # below can reject tiers this driver does not serve (a
        # "bitplane_sharded" config must raise the routing error here too,
        # never silently downgrade to the VMEM tier).
        fmt = (config.coupling_format
               if config.coupling_format in PLANE_FORMATS else "bitplane")
        store = CouplingStore.from_planes(coupling, fmt)
    else:
        store = CouplingStore.build(
            problem.coupling_source,
            coupling if coupling is not None else config.coupling_format,
            num_planes=num_planes)
    store.require(KERNEL_COUPLING_MODES, "fused_anneal")
    return _fused_anneal_impl(problem, jnp.asarray(seed, jnp.uint32), config,
                              chunk_steps, block_r, gather,
                              auto_interpret(interpret), store)
