"""Pallas TPU kernel: fused flash attention (GQA-native, causal-aware).

The dry-run memory profile (EXPERIMENTS.md §Perf) shows the pure-jnp chunked
attention dominating the HBM roofline term: every (q_block × kv_block) score
tile is a dot result that XLA materializes to HBM (~10–200 TB/step at 32k
context). This kernel keeps the score tile, running max/sum, and output
accumulator in VMEM across the KV loop — HBM traffic collapses to
Q + O + nq·(K + V) streams, the standard flash-attention budget.

Layout: grid (batch, kv_head, q_block); the KV loop runs *inside* the kernel
body (fori_loop) so (m, l, acc) never leave VMEM. GQA is native: the q tile
carries the `rep = Hq/Hkv` group dim; K/V tiles are shared across the group.
Causal masking skips fully-masked KV blocks via the loop upper bound
`(qi+1)·bq / bk` — the triangular schedule, which also halves FLOPs vs the
jnp path's full rectangle.

`ref.py` oracle: ``repro.models.layers.chunked_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, block_q: int,
            block_k: int, seq_kv: int, scale: float):
    # q_ref: (1, 1, rep, block_q, d); k_ref/v_ref: (1, 1, seq_kv, d)
    rep = q_ref.shape[2]
    d = q_ref.shape[-1]
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (rep, bq, d)

    num_k = seq_kv // block_k
    if causal:
        # triangular schedule: only blocks overlapping the causal frontier
        num_live = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k, num_k)
    else:
        num_live = num_k

    def body(kj, carry):
        m, l, acc = carry
        k_blk = pl.load(k_ref, (0, 0, pl.ds(kj * block_k, block_k), slice(None))
                        ).astype(jnp.float32)  # (bk, d)
        v_blk = pl.load(v_ref, (0, 0, pl.ds(kj * block_k, block_k), slice(None))
                        ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k_blk, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (rep,bq,bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
            mask = rows >= cols
            s = jnp.where(mask, s, NEG_INF)
        m2 = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m2)
        p = jnp.exp(s - m_new[..., None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        c1 = jnp.exp(m - m_new)
        l_new = l * c1 + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v_blk, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_new = acc * c1[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((rep, block_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep, block_q), jnp.float32)
    acc0 = jnp.zeros((rep, block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_live, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
                    scale: float, block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D), Hq % Hkv == 0.
    Returns (B, Hq, Sq, D), same dtype as q."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq ({sq},{skv}) not divisible by ({block_q},{block_k})")
    qg = q.reshape(b, hkv, rep, sq, d)
    grid = (b, hkv, sq // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, block_q=block_q,
                          block_k=block_k, seq_kv=skv, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rep, block_q, d), lambda bi, hi, qi: (bi, hi, 0, qi, 0)),
            pl.BlockSpec((1, 1, skv, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, skv, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, block_q, d),
                               lambda bi, hi, qi: (bi, hi, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, sq, d), q.dtype),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, hq, sq, d)


def _flash_fwd(q, k, v, causal, scale, block_q, block_k, interpret):
    out = flash_attention(q, k, v, causal, scale, block_q, block_k, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, scale, block_q, block_k, interpret, res, g):
    """Backward via the jnp oracle (recompute-from-inputs, flash-style).

    A dedicated Pallas backward kernel has the same structure as the forward
    (streaming KV blocks, dq/dk/dv accumulators in VMEM) and the same HBM
    budget; the roofline substitution in EXPERIMENTS.md §Perf models the
    fwd+bwd kernel pair. Functionally, recomputing through the chunked-jnp
    path yields exact gradients.
    """
    from ..models.layers import chunked_attention

    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: chunked_attention(
            q_, k_, v_, causal=causal, q_chunk=block_q, kv_chunk=block_k,
            scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
