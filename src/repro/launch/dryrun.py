import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

DOC = """Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, `jax.jit(step).lower(**abstract
inputs).compile()` must succeed on the single-pod 16×16 mesh AND the 2-pod
2×16×16 mesh. `memory_analysis()` proves the per-device footprint fits;
`cost_analysis()` + the compiled HLO feed the §Roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.configs.shapes import InputShape
from repro.launch.abstracts import (abstract_cache, abstract_train_state,
                                    input_specs, rules_for)
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, forward, model_specs, decode_step
from repro.models.config import ModelConfig
from repro.models.sharding import use_sharding
from repro.optim import AdamWConfig
from repro.roofline import analyze_compiled
from repro.train.step import make_train_step

# Per-arch dry-run hints (tuned in EXPERIMENTS.md §Perf iterations).
# train_microbatches sizes the scan-saved residual carries (≈ G·B_mb·S·d·6B
# per device); "rules" overrides shard the residual stream (Megatron-style)
# for the largest models.
HINTS: dict[str, dict] = {
    "starcoder2-7b": {"train_microbatches": 16},
    "stablelm-12b": {"train_microbatches": 16},
    "nemotron-4-340b": {"train_microbatches": 16, "state_dtype": "int8",
                        "rules": {"embed_act": "model"}},
    "qwen2-7b": {"train_microbatches": 8},
    "llava-next-34b": {"train_microbatches": 16, "rules": {"embed_act": "model"}},
    "phi3.5-moe-42b-a6.6b": {"train_microbatches": 8},
    "granite-moe-1b-a400m": {"train_microbatches": 4},
    "hubert-xlarge": {"train_microbatches": 8},
    "rwkv6-1.6b": {"train_microbatches": 4},
    "jamba-1.5-large-398b": {"train_microbatches": 8, "state_dtype": "int8",
                             "rules": {"embed_act": "model"}},
}


def build_lowered(cfg: ModelConfig, shape: InputShape, mesh, multi_pod: bool):
    """Lower one cell; returns (lowered, model_flops_global)."""
    hints = HINTS.get(cfg.name, {})
    rules = rules_for(shape, multi_pod)
    if shape.kind == "train" and hints.get("rules"):
        rules = dataclasses.replace(rules, **hints["rules"])
    n_active = cfg.active_param_count()
    tokens_global = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    with use_sharding(mesh, rules):
        if shape.kind == "train":
            opt = AdamWConfig(state_dtype=hints.get("state_dtype", "float32"))
            state = abstract_train_state(cfg, opt, mesh, rules)
            batch = input_specs(cfg, shape, mesh, rules)
            mb = hints.get("train_microbatches", 1)
            pshard = jax.tree.map(lambda s: s.sharding, state.params)
            gathered = None
            if hints.get("gather_once"):
                from repro.models import model_specs as _specs, param_shardings as _pshard
                grules = dataclasses.replace(rules, embed_w=None)
                gathered = _pshard(_specs(cfg), mesh, grules)
            step = make_train_step(cfg, opt, num_microbatches=mb, donate=False,
                                   param_shardings=pshard,
                                   gathered_shardings=gathered)
            lowered = step.lower(state, batch)
            return lowered, 6.0 * n_active * tokens_global
        serve_cfg = dataclasses.replace(cfg, param_dtype="bfloat16", remat="none")
        specs = model_specs(serve_cfg)
        params = abstract_params(specs, mesh, rules)
        if shape.kind == "prefill":
            batch = input_specs(serve_cfg, shape, mesh, rules)
            fn = jax.jit(lambda p, b: forward(serve_cfg, p, **b))
            lowered = fn.lower(params, batch)
            return lowered, 2.0 * n_active * tokens_global
        # decode: one new token against a seq_len-deep cache
        cache = abstract_cache(serve_cfg, shape, mesh, rules)
        batch = input_specs(serve_cfg, shape, mesh, rules)
        pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
        fn = jax.jit(lambda p, c, t, b: decode_step(serve_cfg, p, c, t, **b))
        lowered = fn.lower(params, cache, pos, batch)
        return lowered, 2.0 * n_active * tokens_global


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_devices = mesh.devices.size
    t0 = time.time()
    try:
        lowered, model_flops = build_lowered(cfg, shape, mesh, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_kind}] memory_analysis: {mem}")
            ca = compiled.cost_analysis()
            print(f"[{arch} × {shape_name} × {mesh_kind}] cost_analysis: "
                  f"flops={ca.get('flops'):.4g} bytes={ca.get('bytes accessed'):.4g}")
        report = analyze_compiled(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_kind,
            num_devices=num_devices, model_flops=model_flops)
        out = dataclasses.asdict(report)
        out.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1),
                   step_time=report.step_time, mfu=report.mfu)
        return out
    except Exception as e:  # a failing cell is a bug in the system
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "error", "error": f"{type(e).__name__}: {e}"}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, action="append")
    ap.add_argument("--shape", choices=tuple(SHAPES), action="append")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append results to this JSON file")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or not args.arch) else args.arch
    shapes = list(SHAPES) if (args.all or not args.shape) else args.shape
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    results = []
    failed = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                r = run_cell(arch, shape_name, mesh_kind)
                status = r["status"]
                extra = (f"bottleneck={r.get('bottleneck')} "
                         f"mfu={100*r.get('mfu', 0):.1f}% "
                         f"compile={r.get('compile_s')}s" if status == "ok"
                         else r.get("reason", r.get("error", "")))
                print(f"== {arch:24s} {shape_name:12s} {mesh_kind:8s} {status:8s} {extra}",
                      flush=True)
                results.append(r)
                failed += status == "error"
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as fh:
                existing = json.load(fh)
        key = lambda r: (r["arch"], r["shape"], r["mesh"])
        merged = {key(r): r for r in existing}
        merged.update({key(r): r for r in results})
        with open(args.out, "w") as fh:
            json.dump(list(merged.values()), fh, indent=1)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
