"""Snowball solve launcher: instances, modes, engines, optional distribution.

    PYTHONPATH=src python -m repro.launch.solve --instance k200 --mode rwa
    PYTHONPATH=src python -m repro.launch.solve --gset path/to/G6 --mode rsa

Long solves can run under the resilient supervisor (crash-safe snapshots,
budgets, bit-identical resume — see DESIGN.md §Resilient solves):

    PYTHONPATH=src python -m repro.launch.solve --instance k200 \\
        --run-dir runs/k200 --deadline-seconds 3600
    # after a crash/preemption, the same command resumes where it stopped
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time

import numpy as np

from repro.configs.snowball import default_solver
from repro.core import tts
from repro.core.resilience import BudgetConfig, run_resilient
from repro.core.solver import solve
from repro.graphs import (complete_bipolar, erdos_renyi, maxcut_to_ising,
                          parse_gset, small_world, torus_grid)
from repro.graphs.maxcut import cut_from_energy
from repro.kernels import fused_anneal


def build_instance(args):
    if args.gset:
        return parse_gset(args.gset, name=args.gset)
    name = args.instance.lower()
    if name.startswith("k"):
        return complete_bipolar(int(name[1:]), seed=args.seed)
    if name.startswith("er"):
        n = int(name[2:])
        return erdos_renyi(n, n * 24, seed=args.seed)
    if name.startswith("sw"):
        return small_world(int(name[2:]), 12, seed=args.seed)
    if name.startswith("torus"):
        side = int(name[5:])
        return torus_grid(side, side, seed=args.seed)
    raise SystemExit(
        f"unknown instance {args.instance!r}: expected k<N> (complete "
        "bipolar), er<N> (Erdős–Rényi, 24·N edges), sw<N> (small-world, "
        "degree 12), or torus<side> (side×side grid) — e.g. k200, er500, "
        "sw1000, torus32 — or pass a Gset-format file via --gset instead")


def build_mesh(spec: str | None):
    """Device mesh for ``--engine sharded``: ``"4"`` → 1-D row sharding over
    4 devices; ``"2x2"`` → the 2-D (groups, rows) layout. ``None`` takes
    every visible device as a 1-D mesh."""
    import jax
    from jax.sharding import Mesh

    devices = jax.devices()
    if spec is None:
        shape = (len(devices),)
    else:
        try:
            shape = tuple(int(s) for s in spec.lower().split("x"))
        except ValueError:
            raise SystemExit(
                f"--mesh-shape {spec!r}: expected e.g. '4' or '2x2'")
    ndev = math.prod(shape)
    if ndev > len(devices):
        raise SystemExit(
            f"--mesh-shape {spec} needs {ndev} devices but only "
            f"{len(devices)} are visible (force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={ndev})")
    names = ("spins",) if len(shape) == 1 else ("groups", "rows")
    return Mesh(np.array(devices[:ndev]).reshape(shape), names)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--instance", default="k200",
                    help="k<N>|er<N>|sw<N>|torus<side>")
    ap.add_argument("--gset", default=None, help="path to a Gset-format file")
    ap.add_argument("--mode", choices=("rsa", "rwa"), default="rwa")
    ap.add_argument("--steps", type=int, default=5000)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--engine", choices=("scan", "fused", "sharded"),
                    default="scan",
                    help="sharded = spin-row-sharded planes over a device "
                    "mesh (see --mesh-shape); always supervised")
    ap.add_argument("--mesh-shape", default=None,
                    help="device mesh for --engine sharded: '4' shards spin "
                    "rows over 4 devices; '2x2' runs 2 replica groups × 2 "
                    "row shards (the bitplane_sharded_2d tier)")
    ap.add_argument("--flip-mode", choices=("single", "colored"),
                    default="single",
                    help="colored = one conflict-graph color class per step "
                    "(O(N/χ) flips/step on sparse instances; runs under the "
                    "resilient supervisor on the 'colored' backend)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tts-threshold", type=float, default=None,
                    help="cut value for TTS(0.99) estimation")
    res = ap.add_argument_group(
        "resilience", "crash-safe supervised solve (any of these flags "
        "routes the run through repro.core.resilience.run_resilient)")
    res.add_argument("--run-dir", default=None,
                     help="snapshot directory; rerunning with the same "
                     "arguments resumes bit-identically from the last "
                     "intact snapshot")
    res.add_argument("--no-resume", action="store_true",
                     help="ignore snapshots already in --run-dir")
    res.add_argument("--deadline-seconds", type=float, default=None,
                     help="wall-clock budget, checked between chunks")
    res.add_argument("--target-energy", type=float, default=None,
                     help="stop once the ensemble best reaches this energy")
    res.add_argument("--max-steps", type=int, default=None,
                     help="step budget (may stop before --steps)")
    res.add_argument("--chunk-steps", type=int, default=256,
                     help="snapshot/budget granularity for untraced runs")
    args = ap.parse_args()

    inst = build_instance(args)
    problem = maxcut_to_ising(inst)
    cfg = default_solver(inst.num_vertices, args.steps, mode=args.mode,
                         num_replicas=args.replicas)
    colored = args.flip_mode == "colored"
    sharded = args.engine == "sharded"
    if colored and sharded:
        raise SystemExit("--engine sharded is single-flip only; drop "
                         "--flip-mode colored")
    if colored:
        cfg = dataclasses.replace(cfg, flip_mode="colored")
    mesh = build_mesh(args.mesh_shape) if sharded else None
    resilient = (colored
                 or sharded
                 or args.run_dir is not None
                 or args.deadline_seconds is not None
                 or args.target_energy is not None
                 or args.max_steps is not None)
    t0 = time.perf_counter()
    if resilient:
        backend = ("colored" if colored
                   else ("sharded_2d" if len(mesh.axis_names) > 1
                         else "sharded") if sharded
                   else "fused" if args.engine == "fused" else "reference")
        rr = run_resilient(
            problem, args.seed, cfg, run_dir=args.run_dir, backend=backend,
            mesh=mesh,
            budget=BudgetConfig(deadline_seconds=args.deadline_seconds,
                                max_steps=args.max_steps,
                                target_energy=args.target_energy),
            chunk_steps=args.chunk_steps, resume=not args.no_resume)
        result = rr.result
    else:
        engine = fused_anneal if args.engine == "fused" else solve
        result = engine(problem, args.seed, cfg)
    result.best_energy.block_until_ready()
    wall = time.perf_counter() - t0

    cuts = cut_from_energy(inst, np.asarray(result.best_energy))
    print(f"instance={inst.name} |V|={inst.num_vertices} |E|={inst.num_edges} "
          f"density={inst.density*100:.1f}%")
    print(f"mode={args.mode} engine={args.engine} steps={args.steps} "
          f"replicas={args.replicas} wall={wall:.2f}s")
    if resilient:
        resumed = ("" if rr.resumed_from_chunk is None
                   else f" resumed_from_chunk={rr.resumed_from_chunk}")
        downgraded = ("" if not rr.downgrades else
                      " tier_downgrades=" + ",".join(
                          f"{a}->{b}@{c}" for a, b, c in rr.downgrades))
        print(f"stop_reason={rr.stop_reason} steps_done={rr.steps_done}/"
              f"{args.steps} chunks={rr.chunks_done}/{rr.total_chunks}"
              f"{resumed}{downgraded}")
    steps_done = rr.steps_done if resilient else args.steps
    if colored:
        from repro.graphs.coloring import greedy_coloring
        col = greedy_coloring(problem.coupling_source)
        flips = float(np.sum(np.asarray(result.num_flips)))
        per_step = flips / max(steps_done, 1)
        print(f"flip_mode=colored color_classes={col.num_classes} "
              f"max_class={col.max_class_size} "
              f"mean_class={col.num_spins / col.num_classes:.1f} "
              f"flips/step={per_step:.1f} (ensemble, {args.replicas} "
              f"replicas)")
    if sharded:
        shape = ", ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
        print(f"engine=sharded backend={backend} mesh=({shape})")
    if sharded or colored:
        # Perf telemetry for the coalescing / mesh-sharding tiers:
        # µs/step (wall clock, compile included) plus the kernel's
        # unique-rows-fetched counter where the tier reports one — the
        # coalescing win is rows/step below replicas/step.
        us = wall / max(steps_done, 1) * 1e6
        line = f"us/step={us:.1f} (wall incl. compile)"
        if result.rows_fetched is not None:
            rf = float(np.sum(np.asarray(result.rows_fetched)))
            baseline = (f"vs {args.replicas}/step uncoalesced" if sharded
                        else f"of N={problem.num_spins} dense")
            line += (f" rows_fetched={rf:.0f} "
                     f"({rf / max(steps_done, 1):.2f} rows/step "
                     f"{baseline})")
        print(line)
    print(f"best cut = {cuts.max():.0f}  (per-replica: {np.sort(cuts)[::-1][:8]})")
    if args.tts_threshold:
        r = tts.estimate(-cuts, threshold=-args.tts_threshold,
                         time_per_run=wall / args.replicas * 1e3)
        print(f"TTS(0.99) @ cut≥{args.tts_threshold:.0f}: {r.tts:.2f} ms "
              f"(P_a={r.success_probability:.2f})")


if __name__ == "__main__":
    main()
