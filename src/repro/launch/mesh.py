"""Production mesh construction (multi-pod dry-run deliverable, step 1).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. Single pod = v5e-256 as (16, 16) = ("data", "model");
multi-pod adds a leading "pod" axis: (2, 16, 16) = ("pod", "data", "model").

`xla_performance_flags` collects the flags a real TPU launch would set for
collective/compute overlap (latency-hiding scheduler, async collectives);
they are inert on CPU but recorded here so launch scripts stay the deployable
artifact.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, pods: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if n % (model_parallel * pods):
        raise ValueError(f"{n} devices not divisible by tp={model_parallel}×pods={pods}")
    data = n // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel), ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def xla_performance_flags() -> list[str]:
    """Flags for compute/communication overlap on real TPU deployments."""
    return [
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
        "--xla_tpu_spmd_threshold_for_allgather_cse=10000",
    ]
