"""Abstract (ShapeDtypeStruct) inputs for the dry-run: no allocation, correct
shardings attached. This is the `input_specs()` deliverable — every model
input (tokens / frontend embeddings / labels / KV caches / optimizer state)
as weak-type-correct, shardable stand-ins.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.shapes import InputShape
from ..models import abstract_params, init_decode_cache, model_specs
from ..models.config import ModelConfig
from ..models.sharding import ShardingRules, make_sharding
from ..optim import AdamWConfig
from ..optim.adamw import QTensor
from ..train.step import TrainState


def rules_for(shape: InputShape, multi_pod: bool) -> ShardingRules:
    """Per-shape sharding rules (see DESIGN.md §5)."""
    if shape.kind == "decode":
        if shape.name == "long_500k":  # batch=1: all parallelism into the cache
            return ShardingRules(batch=None, kv_heads=None,
                                 cache_seq=("data", "model"))
        # decode: batch over pod×data; KV length over model (flash-decode style)
        return ShardingRules(kv_heads=None, cache_seq="model")
    if shape.kind == "prefill":
        return ShardingRules()
    return ShardingRules()  # train defaults


def _sds(shape, dtype, spec_names, mesh, rules):
    sharding = (make_sharding(spec_names, mesh, rules, shape=shape)
                if mesh is not None else None)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: InputShape, mesh=None,
                rules: Optional[ShardingRules] = None) -> dict:
    """Model inputs for one (arch × shape) cell."""
    rules = rules or ShardingRules()
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    out: dict = {}
    if cfg.uses_token_embedding:
        out["tokens"] = _sds((b, s), jnp.int32, ("batch", "seq"), mesh, rules)
    else:
        out["embeddings"] = _sds((b, s, cfg.d_model), jnp.bfloat16,
                                 ("batch", "seq", None), mesh, rules)
    if shape.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32, ("batch", "seq"), mesh, rules)
    return out


_CACHE_AXES = {
    ("attn", "k"): ("layers", "batch", "kv_heads", "cache_seq", None),
    ("attn", "v"): ("layers", "batch", "kv_heads", "cache_seq", None),
    ("mamba", "conv"): ("layers", "batch", "ssm_inner", None),
    ("mamba", "ssm"): ("layers", "batch", "ssm_inner", "ssm_state"),
    ("rwkv", "wkv"): ("layers", "batch", "rwkv_heads", None, None),
    ("rwkv", "shift"): ("layers", "batch", None),
    ("rwkv", "cmix_shift"): ("layers", "batch", None),
}


def abstract_cache(cfg: ModelConfig, shape: InputShape, mesh=None,
                   rules: Optional[ShardingRules] = None) -> dict:
    """Abstract decode cache with shardings (KV length = shape.seq_len)."""
    rules = rules or ShardingRules()
    shaped = jax.eval_shape(
        lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len))

    def assign(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        kind = next((k for k in ("attn", "mamba", "rwkv") if k in keys), None)
        axes = _CACHE_AXES.get((kind, keys[-1]))
        if axes is None:
            axes = ("layers", "batch") + (None,) * (len(leaf.shape) - 2)
        return _sds(leaf.shape, leaf.dtype, axes, mesh, rules)

    return jax.tree_util.tree_map_with_path(assign, shaped)


def abstract_train_state(cfg: ModelConfig, opt: AdamWConfig, mesh=None,
                         rules: Optional[ShardingRules] = None) -> TrainState:
    """Abstract TrainState: params from specs; optimizer moments inherit the
    param shardings (QTensor codes keep lead-dim axes; scales drop the last)."""
    rules = rules or ShardingRules()
    specs = model_specs(cfg)
    aparams = abstract_params(specs, mesh, rules)

    from ..optim.adamw import adamw_init

    astate = jax.eval_shape(lambda p: adamw_init(p, opt), aparams)

    # Collect param axes by path for moment assignment.
    from ..models.params import tree_paths
    axes_by_path = {p: s.axes for p, s in tree_paths(specs)}

    def assign_moments(tree):
        def walk(node, prefix):
            if isinstance(node, QTensor):
                axes = axes_by_path[prefix]
                codes = _sds(node.codes.shape, node.codes.dtype, axes, mesh, rules)
                scales = _sds(node.scales.shape, node.scales.dtype,
                              axes[:-1] + (None,), mesh, rules)
                return QTensor(codes=codes, scales=scales, orig_last=node.orig_last)
            if isinstance(node, dict):
                return {k: walk(v, prefix + (k,)) for k, v in node.items()}
            axes = axes_by_path[prefix]
            return _sds(node.shape, node.dtype, axes, mesh, rules)

        return walk(tree, ())

    m = assign_moments(astate.m)
    v = assign_moments(astate.v)
    step_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=make_sharding((), mesh, rules))
    opt_state = type(astate)(step=step_sds, m=m, v=v)
    return TrainState(params=aparams, opt_state=opt_state, step=step_sds)
