"""Production train launcher: --arch selection, checkpoint/resume, microbatching.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 100 --checkpoint-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.data import DataConfig
from repro.train import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable); full configs are for "
                         "real accelerator meshes")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--state-dtype", choices=("float32", "bfloat16", "int8"),
                    default="float32")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    loop = TrainLoopConfig(
        steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir, num_microbatches=args.microbatches,
        base_lr=args.lr, seed=args.seed, state_dtype=args.state_dtype,
        async_checkpoint=True)
    data = DataConfig(seed=args.seed, global_batch=args.global_batch,
                      seq_len=args.seq_len)
    train_loop(cfg, data, loop, resume=args.resume)


if __name__ == "__main__":
    main()
