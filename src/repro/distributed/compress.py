"""Int8 gradient all-reduce with error feedback (distributed-optimization trick).

Data-parallel gradient exchange dominates the collective roofline term for
small models at large DP degree. Quantizing the summand to int8 (per-tensor
absmax) cuts all-reduce bytes 4× vs fp32; the quantization residual is carried
in a local *error-feedback* buffer and re-added before the next quantization
(Seide et al. / EF-SGD), which preserves convergence (test:
``test_compressed_training_matches_uncompressed_loss``).

Usage (inside a shard_map over the data axis):

    grads_local = jax.grad(loss)(params, local_batch)
    grads, ef = compressed_psum_grads(grads_local, ef, axis="data")
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .shmap import axis_size as _axis_size


class CompressionState(NamedTuple):
    error_feedback: object  # pytree like grads, f32


def init_compression(params) -> CompressionState:
    return CompressionState(error_feedback=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize_tensor(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(g / safe), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_grads(grads, state: CompressionState, axis: str,
                          mean: bool = True):
    """All-reduce int8-compressed grads over ``axis``; returns (grads, state)."""
    n = _axis_size(axis)

    def one(g, ef):
        g32 = g.astype(jnp.float32) + ef
        # Shared scale: one scalar pmax so every shard quantizes consistently,
        # then the int8 codes are summed exactly in int32 — the wire format is
        # the 1-byte code stream (+1 scalar), 4× less than fp32.
        local_max = jnp.max(jnp.abs(g32))
        scale = jax.lax.pmax(local_max, axis) / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(g32 / safe), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        reduced = total.astype(jnp.float32) * safe
        if mean:
            reduced = reduced / n
        new_ef = g32 - q.astype(jnp.float32) * safe  # residual kept locally
        return reduced, new_ef

    out = jax.tree.map(one, grads, state.error_feedback)
    leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], dict)
    reduced = jax.tree.map(lambda t: t[0], out, is_leaf=leaf)
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=leaf)
    return reduced, CompressionState(error_feedback=new_ef)


def compression_ratio(grads) -> float:
    """Bytes saved vs fp32 all-reduce (int8 codes + one f32 scale per tensor)."""
    fp32 = sum(g.size * 4 for g in jax.tree.leaves(grads))
    int8 = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return fp32 / int8
