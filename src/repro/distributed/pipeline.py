"""GPipe-style pipeline parallelism over a mesh axis (optional PP support).

``pipeline_apply`` runs a stage function over P pipeline stages (one per mesh
shard along ``axis``) with M microbatches using the classic GPipe schedule:
T = M + P − 1 ticks; activations hop stage→stage via ``ppermute``. Designed
for the multi-pod mesh's ``pod`` axis when a model's per-pod footprint
requires pipelining instead of wider FSDP (config option ``--pp pod``).

The implementation is numerics-exact w.r.t. the sequential composition of the
stages (test: tests/test_distributed.py::test_pipeline_matches_sequential).
Bubble fraction is (P−1)/(M+P−1) — reported by ``bubble_fraction``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .shmap import axis_size as _axis_size


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches: jax.Array,
                   axis: str) -> jax.Array:
    """Run inside shard_map: every shard along ``axis`` holds ONE stage's params.

    stage_fn(params, x) -> y, same shape as x (residual-stream stages).
    x_microbatches: (M, mb, ...) — meaningful on stage 0 (replicated is fine).
    Returns (M, mb, ...) — meaningful on the LAST stage.
    """
    p = _axis_size(axis)
    stage = jax.lax.axis_index(axis)
    m = x_microbatches.shape[0]
    ticks = m + p - 1
    mb_shape = x_microbatches.shape[1:]
    # Rotate-by-one permutation (stage i -> i+1).
    fwd_perm = [(i, (i + 1) % p) for i in range(p)]

    def tick(carry, t):
        inbox, outputs = carry
        # Stage 0 injects microbatch t (when available); others use the inbox.
        mb_idx = jnp.clip(t, 0, m - 1)
        fresh = jax.lax.dynamic_index_in_dim(x_microbatches, mb_idx, 0, keepdims=False)
        x_in = jnp.where(stage == 0, fresh, inbox)
        # A stage is active when its microbatch index u = t - stage ∈ [0, m).
        u = t - stage
        active = (u >= 0) & (u < m)
        y = stage_fn(stage_params, x_in)
        y = jnp.where(active, y, x_in)
        # Last stage stores its result at slot u.
        store_idx = jnp.clip(u, 0, m - 1)
        should_store = active & (stage == p - 1)
        current = jax.lax.dynamic_index_in_dim(outputs, store_idx, 0, keepdims=False)
        stored = jnp.where(should_store, y, current)
        outputs = jax.lax.dynamic_update_index_in_dim(outputs, stored, store_idx, 0)
        # Ship activations forward for the next tick.
        inbox = jax.lax.ppermute(y, axis, fwd_perm)
        return (inbox, outputs), None

    inbox0 = jnp.zeros(mb_shape, x_microbatches.dtype)
    outputs0 = jnp.zeros((m,) + mb_shape, x_microbatches.dtype)
    (_, outputs), _ = jax.lax.scan(tick, (inbox0, outputs0), jnp.arange(ticks))
    # Broadcast final outputs from the last stage to all shards (so callers can
    # keep a replicated view; a real loss would live on the last stage).
    marker = (stage == p - 1).astype(outputs.dtype)
    outputs = jax.lax.psum(outputs * marker, axis)
    return outputs
