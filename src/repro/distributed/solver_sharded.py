"""Spin-parallel distributed Snowball: the ``bitplane_sharded`` coupling tier.

Where ``solver_dist`` shards *replicas* (independent chains, J replicated),
this driver shards the **problem itself** across the mesh — the HETRI-style
partition of one Ising instance over multiple compute units, applied to the
plane store the reuse-aware near-memory literature makes the central design
axis. Device d owns coupling-plane rows [d·N/D, (d+1)·N/D) plus the matching
slice of the local fields u and spins s, so J capacity scales with
*aggregate* HBM — D× past the single-device ``bitplane_hbm`` wall — while
every replica still runs one global chain.

Per asynchronous MCMC step (paper Alg. 1, collectivized):

* **selection** — each device evaluates flip probabilities for its own spin
  slice; the hierarchical roulette's level-1 block sums (G = N/lane values,
  i.e. N/128 floats, not N) are ``all_gather``-ed so every device runs the
  identical block pick, and the winning block's lane weights are
  ``psum``-combined from their owner (``kernels.common`` supplies both levels
  — the same arithmetic the kernel and oracle run, so trajectories stay
  *exactly* equal to every single-device tier).
* **flip update** — the owner of the selected row contributes its packed
  (B, 1, W) pos/neg row tiles to a ``psum`` broadcast (masked zeros from
  everyone else add exactly), every device decodes the full row through the
  shared ``common.decode_bitplane_rows`` expansion and FMAs its own u-slice.
  Per-step traffic is O(B·N/32) words of row tiles + O(N/lane) block sums —
  never the O(N²) store, never O(N) f32 fields.

RNG, chunk cadence (``kernels.ops.anneal_chunk_plan``), and the best-so-far
merge are shared with ``kernels.ops.fused_anneal`` statement for statement,
so ``solve_sharded`` returns **bit-identical** ``SolveResult``s to the fused
driver on every coupling tier (the four-way parity test in
``tests/test_solver_sharded.py`` asserts ``assert_array_equal`` across
dense / bitplane / bitplane_hbm / bitplane_sharded).
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core import coupling as coupling_store
from ..core import rng
from ..core.bitplane import WORD_BITS, BitPlanes
from ..core.solver import SolveResult, SolverConfig
from ..kernels import common
from ..kernels import ops as _ops
from .shmap import shard_map_compat


def _mesh_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _flat_shard_index(mesh: Mesh, axes):
    """Linear device index over all mesh axes (row-major in axis order —
    the same flattening ``PartitionSpec((axes...))`` uses to lay out the
    sharded dimension, and the one ``solver_dist`` derives replica ids from)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _psum_gather(x, j, lo, axes):
    """x[r, j[r]] with x row-sharded over the spin axis: the owner contributes
    the value, everyone else exact zeros, and the ``psum`` combine restores
    the global gather (v + 0 + … + 0 is exact in f32, so this is
    value-identical to the single-device ``take``)."""
    n_loc = x.shape[1]
    jl = jnp.clip(j - lo, 0, n_loc - 1)
    v = jnp.take_along_axis(x, jl[:, None], axis=1)[:, 0]
    own = (j >= lo) & (j < lo + n_loc)
    return jax.lax.psum(jnp.where(own, v, jnp.zeros((), x.dtype)), axes)


def _sharded_roulette(p_loc, u_roulette, lane, g0, axes):
    """``common.roulette_pick`` with the (R, N) wheel row-sharded.

    Level 1: local (R, G_loc) block sums, ``all_gather`` to the full (R, G)
    block weights (G = N/lane — N/128 f32s per replica, not N), then the
    *shared* ``common.roulette_block_pick`` replicated on every device.
    Level 2: the selected block's lane weights are psum-combined from the
    owner (masked zeros elsewhere) into the *shared*
    ``common.roulette_lane_pick``. Both levels therefore run the identical
    arithmetic of the single-device pick on identical values — the exactness
    argument of the four-way parity tier.
    """
    r_, n_loc = p_loc.shape
    g_loc = n_loc // lane
    pb = p_loc.reshape(r_, g_loc, lane)
    blk_loc = jnp.sum(pb, axis=2)                         # (R, G_loc)
    blk = jax.lax.all_gather(blk_loc, axes, axis=1, tiled=True)  # (R, G)
    g, residual, total, degenerate = common.roulette_block_pick(blk, u_roulette)
    iota_loc = g0 + jax.lax.broadcasted_iota(jnp.int32, (r_, g_loc), 1)
    sel_loc = jnp.sum(jnp.where((iota_loc == g[:, None])[:, :, None], pb, 0.0),
                      axis=1)                             # (R, lane) masked
    sel = jax.lax.psum(sel_loc, axes)
    l = common.roulette_lane_pick(sel, residual, lane)
    return (g * lane + l).astype(jnp.int32), total, degenerate


def _sharded_sweep(planes_loc: BitPlanes, fields0, spins0, energy0, uniforms,
                   temps, pwl_table, *, mode: str, uniformized: bool, n: int,
                   lane: int, axes, lo, g0):
    """T spin-sharded MCMC steps for R replicas — ``kernels.ref.mcmc_sweep``
    statement for statement, with every global op replaced by its collective
    counterpart (gathers → masked ``psum``, row fetch → psum row-tile
    broadcast + shared decode + local column slice). fields0/spins0 are the
    (R, N/D) local slices; energy0 and the uniforms/temps tensors are
    replicated. Returns the local-slice analogue of the kernel's 6-tuple.
    """
    pos, neg = planes_loc.pos, planes_loc.neg            # (B, N/D, W) rows
    r, n_loc = fields0.shape
    col = lo + jnp.arange(n_loc)                         # global column ids

    def fetch_rows(j):
        """(R,) global sites → (R, N/D) decoded local row columns: the owner
        broadcasts its packed (B, 1, W) row tiles via masked psum (integer
        zeros add exactly), every device runs the identical
        ``decode_bitplane_rows`` expansion on its own slice. When the shard
        boundary is word-aligned (N/D % 32 == 0 — every lane-128 size) the
        packed words are sliced *before* decoding, keeping the per-device
        expansion O(B·N/D) instead of O(B·N); bit expansion is per-word, so
        slice-then-decode equals decode-then-slice value for value."""
        jl = jnp.clip(j - lo, 0, n_loc - 1)
        own = (j >= lo) & (j < lo + n_loc)
        pr = jnp.where(own[None, :, None], jnp.take(pos, jl, axis=1),
                       jnp.uint32(0))                    # (B, R, W)
        nr = jnp.where(own[None, :, None], jnp.take(neg, jl, axis=1),
                       jnp.uint32(0))
        pr = jax.lax.psum(pr, axes)
        nr = jax.lax.psum(nr, axes)
        if n_loc % WORD_BITS == 0:
            w_lo = lo // WORD_BITS                       # lo % 32 == 0 too
            w_loc = n_loc // WORD_BITS
            pr = jax.lax.dynamic_slice_in_dim(pr, w_lo, w_loc, axis=2)
            nr = jax.lax.dynamic_slice_in_dim(nr, w_lo, w_loc, axis=2)
            return common.decode_bitplane_rows(pr, nr, n_loc)  # (R, N/D)
        rows = common.decode_bitplane_rows(pr, nr, n)    # (R, N) shared decode
        return jax.lax.dynamic_slice_in_dim(rows, lo, n_loc, axis=1)

    def body(carry, xs):
        u, s, e, be, bs, nf = carry
        u01, temp = xs                                   # (R, 4), (R,)
        sf = s.astype(jnp.float32)
        if mode == "rsa":
            j = common.site_from_uniform(u01[:, 0], n)
            u_j = _psum_gather(u, j, lo, axes)
            s_old = _psum_gather(sf, j, lo, axes)
            de = 2.0 * s_old * u_j
            p_j = common.flip_probability(de, temp, pwl_table)
            accept = u01[:, 1] < p_j
        else:
            de_all = 2.0 * sf * u                        # (R, N/D)
            p_all = common.flip_probability(de_all, temp[:, None], pwl_table)
            j_rw, total, degenerate = _sharded_roulette(
                p_all, u01[:, 2], lane, g0, axes)
            if uniformized:
                accept = jnp.where(degenerate, False,
                                   u01[:, 3] * jnp.float32(n) < total)
                j = j_rw
            else:
                j_fb = common.site_from_uniform(u01[:, 0], n)
                p_fb = _psum_gather(p_all, j_fb, lo, axes)
                accept = jnp.where(degenerate, u01[:, 1] < p_fb, True)
                j = jnp.where(degenerate, j_fb, j_rw)
            de = _psum_gather(de_all, j, lo, axes)
            s_old = _psum_gather(sf, j, lo, axes)
        acc_f = accept.astype(jnp.float32)
        rows = fetch_rows(j)                             # (R, N/D)
        u = u - (2.0 * acc_f * s_old)[:, None] * rows
        onehot = (col[None, :] == j[:, None]).astype(sf.dtype)
        s = jnp.where(accept[:, None], (sf * (1 - 2 * onehot)).astype(s.dtype), s)
        e = e + acc_f * de
        nf = nf + accept.astype(jnp.int32)
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs, nf), None

    init = (fields0.astype(jnp.float32), spins0,
            energy0.astype(jnp.float32), energy0.astype(jnp.float32),
            spins0, jnp.zeros((r,), jnp.int32))
    (u, s, e, be, bs, nf), _ = jax.lax.scan(body, init, (uniforms, temps))
    return u, s, e, be, bs, nf


@functools.lru_cache(maxsize=32)
def sharded_anneal_fn(config: SolverConfig, mesh: Mesh, n: int, *,
                      chunk_steps: int = 256):
    """Build the jitted shard_map'd anneal for one (config, mesh, N).

    Returns ``fn(planes, u0, s0, e0, seed_arr) → (u, s, e, be, bs, nf,
    trace)`` with planes/u0/s0 sharded over the spin axis. Memoized on the
    (hashable) arguments so repeated solves of one configuration reuse the
    jitted callable instead of re-tracing per call — ``jax.jit`` caches on
    function identity, and ``local_anneal`` is a fresh closure per build
    (the analogue of ``_fused_anneal_impl``'s module-level jit). Factored
    out of :func:`solve_sharded` so the jaxpr-pin test can assert the
    sharded step emits collectives (``psum`` / ``all_gather``) and **no**
    ``dot_general`` — the O(N)/step incremental-update contract extends
    across the mesh.
    """
    axes = tuple(mesh.axis_names)
    num_shards = _mesh_size(mesh, axes)
    r = config.num_replicas
    lane = common.default_lane(n)
    n_loc = n // num_shards
    g_loc = n_loc // lane
    chunk_len, num_chunks, rem_steps = _ops.anneal_chunk_plan(
        config, chunk_steps)
    tbl = _ops.solver_pwl_table(config)

    def local_anneal(planes_loc, u0, s0, e0, seed_arr):
        idx = _flat_shard_index(mesh, axes)
        lo = idx * n_loc
        g0 = idx * g_loc
        base = jax.random.fold_in(jax.random.key(0), seed_arr[0])
        state = (u0, s0, e0, e0, s0, jnp.zeros((r,), jnp.int32))

        def chunk(carry, c, clen):
            # Same per-chunk Salt.SWEEP stream, temps tensor, and
            # best-so-far merge as ops.fused_sweep_chunk — replicated
            # computation, identical on every device.
            steps = c * chunk_len + jnp.arange(clen)
            temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
            temps = jnp.broadcast_to(temps[:, None], (clen, r))
            uniforms = rng.uniform01(
                rng.stream(base, rng.Salt.SWEEP, c), (clen, r, 4))
            u, s, e, be, bs, nf = carry
            u, s, e, ce, cs, cf = _sharded_sweep(
                planes_loc, u, s, e, uniforms, temps, tbl,
                mode=config.mode, uniformized=config.uniformized, n=n,
                lane=lane, axes=axes, lo=lo, g0=g0)
            better = ce < be
            state = (u, s, e, jnp.where(better, ce, be),
                     jnp.where(better[:, None], cs, bs), nf + cf)
            return state, state[3]  # best-so-far energy at chunk end

        state, trace = jax.lax.scan(
            partial(chunk, clen=chunk_len), state, jnp.arange(num_chunks))
        if rem_steps:
            state, _ = chunk(state, jnp.int32(num_chunks), clen=rem_steps)
        u, s, e, be, bs, nf = state
        return u, s, e, be, bs, nf, trace

    shard = P(None, axes)        # (R, N) / (B, N, W) spin-axis sharding
    return jax.jit(shard_map_compat(
        local_anneal, mesh=mesh,
        in_specs=(P(None, axes, None), shard, shard, P(), P()),
        out_specs=(shard, shard, P(), P(), shard, P(), P())))


def solve_sharded(problem, seed, config: SolverConfig, mesh: Mesh, *,
                  chunk_steps: int = 256,
                  coupling: Optional[BitPlanes] = None,
                  num_planes: Optional[int] = None,
                  interpret: Optional[bool] = None) -> SolveResult:
    """Anneal with the coupling planes row-sharded across ``mesh``.

    Trajectory-identical to ``solve(..., backend="fused")`` on the same
    seed/config (any single-device coupling tier): same replica init, same
    ``Salt.SWEEP`` chunk streams, same selection/update arithmetic via
    ``kernels.common`` — only the memory placement changes. Per-device plane
    bytes are ``store.nbytes / D``, so J capacity scales with aggregate HBM.

    Requires an integral J (the sharded store is plane-backed; there is no
    sharded dense tier), N divisible by the mesh size, and the per-shard
    spin count divisible by the roulette lane (block-aligned sharding).
    ``config.coupling_format`` must be "auto" or "bitplane_sharded".
    ``coupling`` takes pre-packed tile-aligned planes to skip the re-encode
    (the benchmark path); ``num_planes`` forces the precision B.
    """
    n = problem.num_spins
    axes = tuple(mesh.axis_names)
    num_shards = _mesh_size(mesh, axes)
    if config.coupling_format not in ("auto", "bitplane_sharded"):
        raise ValueError(
            f"solve_sharded serves coupling_format='bitplane_sharded' "
            f"(or 'auto'), got {config.coupling_format!r} — use "
            f"solve(backend='fused') for the single-device tiers")
    if coupling is not None:
        store = coupling_store.CouplingStore.from_planes(
            coupling, "bitplane_sharded")
        coupling_store.validate_planes_cover(coupling, n)
    else:
        store = coupling_store.CouplingStore.build(
            problem.couplings, "bitplane_sharded", num_planes=num_planes)
    if n % num_shards:
        raise ValueError(f"N={n} spin rows cannot shard evenly over the "
                         f"{num_shards}-device mesh")
    lane = common.default_lane(n)
    n_loc = n // num_shards
    if n_loc % lane:
        raise ValueError(
            f"per-shard spin count {n_loc} is not a multiple of the roulette "
            f"lane {lane}: shard boundaries must align with selection blocks")
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0),
                              jnp.asarray(seed, jnp.uint32))
    u0, s0, e0, _, _, _ = _ops.fused_init_state(
        problem, base, r, interpret=_ops.auto_interpret(interpret),
        planes=store.planes)
    fn = sharded_anneal_fn(config, mesh, n, chunk_steps=chunk_steps)
    seed_arr = jnp.asarray([seed], jnp.uint32)
    u, s, e, be, bs, nf, trace = fn(store.planes, u0, s0, e0, seed_arr)
    return SolveResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(jnp.int8),
        final_energy=e + problem.offset,
        num_flips=nf,
        trace_energy=((trace + problem.offset).astype(jnp.float32)
                      if config.trace_every else jnp.zeros((0, r), jnp.float32)),
    )
