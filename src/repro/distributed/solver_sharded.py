"""Spin-parallel distributed Snowball: the ``bitplane_sharded`` coupling tier.

Where ``solver_dist`` shards *replicas* (independent chains, J replicated),
this driver shards the **problem itself** across the mesh — the HETRI-style
partition of one Ising instance over multiple compute units, applied to the
plane store the reuse-aware near-memory literature makes the central design
axis. Device d owns coupling-plane rows [d·N/D, (d+1)·N/D) plus the matching
slice of the local fields u and spins s, so J capacity scales with
*aggregate* HBM — D× past the single-device ``bitplane_hbm`` wall — while
every replica still runs one global chain.

Per asynchronous MCMC step (paper Alg. 1, collectivized):

* **selection** — each device evaluates flip probabilities for its own spin
  slice; the hierarchical roulette's level-1 block sums (G = N/lane values,
  i.e. N/128 floats, not N) are ``all_gather``-ed so every device runs the
  identical block pick, and the winning block's lane weights are
  ``psum``-combined from their owner (``kernels.common`` supplies both levels
  — the same arithmetic the kernel and oracle run, so trajectories stay
  *exactly* equal to every single-device tier).
* **flip update** — the owner of the selected row contributes its packed
  (B, 1, W) pos/neg row tiles to a ``psum`` broadcast (masked zeros from
  everyone else add exactly), every device decodes the full row through the
  shared ``common.decode_bitplane_rows`` expansion and FMAs its own u-slice.
  The replica-apply loop is software-pipelined: replica r+1's row-tile psum
  is issued before replica r's decode+FMA consumes its tiles (the
  cross-device analogue of the HBM tier's DMA double-buffer), so the
  broadcast overlaps the previous replica's compute instead of blocking the
  step. Per-step traffic is O(B·N/32) words of row tiles + O(N/lane) block
  sums — never the O(N²) store, never O(N) f32 fields.

The solve is **dense-J-free end to end**: replica init runs inside the
shard_map, plane-natively per device (u₀ from the device's own plane slab,
e₀ via the shared ``ising.energy_from_fields`` einsum on the all_gather'd
u^(J)), and edge-list problems encode each device's slab straight from the
O(nnz) edges (:func:`shard_planes_from_edges`) — neither the full (B, N, W)
store nor any (N, N) f32 exists on any single host or device at any point.

RNG, chunk cadence (``kernels.ops.anneal_chunk_plan``), and the best-so-far
merge are shared with ``kernels.ops.fused_anneal`` statement for statement,
so ``solve_sharded`` returns **bit-identical** ``SolveResult``s to the fused
driver on every coupling tier (the parity test in
``tests/test_solver_sharded.py`` asserts ``assert_array_equal`` across
dense / bitplane / bitplane_hbm / bitplane_sharded / sharded_2d).

**2-D meshes — rows × replica groups** (the ``bitplane_sharded_2d`` tier):
on a multi-axis mesh the **last** axis row-shards the planes exactly as
above, while the leading axes form replica *groups*: planes are replicated
across groups, and each group runs an independent contiguous block of
``R / G`` replicas with **global** replica indices. All hot-path collectives
(the row-tile psums, the block-sum all_gathers, the masked psum gathers)
are scoped to the group's rows sub-axis only — no cross-group traffic per
step — so per-device J bytes are ``total / rows_per_group`` while replica
throughput scales with the group count. Every replica's RNG (``Salt.REPLICA``
keys, per-chunk ``Salt.SWEEP`` uniforms drawn at the full (T, R, 4) shape
and sliced to the group's block) is computed at its global index, so the
concatenation of the group blocks reproduces the full-R fused trajectory
bit for bit — the 1-D tier is the degenerate single-group case of the same
code path.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import coupling as coupling_store
from ..core import ising, rng
from ..core.bitplane import (WORD_BITS, BitPlanes, edge_plane_words,
                             local_fields_from_planes)
from ..core.solver import SolveResult, SolverConfig
from ..kernels import common
from ..kernels import ops as _ops
from .shmap import shard_map_compat


def _mesh_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _flat_shard_index(mesh: Mesh, axes):
    """Linear device index over all mesh axes (row-major in axis order —
    the same flattening ``PartitionSpec((axes...))`` uses to lay out the
    sharded dimension, and the one ``solver_dist`` derives replica ids from)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _mesh_axes_split(mesh: Mesh):
    """Split a sharded-tier mesh into ``(group_axes, row_axes)``.

    The **last** mesh axis always row-shards the plane store (J capacity);
    any leading axes are replica-group axes — planes replicated across them,
    each group running an independent contiguous block of replicas
    (throughput). A 1-D mesh is the degenerate no-group case
    (``group_axes == ()``), so the 1-D tier is exactly this path."""
    axes = tuple(mesh.axis_names)
    return axes[:-1], axes[-1:]


def _mesh_desc(mesh: Mesh) -> str:
    return "(" + ", ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names) + ")"


def nearest_row_shard_counts(n: int, near: int, limit: int = 3):
    """The row-shard counts d closest to ``near`` that split N evenly into
    lane-aligned shards (``N % d == 0 and (N // d) % default_lane(N) == 0``)
    — the actionable half of the sharded tier's divisibility errors."""
    lane = common.default_lane(n)
    valid = [d for d in range(1, max(n // lane, 1) + 1)
             if n % d == 0 and (n // d) % lane == 0]
    return tuple(sorted(valid, key=lambda d: (abs(d - near), d))[:limit])


def _check_row_shardable(n: int, mesh: Mesh) -> int:
    """Validate that N rows split evenly (and lane-aligned) over the mesh's
    row axis; returns the row-shard count. The error names N, the mesh
    shape, and the nearest valid row-shard counts — both the 1-D and 2-D
    paths route through here, so neither can silently mis-shard."""
    grp_axes, row_axes = _mesh_axes_split(mesh)
    num_rows = _mesh_size(mesh, row_axes)
    lane = common.default_lane(n)
    where = (f"row axis {row_axes[0]!r}" if grp_axes else "mesh")
    if n % num_rows:
        raise ValueError(
            f"N={n} spin rows cannot shard evenly over the {num_rows} "
            f"shard(s) of the {where} of mesh {_mesh_desc(mesh)} "
            f"(N % {num_rows} == {n % num_rows}); nearest valid row-shard "
            f"counts for N={n}: {nearest_row_shard_counts(n, num_rows)}")
    if (n // num_rows) % lane:
        raise ValueError(
            f"per-shard spin count {n // num_rows} is not a multiple of the "
            f"roulette lane {lane} (N={n} over the {num_rows} shard(s) of "
            f"the {where} of mesh {_mesh_desc(mesh)}): shard boundaries "
            f"must align with selection blocks; nearest valid row-shard "
            f"counts for N={n}: {nearest_row_shard_counts(n, num_rows)}")
    return num_rows


def _check_group_replicas(config: SolverConfig, mesh: Mesh) -> int:
    """Validate that the replica count splits evenly over the mesh's replica
    groups; returns the group count (1 on a 1-D mesh)."""
    grp_axes, _ = _mesh_axes_split(mesh)
    num_groups = _mesh_size(mesh, grp_axes)
    r = config.num_replicas
    if r % num_groups:
        valid = tuple(g for g in range(1, r + 1) if r % g == 0)
        raise ValueError(
            f"num_replicas={r} cannot split evenly over the {num_groups} "
            f"replica group(s) of mesh {_mesh_desc(mesh)} (group axes "
            f"{grp_axes}); use a replica count divisible by {num_groups} "
            f"or a group count in {valid}")
    return num_groups


def _psum_gather(x, j, lo, axes):
    """x[r, j[r]] with x row-sharded over the spin axis: the owner contributes
    the value, everyone else exact zeros, and the ``psum`` combine restores
    the global gather (v + 0 + … + 0 is exact in f32, so this is
    value-identical to the single-device ``take``)."""
    n_loc = x.shape[1]
    jl = jnp.clip(j - lo, 0, n_loc - 1)
    v = jnp.take_along_axis(x, jl[:, None], axis=1)[:, 0]
    own = (j >= lo) & (j < lo + n_loc)
    return jax.lax.psum(jnp.where(own, v, jnp.zeros((), x.dtype)), axes)


def _sharded_roulette(p_loc, u_roulette, lane, g0, axes):
    """``common.roulette_pick`` with the (R, N) wheel row-sharded.

    Level 1: local (R, G_loc) block sums, ``all_gather`` to the full (R, G)
    block weights (G = N/lane — N/128 f32s per replica, not N), then the
    *shared* ``common.roulette_block_pick`` replicated on every device.
    Level 2: the selected block's lane weights are psum-combined from the
    owner (masked zeros elsewhere) into the *shared*
    ``common.roulette_lane_pick``. Both levels therefore run the identical
    arithmetic of the single-device pick on identical values — the exactness
    argument of the four-way parity tier.
    """
    r_, n_loc = p_loc.shape
    g_loc = n_loc // lane
    pb = p_loc.reshape(r_, g_loc, lane)
    blk_loc = jnp.sum(pb, axis=2)                         # (R, G_loc)
    blk = jax.lax.all_gather(blk_loc, axes, axis=1, tiled=True)  # (R, G)
    g, residual, total, degenerate = common.roulette_block_pick(blk, u_roulette)
    iota_loc = g0 + jax.lax.broadcasted_iota(jnp.int32, (r_, g_loc), 1)
    sel_loc = jnp.sum(jnp.where((iota_loc == g[:, None])[:, :, None], pb, 0.0),
                      axis=1)                             # (R, lane) masked
    sel = jax.lax.psum(sel_loc, axes)
    l = common.roulette_lane_pick(sel, residual, lane)
    return (g * lane + l).astype(jnp.int32), total, degenerate


def _sharded_sweep(planes_loc: BitPlanes, fields0, spins0, energy0, uniforms,
                   temps, pwl_table, *, mode: str, uniformized: bool, n: int,
                   lane: int, axes, lo, g0, coalesce: bool = True):
    """T spin-sharded MCMC steps for R replicas — ``kernels.ref.mcmc_sweep``
    statement for statement, with every global op replaced by its collective
    counterpart (gathers → masked ``psum``, row fetch → psum row-tile
    broadcast + shared decode + local column slice). fields0/spins0 are the
    (R, N/D) local slices; energy0 and the uniforms/temps tensors are
    replicated. ``coalesce`` (default on) combines duplicate per-step row
    selections into one psum broadcast per *unique* row. Returns the
    local-slice analogue of the kernel's 7-tuple — the trailing (R,) int32
    counts row-tile broadcasts attributed per replica.
    """
    pos, neg = planes_loc.pos, planes_loc.neg            # (B, N/D, W) rows
    r, n_loc = fields0.shape
    col = lo + jnp.arange(n_loc)                         # global column ids

    num_planes = pos.shape[0]
    num_words = pos.shape[2]

    def issue(site_l, is_own):
        """One (2B, 1, W) stacked pos∥neg row-tile psum broadcast: the owner
        contributes its packed words, everyone else exact integer zeros."""
        tiles = jnp.concatenate(
            [jnp.take(pos, site_l, axis=1),
             jnp.take(neg, site_l, axis=1)], axis=0)[:, None, :]
        tiles = jnp.where(is_own, tiles, jnp.uint32(0))  # (2B, 1, W)
        return jax.lax.psum(tiles, axes)

    def decode(tiles):
        pr, nr = tiles[:num_planes], tiles[num_planes:]
        if n_loc % WORD_BITS == 0:
            w_lo = lo // WORD_BITS                   # lo % 32 == 0 too
            w_loc = n_loc // WORD_BITS
            pr = jax.lax.dynamic_slice_in_dim(pr, w_lo, w_loc, axis=2)
            nr = jax.lax.dynamic_slice_in_dim(nr, w_lo, w_loc, axis=2)
            return common.decode_bitplane_rows(pr, nr, n_loc)[0]  # (N/D,)
        rows = common.decode_bitplane_rows(pr, nr, n)[0]  # shared decode
        return jax.lax.dynamic_slice_in_dim(rows, lo, n_loc, axis=0)

    def fetch_rows(j):
        """(R,) global sites → ((R, N/D) decoded local row columns, (R,)
        int32 broadcast counts): the owner broadcasts its packed (B, 1, W)
        row tiles via masked psum (integer zeros add exactly), every device
        runs the identical ``decode_bitplane_rows`` expansion on its own
        slice. When the shard boundary is word-aligned (N/D % 32 == 0 —
        every lane-128 size) the packed words are sliced *before* decoding,
        keeping the per-device expansion O(B·N/D) instead of O(B·N); bit
        expansion is per-word, so slice-then-decode equals decode-then-slice
        value for value.

        The replica-apply loop is **software-pipelined** — the cross-device
        analogue of the HBM tier's DMA double-buffer: replica r+1's row-tile
        psum is *issued* before replica r's decode+FMA consumes its tiles
        (replicas are independent, so the prefetch is always safe), letting
        XLA's async collectives run the broadcast under the previous decode
        instead of blocking the step on a synchronous (B, R, W) combine. One
        psum per replica moves the stacked (2B, 1, W) pos∥neg tiles; uint32
        adds are exact, per-replica decode is the per-row expansion the
        batched form ran, and the stack keeps replica order — so the
        trajectory is bit-identical to the un-overlapped formulation (the
        four-way parity tier asserts it end to end).

        With ``coalesce`` the pipeline runs over the step's **unique** sites
        (``common.coalesce_rows``): slot m's psum is ``lax.cond``-gated on
        ``m < nu`` — the predicate is replicated (computed from the
        replicated j), so every device takes the same branch and the
        collective is jointly skipped, cutting interconnect traffic from R
        to nu broadcasts — and the decoded unique rows are gathered back to
        replica order with ``jnp.take``. The decoded row is a function of
        the site alone, so the broadcast-back is byte-identical to
        fetch-per-replica and the trajectory cannot move."""
        if coalesce:
            nu, usite, uo, fetched = common.coalesce_rows(j)
            jl = jnp.clip(usite - lo, 0, n_loc - 1)
            own = (usite >= lo) & (usite < lo + n_loc)
            zeros = jnp.zeros((2 * num_planes, 1, num_words), jnp.uint32)

            def issue_unique(mi):
                return jax.lax.cond(mi < nu,
                                    lambda: issue(jl[mi], own[mi]),
                                    lambda: zeros)

            in_flight = issue_unique(0)
            rows = []
            for mi in range(r):           # static unroll: R is small
                tiles = in_flight
                if mi + 1 < r:
                    in_flight = issue_unique(mi + 1)
                rows.append(decode(tiles))
            # Broadcast the unique rows back to every selecting replica
            # (slots ≥ nu hold zeros and are never referenced by uo < nu).
            return jnp.take(jnp.stack(rows, axis=0), uo, axis=0), fetched

        jl = jnp.clip(j - lo, 0, n_loc - 1)
        own = (j >= lo) & (j < lo + n_loc)
        in_flight = issue(jl[0], own[0])
        rows = []
        for ri in range(r):               # static unroll: R is small
            tiles = in_flight
            if ri + 1 < r:
                # next broadcast under this decode
                in_flight = issue(jl[ri + 1], own[ri + 1])
            rows.append(decode(tiles))
        return jnp.stack(rows, axis=0), jnp.ones((r,), jnp.int32)

    def body(carry, xs):
        u, s, e, be, bs, nf, rf = carry
        u01, temp = xs                                   # (R, 4), (R,)
        sf = s.astype(jnp.float32)
        if mode == "rsa":
            j = common.site_from_uniform(u01[:, 0], n)
            u_j = _psum_gather(u, j, lo, axes)
            s_old = _psum_gather(sf, j, lo, axes)
            de = 2.0 * s_old * u_j
            p_j = common.flip_probability(de, temp, pwl_table)
            accept = u01[:, 1] < p_j
        else:
            de_all = 2.0 * sf * u                        # (R, N/D)
            p_all = common.flip_probability(de_all, temp[:, None], pwl_table)
            j_rw, total, degenerate = _sharded_roulette(
                p_all, u01[:, 2], lane, g0, axes)
            if uniformized:
                accept = jnp.where(degenerate, False,
                                   u01[:, 3] * jnp.float32(n) < total)
                j = j_rw
            else:
                j_fb = common.site_from_uniform(u01[:, 0], n)
                p_fb = _psum_gather(p_all, j_fb, lo, axes)
                accept = jnp.where(degenerate, u01[:, 1] < p_fb, True)
                j = jnp.where(degenerate, j_fb, j_rw)
            de = _psum_gather(de_all, j, lo, axes)
            s_old = _psum_gather(sf, j, lo, axes)
        acc_f = accept.astype(jnp.float32)
        rows, fetched = fetch_rows(j)                    # (R, N/D), (R,)
        rf = rf + fetched
        u = u - (2.0 * acc_f * s_old)[:, None] * rows
        onehot = (col[None, :] == j[:, None]).astype(sf.dtype)
        s = jnp.where(accept[:, None], (sf * (1 - 2 * onehot)).astype(s.dtype), s)
        e = e + acc_f * de
        nf = nf + accept.astype(jnp.int32)
        better = e < be
        be = jnp.where(better, e, be)
        bs = jnp.where(better[:, None], s, bs)
        return (u, s, e, be, bs, nf, rf), None

    init = (fields0.astype(jnp.float32), spins0,
            energy0.astype(jnp.float32), energy0.astype(jnp.float32),
            spins0, jnp.zeros((r,), jnp.int32), jnp.zeros((r,), jnp.int32))
    (u, s, e, be, bs, nf, rf), _ = jax.lax.scan(body, init, (uniforms, temps))
    return u, s, e, be, bs, nf, rf


def _sharded_init(planes_loc: BitPlanes, fields, base, *, r: int, n: int,
                  n_loc: int, lo, axes, r0=0):
    """Plane-native per-device replica init — ``ops.fused_init_state`` with
    every full-width touch replaced by its sharded counterpart, so neither
    the full (B, N, W) planes nor any dense J is ever needed on one device.

    Key derivation (``Salt.REPLICA`` → ``Salt.INIT``) and the spin draw are
    replicated computation — byte-for-byte the fused init's, O(R·N). Each
    device then runs the Hamming-weight accumulation on **its own plane
    slab** only (u^(J) is per-row arithmetic, so the row slice of the result
    equals the slice of the full-plane result bitwise), and e₀ is assembled
    by the shared ``ising.energy_from_fields`` on the ``all_gather``-ed
    u^(J) — the identical einsum the fused init runs on identical values, so
    sharded replicas start from bit-equal (u₀, s₀, e₀) for any h. Returns
    the local slices ``(u0_loc, s0_loc, e0)``.

    ``r0`` is the **global** index of this device's first replica (a replica
    group on a 2-D mesh inits its own contiguous block): key derivation is
    per-replica (``Salt.REPLICA`` folds the global index), so computing the
    block alone is bitwise the block slice of the full-R computation.
    """
    replica_keys = jax.vmap(
        lambda i: rng.stream(base, rng.Salt.REPLICA, i))(r0 + jnp.arange(r))
    spins0 = jax.vmap(lambda k: ising.random_spins(
        rng.stream(k, rng.Salt.INIT), (n,)))(replica_keys)
    spins0 = spins0.astype(jnp.float32)                  # (R, N) replicated
    u_j_loc = local_fields_from_planes(planes_loc, spins0)  # (R, N/D) exact
    h_loc = jax.lax.dynamic_slice_in_dim(fields, lo, n_loc)
    u0 = (u_j_loc + h_loc[None, :]).astype(jnp.float32)
    u_j = jax.lax.all_gather(u_j_loc, axes, axis=1, tiled=True)  # (R, N)
    e0 = ising.energy_from_fields(u_j, spins0, fields)
    s0 = jax.lax.dynamic_slice_in_dim(spins0, lo, n_loc, axis=1)
    return u0, s0, e0


def _group_layout(config: SolverConfig, mesh: Mesh, n: int):
    """The static (groups × rows) decomposition one (config, mesh, N) fixes:
    ``(grp_axes, row_axes, num_groups, r_loc, n_loc)`` with ``r_loc`` the
    per-group replica-block size and ``n_loc`` the per-row spin slice."""
    grp_axes, row_axes = _mesh_axes_split(mesh)
    num_groups = _mesh_size(mesh, grp_axes)
    num_rows = _mesh_size(mesh, row_axes)
    return grp_axes, row_axes, num_groups, config.num_replicas // num_groups, \
        n // num_rows


def _group_specs(grp_axes, row_axes):
    """PartitionSpecs of the 2-D layout — degenerate to the 1-D tier's specs
    when ``grp_axes`` is empty: replica-state arrays (R, N) shard replicas
    over the groups and spins over the rows, per-replica scalars (R,) shard
    over the groups alone, and the (chunks, R) trace shards its replica
    axis over the groups."""
    grp = tuple(grp_axes) if grp_axes else None
    rows = tuple(row_axes)
    state = P(grp, rows)
    rep = P(grp)
    trace = P(None, grp)
    return state, rep, trace


@functools.lru_cache(maxsize=32)
def sharded_anneal_fn(config: SolverConfig, mesh: Mesh, n: int, *,
                      chunk_steps: int = 256, coalesce: bool = True):
    """Build the jitted shard_map'd anneal for one (config, mesh, N).

    Returns ``fn(planes, fields, seed_arr) → (u, s, e, be, bs, nf, rows,
    trace)`` — ``rows`` is the (R,) per-replica row-broadcast count —
    with the planes sharded over the spin axis and ``fields`` (the (N,) h —
    O(N), not the O(N²) store) replicated; replica init runs *inside* the
    shard_map, plane-natively per device (:func:`_sharded_init`), so the
    driver never touches full planes or a dense J on any single host.
    Memoized on the (hashable) arguments so repeated solves of one
    configuration reuse the jitted callable instead of re-tracing per call —
    ``jax.jit`` caches on function identity, and ``local_anneal`` is a fresh
    closure per build (the analogue of ``_fused_anneal_impl``'s module-level
    jit). The per-step jaxpr pin (collectives present, no ``dot_general``)
    lives on :func:`sharded_sweep_fn` — the one-time init here legitimately
    contains O(R·N) contractions (the e₀ einsum and the popcount weighting).

    On a multi-axis mesh the leading axes are replica groups: each group's
    devices run the block of ``R / G`` replicas at global indices
    ``[g·R/G, (g+1)·R/G)``, with per-chunk uniforms drawn at the full
    (clen, R, 4) shape and ``dynamic_slice``d to the block — so every
    replica consumes exactly the bits the 1-D and fused paths would hand
    it, and the gathered (R, ·) outputs are bit-identical to theirs.
    """
    grp_axes, row_axes, num_groups, r_loc, n_loc = _group_layout(
        config, mesh, n)
    r_total = config.num_replicas
    lane = common.default_lane(n)
    g_loc = n_loc // lane
    chunk_len, num_chunks, rem_steps = _ops.anneal_chunk_plan(
        config, chunk_steps)
    tbl = _ops.solver_pwl_table(config)

    def local_anneal(planes_loc, fields, seed_arr):
        row_idx = _flat_shard_index(mesh, row_axes)
        lo = row_idx * n_loc
        g0 = row_idx * g_loc
        r0 = _flat_shard_index(mesh, grp_axes) * r_loc
        base = jax.random.fold_in(jax.random.key(0), seed_arr[0])
        u0, s0, e0 = _sharded_init(planes_loc, fields, base, r=r_loc, n=n,
                                   n_loc=n_loc, lo=lo, axes=row_axes, r0=r0)
        state = (u0, s0, e0, e0, s0, jnp.zeros((r_loc,), jnp.int32))
        rows0 = jnp.zeros((r_loc,), jnp.int32)

        def chunk(carry, c, clen):
            # Same per-chunk Salt.SWEEP stream, temps tensor, and
            # best-so-far merge as ops.fused_sweep_chunk — replicated
            # computation, identical on every device; the group consumes
            # its contiguous replica block of the full-R draw.
            steps = c * chunk_len + jnp.arange(clen)
            temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
            temps = jnp.broadcast_to(temps[:, None], (clen, r_loc))
            uniforms = rng.uniform01(
                rng.stream(base, rng.Salt.SWEEP, c), (clen, r_total, 4))
            uniforms = jax.lax.dynamic_slice_in_dim(uniforms, r0, r_loc,
                                                    axis=1)
            (u, s, e, be, bs, nf), rows = carry
            u, s, e, ce, cs, cf, rf = _sharded_sweep(
                planes_loc, u, s, e, uniforms, temps, tbl,
                mode=config.mode, uniformized=config.uniformized, n=n,
                lane=lane, axes=row_axes, lo=lo, g0=g0, coalesce=coalesce)
            better = ce < be
            state = (u, s, e, jnp.where(better, ce, be),
                     jnp.where(better[:, None], cs, bs), nf + cf)
            return (state, rows + rf), state[3]  # best-so-far at chunk end

        (state, rows), trace = jax.lax.scan(
            partial(chunk, clen=chunk_len), (state, rows0),
            jnp.arange(num_chunks))
        if rem_steps:
            (state, rows), _ = chunk((state, rows), jnp.int32(num_chunks),
                                     clen=rem_steps)
        u, s, e, be, bs, nf = state
        return u, s, e, be, bs, nf, rows, trace

    state_s, rep_s, trace_s = _group_specs(grp_axes, row_axes)
    return jax.jit(shard_map_compat(
        local_anneal, mesh=mesh,
        in_specs=(P(None, tuple(row_axes), None), P(), P()),
        out_specs=(state_s, state_s, rep_s, rep_s, state_s, rep_s, rep_s,
                   trace_s)))


@functools.lru_cache(maxsize=32)
def sharded_init_fn(config: SolverConfig, mesh: Mesh, n: int):
    """A jitted shard_map around :func:`_sharded_init` alone — the one-time
    replica init without the anneal, for drivers that advance the chain in
    host-visible chunks (the resilient supervisor, ``core.resilience``).
    Signature: ``fn(planes, fields, seed_arr) → (u0_loc, s0_loc, e0)`` with
    planes/u/s sharded over the spin axis and e₀ replicated — exactly the
    state ``sharded_anneal_fn``'s ``local_anneal`` starts from, so a chunked
    drive of :func:`sharded_sweep_fn` from this init replays the monolithic
    trajectory bit for bit (2-D meshes included: each replica group inits
    its own global-index replica block)."""
    grp_axes, row_axes, _, r_loc, n_loc = _group_layout(config, mesh, n)

    def local_init(planes_loc, fields, seed_arr):
        row_idx = _flat_shard_index(mesh, row_axes)
        r0 = _flat_shard_index(mesh, grp_axes) * r_loc
        base = jax.random.fold_in(jax.random.key(0), seed_arr[0])
        return _sharded_init(planes_loc, fields, base, r=r_loc, n=n,
                             n_loc=n_loc, lo=row_idx * n_loc, axes=row_axes,
                             r0=r0)

    state_s, rep_s, _ = _group_specs(grp_axes, row_axes)
    return jax.jit(shard_map_compat(
        local_init, mesh=mesh,
        in_specs=(P(None, tuple(row_axes), None), P(), P()),
        out_specs=(state_s, state_s, rep_s)))


def sharded_sweep_fn(config: SolverConfig, mesh: Mesh, n: int, *,
                     coalesce: bool = True):
    """A jitted shard_map around :func:`_sharded_sweep` alone — the per-step
    engine without the one-time init. This is the jaxpr-pin surface: the
    *step* must move data with collectives (psum row-tile broadcast,
    all_gather'd block sums) and must never reintroduce a quadratic
    contraction (``dot_general``) — the O(N)/step incremental-update
    contract extended across the mesh. Signature:
    ``fn(planes, u0_loc, s0_loc, e0, uniforms, temps)`` with planes/u/s
    sharded over the spin axis; the seventh output is the (R,) row-broadcast
    counter. ``coalesce=False`` restores the one-psum-per-replica fetch —
    the uncoalesced oracle the parity tests diff against.

    The uniforms/temps inputs are always the **full-R** (T, R, 4) / (T, R)
    tensors, replicated; on a 2-D mesh each replica group ``dynamic_slice``s
    its contiguous block — so the chunked driver feeds identical host-side
    tensors to every mesh shape, and the jaxpr pin can assert that the only
    collectives in the step are scoped to the rows sub-axis (no cross-group
    traffic on the hot path).
    """
    grp_axes, row_axes, _, r_loc, n_loc = _group_layout(config, mesh, n)
    lane = common.default_lane(n)
    g_loc = n_loc // lane
    tbl = _ops.solver_pwl_table(config)

    def local_sweep(planes_loc, u0, s0, e0, uniforms, temps):
        row_idx = _flat_shard_index(mesh, row_axes)
        r0 = _flat_shard_index(mesh, grp_axes) * r_loc
        uniforms = jax.lax.dynamic_slice_in_dim(uniforms, r0, r_loc, axis=1)
        temps = jax.lax.dynamic_slice_in_dim(temps, r0, r_loc, axis=1)
        return _sharded_sweep(
            planes_loc, u0, s0, e0, uniforms, temps, tbl, mode=config.mode,
            uniformized=config.uniformized, n=n, lane=lane, axes=row_axes,
            lo=row_idx * n_loc, g0=row_idx * g_loc, coalesce=coalesce)

    state_s, rep_s, _ = _group_specs(grp_axes, row_axes)
    return jax.jit(shard_map_compat(
        local_sweep, mesh=mesh,
        in_specs=(P(None, tuple(row_axes), None), state_s, state_s, rep_s,
                  P(), P()),
        out_specs=(state_s, state_s, rep_s, rep_s, state_s, rep_s, rep_s)))


def shard_planes_from_edges(edges: ising.EdgeList, mesh: Mesh,
                            num_planes: Optional[int] = None) -> BitPlanes:
    """Edge list → row-sharded plane store with **no full-plane host build**:
    each device's (B, N/D, W) slab is encoded directly from the O(nnz) edge
    arrays (``bitplane.edge_plane_words`` with ``row_range``) and placed via
    ``jax.make_array_from_callback``, so the complete (B, N, W) store — let
    alone the (N, N) f32 J — never exists on any single host or device. This
    is the ingestion path that moves the init wall: setup cost becomes
    O(nnz + plane-slab bytes) per device instead of O(N²) on one host.

    On a 2-D mesh the slabs shard over the **rows** (last) axis only and
    replicate across the replica-group axes; the slab cache below encodes
    each distinct row range exactly once per host, so the G group copies
    of one slab cost one encode, not G.
    """
    _, row_axes = _mesh_axes_split(mesh)
    n = edges.num_spins
    _check_row_shardable(n, mesh)
    if num_planes is None:
        num_planes = max(1, edges.max_abs_weight.bit_length())
    align = coupling_store.FORMATS["bitplane_sharded"].align_words
    w_min = -(-n // WORD_BITS)
    num_words = -(-w_min // align) * align
    sharding = NamedSharding(mesh, P(None, tuple(row_axes), None))
    shape = (num_planes, n, num_words)
    slabs = {}

    def slab(index):
        sl = index[1]
        lo = 0 if sl.start is None else int(sl.start)
        hi = n if sl.stop is None else int(sl.stop)
        if (lo, hi) not in slabs:   # encode each row slab exactly once
            slabs[(lo, hi)] = edge_plane_words(
                edges, num_planes, align_words=align, row_range=(lo, hi))
        return slabs[(lo, hi)]

    pos = jax.make_array_from_callback(shape, sharding,
                                       lambda idx: slab(idx)[0])
    neg = jax.make_array_from_callback(shape, sharding,
                                       lambda idx: slab(idx)[1])
    return BitPlanes(pos=pos, neg=neg, num_spins=n)


def resolve_sharded_planes(problem, config: SolverConfig, mesh: Mesh, *,
                           coupling: Optional[BitPlanes] = None,
                           num_planes: Optional[int] = None) -> BitPlanes:
    """Validate a (problem, config, mesh) triple for the sharded tier and
    produce the row-sharded plane store — the shared front door of
    ``solve_sharded`` and the resilient supervisor. Pre-packed ``coupling``
    planes skip the re-encode; edge-list problems encode per-device slabs
    straight from the O(nnz) edges; a dense J routes through
    ``CouplingStore.build``. Raises the driver's routing/alignment errors.
    On a multi-axis mesh the resolved format is ``bitplane_sharded_2d``
    (row-sharded within each replica group, replicated across groups)."""
    n = problem.num_spins
    grp_axes, _ = _mesh_axes_split(mesh)
    fmt = "bitplane_sharded_2d" if grp_axes else "bitplane_sharded"
    if config.coupling_format not in ("auto", "bitplane_sharded",
                                      "bitplane_sharded_2d"):
        raise ValueError(
            f"solve_sharded serves coupling_format='bitplane_sharded' / "
            f"'bitplane_sharded_2d' (or 'auto'), got "
            f"{config.coupling_format!r} — use solve(backend='fused') for "
            f"the single-device tiers")
    if config.coupling_format == "bitplane_sharded_2d" and not grp_axes:
        raise ValueError(
            f"coupling_format='bitplane_sharded_2d' needs a (groups..., "
            f"rows) mesh with at least 2 axes; mesh {_mesh_desc(mesh)} has "
            f"one — use 'bitplane_sharded' (or 'auto') for 1-D meshes")
    _check_row_shardable(n, mesh)
    _check_group_replicas(config, mesh)
    if coupling is not None:
        store = coupling_store.CouplingStore.from_planes(coupling, fmt)
        coupling_store.validate_planes_cover(coupling, n)
        return store.planes
    if problem.couplings is None:
        return shard_planes_from_edges(problem.edges, mesh, num_planes)
    store = coupling_store.CouplingStore.build(
        problem.couplings, fmt, num_planes=num_planes)
    return store.planes


def solve_sharded(problem, seed, config: SolverConfig, mesh: Mesh, *,
                  chunk_steps: int = 256,
                  coupling: Optional[BitPlanes] = None,
                  num_planes: Optional[int] = None,
                  coalesce: bool = True) -> SolveResult:
    """Anneal with the coupling planes row-sharded across ``mesh``.

    Trajectory-identical to ``solve(..., backend="fused")`` on the same
    seed/config (any single-device coupling tier): same replica init (now
    computed plane-natively *inside* the shard_map — each device initializes
    its own u₀ slice from its plane slab, e₀ via the shared
    ``energy_from_fields`` einsum on the gathered u^(J)), same ``Salt.SWEEP``
    chunk streams, same selection/update arithmetic via ``kernels.common`` —
    only the memory placement changes. Per-device plane bytes are
    ``store.nbytes / D``, so J capacity scales with aggregate HBM — and for
    **edge-list problems** the planes are encoded per device straight from
    the O(nnz) edges (:func:`shard_planes_from_edges`), so no host ever
    materializes the full store or any dense J at any point of the solve.

    On a multi-axis mesh the last axis row-shards the planes within each
    replica group and the leading axes replicate the planes across
    independent replica groups (the ``bitplane_sharded_2d`` tier): per-device
    J bytes are ``store.nbytes / rows_per_group`` while replica throughput
    scales with the group count, and the (R, ·) results are still
    bit-identical to the fused and 1-D paths.

    Requires an integral J (the sharded store is plane-backed; there is no
    sharded dense tier), N divisible by the row-shard count with per-shard
    spin counts divisible by the roulette lane (block-aligned sharding), and
    ``config.num_replicas`` divisible by the group count.
    ``config.coupling_format`` must be "auto", "bitplane_sharded", or (2-D
    meshes) "bitplane_sharded_2d".
    ``coupling`` takes pre-packed tile-aligned planes to skip the re-encode
    (the benchmark path); ``num_planes`` forces the precision B.
    ``coalesce`` (default on) broadcasts each step's unique rows once
    instead of once per replica — identical trajectories, and the result's
    ``rows_fetched`` records the realized per-replica broadcast counts.
    """
    n = problem.num_spins
    planes = resolve_sharded_planes(problem, config, mesh, coupling=coupling,
                                    num_planes=num_planes)
    r = config.num_replicas
    fn = sharded_anneal_fn(config, mesh, n, chunk_steps=chunk_steps,
                           coalesce=coalesce)
    seed_arr = jnp.asarray([seed], jnp.uint32)
    u, s, e, be, bs, nf, rows, trace = fn(planes, problem.fields, seed_arr)
    return SolveResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(jnp.int8),
        final_energy=e + problem.offset,
        num_flips=nf,
        trace_energy=((trace + problem.offset).astype(jnp.float32)
                      if config.trace_every else jnp.zeros((0, r), jnp.float32)),
        rows_fetched=rows,
    )
