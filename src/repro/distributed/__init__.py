from .compress import CompressionState, compressed_psum_grads, init_compression  # noqa: F401
from .shmap import shard_map_compat  # noqa: F401
from .solver_sharded import solve_sharded  # noqa: F401
