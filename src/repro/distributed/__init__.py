from .compress import CompressionState, compressed_psum_grads, init_compression  # noqa: F401
