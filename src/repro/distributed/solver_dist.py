"""Distributed Snowball: replica ensembles sharded over the mesh via shard_map.

Mapping (DESIGN.md §2): replicas (independent Markov chains = the TTS
Bernoulli trials) shard over the flattened data axes (`pod` × `data`); the
coupling matrix J is replicated (or bit-plane packed — 16× smaller — for very
large N). Every ``exchange_every`` chunks, the globally best configuration is
broadcast and the *worst* replicas restart from it with fresh noise — an
elitist restart in the spirit of the paper's ensemble methodology (and unlike
parallel tempering, it needs no temperature ladder; paper §IV-A discusses why
PT is avoided).

Fault-tolerance posture: replicas are independent — losing a host removes its
replicas but never invalidates the ensemble; TTS statistics just lose trials.
Elastic rescale = re-seeding replica ids (stateless RNG streams).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import ising, rng
from ..core.bitplane import local_fields_from_planes
from ..core.coupling import KERNEL_COUPLING_MODES, CouplingStore
from ..core.solver import SolveResult, SolverConfig, _mcmc_config
from ..core import mcmc
from .shmap import shard_map_compat


@dataclasses.dataclass(frozen=True)
class DistSolverConfig:
    base: SolverConfig
    replicas_per_device: int = 1
    exchange_every: int = 0      # chunks between best-exchange; 0 = never
    restart_fraction: float = 0.25  # worst fraction restarted at exchange
    backend: str = "reference"   # "reference" | "fused" per-chunk engine


def _init_chain_from_planes(planes, fields_h, spins) -> mcmc.ChainState:
    """``mcmc.init_chain`` off the packed planes — no dense J required.

    Trajectory-exact vs the dense init for integer J: the Hamming-weight
    u^(J) equals the f32 matmul exactly (integer sums below 2²⁴), and the
    energy is assembled by ``ising.energy_from_fields`` — the *same einsum
    contractions* as ``ising.energy`` on those identical u^(J) values — so
    dense-fed and plane-fed shards produce bit-identical chains (asserted by
    ``test_distributed_fused_bitplane_matches_dense``)."""
    u_j = local_fields_from_planes(planes, spins)      # == J @ s exactly
    e = ising.energy_from_fields(u_j, spins, fields_h).astype(jnp.float32)
    return mcmc.ChainState(
        spins=spins.astype(ising.SPIN_DTYPE),
        fields=(u_j + fields_h).astype(jnp.float32),
        energy=e,
        best_energy=e,
        best_spins=spins.astype(ising.SPIN_DTYPE),
        num_flips=jnp.int32(0),
    )


def _chunk_runner(problem, mc, schedule, chunk_steps):
    """Run `chunk_steps` MCMC steps on a block of replicas (vmapped chains)."""

    def run(states, replica_keys, chunk_idx):
        def one_step(states, t):
            temperature = schedule(t)
            step_keys = jax.vmap(lambda k: rng.stream(k, t))(replica_keys)
            new_states, _ = jax.vmap(
                lambda st, k: mcmc.step(problem, st, k, temperature, mc))(states, step_keys)
            return new_states

        t0 = chunk_idx * chunk_steps
        return jax.lax.fori_loop(t0, t0 + chunk_steps,
                                 lambda t, st: one_step(st, t), states)

    return run


def _fused_chunk_runner(base_cfg: SolverConfig, chunk_steps: int, r_local: int,
                        interpret: bool, store: CouplingStore):
    """Run `chunk_steps` steps as one VMEM-resident fused sweep per shard.

    Replica chains stay in ``mcmc.ChainState`` so the elitist-exchange logic
    is backend-agnostic; the sweep kernel consumes/produces the state arrays
    directly. Per-device RNG: chunk uniforms come from the dedicated
    ``Salt.SWEEP`` stream folded with the device index, so shards draw
    disjoint streams by construction. ``store`` is the resolved
    ``CouplingStore`` (per ``base_cfg.coupling_format`` via
    ``solve_distributed``); the runner closes over its payload, replicated
    to every shard — in the HBM tier each shard streams rows from its own
    HBM-resident plane copy.
    """
    from ..kernels import ops as _ops

    tbl = _ops.solver_pwl_table(base_cfg)
    block_r = _ops.fit_block(r_local, 8)

    def run(states, base, device_idx, chunk_idx, dense_J=None):
        # Plane stores close over the encoded payload (replicated constant);
        # the dense store consumes the caller's per-shard J operand so the
        # matrix enters the shard exactly once either way.
        couplings = dense_J if dense_J is not None else store.kernel_operand
        steps = chunk_idx * chunk_steps + jnp.arange(chunk_steps)
        temps = jax.vmap(base_cfg.schedule)(steps).astype(jnp.float32)
        temps = jnp.broadcast_to(temps[:, None], (chunk_steps, r_local))
        state = (states.fields, states.spins.astype(jnp.float32),
                 states.energy, states.best_energy,
                 states.best_spins.astype(jnp.float32), states.num_flips)
        u, s, e, be, bs, nf = _ops.fused_sweep_chunk(
            couplings, state,
            rng.stream(base, rng.Salt.SWEEP, device_idx, chunk_idx),
            chunk_steps, temps, mode=base_cfg.mode,
            uniformized=base_cfg.uniformized, pwl_table=tbl,
            block_r=block_r, coupling=store.fmt, interpret=interpret)
        return mcmc.ChainState(
            spins=s.astype(ising.SPIN_DTYPE),
            fields=u,
            energy=e,
            best_energy=be,
            best_spins=bs.astype(ising.SPIN_DTYPE),
            num_flips=nf,
        )

    return run


class _DistSetup(NamedTuple):
    """Host-level setup shared by ``solve_distributed`` and the resilient
    chunk surfaces: chunk cadence, resolved store, per-chunk runner, and
    whether the dense J must be shipped into shard_map as an operand."""
    axes: tuple
    num_devices: int
    r_local: int
    r_total: int
    chunk: int
    num_chunks: int
    store: "CouplingStore | None"
    runner: object
    ship_dense: bool


def _dist_setup(problem: ising.IsingProblem, config: DistSolverConfig,
                mesh: Mesh) -> _DistSetup:
    axes = tuple(mesh.axis_names)
    num_devices = 1
    for a in axes:
        num_devices *= mesh.shape[a]
    r_local = config.replicas_per_device
    base_cfg = config.base
    chunk = max(base_cfg.trace_every, 1) if base_cfg.trace_every else 64
    num_chunks = max(base_cfg.num_steps // chunk, 1)
    store = None
    if config.backend == "fused":
        from ..kernels.ops import auto_interpret
        store = CouplingStore.build(
            problem.coupling_source, base_cfg.coupling_format).require(
            KERNEL_COUPLING_MODES, "solve_distributed")
        runner = _fused_chunk_runner(base_cfg, chunk, r_local,
                                     auto_interpret(None), store)
    elif config.backend == "reference":
        if problem.couplings is None:
            raise ValueError(
                "backend='reference' needs the dense J; edge-list "
                "(dense-J-free) problems are served by backend='fused'")
        runner = _chunk_runner(problem, _mcmc_config(base_cfg),
                               base_cfg.schedule, chunk)
    else:
        raise ValueError(
            f"backend must be 'reference' or 'fused', got {config.backend!r}")
    # When the fused runner closes over encoded planes, the dense J never
    # enters shard_map at all — at N=16k that is a 1 GiB replicated operand
    # that the shard would otherwise receive only to ignore (chain (re)inits
    # run off the planes too, see ``_init_chain_from_planes``).
    ship_dense = store is None or store.planes is None
    return _DistSetup(axes=axes, num_devices=num_devices, r_local=r_local,
                      r_total=r_local * num_devices, chunk=chunk,
                      num_chunks=num_chunks, store=store, runner=runner,
                      ship_dense=ship_dense)


def _dist_chain_init(J, h, store):
    """The per-shard chain (re)init closure: dense J when shipped, else the
    plane-backed init off the replicated store."""
    if J is not None:
        prob = ising.IsingProblem(couplings=J, fields=h, offset=0.0)
        return lambda sp: mcmc.init_chain(prob, sp)
    return lambda sp: _init_chain_from_planes(store.planes, h, sp)


def _dist_ids(mesh: Mesh, axes, seed_arr, r_local: int):
    """Per-device RNG derivation inside shard_map: the flattened device index
    (axis sizes are static — read off the mesh, not the
    unavailable-in-old-JAX ``lax.axis_size``), the folded base key, and the
    per-replica ``Salt.REPLICA`` keys. Recomputable from (seed, mesh) alone —
    what lets a resumed run rebuild identical streams with no carried RNG
    state."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    base = jax.random.fold_in(jax.random.key(0), seed_arr[0])
    rep_ids = idx * r_local + jnp.arange(r_local)
    keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(rep_ids)
    return idx, base, keys


def _dist_local_init(h, seed_arr, J, *, store, mesh, axes, r_local, n):
    """Per-device replica init (inside shard_map): chains, keys, ids."""
    idx, base, keys = _dist_ids(mesh, axes, seed_arr, r_local)
    chain_init = _dist_chain_init(J, h, store)
    spins0 = jax.vmap(lambda k: ising.random_spins(
        rng.stream(k, rng.Salt.INIT), (n,)))(keys)
    states = jax.vmap(chain_init)(spins0)
    return states, keys, base, idx, chain_init


def _elitist_exchange(states: mcmc.ChainState, chain_init, *, axes, n: int,
                      r_local: int, restart_fraction: float) -> mcmc.ChainState:
    """Cross-device elitist restart: broadcast the globally best configuration
    (psum-of-onehot winner-take-all) and restart the worst local replicas
    from it. Factored to module level so ``solve_distributed``'s scan and the
    resilient per-chunk surface run the identical exchange arithmetic."""
    # Global best config across ALL devices (psum-of-onehot trick).
    local_best = jnp.min(states.best_energy)
    global_best = local_best
    for a in axes:
        global_best = jax.lax.pmin(global_best, a)
    is_best = (states.best_energy == global_best)
    # Winner-take-all broadcast of the best spins.
    local_vote = jnp.where(jnp.any(is_best),
                           states.best_spins[jnp.argmax(is_best)],
                           jnp.zeros((n,), states.best_spins.dtype))
    count = jnp.any(is_best).astype(jnp.int32)
    total_vote = local_vote.astype(jnp.int32)
    total_count = count
    for a in axes:
        total_vote = jax.lax.psum(total_vote, a)
        total_count = jax.lax.psum(total_count, a)
    best_spins = jnp.sign(total_vote).astype(states.spins.dtype)
    # Ties can cancel the vote; fall back to local state then.
    usable = jnp.any(best_spins != 0) & (total_count > 0)
    # Restart the worst replicas from the broadcast best.
    order = jnp.argsort(states.energy)
    k_restart = max(int(r_local * restart_fraction), 1)
    worst = order[-k_restart:]

    def restart_one(states, j):
        spins = jnp.where(usable, best_spins, states.spins[j])
        st_j = chain_init(spins)
        improved = st_j.energy < states.best_energy[j]
        new_best_s = jnp.where(improved, st_j.spins,
                               states.best_spins[j])
        return mcmc.ChainState(
            spins=states.spins.at[j].set(st_j.spins),
            fields=states.fields.at[j].set(st_j.fields),
            energy=states.energy.at[j].set(st_j.energy),
            best_energy=states.best_energy.at[j].set(
                jnp.minimum(states.best_energy[j], st_j.energy)),
            best_spins=states.best_spins.at[j].set(new_best_s),
            num_flips=states.num_flips,
        )

    return jax.lax.fori_loop(
        0, k_restart, lambda i, st: restart_one(st, worst[i]), states)


def _dist_chunk(states: mcmc.ChainState, c, *, config: DistSolverConfig, J,
                runner, keys, base, idx, chain_init, axes, n: int,
                r_local: int) -> mcmc.ChainState:
    """One distributed chunk (inside shard_map): advance ``chunk`` steps via
    the backend runner, then the conditional elitist exchange — the single
    chunk body under ``solve_distributed``'s scan and the resilient
    supervisor's per-chunk jit."""
    if config.backend == "fused":
        states = runner(states, base, idx, c, dense_J=J)
    else:
        states = runner(states, keys, c)
    if config.exchange_every:
        states = jax.lax.cond(
            (c + 1) % config.exchange_every == 0,
            lambda s: _elitist_exchange(
                s, chain_init, axes=axes, n=n, r_local=r_local,
                restart_fraction=config.restart_fraction),
            lambda s: s, states)
    return states


def dist_operands(problem: ising.IsingProblem, seed, setup: _DistSetup):
    """The replicated shard_map operands for a (problem, seed):
    ``[h, seed_arr(, dense J)]`` — shared between the monolithic solve and
    the resilient chunk surfaces so both ship the identical inputs."""
    seed_arr = jnp.asarray([seed], jnp.uint32)
    operands = [problem.fields, seed_arr]
    if setup.ship_dense:
        operands.append(problem.couplings)
    return operands


def solve_distributed(problem: ising.IsingProblem, seed, config: DistSolverConfig,
                      mesh: Mesh) -> SolveResult:
    """shard_map annealing over every mesh axis (replica-parallel)."""
    setup = _dist_setup(problem, config, mesh)
    axes = setup.axes
    n = problem.num_spins
    r_local = setup.r_local

    def local_solve(h, seed_arr, *dense_args):
        J = dense_args[0] if dense_args else None
        states, keys, base, idx, chain_init = _dist_local_init(
            h, seed_arr, J, store=setup.store, mesh=mesh, axes=axes,
            r_local=r_local, n=n)

        def chunk_body(carry, c):
            states = _dist_chunk(carry, c, config=config, J=J,
                                 runner=setup.runner, keys=keys, base=base,
                                 idx=idx, chain_init=chain_init, axes=axes,
                                 n=n, r_local=r_local)
            return states, states.best_energy  # (r_local,) per chunk

        states, trace = jax.lax.scan(chunk_body, states,
                                     jnp.arange(setup.num_chunks))
        return (states.best_energy, states.best_spins, states.energy,
                states.num_flips, trace)

    spec_rep = P()  # replicated inputs
    out_specs = (P(axes), P(axes), P(axes), P(axes), P(None, axes))
    operands = dist_operands(problem, seed, setup)
    fn = jax.jit(shard_map_compat(
        local_solve, mesh=mesh,
        in_specs=(spec_rep,) * len(operands),
        out_specs=out_specs))
    be, bs, fe, nf, trace = fn(*operands)
    return SolveResult(best_energy=be + problem.offset, best_spins=bs,
                       final_energy=fe + problem.offset, num_flips=nf,
                       trace_energy=trace + problem.offset)


def dist_resilient_fns(problem: ising.IsingProblem, config: DistSolverConfig,
                       mesh: Mesh):
    """Chunk-granular surfaces of the replica-sharded driver for the
    resilient supervisor (``core.resilience``): ``(init_fn, chunk_fn,
    setup)``.

    ``init_fn(*operands) → state6`` and ``chunk_fn(*state6, *operands,
    c_arr) → state6`` are jitted shard_maps whose composition over
    ``c = 0 .. setup.num_chunks-1`` replays ``solve_distributed``'s scan bit
    for bit — same per-device RNG derivation (:func:`_dist_ids`), same chunk
    cadence, same elitist exchange (:func:`_dist_chunk`). ``state6`` is the
    ``ChainState`` leaf tuple ``(spins, fields, energy, best_energy,
    best_spins, num_flips)`` as *global* arrays sharded on the leading
    replica axis; ``operands`` comes from :func:`dist_operands`; ``c_arr``
    is the chunk index as a replicated (1,) int32 (dynamic, so every chunk
    reuses one compiled program)."""
    setup = _dist_setup(problem, config, mesh)
    axes = setup.axes
    n = problem.num_spins
    r_local = setup.r_local
    n_ops = 3 if setup.ship_dense else 2
    rep = P()
    state_specs = (P(axes),) * 6

    def local_init(h, seed_arr, *dense):
        J = dense[0] if dense else None
        states, _, _, _, _ = _dist_local_init(
            h, seed_arr, J, store=setup.store, mesh=mesh, axes=axes,
            r_local=r_local, n=n)
        return tuple(states)

    def local_chunk(sp, fu, en, be, bs, nf, h, seed_arr, c_arr, *dense):
        J = dense[0] if dense else None
        idx, base, keys = _dist_ids(mesh, axes, seed_arr, r_local)
        chain_init = _dist_chain_init(J, h, setup.store)
        states = mcmc.ChainState(spins=sp, fields=fu, energy=en,
                                 best_energy=be, best_spins=bs, num_flips=nf)
        states = _dist_chunk(states, c_arr[0], config=config, J=J,
                             runner=setup.runner, keys=keys, base=base,
                             idx=idx, chain_init=chain_init, axes=axes, n=n,
                             r_local=r_local)
        return tuple(states)

    init_fn = jax.jit(shard_map_compat(
        local_init, mesh=mesh,
        in_specs=(rep,) * n_ops,
        out_specs=state_specs))
    chunk_fn = jax.jit(shard_map_compat(
        local_chunk, mesh=mesh,
        in_specs=state_specs + (rep, rep, rep) + (rep,) * (n_ops - 2),
        out_specs=state_specs))
    return init_fn, chunk_fn, setup
