"""shard_map across JAX versions.

Newer JAX exposes ``jax.shard_map`` (with ``check_vma``); older releases ship
``jax.experimental.shard_map.shard_map`` (with ``check_rep``). Every
shard_map call in this repo goes through :func:`shard_map_compat` so the
distributed drivers and their tests run on both.
"""
from __future__ import annotations

import jax


def axis_size(axis: str):
    """Size of a mapped mesh axis — ``lax.axis_size`` on new JAX, the
    ``psum(1, axis)`` idiom (constant-folded) on old releases."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on any supported JAX."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
