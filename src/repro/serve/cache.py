"""Content-hash-keyed caches for the serving layer.

Two reuse levers dominate repeated solves of one instance (the reuse-aware
near-memory study in PAPERS.md makes the same point for all-digital Ising
machines):

* the **coupling store** — the host-side resolve→encode is the expensive
  per-instance setup (O(N²·B) for dense ingestion, O(nnz) for edge lists);
  :class:`LRUStoreCache` keys built ``CouplingStore``s on the coupling
  content hash + resolved tier so a repeat solve performs **zero**
  re-encodes (the same memoization contract ``solve(store=)`` tests pin,
  now held service-side), and
* the **best solution seen** — :class:`WarmStartCache` remembers the best
  (energy, spins) any request ever reached on a problem, keyed on the full
  problem content hash; a later request whose target energy is already met
  is answered from cache without any solver launch.

Keys are *content* hashes, never object identities: ``EdgeList`` problems
hash via the canonical-COO ``_digest`` (permutation/duplication-invariant —
pinned by ``tests/test_core_ising.py``), dense problems via the J bytes, so
two tenants submitting the same instance share cache entries.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import NamedTuple, Optional

import jax
import numpy as np

from ..core import ising
from ..core.coupling import CouplingStore, resolve_format
from ..core.resilience import problem_fingerprint


def coupling_digest(problem: ising.IsingProblem) -> str:
    """Content hash of the couplings alone (the store depends on J, not on
    fields/offset): the canonical ``EdgeList`` digest for dense-J-free
    problems, sha256 over the J bytes for dense ones."""
    if problem.couplings is None:
        return "edges:" + problem.edges._digest.hex()
    J = np.ascontiguousarray(jax.device_get(problem.couplings))
    h = hashlib.sha256()
    # dtype is part of the content: an int32 J and its float32 bit-pattern
    # twin have identical shape+bytes but encode different couplings — a
    # shared cache key would hand one tenant a store built from the other's
    # matrix.
    h.update(str(J.dtype).encode())
    h.update(repr(J.shape).encode())
    h.update(J.tobytes())
    return "dense:" + h.hexdigest()


def problem_digest(problem: ising.IsingProblem) -> str:
    """Content hash of the full problem (couplings + fields + offset) — the
    warm-start cache key; identical to the resilience supervisor's snapshot
    fingerprint so the two subsystems agree on problem identity."""
    return problem_fingerprint(problem)


class LRUStoreCache:
    """Bounded LRU of built ``CouplingStore``s keyed on
    ``(coupling_digest, resolved tier)``. ``get_or_build`` resolves
    ``config.coupling_format`` first, so "auto" and an explicit matching
    tier share one entry."""

    def __init__(self, capacity: int = 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get_or_build(self, problem: ising.IsingProblem,
                     fmt: str = "auto") -> tuple:
        """``(store, hit)`` for the problem's couplings at the resolved
        tier; builds (one encode) and caches on miss, evicting the least
        recently used entry past capacity."""
        resolved = resolve_format(fmt, problem.coupling_source,
                                  problem.num_spins)
        key = (coupling_digest(problem), resolved)
        store = self._entries.get(key)
        if store is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return store, True
        self.misses += 1
        store = CouplingStore.build(problem.coupling_source, resolved)
        self._entries[key] = store
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return store, False


class BestRecord(NamedTuple):
    energy: float          # ensemble-best energy incl. the problem offset
    spins: np.ndarray      # (N,) the spins that reached it


class WarmStartCache:
    """Bounded LRU of the best solution ever observed per problem content
    hash. ``observe`` folds in any ``SolveResult``-shaped result (keeps the
    minimum); ``lookup`` answers a later request on the same instance."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def observe(self, key: str, result) -> BestRecord:
        """Fold a finished solve into the cache; returns the (possibly
        pre-existing) best record for the key."""
        energies = np.asarray(jax.device_get(result.best_energy)).ravel()
        spins = np.asarray(jax.device_get(result.best_spins))
        spins = spins.reshape(-1, spins.shape[-1])
        i = int(np.argmin(energies))
        record = BestRecord(float(energies[i]), spins[i])
        prev = self._entries.get(key)
        if prev is None or record.energy < prev.energy:
            self._entries[key] = record
        else:
            record = prev
        self._entries.move_to_end(key)
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        return record

    def lookup(self, key: str) -> Optional[BestRecord]:
        record = self._entries.get(key)
        if record is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return record
