"""Solver-as-a-service front end, built only on the ``core.backend``
registry: shape-bucketed request batching, content-hash-keyed store and
warm-start caches, and budgeted admission control behind a synchronous
:class:`SolverService` API. See DESIGN.md §Serving layer."""
from .cache import (LRUStoreCache, WarmStartCache, coupling_digest,
                    problem_digest)                              # noqa: F401
from .batching import (bucket_replicas, bucket_spins, pad_problem,
                       plan_batches, BatchPlan)                  # noqa: F401
from .service import (AdmissionError, ServeConfig, ServeResult,
                      SolveRequest, SolverService)               # noqa: F401
