"""The synchronous multi-tenant solver service.

:class:`SolverService` is the front end the ROADMAP's serving item asks
for, built **only** on the ``core.backend`` registry — it never touches a
driver directly, so any registered execution path is servable:

* **Admission** (:meth:`SolverService.submit`): a bounded pending queue,
  instance-size and step-budget caps, and capability checks against the
  registry (an edge-list problem aimed at a backend without edge-list
  support is refused at submit, not deep in a kernel). Per-request
  :class:`~repro.core.resilience.BudgetConfig` budgets ride the same
  supervisor long solves use — a deadline/step-bounded request runs under
  ``run_resilient`` and returns an honest best-so-far with its
  ``stop_reason``.
* **Caching**: a shared :class:`~repro.serve.cache.LRUStoreCache` makes
  warm-instance solves perform zero re-encodes, and a
  :class:`~repro.serve.cache.WarmStartCache` answers a request whose
  ``target_energy`` was already reached on that instance without any
  launch at all (``stop_reason="cached_target"``).
* **Batching** (:meth:`SolverService.drain`): pending requests are
  shape-bucketed and planned by :func:`~repro.serve.batching.plan_batches`
  — same-instance requests stack into the replica axis of one fused
  launch, seed-pinned requests take the bit-identical ``solve_many`` vmap
  lane, everything else launches singly. ``ServeConfig(batching=False)``
  forces one launch per request (the sequential baseline the throughput
  benchmark compares against).

The API is deliberately synchronous — ``submit`` then ``drain``, or the
one-shot ``solve`` — because the batching/caching policy is what this
layer owns; an async transport in front of it changes nothing below
``drain``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional

import jax
import numpy as np

from ..core import ising
from ..core.backend import get_backend
from ..core.resilience import BudgetConfig, run_resilient
from ..core.solver import SolveResult, SolverConfig, solve_many
from .batching import bucket_spins, pad_problem, plan_batches
from .cache import LRUStoreCache, WarmStartCache, problem_digest


class AdmissionError(RuntimeError):
    """The request was refused at the door (queue full, instance or budget
    over the service caps, or a capability mismatch) — resubmit later or
    resize the request; nothing was enqueued."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service policy knobs."""
    max_pending: int = 256          # admission queue bound
    max_spins: int = 16384          # largest admissible instance
    max_steps: int = 1_000_000      # largest admissible per-request num_steps
    store_cache_entries: int = 16
    warm_cache_entries: int = 256
    pad_spins: bool = True          # bucket N (see batching.SPIN_BUCKETS)
    batching: bool = True           # False = one launch per request
    max_stack_replicas: int = 256   # replica-axis cap per stacked launch


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One tenant request. ``seed=None`` lets the service pick (and makes
    the request stackable); a pinned seed guarantees the result is
    bit-identical to ``solve(problem, seed, config)`` alone, batched or
    not. ``budget`` routes the run through the resilient supervisor."""
    problem: ising.IsingProblem
    config: SolverConfig
    seed: Optional[int] = None
    budget: Optional[BudgetConfig] = None
    backend: str = "fused"


class ServeResult(NamedTuple):
    request_id: int
    result: SolveResult        # replica-sliced back to the request's shape
    stop_reason: str           # "completed" | budget reasons | "cached_target"
    batched: str               # plan kind: "stack" | "vmap" | "single" | ...
    store_hit: bool            # coupling store came from cache (0 encodes)
    warm_hit: bool             # answered/observed via the warm-start cache
    wall_seconds: float        # admission -> result assembly


@dataclasses.dataclass
class _Admitted:
    id: int
    request: SolveRequest
    problem: ising.IsingProblem     # padded to the spin bucket
    orig_n: int
    problem_key: str                # warm-start key (padded problem content)
    config: SolverConfig
    seed: Optional[int]
    t_submit: float

    # plan_batches reads .problem_key / .config / .seed from its items.


class SolverService:
    """See the module docstring. One instance per process; all state
    (queue, caches, counters) is host-side and single-threaded by design."""

    def __init__(self, config: ServeConfig = ServeConfig(), *, mesh=None):
        self.config = config
        self.mesh = mesh
        self.stores = LRUStoreCache(config.store_cache_entries)
        self.warm = WarmStartCache(config.warm_cache_entries)
        self._pending: list = []
        self._next_id = 0
        self.stats = {"admitted": 0, "rejected": 0, "completed": 0,
                      "launches": 0, "stacked_requests": 0,
                      "vmapped_requests": 0, "single_requests": 0,
                      "budgeted_requests": 0, "cached_answers": 0}

    # ---------------------------------------------------------------- admit

    def submit(self, request: SolveRequest) -> int:
        """Admission-check and enqueue; returns the ticket id consumed by
        :meth:`drain`. Raises :class:`AdmissionError` on refusal."""
        cfg = self.config
        if len(self._pending) >= cfg.max_pending:
            self._reject(f"pending queue is full ({cfg.max_pending})")
        n = request.problem.num_spins
        if n > cfg.max_spins:
            self._reject(f"instance N={n} over the service cap "
                         f"{cfg.max_spins}")
        if request.config.num_steps > cfg.max_steps:
            self._reject(f"num_steps={request.config.num_steps} over the "
                         f"service cap {cfg.max_steps}; lower it or pass a "
                         f"BudgetConfig(max_steps=...) under the cap")
        backend = get_backend(request.backend)   # unknown name raises here
        caps = backend.capabilities
        if request.problem.couplings is None and not caps.edge_list:
            self._reject(f"backend {request.backend!r} cannot serve "
                         "edge-list (dense-J-free) problems")
        if caps.needs_mesh and self.mesh is None:
            self._reject(f"backend {request.backend!r} needs a mesh; "
                         "construct SolverService(mesh=...)")
        problem = request.problem
        if cfg.pad_spins:
            problem = pad_problem(problem, bucket_spins(n))
        admitted = _Admitted(
            id=self._next_id, request=request, problem=problem, orig_n=n,
            problem_key=problem_digest(problem), config=request.config,
            seed=request.seed, t_submit=time.perf_counter())
        self._next_id += 1
        self._pending.append(admitted)
        self.stats["admitted"] += 1
        return admitted.id

    def _reject(self, why: str):
        self.stats["rejected"] += 1
        raise AdmissionError(why)

    # ---------------------------------------------------------------- drain

    def drain(self) -> dict:
        """Execute every pending request and return ``{ticket id:
        ServeResult}``. Batched per :func:`plan_batches` unless
        ``ServeConfig(batching=False)``."""
        pending, self._pending = self._pending, []
        out: dict = {}
        plain = []
        for a in pending:
            if self._answer_from_warm_cache(a, out):
                continue
            if a.request.budget is not None:
                self._run_budgeted(a, out)
            elif a.request.backend != "fused" or not self.config.batching:
                self._run_single(a, out)
            else:
                plain.append(a)
        for plan in plan_batches(
                plain, max_stack_replicas=self.config.max_stack_replicas):
            self._run_plan(plan, out)
        self.stats["completed"] += len(out)
        return out

    def solve(self, problem: ising.IsingProblem, config: SolverConfig, *,
              seed: Optional[int] = None,
              budget: Optional[BudgetConfig] = None,
              backend: str = "fused") -> ServeResult:
        """One-shot synchronous request: submit + drain + unwrap."""
        ticket = self.submit(SolveRequest(problem=problem, config=config,
                                          seed=seed, budget=budget,
                                          backend=backend))
        return self.drain()[ticket]

    # ------------------------------------------------------------- execution

    def _store_for(self, a: _Admitted):
        """(store, hit) via the LRU cache when the backend takes one."""
        caps = get_backend(a.request.backend).capabilities
        if not caps.supports_store:
            return None, False
        store, hit = self.stores.get_or_build(
            a.problem, getattr(a.config, "coupling_format", "auto"))
        if store.dense is not None and store.dense is not a.problem.couplings:
            # The cache key is a hash of the exact J bytes, so the cached
            # store's dense array is byte-identical to this request's —
            # rebind the problem to it to satisfy the driver's
            # store-holds-this-problem's-J identity contract.
            a.problem = dataclasses.replace(a.problem, couplings=store.dense)
        return store, hit

    def _effective_seed(self, a: _Admitted) -> int:
        # Service-assigned seeds are the ticket id: deterministic for a
        # given submission order, distinct across requests.
        return a.seed if a.seed is not None else a.id

    def _answer_from_warm_cache(self, a: _Admitted, out: dict) -> bool:
        budget = a.request.budget
        if budget is None or budget.target_energy is None:
            return False
        record = self.warm.lookup(a.problem_key)
        if record is None or record.energy > budget.target_energy:
            return False
        n = a.orig_n
        result = SolveResult(
            best_energy=np.asarray([record.energy], np.float32),
            best_spins=record.spins[None, :n],
            final_energy=np.asarray([record.energy], np.float32),
            num_flips=np.zeros((1,), np.int32),
            trace_energy=np.zeros((0, 1), np.float32))
        self.stats["cached_answers"] += 1
        out[a.id] = ServeResult(
            request_id=a.id, result=result, stop_reason="cached_target",
            batched="cached", store_hit=True, warm_hit=True,
            wall_seconds=time.perf_counter() - a.t_submit)
        return True

    def _run_budgeted(self, a: _Admitted, out: dict):
        store, hit = self._store_for(a)
        rr = run_resilient(a.problem, self._effective_seed(a), a.config,
                           backend=a.request.backend, mesh=self.mesh,
                           budget=a.request.budget, store=store)
        self.stats["launches"] += 1
        self.stats["budgeted_requests"] += 1
        self._finish(a, rr.result, out, kind="budgeted", store_hit=hit,
                     stop_reason=rr.stop_reason)

    def _run_single(self, a: _Admitted, out: dict):
        store, hit = self._store_for(a)
        backend = get_backend(a.request.backend)
        result = backend.run(a.problem, self._effective_seed(a), a.config,
                             mesh=self.mesh, store=store)
        self.stats["launches"] += 1
        self.stats["single_requests"] += 1
        self._finish(a, result, out, kind="single", store_hit=hit)

    def _run_plan(self, plan, out: dict):
        first = plan.requests[0]
        if plan.kind == "single":
            self._run_single(first, out)
            return
        store, hit = self._store_for(first)
        self.stats["launches"] += 1
        if plan.kind == "vmap":
            seeds = [a.seed for a in plan.requests]
            batched = solve_many(first.problem, seeds, plan.config,
                                 backend="fused", store=store)
            for i, a in enumerate(plan.requests):
                lane = jax.tree_util.tree_map(lambda x: x[i], batched)
                self.stats["vmapped_requests"] += 1
                self._finish(a, lane, out, kind="vmap", store_hit=hit)
            return
        if plan.kind != "stack":
            raise ValueError(f"unknown plan kind {plan.kind!r}")
        backend = get_backend(first.request.backend)
        result = backend.run(first.problem, first.id, plan.config,
                             mesh=self.mesh, store=store)
        for a, (off, r) in zip(plan.requests, plan.spans):
            sliced = SolveResult(
                best_energy=result.best_energy[off:off + r],
                best_spins=result.best_spins[off:off + r],
                final_energy=result.final_energy[off:off + r],
                num_flips=result.num_flips[off:off + r],
                trace_energy=result.trace_energy[:, off:off + r])
            self.stats["stacked_requests"] += 1
            self._finish(a, sliced, out, kind="stack", store_hit=hit)

    def _finish(self, a: _Admitted, result, out: dict, *, kind: str,
                store_hit: bool, stop_reason: str = "completed"):
        record = self.warm.observe(a.problem_key, result)
        n = a.orig_n
        if result.best_spins.shape[-1] != n:
            result = result._replace(
                best_spins=result.best_spins[..., :n])
        out[a.id] = ServeResult(
            request_id=a.id, result=result, stop_reason=stop_reason,
            batched=kind, store_hit=store_hit,
            warm_hit=record.energy < float(np.min(np.asarray(
                jax.device_get(result.best_energy)))),
            wall_seconds=time.perf_counter() - a.t_submit)
