"""Shape-bucketed request batching for the serving layer.

A jit cache entry is keyed on array shapes + static config, so a service
facing many tenants wants requests to *collide* on shape:

* **Spin bucketing** — :func:`bucket_spins` rounds N up to a bucket
  boundary and :func:`pad_problem` embeds the instance into the bucket
  with isolated zero-coupling, zero-field spins. Padded spins contribute
  exactly zero energy, so every reported energy is exact for the original
  instance; trajectories are those of the padded instance (the spin
  selector sees N_pad sites), which is the documented serving trade — two
  different 900- and 1000-spin instances now share one compiled program.
* **Replica stacking** — compatible requests on the *same* problem (same
  content hash, same config modulo ``num_replicas``) stack into the
  replica axis of one fused launch: one launch of R_total replicas instead
  of k launches, with per-request replica spans sliced back out
  (:class:`StackPlan`). ``bucket_replicas`` pads R_total to a power of two
  so stacked launches also collide in the jit cache; surplus replicas run
  and are dropped. Replica streams are keyed by position in the launch, so
  stacked results depend on batch composition — requests that pin a seed
  for reproducibility take the vmap lane instead.
* **vmap fallback** — seed-pinned requests with identical full configs
  batch via ``solve_many`` (a vmap over seeds): still one launch, and each
  lane is bit-identical to the request solved alone (asserted by
  ``tests/test_serve.py``).

:func:`plan_batches` is pure planning — grouping, stacking, and lane
assignment with no execution — so the policy is unit-testable without
touching a kernel; ``serve.service.SolverService`` executes the plans.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core import ising

#: Default N buckets: fine-grained where small instances live, then powers
#: of two out to the HBM-streamed sizes.
SPIN_BUCKETS = (64, 128, 256, 384, 512, 768, 1024, 1536, 2048, 4096, 8192,
                16384)


def bucket_spins(n: int, buckets: Sequence[int] = SPIN_BUCKETS) -> int:
    """The smallest bucket boundary >= n (past the table: the next multiple
    of the last bucket, so arbitrarily large instances still quantize)."""
    if n <= 0:
        raise ValueError(f"num_spins must be positive, got {n}")
    for b in buckets:
        if n <= b:
            return b
    last = buckets[-1]
    return ((n + last - 1) // last) * last


def bucket_replicas(r: int) -> int:
    """Replica-axis bucket: the next power of two (>= 1)."""
    if r <= 0:
        raise ValueError(f"num_replicas must be positive, got {r}")
    return 1 << (r - 1).bit_length()


def pad_problem(problem: ising.IsingProblem,
                n_pad: int) -> ising.IsingProblem:
    """Embed the instance into ``n_pad`` spins with isolated zero-coupling,
    zero-field padding spins — exact energies for the original spins, one
    shared compiled program per bucket. Edge-list problems stay dense-J-free
    (only ``num_spins`` grows; the edge set is untouched)."""
    n = problem.num_spins
    if n_pad < n:
        raise ValueError(f"cannot pad N={n} down to {n_pad}")
    if n_pad == n:
        return problem
    fields = np.zeros((n_pad,), np.float32)
    fields[:n] = np.asarray(problem.fields)
    if problem.couplings is None:
        e = problem.edges
        edges = ising.EdgeList.create(e.rows, e.cols, e.weights,
                                      num_spins=n_pad)
        return ising.IsingProblem.create_sparse(edges, fields,
                                                offset=float(problem.offset))
    J = np.zeros((n_pad, n_pad), np.float32)
    J[:n, :n] = np.asarray(problem.couplings)
    return ising.IsingProblem.create(J, fields, offset=float(problem.offset))


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One planned launch. ``kind`` is "stack" (one fused launch, requests
    side by side on the replica axis; ``spans`` holds each request's
    ``(offset, num_replicas)``), "vmap" (``solve_many`` over the requests'
    pinned seeds), or "single" (one request, plain launch)."""
    kind: str
    requests: tuple            # the admitted requests, plan order
    config: object             # the launch SolverConfig
    spans: Optional[tuple] = None       # stack: ((offset, r), ...) per request
    launch_replicas: int = 0            # stack: bucketed replica-axis width


def _group_key(req) -> tuple:
    # Stack-compatibility: same problem content + same config modulo the
    # replica-axis width (which stacking itself determines). Every other
    # config field splits the group — in particular ``flip_mode``: a colored
    # request and a single-flip request run different kernels and must never
    # share a launch's replica axis.
    return (req.problem_key,
            dataclasses.replace(req.config, num_replicas=1))


def plan_batches(requests: Sequence, *,
                 max_stack_replicas: int = 256) -> list:
    """Group admitted requests into launch plans. Within one (problem,
    config-modulo-replicas) group: seed-pinned requests with identical full
    configs form vmap lanes (>= 2 lanes; a lone request launches single),
    seed-free requests stack into the replica axis up to
    ``max_stack_replicas`` per launch. Plan order preserves request order
    within each group, and groups are emitted in first-seen order."""
    groups: dict = {}
    for req in requests:
        groups.setdefault(_group_key(req), []).append(req)
    plans = []
    for key, reqs in groups.items():
        pinned = [r for r in reqs if r.seed is not None]
        free = [r for r in reqs if r.seed is None]
        by_cfg: dict = {}
        for r in pinned:
            by_cfg.setdefault(r.config, []).append(r)
        for cfg, lane in by_cfg.items():
            if len(lane) >= 2:
                plans.append(BatchPlan(kind="vmap", requests=tuple(lane),
                                       config=cfg))
            else:
                plans.append(BatchPlan(kind="single", requests=tuple(lane),
                                       config=cfg))
        while free:
            # Greedy fill up to the stack cap; a lone oversized request
            # still launches (singly) rather than starving.
            take = [free.pop(0)]
            total = take[0].config.num_replicas
            while free and total + free[0].config.num_replicas <= max_stack_replicas:
                r = free.pop(0)
                take.append(r)
                total += r.config.num_replicas
            if len(take) == 1:
                plans.append(BatchPlan(kind="single", requests=tuple(take),
                                       config=take[0].config))
                continue
            spans, off = [], 0
            for r in take:
                spans.append((off, r.config.num_replicas))
                off += r.config.num_replicas
            width = bucket_replicas(off)
            cfg = dataclasses.replace(take[0].config, num_replicas=width)
            plans.append(BatchPlan(kind="stack", requests=tuple(take),
                                   config=cfg, spans=tuple(spans),
                                   launch_replicas=width))
    return plans
