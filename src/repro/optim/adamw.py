"""AdamW with sharded states and optional 8-bit (block-quantized) moments.

Optimizer states inherit each parameter's sharding (quantization blocks run
along the **last** axis only, so leading-dim shardings — FSDP on `embed`,
TP on `heads`/`ffn` — are preserved on the int8 codes). The 8-bit mode stores
m and v as int8 with fp32 absmax per 256-element block (Dettmers-style),
cutting optimizer HBM 4× vs fp32 — this is what lets nemotron-4-340b fit
training state on 256 × 16 GiB chips (EXPERIMENTS.md §Dry-run).

Gradient clipping is global-norm; weight decay is decoupled (AdamW).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

QBLOCK = 256  # elements per quantization block (last axis)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_dtype: str = "float32"     # float32 | bfloat16 | int8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Last-axis blockwise-quantized tensor (sharding-preserving)."""

    codes: jax.Array   # int8, shape = lead_dims + (padded_last,)
    scales: jax.Array  # f32,  shape = lead_dims + (num_blocks,)
    orig_last: int     # static: unpadded last-dim size

    def tree_flatten(self):
        return (self.codes, self.scales), (self.orig_last,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(codes=children[0], scales=children[1], orig_last=aux[0])


def _quantize(x: jax.Array) -> QTensor:
    lead = x.shape[:-1]
    last = x.shape[-1] if x.ndim else 1
    xf = x.astype(jnp.float32).reshape(lead + (last,))
    nb = -(-last // QBLOCK)
    pad = nb * QBLOCK - last
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = xf.reshape(lead + (nb, QBLOCK))
    scales = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)
    codes = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    return QTensor(codes=codes.reshape(lead + (nb * QBLOCK,)), scales=scales,
                   orig_last=last)


def _dequantize(q: QTensor, shape) -> jax.Array:
    lead = q.codes.shape[:-1]
    nb = q.scales.shape[-1]
    blocks = q.codes.astype(jnp.float32).reshape(lead + (nb, QBLOCK))
    out = (blocks * q.scales[..., None]).reshape(lead + (nb * QBLOCK,))
    out = out[..., :q.orig_last]
    return out.reshape(shape)


class AdamWState(NamedTuple):
    step: jax.Array
    m: object  # pytree congruent with params at param positions
    v: object


def _encode(x, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.dtype(dtype))


def _decode(x, shape, dtype: str):
    if dtype == "int8":
        return _dequantize(x, shape)
    return x.astype(jnp.float32)


def _map_over_params(params, fn, *rests):
    """tree.map over the *param* tree structure; rest trees may hold QTensor
    (or any subtree) at each param-leaf position."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_rests = [treedef.flatten_up_to(r) for r in rests]
    out = [fn(p, *(fr[i] for fr in flat_rests)) for i, p in enumerate(flat_p)]
    return out, treedef


def adamw_init(params, config: AdamWConfig) -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape if p.ndim else (1,), jnp.float32)
        return _encode(z, config.state_dtype)

    flat, treedef = _map_over_params(params, zero_like)
    m = jax.tree.unflatten(treedef, flat)
    flat_v, _ = _map_over_params(params, zero_like)
    v = jax.tree.unflatten(treedef, flat_v)
    return AdamWState(step=jnp.int32(0), m=m, v=v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, config: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state, metrics)."""
    lr = config.learning_rate if lr is None else lr
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, config.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1, b2 = config.beta1, config.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        shape = p.shape if p.ndim else (1,)
        g32 = g.astype(jnp.float32).reshape(shape) * clip
        m32 = b1 * _decode(m, shape, config.state_dtype) + (1 - b1) * g32
        v32 = b2 * _decode(v, shape, config.state_dtype) + (1 - b2) * g32 * g32
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + config.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + config.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32).reshape(shape) - lr * update).astype(p.dtype)
        return (new_p.reshape(p.shape), _encode(m32, config.state_dtype),
                _encode(v32, config.state_dtype))

    flat, treedef = _map_over_params(params, upd, grads, state.m, state.v)
    new_params = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in flat])
    metrics = {"grad_norm": gnorm, "clip_factor": clip}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics


def state_bytes(state: AdamWState) -> int:
    """Actual optimizer-state bytes (for the memory accounting in §Dry-run)."""
    total = 0
    for leaf in jax.tree.leaves(state):
        total += leaf.size * leaf.dtype.itemsize
    return total
