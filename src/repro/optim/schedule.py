"""Learning-rate schedules for the train loop."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_lr(base_lr: float, total_steps: int, min_ratio: float = 0.1):
    def lr(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(total_steps, 1), 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * (min_ratio + (1 - min_ratio) * cos)

    return lr


def linear_warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                         min_ratio: float = 0.1):
    decay = cosine_lr(base_lr, max(total_steps - warmup_steps, 1), min_ratio)

    def lr(step):
        step_f = step.astype(jnp.float32)
        warm = base_lr * step_f / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, decay(step - warmup_steps))

    return lr
