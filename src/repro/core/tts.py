"""Time-to-solution statistics (paper Eq. 32).

    TTS(p) = t_a · ln(1 − p) / ln(1 − P_a(t_a))

with each run a Bernoulli trial succeeding with probability ``P_a(t_a)``.
Edge cases follow the standard convention (Rønnow et al.): P_a = 0 ⇒ ∞;
P_a ≥ p ⇒ a single run suffices ⇒ TTS = t_a.

We report TTS both in *steps* (hardware-neutral, what the algorithm controls)
and in seconds given a per-step cost (from measurement or the roofline model).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class TTSResult:
    success_probability: float
    num_runs: int
    num_successes: int
    tts: float              # in the unit of ``time_per_run``
    time_per_run: float
    target_probability: float


def success_probability(best_energies, threshold: float) -> float:
    """Fraction of runs reaching the target (energy ≤ threshold).

    Zero runs means zero observed successes — 0.0, matching ``estimate``
    (``np.mean`` of an empty array would be NaN plus a RuntimeWarning).
    """
    best = np.asarray(best_energies)
    if best.size == 0:
        return 0.0
    return float(np.mean(best <= threshold))


def tts(p_success: float, time_per_run: float, target: float = 0.99) -> float:
    """Eq. 32 with edge cases."""
    if not (0.0 < target < 1.0):
        raise ValueError("target must be in (0, 1)")
    if p_success <= 0.0:
        return math.inf
    if p_success >= target:
        return time_per_run
    return time_per_run * math.log1p(-target) / math.log1p(-p_success)


def estimate(best_energies, threshold: float, time_per_run: float,
             target: float = 0.99) -> TTSResult:
    best = np.asarray(best_energies).reshape(-1)
    hits = int(np.sum(best <= threshold))
    p = hits / best.size if best.size else 0.0
    return TTSResult(
        success_probability=p,
        num_runs=int(best.size),
        num_successes=hits,
        tts=tts(p, time_per_run, target),
        time_per_run=time_per_run,
        target_probability=target,
    )
