"""First-class coupling-store subsystem: every J tier behind one descriptor.

The fused backend stores the coupling matrix in one of four tiers (paper
§IV-B1 makes configurable coupling precision the digital machine's edge; the
reuse-aware near-memory Ising literature makes J *placement* the central
design axis):

* ``dense``            — (N, N) f32, VMEM-resident (the f32 wall at N≈2000).
* ``bitplane``         — packed signed bit-planes in VMEM, 2·B bits/coupler
                         (the N≈2000 → N≈11k wall move).
* ``bitplane_hbm``     — the same planes resident in HBM, selected rows
                         double-buffered through VMEM scratch (N-ceiling =
                         single-device HBM).
* ``bitplane_sharded`` — the planes **row-sharded across the mesh** (device d
                         owns rows [d·N/D, (d+1)·N/D) plus the matching slice
                         of the local fields u); J capacity scales with
                         aggregate HBM, D× past the single-device wall. Spin
                         selection is a local partial roulette combined across
                         devices; the flip broadcast is the owner's (B, 1, W)
                         row tiles — O(B·N/32) words/step. Served by the
                         spin-parallel driver
                         ``repro.distributed.solver_sharded.solve_sharded``;
                         the other three tiers are single-device kernel modes.
* ``bitplane_sharded_2d`` — the sharded planes on a **(groups…, rows)**
                         mesh: the last axis row-shards exactly as above
                         *within* each replica group, the leading axes
                         replicate the planes across groups that each run an
                         independent block of R/G replicas at global indices.
                         Per-device J bytes = total / rows_per_group; replica
                         throughput scales with the group count; all hot-path
                         collectives stay inside the group's rows sub-axis.
                         Served by the same spin-parallel driver (a 1-D mesh
                         is its degenerate single-group case).

Before this module existed the resolve→encode→(planes, fmt) plumbing was
hand-rolled in every driver (``solve``, ``solve_tempering``,
``solve_distributed``) and the format constants lived in ``kernels.ops``.
Now :meth:`CouplingStore.build` is the single host-side entry point
(plane packing is host-side numpy, so it must run *outside* jit — an explicit
plane format under a jax trace raises), :data:`FORMATS` is the registry every
consumer dispatches through, and the kernel-side contract is
:func:`validate_kernel_operand` plus the store's ``kernel_operand``.

``build`` consumes either the dense (N, N) J or a canonical
:class:`~repro.core.ising.EdgeList` — the dense-J-free ingestion path:
edges pack straight into planes in O(nnz) (``bitplane.encode_edges``) and
can never resolve to a dense store, so an instance given as an edge list is
solved end to end without any (N, N) array existing. :func:`timed_build` /
:func:`measure_host_build` record the setup cost (wall seconds + tracemalloc
peak) the benchmark's ``setup_seconds`` / ``peak_j_build_bytes`` cells gate.
"""
from __future__ import annotations

import dataclasses
import time
import tracemalloc
from typing import Optional, Sequence

import jax
import numpy as np

from .bitplane import BitPlanes, encode_couplings, encode_edges
from .ising import EdgeList

#: The f32 VMEM wall (DESIGN.md §Backends): above this N a dense f32 J no
#: longer fits VMEM alongside the sweep state, so ``coupling_format="auto"``
#: switches integral-J problems to the packed bit-plane store.
DENSE_COUPLING_MAX_N = 2000

#: The packed-VMEM wall: above this N even the bit-plane store (2·B bits per
#: coupler; pos+neg = N²·B/4 bytes ≈ 16 MiB at N=8k, B=1) no longer fits VMEM
#: alongside the sweep state, so ``coupling_format="auto"`` switches to the
#: HBM-streamed plane store (``bitplane_hbm``).
BITPLANE_VMEM_MAX_N = 8000

#: Word-axis alignment for HBM-resident (streamed or sharded) planes: those
#: paths move whole (B, 1, W) row tiles per step, so W is padded to the
#: 128-word TPU lane tile (zero bits — decode truncates to N, so padding is
#: representation-invisible).
STREAM_ALIGN_WORDS = 128

#: What the fused sweep holds per coupler: dense f32 = 32 bits; bit-planes =
#: 2·B bits (pos + neg planes). Used by "auto" resolution and the benchmark's
#: J-bytes accounting.
DENSE_COUPLING_BITS = 32


@dataclasses.dataclass(frozen=True)
class CouplingFormatSpec:
    """Registry row for one resolved coupling format."""

    name: str
    packed: bool        #: consumes a packed ``BitPlanes`` (vs a dense (N, N) J)
    align_words: int    #: word-axis padding the encoder applies for this tier
    kernel_mode: bool   #: implemented by the single-device Pallas sweep kernel
    #: row fetches move data (HBM DMA / mesh psum) rather than read VMEM, so
    #: duplicate per-step selections are worth coalescing to unique rows
    #: (``kernels.common.coalesce_rows`` — the reuse-aware fetch plan).
    coalescable: bool
    summary: str


#: The format registry — the single source of truth for which coupling tiers
#: exist, how their planes are padded, and which execution path serves them.
FORMATS: dict[str, CouplingFormatSpec] = {spec.name: spec for spec in (
    CouplingFormatSpec("dense", False, 1, True, False,
                       "(N, N) f32 J resident in VMEM"),
    CouplingFormatSpec("bitplane", True, 1, True, False,
                       "packed signed bit-planes resident in VMEM"),
    CouplingFormatSpec("bitplane_hbm", True, STREAM_ALIGN_WORDS, True, True,
                       "planes in HBM, rows streamed through VMEM scratch"),
    CouplingFormatSpec("bitplane_sharded", True, STREAM_ALIGN_WORDS, False,
                       True,
                       "planes row-sharded across the mesh (spin-parallel)"),
    CouplingFormatSpec("bitplane_sharded_2d", True, STREAM_ALIGN_WORDS, False,
                       True,
                       "planes row-sharded within each replica group of a "
                       "(groups, rows) mesh, replicated across groups"),
)}

#: Valid values of the ``coupling_format`` knob on ``SolverConfig`` /
#: ``TemperingConfig`` ("auto" + every registered format).
COUPLING_FORMATS = ("auto",) + tuple(FORMATS)

#: Formats whose payload is a packed ``BitPlanes``.
PLANE_FORMATS = tuple(s.name for s in FORMATS.values() if s.packed)

#: Formats the single-device Pallas sweep kernel implements (the sharded tier
#: is served by the spin-parallel shard_map driver instead).
KERNEL_COUPLING_MODES = tuple(s.name for s in FORMATS.values() if s.kernel_mode)

#: Kernel modes that consume a packed ``BitPlanes``.
KERNEL_PLANE_MODES = tuple(
    s.name for s in FORMATS.values() if s.packed and s.kernel_mode)

#: Formats whose per-step row fetch is real data movement (HBM DMA or mesh
#: psum) and therefore benefits from the reuse-aware unique-row coalescing.
COALESCABLE_FORMATS = tuple(
    s.name for s in FORMATS.values() if s.coalescable)


def resolve_format(fmt: Optional[str], couplings, n: int) -> str:
    """Resolve the ``coupling_format`` knob to a registered format name.

    "auto" (or None) selects a packed store exactly when the couplings are
    concrete (host-inspectable — encoding runs in numpy), integral, N is
    past the f32 VMEM crossover (:data:`DENSE_COUPLING_MAX_N`), **and** the
    packed store is actually smaller — 2·B bits per coupler must beat the 32
    of dense f32, so integer magnitudes needing B ≥ 16 planes stay dense.
    Past the packed-VMEM wall (:data:`BITPLANE_VMEM_MAX_N`) "auto" escalates
    to "bitplane_hbm": planes in HBM, rows streamed through VMEM scratch.
    "auto" never resolves to "bitplane_sharded" — the sharded tier needs a
    mesh, so only its driver (or an explicit knob) selects it.
    An explicit plane format under a jax trace raises — the planes cannot be
    packed from a tracer; encode first and pass them in.

    An :class:`~repro.core.ising.EdgeList` source is dense-J-free by
    contract: "auto" always resolves to a plane tier (VMEM planes up to the
    packed wall, HBM-streamed past it — never "dense", which would
    materialize the (N, N) f32 the representation exists to avoid), and an
    explicit "dense" raises.
    """
    if isinstance(couplings, EdgeList):
        if fmt in (None, "auto"):
            return "bitplane" if n <= BITPLANE_VMEM_MAX_N else "bitplane_hbm"
        if fmt not in FORMATS:
            raise ValueError(f"coupling format must be one of "
                             f"{COUPLING_FORMATS}, got {fmt!r}")
        if not FORMATS[fmt].packed:
            raise ValueError(
                "edge-list couplings are dense-J-free: coupling_format="
                f"{fmt!r} would materialize the (N, N) f32 matrix — use a "
                f"plane format ({PLANE_FORMATS}) or edges.to_dense() "
                "explicitly for small N")
        return fmt
    traced = isinstance(couplings, jax.core.Tracer)
    if fmt in (None, "auto"):
        if traced or n <= DENSE_COUPLING_MAX_N:
            return "dense"
        J = np.asarray(couplings)
        if not np.array_equal(J, np.rint(J)):
            return "dense"
        num_planes = max(1, int(np.abs(J).max(initial=0)).bit_length())
        if 2 * num_planes >= DENSE_COUPLING_BITS:
            return "dense"
        return "bitplane" if n <= BITPLANE_VMEM_MAX_N else "bitplane_hbm"
    if fmt not in FORMATS:
        raise ValueError(
            f"coupling format must be one of {COUPLING_FORMATS}, got {fmt!r}")
    if FORMATS[fmt].packed and traced:
        raise ValueError(f"coupling_format={fmt!r} needs concrete couplings "
                         "(plane packing happens on the host, outside jit)")
    return fmt


def encode_planes(couplings, num_planes: Optional[int] = None,
                  fmt: str = "bitplane") -> BitPlanes:
    """Pack a concrete integral J (dense matrix **or** edge list) for a
    plane-backed coupling tier.

    ``num_planes`` defaults to the fewest planes that represent |J|max
    (B = bit_length(|J|max), ≥ 1) — memory is linear in B, so auto-selection
    never over-allocates precision (paper §IV-B1). The word axis is padded to
    the registry's per-format alignment (:data:`STREAM_ALIGN_WORDS` for the
    HBM-streamed and sharded tiers) so each moved row tile is a
    full-lane-width copy (padding is zero bits; decode truncates). An
    :class:`EdgeList` routes through the O(nnz) sparse encoder — the
    dense-J-free ingestion path.
    """
    if isinstance(couplings, EdgeList):
        return encode_edges(couplings, num_planes,
                            align_words=FORMATS[fmt].align_words)
    J = np.asarray(couplings)
    if num_planes is None:
        amax = int(np.abs(np.rint(J)).max(initial=0))
        num_planes = max(1, amax.bit_length())
    return encode_couplings(J, num_planes,
                            align_words=FORMATS[fmt].align_words)


def validate_kernel_operand(coupling: str, couplings, n: int,
                            gather: str = "dynamic") -> None:
    """The kernel-side contract: what ``kernels.sweep.mcmc_sweep`` may be fed
    for each store mode (shared with the spin-sharded driver's own checks)."""
    if coupling not in KERNEL_COUPLING_MODES:
        raise ValueError(
            f"coupling must be one of {KERNEL_COUPLING_MODES}, got {coupling!r}")
    if coupling in KERNEL_PLANE_MODES:
        if not isinstance(couplings, BitPlanes):
            raise TypeError(f"coupling={coupling!r} needs a BitPlanes "
                            f"couplings argument, got {type(couplings).__name__}")
        validate_planes_cover(couplings, n)
        if gather == "onehot":
            raise ValueError("gather='onehot' requires a dense J (the MXU "
                             "contraction cannot consume packed planes)")
    else:
        assert couplings.shape == (n, n)


def validate_planes_cover(planes: BitPlanes, n: int) -> None:
    """Shared shape contract for every plane consumer (kernel or sharded)."""
    from .bitplane import WORD_BITS

    if planes.num_spins != n:
        raise ValueError(f"BitPlanes N={planes.num_spins} != state N={n}")
    if planes.num_words * WORD_BITS < n:
        raise ValueError(f"BitPlanes W={planes.num_words} words cannot "
                         f"cover N={n} couplers")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CouplingStore:
    """One J tier as a value: resolved format + its payload.

    A pytree whose format/size live in the aux data, so jitted driver impls
    can take a store directly (the format is static, the payload traced) —
    replacing the ``(planes, fmt)`` tuples every driver used to hand-roll.
    """

    fmt: str
    num_spins: int
    dense: Optional[jax.Array] = None
    planes: Optional[BitPlanes] = None

    def tree_flatten(self):
        return (self.dense, self.planes), (self.fmt, self.num_spins)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(fmt=aux[0], num_spins=aux[1], dense=children[0],
                   planes=children[1])

    @classmethod
    def build(cls, couplings, fmt: Optional[str] = "auto", *,
              num_planes: Optional[int] = None) -> "CouplingStore":
        """The single host-side resolve→encode entry point every driver
        dispatches through (``solve`` / ``solve_tempering`` /
        ``solve_distributed`` / ``solve_sharded``). Runs outside jit: "auto"
        under a trace quietly stays dense; an explicit plane format under a
        trace raises (see :func:`resolve_format`). ``couplings`` is the dense
        (N, N) J **or** an :class:`EdgeList` — the latter packs planes in
        O(nnz) and can never produce a dense store."""
        if isinstance(couplings, EdgeList):
            n = couplings.num_spins
        else:
            n = int(couplings.shape[-1])
        resolved = resolve_format(fmt, couplings, n)
        if FORMATS[resolved].packed:
            return cls(fmt=resolved, num_spins=n,
                       planes=encode_planes(couplings, num_planes, resolved))
        return cls(fmt=resolved, num_spins=n, dense=couplings)

    @classmethod
    def from_planes(cls, planes: BitPlanes, fmt: str = "bitplane") -> "CouplingStore":
        """Wrap pre-packed planes (skips the O(N²·B) re-encode — the
        benchmark / repeated-solve path)."""
        if not FORMATS[fmt].packed:
            raise ValueError(f"from_planes needs a plane format, got {fmt!r}")
        return cls(fmt=fmt, num_spins=planes.num_spins, planes=planes)

    @property
    def spec(self) -> CouplingFormatSpec:
        return FORMATS[self.fmt]

    @property
    def kernel_operand(self):
        """What the sweep consumes: the packed planes or the dense J."""
        return self.planes if self.spec.packed else self.dense

    @property
    def nbytes(self) -> int:
        if self.spec.packed:
            return self.planes.nbytes
        return int(self.dense.size) * int(self.dense.dtype.itemsize)

    def plane_bytes_per_shard(self, num_shards: int) -> int:
        """Per-device plane bytes under row-sharding (the sharded tier's
        memory accounting: total planes divided across the mesh)."""
        if not self.spec.packed:
            raise ValueError(f"{self.fmt!r} store has no planes to shard")
        if self.num_spins % num_shards:
            raise ValueError(f"N={self.num_spins} rows cannot shard evenly "
                             f"over {num_shards} devices")
        return self.planes.nbytes // num_shards

    def plane_bytes_per_device(self, mesh_shape: Sequence[int]) -> int:
        """Per-device plane bytes on a ``(groups..., rows)`` mesh shape: the
        planes row-shard over the **last** axis only and replicate across the
        leading replica-group axes, so only ``rows`` divides the footprint —
        the capacity half of the 2-D capacity × throughput trade."""
        rows = int(tuple(mesh_shape)[-1])
        return self.plane_bytes_per_shard(rows)

    def require_num_spins(self, n: int, driver: str) -> "CouplingStore":
        """Prebuilt-store contract check: a memoized store must match the
        problem it is reused against."""
        if self.num_spins != n:
            raise ValueError(f"prebuilt CouplingStore is for N="
                             f"{self.num_spins} but {driver} got a problem "
                             f"with N={n}")
        return self

    def require(self, supported: Sequence[str], driver: str) -> "CouplingStore":
        """Driver-side registry check: raise if this store's tier is served
        by a different execution path."""
        if self.fmt not in tuple(supported):
            hint = (" — the spin-sharded store is served by the spin-parallel "
                    "driver repro.distributed.solver_sharded.solve_sharded"
                    if self.fmt in ("bitplane_sharded", "bitplane_sharded_2d")
                    else "")
            raise ValueError(
                f"coupling_format={self.fmt!r} is not supported by {driver} "
                f"(supported: {tuple(supported)}){hint}")
        return self


def measure_host_build(thunk):
    """Run a host-side build step under wall-clock + tracemalloc peak
    accounting. Returns ``(result, stats)`` with ``stats = {"seconds",
    "peak_bytes"}`` — ``peak_bytes`` is the peak *additional* traced host
    allocation during the call (python/numpy; device buffers staged from
    numpy are counted at staging). This is the measurement behind the
    benchmark's ``setup_seconds`` / ``peak_j_build_bytes`` cells: a dense
    ingest at N=16384 peaks in the GiBs (the (N, N) f32 plus the encoder's
    int64 temporaries), a sparse→plane ingest peaks at roughly the plane
    bytes themselves — the dense-J-free claim as a recorded number.
    """
    was_tracing = tracemalloc.is_tracing()
    if not was_tracing:
        tracemalloc.start()
    try:
        base, _ = tracemalloc.get_traced_memory()
        tracemalloc.reset_peak()
        t0 = time.perf_counter()
        result = thunk()
        seconds = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
    finally:
        if not was_tracing:
            tracemalloc.stop()
    return result, {"seconds": seconds, "peak_bytes": int(max(peak - base, 0))}


def timed_build(couplings, fmt: Optional[str] = "auto", *,
                num_planes: Optional[int] = None):
    """:meth:`CouplingStore.build` under :func:`measure_host_build` —
    ``(store, stats)`` for the benchmark's setup-cost cells."""
    return measure_host_build(
        lambda: CouplingStore.build(couplings, fmt, num_planes=num_planes))
