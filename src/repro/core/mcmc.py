"""Dual-mode MCMC spin selection with asynchronous single-spin updates (paper Alg. 1).

Mode I  — **RSA** (random-scan): select a site uniformly (Eq. 22), accept the
flip with the Glauber probability (Eq. 2/26). Satisfies detailed balance w.r.t.
the Gibbs distribution π_T (paper Eq. 6–9).

Mode II — **RWA** (roulette-wheel): evaluate all N candidate flip probabilities
in parallel, select exactly one index with probability ``p_i / Σ_j p_j``
(Eq. 10/29) and flip it *deterministically* (rejection-free). An optional
*uniformized* variant performs a null transition with probability ``1 − W/W*``
(W* = N), which restores invariance of the Gibbs distribution (paper §IV-B3c).
If the aggregate weight W is numerically degenerate (≤ 0 or non-finite) the
kernel falls back to a single random-scan update (Alg. 1 lines 10–14).

Both modes flip at most one spin per step and propagate its effect to every
local field immediately via the incremental rule ``u_i ← u_i − 2 J_ij s_j_old``
(Eq. 27/31) — the asynchronous-update semantics of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import ising, rng
from .pwl import FlipProbFn, exact_flip_probability


class ChainState(NamedTuple):
    """State of one Markov chain (one replica)."""

    spins: jax.Array       # (N,) int8 ±1
    fields: jax.Array      # (N,) f32 — full local field u_i = u_i^(J) + h_i
    energy: jax.Array      # () f32 — H(s), tracked incrementally
    best_energy: jax.Array # () f32
    best_spins: jax.Array  # (N,) int8
    num_flips: jax.Array   # () int32 — accepted flips (diagnostics)


class StepInfo(NamedTuple):
    site: jax.Array      # () int32 — selected spin
    accepted: jax.Array  # () bool
    temperature: jax.Array  # () f32


@dataclasses.dataclass(frozen=True)
class MCMCConfig:
    """Static configuration of the dual-mode engine."""

    mode: str = "rwa"              # "rsa" | "rwa"
    uniformized: bool = False      # RWA only: uniformized CTMC variant
    flip_prob: FlipProbFn = exact_flip_probability  # exact or PWL (paper LUT)

    def __post_init__(self):
        if self.mode not in ("rsa", "rwa"):
            raise ValueError(f"mode must be 'rsa' or 'rwa', got {self.mode!r}")


def init_chain(problem: ising.IsingProblem, spins: jax.Array) -> ChainState:
    """Local-field initialization from scratch (Alg. 1 lines 2–3)."""
    u = ising.local_fields(problem, spins)
    e = ising.energy(problem, spins)
    return ChainState(
        spins=spins.astype(ising.SPIN_DTYPE),
        fields=u.astype(jnp.float32),
        energy=e.astype(jnp.float32),
        best_energy=e.astype(jnp.float32),
        best_spins=spins.astype(ising.SPIN_DTYPE),
        num_flips=jnp.int32(0),
    )


def _apply_flip(problem: ising.IsingProblem, state: ChainState, j: jax.Array,
                accept: jax.Array, delta_e: jax.Array) -> ChainState:
    """Asynchronous single-spin update + incremental field maintenance."""
    s_old_j = jnp.take(state.spins, j)  # pre-flip spin cache (Alg. 1 line 15/22)
    acc_f = accept.astype(jnp.float32)
    new_spins = state.spins.at[j].set(
        jnp.where(accept, -s_old_j, s_old_j).astype(state.spins.dtype))
    row = jnp.take(problem.couplings, j, axis=0)  # == column j (J symmetric)
    new_fields = state.fields - (2.0 * acc_f * s_old_j.astype(jnp.float32)) * row
    new_energy = state.energy + acc_f * delta_e
    better = new_energy < state.best_energy
    return ChainState(
        spins=new_spins,
        fields=new_fields,
        energy=new_energy,
        best_energy=jnp.where(better, new_energy, state.best_energy),
        best_spins=jnp.where(better, new_spins, state.best_spins),
        num_flips=state.num_flips + accept.astype(jnp.int32),
    )


def rsa_step(problem: ising.IsingProblem, state: ChainState, key: jax.Array,
             temperature: jax.Array, config: MCMCConfig) -> tuple[ChainState, StepInfo]:
    """Mode I: random-scan selection + stochastic Glauber accept (paper §IV-B3b)."""
    n = problem.num_spins
    j = rng.uniform_index(rng.stream(key, rng.Salt.SITE), n)
    u_j = jnp.take(state.fields, j)
    s_j = jnp.take(state.spins, j).astype(jnp.float32)
    delta_e = 2.0 * s_j * u_j  # Eq. 24
    p = config.flip_prob(delta_e, temperature)  # Eq. 25
    accept = rng.uniform01(rng.stream(key, rng.Salt.ACCEPT)) < p  # Eq. 26
    new_state = _apply_flip(problem, state, j, accept, delta_e)
    return new_state, StepInfo(site=j, accepted=accept, temperature=jnp.float32(temperature))


def rwa_step(problem: ising.IsingProblem, state: ChainState, key: jax.Array,
             temperature: jax.Array, config: MCMCConfig) -> tuple[ChainState, StepInfo]:
    """Mode II: roulette-wheel selection + deterministic flip (paper §IV-B3c)."""
    n = problem.num_spins
    delta_e_all = 2.0 * state.spins.astype(jnp.float32) * state.fields  # Alg. 1 line 7
    p_all = config.flip_prob(delta_e_all, temperature)  # Alg. 1 line 8
    total = jnp.sum(p_all)  # W, Eq. 28
    degenerate = (total <= 0) | ~jnp.isfinite(total)  # Alg. 1 line 9

    # Roulette wheel: r ∈ [0, W); first j with cumsum(p)[j] > r.
    wheel = jnp.cumsum(p_all)
    r = rng.uniform01(rng.stream(key, rng.Salt.ROULETTE)) * jnp.where(degenerate, 1.0, total)
    j_rw = jnp.clip(jnp.searchsorted(wheel, r, side="right"), 0, n - 1).astype(jnp.int32)

    if config.uniformized:
        # Null transition with probability 1 − W/W*, W* = N (uniformized CTMC).
        coin = rng.uniform01(rng.stream(key, rng.Salt.UNIFORMIZE)) * jnp.float32(n)
        accept_rw = coin < total
        # With uniformization, W = 0 ⇒ always a null transition.
        j = j_rw
        accept = jnp.where(degenerate, False, accept_rw)
    else:
        # Fallback: conventional random-scan single-site update (Alg. 1 lines 10–14).
        j_fb = rng.uniform_index(rng.stream(key, rng.Salt.SITE), n)
        p_fb = jnp.take(p_all, j_fb)
        accept_fb = rng.uniform01(rng.stream(key, rng.Salt.ACCEPT)) < p_fb
        j = jnp.where(degenerate, j_fb, j_rw)
        accept = jnp.where(degenerate, accept_fb, True)

    delta_e = jnp.take(delta_e_all, j)
    new_state = _apply_flip(problem, state, j, accept, delta_e)
    return new_state, StepInfo(site=j, accepted=accept, temperature=jnp.float32(temperature))


def step(problem: ising.IsingProblem, state: ChainState, key: jax.Array,
         temperature: jax.Array, config: MCMCConfig) -> tuple[ChainState, StepInfo]:
    """One dual-mode Monte Carlo step (mode is static — one datapath, two schemes)."""
    if config.mode == "rsa":
        return rsa_step(problem, state, key, temperature, config)
    return rwa_step(problem, state, key, temperature, config)
