"""Greedy 1-opt post-processing (beyond-paper quality polish).

After annealing, repeatedly flip the single spin with the most negative
ΔE = 2 s_i u_i until no improving flip exists — a deterministic descent that
costs Θ(N) per flip with the same incremental local-field update the paper's
hardware uses (Eq. 12). Ising machines commonly attach such a local-search
stage; it never hurts the cut and typically recovers the last fraction of a
percent the stochastic schedule leaves on the table.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ising


@partial(jax.jit, static_argnames=("max_flips",))
def greedy_descent(problem: ising.IsingProblem, spins: jax.Array,
                   max_flips: int = 512):
    """spins: (..., N) ±1. Returns (refined spins, refined energy)."""

    def one_chain(s):
        u = ising.local_fields(problem, s)
        e = ising.energy(problem, s)

        def body(carry):
            s, u, e, _, count = carry
            de = 2.0 * s.astype(jnp.float32) * u
            j = jnp.argmin(de)
            improving = de[j] < -1e-6
            s_old = s[j]
            s = jnp.where(improving, s.at[j].set(-s_old), s)
            row = jnp.take(problem.couplings, j, axis=0)
            u = jnp.where(improving, u - 2.0 * row * s_old.astype(u.dtype), u)
            e = jnp.where(improving, e + de[j], e)
            return s, u, e, improving, count + 1

        s, u, e, _, _ = jax.lax.while_loop(
            lambda c: c[3] & (c[4] < max_flips), body,
            (s, u, e, jnp.bool_(True), jnp.int32(0)))
        return s, e

    flat = spins.reshape(-1, spins.shape[-1])
    s_out, e_out = jax.vmap(one_chain)(flat)
    return (s_out.reshape(spins.shape),
            e_out.reshape(spins.shape[:-1]) + problem.offset)
