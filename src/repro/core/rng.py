"""Stateless counter-based RNG (paper §IV-B3d).

Snowball's hardware draws every variate as a pure function of a global 64-bit
seed and a small set of indices (annealing stage k, iteration t, salt r) —
exactly the semantics of JAX's threefry counter RNG with ``fold_in``. Each
logical stream (site-selection, accept/reject, roulette radius, replica id) has
a fixed salt so independent numbers are produced in parallel with no shared
state, mirroring the paper's argument (i) for statelessness.
"""
from __future__ import annotations

from enum import IntEnum

import jax
import jax.numpy as jnp


class Salt(IntEnum):
    """Purpose-specific salts (paper: 'a purpose-specific salt r')."""

    SITE = 0          # which spin index to visit (random-scan, Eq. 22)
    ACCEPT = 1        # accept/reject uniform (Eq. 26)
    ROULETTE = 2      # roulette radius r ∈ [0, W) (§IV-B3c)
    UNIFORMIZE = 3    # null-transition coin of the uniformized chain
    INIT = 4          # initial spin configuration
    REPLICA = 5       # replica stream split
    PROBLEM = 6       # problem/instance generation
    SWEEP = 7         # fused-sweep chunk uniforms (disjoint from ROULETTE by
                      # construction — the sequential engine never uses it)


def base_key(seed: int) -> jax.Array:
    """Global 64-bit seed supplied by the host."""
    return jax.random.key(seed)


def stream(key: jax.Array, *indices) -> jax.Array:
    """Pure function (seed, i0, i1, ...) -> key. No RNG state is carried."""
    for ix in indices:
        key = jax.random.fold_in(key, jnp.asarray(ix, dtype=jnp.uint32))
    return key


def index_from_uniform(u01: jax.Array, n: int) -> jax.Array:
    """Canonical ``u ∈ [0,1) → site index`` rescaling (paper Eq. 22).

    This is the single site-derivation shared by the sequential engine
    (:func:`uniform_index`), the fused sweep kernel, and its jnp oracle, so
    backend-parity tests can require exact trajectory agreement. float32
    resolution (2⁻²⁴) is ample for the VMEM-resident problem sizes (N ≲ 4k).
    """
    j = (u01.astype(jnp.float32) * jnp.float32(n)).astype(jnp.int32)
    return jnp.minimum(j, jnp.int32(n - 1))


#: Largest N for which the shared float32 rescaling is used by
#: :func:`uniform_index` — covers every VMEM-resident fused-sweep size, so
#: the sequential engine and the kernel draw sites identically there.
FLOAT_INDEX_MAX_N = 4096


def uniform_index(key: jax.Array, n: int) -> jax.Array:
    """Uniform site index. For N up to :data:`FLOAT_INDEX_MAX_N` this is one
    32-bit draw through the canonical :func:`index_from_uniform` rescaling
    (Eq. 22) — bit-compatible with the fused sweep's site stream. Larger N
    (where float32 rounding against 1/N buckets would bias selection) uses
    the exact fixed-point ``floor(u·N/2³²)`` in 32-bit integer lanes up to
    N ≤ 2¹⁶, then JAX's unbiased bounded-int sampler."""
    if n <= FLOAT_INDEX_MAX_N:
        return index_from_uniform(uniform01(key), n)
    if n <= (1 << 16):
        u = jax.random.bits(key, (), jnp.uint32)
        hi = u >> jnp.uint32(16)
        lo = u & jnp.uint32(0xFFFF)
        nn = jnp.uint32(n)
        # floor(u·N/2³²) == floor((hi·N + floor(lo·N/2¹⁶)) / 2¹⁶); all ≤ 2³².
        return ((hi * nn + ((lo * nn) >> jnp.uint32(16))) >> jnp.uint32(16)).astype(jnp.int32)
    return jax.random.randint(key, (), 0, n, dtype=jnp.int32)


def uniform01(key: jax.Array, shape=()) -> jax.Array:
    """Uniform real in [0, 1) from a 32-bit draw (Eq. 26 rescaling)."""
    u = jax.random.bits(key, shape, jnp.uint32)
    return u.astype(jnp.float32) * jnp.float32(2.0**-32)
