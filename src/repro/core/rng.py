"""Stateless counter-based RNG (paper §IV-B3d).

Snowball's hardware draws every variate as a pure function of a global 64-bit
seed and a small set of indices (annealing stage k, iteration t, salt r) —
exactly the semantics of JAX's threefry counter RNG with ``fold_in``. Each
logical stream (site-selection, accept/reject, roulette radius, replica id) has
a fixed salt so independent numbers are produced in parallel with no shared
state, mirroring the paper's argument (i) for statelessness.
"""
from __future__ import annotations

from enum import IntEnum

import jax
import jax.numpy as jnp


class Salt(IntEnum):
    """Purpose-specific salts (paper: 'a purpose-specific salt r')."""

    SITE = 0          # which spin index to visit (random-scan, Eq. 22)
    ACCEPT = 1        # accept/reject uniform (Eq. 26)
    ROULETTE = 2      # roulette radius r ∈ [0, W) (§IV-B3c)
    UNIFORMIZE = 3    # null-transition coin of the uniformized chain
    INIT = 4          # initial spin configuration
    REPLICA = 5       # replica stream split
    PROBLEM = 6       # problem/instance generation


def base_key(seed: int) -> jax.Array:
    """Global 64-bit seed supplied by the host."""
    return jax.random.key(seed)


def stream(key: jax.Array, *indices) -> jax.Array:
    """Pure function (seed, i0, i1, ...) -> key. No RNG state is carried."""
    for ix in indices:
        key = jax.random.fold_in(key, jnp.asarray(ix, dtype=jnp.uint32))
    return key


def uniform_index(key: jax.Array, n: int) -> jax.Array:
    """Uniform site index via the paper's fixed-point scaling (Eq. 22):
    j = floor(u·N / 2³²) for a uniform 32-bit integer u. Computed with exact
    nested floor-division in 32-bit lanes (x64 is disabled); valid for N ≤ 2¹⁶,
    beyond which two independent draws are combined."""
    if n <= (1 << 16):
        u = jax.random.bits(key, (), jnp.uint32)
        hi = u >> jnp.uint32(16)
        lo = u & jnp.uint32(0xFFFF)
        nn = jnp.uint32(n)
        # floor(u·N/2³²) == floor((hi·N + floor(lo·N/2¹⁶)) / 2¹⁶); all ≤ 2³².
        return ((hi * nn + ((lo * nn) >> jnp.uint32(16))) >> jnp.uint32(16)).astype(jnp.int32)
    # Large N: fall back to JAX's unbiased bounded-int sampler.
    return jax.random.randint(key, (), 0, n, dtype=jnp.int32)


def uniform01(key: jax.Array, shape=()) -> jax.Array:
    """Uniform real in [0, 1) from a 32-bit draw (Eq. 26 rescaling)."""
    u = jax.random.bits(key, shape, jnp.uint32)
    return u.astype(jnp.float32) * jnp.float32(2.0**-32)
