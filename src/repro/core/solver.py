"""Annealed replica-ensemble solver driver (paper Alg. 1 + §V methodology).

Runs R independent Markov chains ("replicas") of the dual-mode MCMC engine
under a programmable annealing schedule. Replicas map onto the hardware's
batch/`data` mesh axis (each Bernoulli trial of the TTS methodology, Eq. 32);
a single chain is the paper's single FPGA kernel.

Tracing is chunked (outer scan emits, inner loop runs ``trace_every`` steps
silently) so million-step runs keep O(K / trace_every) trace memory.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import ising, mcmc, rng
# The CouplingFormat knob values ("auto" | "dense" | "bitplane" |
# "bitplane_hbm" | "bitplane_sharded") now live in the first-class coupling
# subsystem (``core.coupling``) — re-exported here for back-compat; see
# ``core.coupling.FORMATS`` for what each tier means and which driver serves
# it. The reference backend always consumes the dense J.
from .coupling import COUPLING_FORMATS  # noqa: F401
from .pwl import make_flip_probability, make_pwl_sigmoid
from .schedules import Schedule


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Hashable (static) solver configuration."""

    num_steps: int
    schedule: Schedule
    mode: str = "rwa"               # "rsa" | "rwa"
    uniformized: bool = False
    use_pwl: bool = True            # paper-faithful LUT logistic; False = exact sigmoid
    pwl_segments: int = 64
    pwl_zmax: float = 8.0
    num_replicas: int = 8
    trace_every: int = 0            # 0 disables the energy trace
    coupling_format: str = "auto"   # fused-backend J store; see COUPLING_FORMATS
    #: "single" = one spin per replica per step (the paper's async update);
    #: "colored" = one conflict-graph color class per step — O(N/χ) flips on
    #: sparse instances with exact block-Gibbs semantics (ROADMAP item 3,
    #: DESIGN.md §Graph-colored parallel flips). Served by the "colored"
    #: backend; the selection-mode knobs (mode/uniformized) don't apply there.
    flip_mode: str = "single"       # "single" | "colored"


class SolveResult(NamedTuple):
    best_energy: jax.Array     # (R,) incl. problem offset
    best_spins: jax.Array      # (R, N)
    final_energy: jax.Array    # (R,) incl. problem offset
    num_flips: jax.Array       # (R,)
    trace_energy: jax.Array    # (num_chunks, R) best-so-far at chunk ends, or (0, R)
    #: (R,) coupling-row fetches attributed per replica, or None on paths
    #: that don't instrument row traffic (reference oracle, tempering, …).
    #: Uncoalesced tiers count one fetch per replica per step (sum = R·T);
    #: the reuse-aware coalesced tiers (``bitplane_hbm``/``bitplane_sharded``)
    #: fetch each step's unique rows once, so the sum is the actual row
    #: traffic — strictly below R·T whenever replicas collide on a row.
    rows_fetched: Optional[jax.Array] = None

    @property
    def ensemble_best(self) -> jax.Array:
        return jnp.min(self.best_energy)


def _mcmc_config(config: SolverConfig) -> mcmc.MCMCConfig:
    if config.use_pwl:
        fp = make_flip_probability(make_pwl_sigmoid(config.pwl_segments, config.pwl_zmax))
    else:
        fp = make_flip_probability(None)
    return mcmc.MCMCConfig(mode=config.mode, uniformized=config.uniformized, flip_prob=fp)


def reference_init_state(problem: ising.IsingProblem, seed: jax.Array,
                         config: SolverConfig):
    """Replica init for the reference engine: ``(states, replica_keys)`` with
    the exact ``Salt.REPLICA`` → ``Salt.INIT`` derivation of ``_run`` — the
    single definition shared with the resilient chunked driver
    (``core.resilience``), so a resumed reference trajectory starts from the
    identical ensemble."""
    n = problem.num_spins
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0), seed)
    replica_keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(jnp.arange(r))
    init_spins = jax.vmap(lambda k: ising.random_spins(rng.stream(k, rng.Salt.INIT), (n,)))(replica_keys)
    states = jax.vmap(lambda s: mcmc.init_chain(problem, s))(init_spins)
    return states, replica_keys


def _reference_step(problem, states, replica_keys, t, config: SolverConfig,
                    mc: mcmc.MCMCConfig):
    temperature = config.schedule(t)
    step_keys = jax.vmap(lambda k: rng.stream(k, t))(replica_keys)
    new_states, _ = jax.vmap(
        lambda st, k: mcmc.step(problem, st, k, temperature, mc))(states, step_keys)
    return new_states


def run_reference_chunk(problem, states, replica_keys, c, *, clen: int,
                        chunk_len: int, config: SolverConfig,
                        mc: mcmc.MCMCConfig):
    """``clen`` sequential reference MCMC steps starting at global step
    ``c·chunk_len`` — the chunk body under ``_run``'s traced scan and the
    resilient supervisor's per-chunk jit (``core.resilience``). The engine is
    a pure fold over the per-step function (every step keyed by its absolute
    step index ``t``, no carried RNG state), so chunked composition is
    value-identical to one long loop — the resume-parity axis of the
    backend-parity contract."""
    t0 = c * chunk_len
    return jax.lax.fori_loop(
        0, clen,
        lambda i, st: _reference_step(problem, st, replica_keys, t0 + i,
                                      config, mc),
        states)


def _run(problem: ising.IsingProblem, seed: jax.Array, config: SolverConfig) -> SolveResult:
    r = config.num_replicas
    mc = _mcmc_config(config)
    states, replica_keys = reference_init_state(problem, seed, config)

    if config.trace_every and config.trace_every > 0:
        chunk = config.trace_every
        num_chunks = max(config.num_steps // chunk, 1)

        def chunk_body(carry, c):
            states = run_reference_chunk(problem, carry, replica_keys, c,
                                         clen=chunk, chunk_len=chunk,
                                         config=config, mc=mc)
            return states, states.best_energy

        states, trace = jax.lax.scan(chunk_body, states, jnp.arange(num_chunks))
        trace = trace + problem.offset
    else:
        states = jax.lax.fori_loop(
            0, config.num_steps,
            lambda t, st: _reference_step(problem, st, replica_keys, t,
                                          config, mc),
            states)
        trace = jnp.zeros((0, r), jnp.float32)

    return SolveResult(
        best_energy=states.best_energy + problem.offset,
        best_spins=states.best_spins,
        final_energy=states.energy + problem.offset,
        num_flips=states.num_flips,
        trace_energy=trace,
    )


_run_jit = partial(jax.jit, static_argnames=("config",))(_run)


def solve(problem: ising.IsingProblem, seed, config: SolverConfig,
          backend: str = "reference", *, store=None, mesh=None) -> SolveResult:
    """Entry point — a thin wrapper over the ``core.backend`` registry.
    ``seed`` is a dynamic int32 (host 64-bit seed).

    ``backend`` names any registered execution path ("reference" is the
    paper-faithful one-flip-per-XLA-op oracle scan; "fused" the production
    VMEM-resident Pallas sweep with same modes, schedule, PWL/uniformized
    options, and trace shape/dtype/cadence, O(N) per-step work, different
    documented RNG stream layout; "sharded"/"distributed" need ``mesh``;
    "tempering" consumes a ``TemperingConfig``) or "auto" to resolve one
    from the config type. Dispatch happens on the host (not under jit) so
    the fused path can resolve ``config.coupling_format`` and pack
    bit-planes from the concrete J — for edge-list (dense-J-free) problems
    via the O(nnz) sparse encoder.

    ``store`` takes a prebuilt ``core.coupling.CouplingStore`` so repeated
    solves of one instance (TTS sweeps, restarts) skip the resolve→encode
    entirely; fused backend only (the reference oracle always consumes the
    dense J). Edge-list problems require ``backend="fused"``.
    """
    # Lazy import: backend.py imports this module for the config/chunk fns.
    from .backend import get_backend, resolve_backend
    if backend == "auto":
        backend = resolve_backend(config, backend, mesh)
    return get_backend(backend).run(problem, seed, config, mesh=mesh,
                                    store=store)


def solve_many(problem: ising.IsingProblem, seeds, config: SolverConfig,
               backend: str = "reference", *, store=None) -> SolveResult:
    """Independent runs (for TTS success-probability estimation). A prebuilt
    ``store`` is encoded once and reused across every vmapped run."""
    return jax.vmap(lambda s: solve(problem, s, config, backend, store=store))(
        jnp.asarray(seeds, jnp.uint32))
