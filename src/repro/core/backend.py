"""The execution-path registry: every solve driver behind one interface.

The repo grew five ways to advance an Ising trajectory — the reference
oracle scan (``core.solver``), the fused Pallas sweep over the coupling
tiers (``kernels.ops``), fused parallel tempering (``core.tempering``), the
replica-parallel distributed driver (``distributed.solver_dist``), and the
spin-sharded driver (``distributed.solver_sharded``). Each used to hand-roll
config resolution, store plumbing, and chunk cadence, and joining the
resilience / parity contracts meant editing four files. This module is the
single enumeration point instead:

* :class:`Backend` — the uniform interface. ``prepare`` resolves the
  coupling tier and builds (or passes through) the stored operands,
  ``run`` is the monolithic jitted driver, ``runner`` yields the
  chunk-granular driver the resilient supervisor and the serving layer
  consume (``init`` / ``run_chunk`` / ``finalize`` — the same chunk bodies
  the monolithic scans use, so chunked execution is bit-identical).
* :class:`Capabilities` — what each path can serve (edge-list problems,
  mesh requirement, prebuilt-store reuse, resume support, tier-fallback
  eligibility), replacing per-driver special cases in callers.
* :data:`BACKENDS` + :func:`register` — the registry.
  ``core.resilience.run_resilient``, the public ``solve`` entry point, the
  ``serve.SolverService`` front end, and the registry-completeness test
  (``tests/test_backend_registry.py``) all enumerate it, so a new
  execution path joins every contract by registering here — not by editing
  the supervisor, the dispatchers, and the test matrices separately.

Chunk-runner protocol (duck-typed; what ``runner()`` returns):
``init() -> state``, ``run_chunk(state, k) -> state``, ``unit_len(k)``,
``best_energy(state) -> float``, ``trace_row(state)``,
``finalize(state, rows) -> result``, plus attributes ``total_units``,
``collect_trace``, ``num_replicas``, ``backend``, ``fmt``. The state is a
pytree of device arrays that round-trips through a checkpoint losslessly,
and every chunk's RNG is a pure function of ``(seed, chunk index)`` — no
carried RNG state, which is what makes resume bit-identical.
"""
from __future__ import annotations

import abc
import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ising, rng
from .coupling import (KERNEL_COUPLING_MODES, CouplingStore, resolve_format)
from .solver import (SolveResult, SolverConfig, _mcmc_config,
                     reference_init_state, run_reference_chunk)
from .tempering import (TemperingConfig, TemperingResult,
                        fused_tempering_round, tempering_round_count)


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What an execution path can serve — the registry's contract surface.

    ``edge_list``     dense-J-free (``EdgeList``) problems supported.
    ``needs_mesh``    requires a device mesh (sharded / distributed).
    ``supports_store``  accepts a prebuilt ``CouplingStore`` (the
                      zero-re-encode memoization contract).
    ``supports_resume`` drivable chunk-by-chunk with bit-identical resume —
                      membership in the resume-parity matrix is asserted
                      for every backend with this bit set.
    ``tier_fallback`` participates in the coupling-tier downgrade ladder
                      (``coupling_format="auto"`` only).
    ``fixed_fmt``     the single coupling tier the path serves, or None
                      when the tier follows ``config.coupling_format``.
    ``auto``          eligible for ``backend="auto"`` config-type dispatch
                      (the reference oracle is explicit-only).
    """
    edge_list: bool
    needs_mesh: bool
    supports_store: bool
    supports_resume: bool
    tier_fallback: bool
    fixed_fmt: Optional[str] = None
    auto: bool = True
    summary: str = ""


class Backend(abc.ABC):
    """One registered execution path. Stateless; all methods take the
    problem/config explicitly so a single instance serves every request."""

    name: str
    capabilities: Capabilities

    @abc.abstractmethod
    def config_cls(self) -> type:
        """The config dataclass this path consumes (lazy import — the
        distributed config lives outside ``core``)."""

    def check_config(self, config) -> None:
        cls = self.config_cls()
        if not isinstance(config, cls):
            raise TypeError(
                f"backend {self.name!r} consumes {cls.__name__}, got "
                f"{type(config).__name__}")

    def matches_config(self, config) -> bool:
        """Whether ``backend="auto"`` may resolve to this path for
        ``config``. Default: the config-type check alone; paths that split
        one config class across execution modes (``SolverConfig.flip_mode``
        routes "single" to fused/sharded and "colored" to the colored
        backend) refine this so resolution is unambiguous."""
        return isinstance(config, self.config_cls())

    def prepare(self, problem: ising.IsingProblem, config, *, mesh=None,
                fmt: Optional[str] = None, store=None):
        """Resolve the coupling tier and build the stored operands for this
        path (a ``CouplingStore``, sharded planes, …) — the cacheable,
        host-side part of a solve. ``fmt`` is a tier override (the fallback
        ladder); a prebuilt ``store`` passes straight through when no
        override is in play. Returns None for paths with no separable
        store (reference consumes the dense J as-is; the distributed store
        is per-device by construction)."""
        return None

    @abc.abstractmethod
    def run(self, problem: ising.IsingProblem, seed, config, *, mesh=None,
            store=None):
        """The monolithic jitted driver — one launch for the whole
        trajectory (the fast path; `runner` is the resumable one)."""

    @abc.abstractmethod
    def runner(self, problem: ising.IsingProblem, seed, config, *,
               mesh=None, chunk_steps: int = 256, fmt: Optional[str] = None,
               store=None):
        """The chunk-granular driver (see the module docstring for the
        protocol) — bit-identical to ``run`` under any chunking."""


# --------------------------------------------------------------------------
# The registry.

BACKENDS: dict[str, Backend] = {}


def register(backend: Backend) -> Backend:
    """Add an execution path to the registry (latest registration wins —
    deliberate, so tests can shadow a backend). Registration is what joins
    the resilience, parity, and serving contracts."""
    BACKENDS[backend.name] = backend
    return backend


def backend_names() -> tuple:
    return tuple(sorted(BACKENDS))


def get_backend(name: str) -> Backend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}: registered backends are "
            f"{backend_names()}; 'auto' resolves one from the config type"
        ) from None


def resolve_backend(config, backend: str = "auto", mesh=None) -> str:
    """Registry-driven ``backend="auto"`` resolution: match the config type
    against each registered path's ``config_cls`` and prefer the
    mesh-matching candidate — ``TemperingConfig`` → tempering,
    ``DistSolverConfig`` → distributed, ``SolverConfig`` → sharded when a
    mesh is supplied, else fused. Explicit names are validated against the
    registry."""
    if backend != "auto":
        get_backend(backend)
        return backend
    cands = [b for name, b in sorted(BACKENDS.items())
             if b.capabilities.auto and b.matches_config(config)]
    if not cands:
        raise TypeError(f"unrecognized config type {type(config).__name__}")
    return min(cands, key=lambda b: b.capabilities.needs_mesh
               != (mesh is not None)).name


def current_fmt(problem: ising.IsingProblem, config, backend: str,
                fmt: Optional[str]) -> str:
    """The coupling tier a run attempt will use: the ladder override if one
    is active, the backend's fixed tier if it has one, else the resolved
    ``config.coupling_format``."""
    if fmt is not None:
        return fmt
    fixed = get_backend(backend).capabilities.fixed_fmt
    if fixed is not None:
        return fixed
    return resolve_format(getattr(config, "coupling_format", "auto"),
                          problem.coupling_source, problem.num_spins)


def fallback_enabled(config, backend: str) -> bool:
    """Whether the tier-downgrade ladder applies: the backend opts in via
    its capabilities AND the config left the tier on "auto"."""
    return (get_backend(backend).capabilities.tier_fallback
            and getattr(config, "coupling_format", None) == "auto")


def capability_rows() -> list:
    """(name, Capabilities) rows in name order — the DESIGN.md table and
    the registry-completeness test read the same source of truth."""
    return [(name, BACKENDS[name].capabilities) for name in backend_names()]


# --------------------------------------------------------------------------
# Per-backend chunk runners. Each runner drives the SAME chunk body the
# monolithic driver scans over, one host-visible unit at a time; the state it
# carries across units is a pytree of device arrays that round-trips through
# the checkpoint losslessly.

@partial(jax.jit, static_argnames=("config", "interpret"))
def _fused_init(problem, seed, config: SolverConfig, store: CouplingStore,
                interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    return _ops.fused_init_state(problem, base, config.num_replicas,
                                 interpret=interpret, planes=store.planes)


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len", "gather",
                                   "interpret"))
def _fused_chunk(state, seed, c, store: CouplingStore, *,
                 config: SolverConfig, clen: int, chunk_len: int,
                 gather: str, interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    return _ops.anneal_chunk_step(store, state, base, c, clen=clen,
                                  chunk_len=chunk_len, config=config,
                                  gather=gather, block_r=8,
                                  interpret=interpret)


class FusedRunner:
    """``solve(backend="fused")`` / ``fused_anneal``, chunk at a time."""

    backend = "fused"

    def __init__(self, problem, seed, config: SolverConfig,
                 store: CouplingStore, chunk_steps: int):
        from ..kernels import ops as _ops
        self.problem = problem
        self.config = config
        self.store = store
        self.fmt = store.fmt
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.interpret = _ops.auto_interpret(None)
        self.gather = _ops.anneal_gather(store, "dynamic", problem.num_spins)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        return _fused_init(self.problem, self.seed, self.config, self.store,
                           self.interpret)

    def run_chunk(self, state, k: int):
        return _fused_chunk(state, self.seed, jnp.int32(k), self.store,
                            config=self.config, clen=self.unit_len(k),
                            chunk_len=self.chunk_len, gather=self.gather,
                            interpret=self.interpret)

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        u, s, e, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = (jnp.asarray(np.stack(rows)) + off).astype(jnp.float32)
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(best_energy=be + off, best_spins=bs.astype(jnp.int8),
                           final_energy=e + off, num_flips=nf,
                           trace_energy=trace)


@partial(jax.jit, static_argnames=("config", "interpret"))
def _colored_init(plan, seed, config: SolverConfig, interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    return _ops.fused_init_state(plan.problem, base, config.num_replicas,
                                 interpret=interpret,
                                 planes=plan.store.planes)


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len",
                                   "interpret"))
def _colored_chunk(state, seed, c, plan, *, config: SolverConfig, clen: int,
                   chunk_len: int, interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    return _ops.colored_chunk_step(plan, state, base, c, clen=clen,
                                   chunk_len=chunk_len, config=config,
                                   block_r=8, interpret=interpret,
                                   with_rows_fetched=True)


class ColoredRunner:
    """``solve(backend="colored")`` / ``colored_anneal``, chunk at a time.
    The carried 6-tuple lives in the plan's color-sorted spin order (the
    permutation is deterministic from the problem, so a resumed run rebuilds
    the identical layout); ``finalize`` maps best spins back to original
    vertex order."""

    backend = "colored"

    def __init__(self, problem, seed, config: SolverConfig, plan,
                 chunk_steps: int):
        from ..kernels import ops as _ops
        self.problem = problem
        self.config = config
        self.plan = plan
        self.fmt = plan.store.fmt
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.interpret = _ops.auto_interpret(None)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas
        self._rows_fetched = None

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        return _colored_init(self.plan, self.seed, self.config,
                             self.interpret)

    def run_chunk(self, state, k: int):
        # Like ShardedRunner, the row-fetch counter rides on the runner:
        # the 6-tuple snapshot contract stays fixed and the counter covers
        # the chunks this process ran (telemetry only).
        state, rf = _colored_chunk(state, self.seed, jnp.int32(k), self.plan,
                                   config=self.config, clen=self.unit_len(k),
                                   chunk_len=self.chunk_len,
                                   interpret=self.interpret)
        self._rows_fetched = (rf if self._rows_fetched is None
                              else self._rows_fetched + rf)
        return state

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        from ..kernels import ops as _ops
        u, s, e, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = (jnp.asarray(np.stack(rows)) + off).astype(jnp.float32)
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(
            best_energy=be + off,
            best_spins=_ops.unpermute_spins(self.plan, bs.astype(jnp.int8)),
            final_energy=e + off, num_flips=nf, trace_energy=trace,
            rows_fetched=self._rows_fetched)


@partial(jax.jit, static_argnames=("config",))
def _reference_init(problem, seed, config: SolverConfig):
    states, _ = reference_init_state(problem, seed, config)
    return states


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len"))
def _reference_chunk(problem, states, seed, c, *, config: SolverConfig,
                     clen: int, chunk_len: int):
    # Replica keys are a pure function of the seed — recomputed per chunk so
    # the snapshot carries chain state only, never RNG state.
    base = jax.random.fold_in(jax.random.key(0), seed)
    keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(
        jnp.arange(config.num_replicas))
    return run_reference_chunk(problem, states, keys, c, clen=clen,
                               chunk_len=chunk_len, config=config,
                               mc=_mcmc_config(config))


class ReferenceRunner:
    """``solve(backend="reference")``, chunk at a time. Every step is keyed
    by its absolute index, so *any* chunking composes to the same values as
    the monolithic loop — traced runs use the trace cadence, untraced runs
    the supervisor's ``chunk_steps``."""

    backend = "reference"
    fmt = "dense"

    def __init__(self, problem, seed, config: SolverConfig, chunk_steps: int):
        from ..kernels import ops as _ops
        if problem.couplings is None:
            raise ValueError(
                "backend='reference' needs the dense J; edge-list "
                "(dense-J-free) problems are served by backend='fused'")
        self.problem = problem
        self.config = config
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        return _reference_init(self.problem, self.seed, self.config)

    def run_chunk(self, states, k: int):
        return _reference_chunk(self.problem, states, self.seed,
                                jnp.int32(k), config=self.config,
                                clen=self.unit_len(k),
                                chunk_len=self.chunk_len)

    def best_energy(self, states) -> float:
        return float(jnp.min(states.best_energy)) + float(self.problem.offset)

    def trace_row(self, states):
        return states.best_energy

    def finalize(self, states, rows) -> SolveResult:
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = jnp.asarray(np.stack(rows)) + off
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(best_energy=states.best_energy + off,
                           best_spins=states.best_spins,
                           final_energy=states.energy + off,
                           num_flips=states.num_flips,
                           trace_energy=trace)


@partial(jax.jit, static_argnames=("config", "interpret"))
def _tempering_init(problem, seed, config: TemperingConfig,
                    store: CouplingStore, interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    state = _ops.fused_init_state(problem, base, config.num_replicas,
                                  interpret=interpret, planes=store.planes)
    return (state, jnp.int32(0), jnp.int32(0))


@partial(jax.jit, static_argnames=("config", "interpret"))
def _tempering_round(carry, seed, round_idx, store: CouplingStore, *,
                     config: TemperingConfig, interpret: bool):
    state, acc, tot = carry
    base = jax.random.fold_in(jax.random.key(0), seed)
    return fused_tempering_round(state, acc, tot, base, round_idx, config,
                                 store, interpret=interpret)


class TemperingRunner:
    """``solve_tempering(backend="fused")``, one swap round per unit. The
    carried state is ``(kernel 6-tuple, swap-accept, swap-total)`` so the
    acceptance statistic survives resume too."""

    backend = "tempering"

    def __init__(self, problem, seed, config: TemperingConfig,
                 store: CouplingStore):
        from ..kernels import ops as _ops
        if config.backend != "fused":
            raise ValueError(
                "the chunked tempering runner serves the fused backend only "
                "— the reference chains run one flip per XLA op and have no "
                "chunked surface to checkpoint at; set "
                "TemperingConfig(backend='fused')")
        self.problem = problem
        self.config = config
        self.store = store
        self.fmt = store.fmt
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.interpret = _ops.auto_interpret(None)
        self.total_units = tempering_round_count(config)
        self.collect_trace = False
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        return self.config.swap_every

    def init(self):
        return _tempering_init(self.problem, self.seed, self.config,
                               self.store, self.interpret)

    def run_chunk(self, carry, k: int):
        return _tempering_round(carry, self.seed, jnp.int32(k), self.store,
                                config=self.config, interpret=self.interpret)

    def best_energy(self, carry) -> float:
        return float(jnp.min(carry[0][3])) + float(self.problem.offset)

    def trace_row(self, carry):
        return carry[0][3]

    def finalize(self, carry, rows) -> TemperingResult:
        (u, s, e, be, bs, nf), acc, tot = carry
        off = self.problem.offset
        return TemperingResult(
            best_energy=be + off,
            best_spins=bs.astype(ising.SPIN_DTYPE),
            final_energy=e + off,
            swap_acceptance=acc.astype(jnp.float32) / jnp.maximum(tot, 1),
            num_flips=nf)


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len"))
def _sharded_chunk_inputs(seed, c, *, config: SolverConfig, clen: int,
                          chunk_len: int):
    # Replicated per-chunk uniforms + temps — the identical values
    # sharded_anneal_fn's local_anneal computes (replicated) on every device.
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0), seed)
    steps = c * chunk_len + jnp.arange(clen)
    temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
    temps = jnp.broadcast_to(temps[:, None], (clen, r))
    uniforms = rng.uniform01(rng.stream(base, rng.Salt.SWEEP, c),
                             (clen, r, 4))
    return uniforms, temps


@jax.jit
def _best_merge(be, bs, nf, ce, cs, cf):
    # ops.fused_sweep_chunk's best-so-far merge, on (possibly sharded) arrays.
    better = ce < be
    return (jnp.where(better, ce, be), jnp.where(better[:, None], cs, bs),
            nf + cf)


class ShardedRunner:
    """``solve_sharded``, chunk at a time: init via ``sharded_init_fn``, the
    per-chunk sweep via ``sharded_sweep_fn``, the best merge identical to the
    in-scan one. State leaves keep their spin-axis shardings across the
    checkpoint round-trip (restore device_puts to the template shardings).
    Serves 1-D and multi-axis (replica groups × rows) meshes alike — the
    chunk inputs are always the full-R replicated tensors; the shard_map
    slices each group's block (``solver_sharded.sharded_sweep_fn``)."""

    def __init__(self, problem, seed, config: SolverConfig, mesh,
                 chunk_steps: int, backend: str = "sharded"):
        from ..distributed import solver_sharded as _ss
        from ..kernels import ops as _ops
        self.backend = backend
        self.fmt = ("bitplane_sharded_2d" if len(mesh.axis_names) > 1
                    else "bitplane_sharded")
        self.problem = problem
        self.config = config
        self.mesh = mesh
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.planes = _ss.resolve_sharded_planes(problem, config, mesh)
        n = problem.num_spins
        self._init_fn = _ss.sharded_init_fn(config, mesh, n)
        self._sweep_fn = _ss.sharded_sweep_fn(config, mesh, n)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas
        self._rows_fetched = None

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        from jax.sharding import NamedSharding, PartitionSpec
        seed_arr = jnp.asarray([self.seed], jnp.uint32)
        u0, s0, e0 = self._init_fn(self.planes, self.problem.fields, seed_arr)
        # num_flips laid out over the mesh like e0 (replica axis over the
        # group axes on a 2-D mesh, replicated on 1-D) — a default-device
        # zeros would commit the resume template's leaf to one device and
        # clash with the mesh-committed state in the merge.
        grp = tuple(self.mesh.axis_names[:-1]) or None
        nf = jax.device_put(np.zeros((self.num_replicas,), np.int32),
                            NamedSharding(self.mesh, PartitionSpec(grp)))
        return (u0, s0, e0, e0, s0, nf)

    def run_chunk(self, state, k: int):
        u, s, e, be, bs, nf = state
        uniforms, temps = _sharded_chunk_inputs(
            self.seed, jnp.int32(k), config=self.config,
            clen=self.unit_len(k), chunk_len=self.chunk_len)
        # The row-broadcast counter rides on the runner, not the state: the
        # 6-tuple snapshot contract stays fixed, and a resumed run could not
        # reconstruct the pre-crash traffic anyway — the counter covers the
        # chunks this process ran (telemetry only; trajectories unaffected).
        u, s, e, ce, cs, cf, rf = self._sweep_fn(self.planes, u, s, e,
                                                 uniforms, temps)
        self._rows_fetched = (rf if self._rows_fetched is None
                              else self._rows_fetched + rf)
        be, bs, nf = _best_merge(be, bs, nf, ce, cs, cf)
        return (u, s, e, be, bs, nf)

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        u, s, e, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = (jnp.asarray(np.stack(rows)) + off).astype(jnp.float32)
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(best_energy=be + off, best_spins=bs.astype(jnp.int8),
                           final_energy=e + off, num_flips=nf,
                           trace_energy=trace,
                           rows_fetched=self._rows_fetched)


class DistRunner:
    """``solve_distributed``, chunk at a time via
    ``solver_dist.dist_resilient_fns`` — same per-device RNG, chunk cadence,
    and elitist exchange as the monolithic scan. Excluded from the tier
    ladder (the store choice is per-device by construction)."""

    backend = "distributed"

    def __init__(self, problem, seed, config, mesh):
        from ..distributed import solver_dist as _sd
        self.problem = problem
        self.config = config
        init_fn, chunk_fn, setup = _sd.dist_resilient_fns(problem, config,
                                                          mesh)
        self._init_fn = init_fn
        self._chunk_fn = chunk_fn
        self.operands = _sd.dist_operands(problem, seed, setup)
        self.fmt = setup.store.fmt if setup.store is not None else "dense"
        self.chunk_len = setup.chunk
        self.total_units = setup.num_chunks
        self.collect_trace = True   # the dist trace is always on
        self.num_replicas = setup.r_total

    def unit_len(self, k: int) -> int:
        return self.chunk_len

    def init(self):
        return tuple(self._init_fn(*self.operands))

    def run_chunk(self, state, k: int):
        c_arr = jnp.asarray([k], jnp.int32)
        h, seed_arr = self.operands[0], self.operands[1]
        return tuple(self._chunk_fn(*state, h, seed_arr, c_arr,
                                    *self.operands[2:]))

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        sp, fu, en, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        trace = ((jnp.asarray(np.stack(rows)) + off) if rows
                 else jnp.zeros((0, r), jnp.float32))
        return SolveResult(best_energy=be + off, best_spins=bs,
                           final_energy=en + off, num_flips=nf,
                           trace_energy=trace)


# --------------------------------------------------------------------------
# The registered execution paths.

class ReferenceBackend(Backend):
    name = "reference"
    capabilities = Capabilities(
        edge_list=False, needs_mesh=False, supports_store=False,
        supports_resume=True, tier_fallback=False, fixed_fmt="dense",
        auto=False,
        summary="paper-faithful one-flip-per-XLA-op oracle scan")

    def config_cls(self):
        return SolverConfig

    def run(self, problem, seed, config, *, mesh=None, store=None):
        from .solver import _run_jit
        self.check_config(config)
        _require_single_flip(config, self.name)
        if store is not None:
            raise ValueError(
                "a prebuilt CouplingStore serves the fused backend only; "
                "backend='reference' always consumes the dense J")
        if problem.couplings is None:
            raise ValueError(
                "backend='reference' needs the dense J; edge-list "
                "(dense-J-free) problems are served by backend='fused' or "
                "solve_sharded")
        return _run_jit(problem, jnp.asarray(seed, jnp.uint32), config)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        return ReferenceRunner(problem, seed, config, chunk_steps)


def _require_single_flip(config, name: str) -> None:
    """The routing guard of the single-flip paths: a colored config reaching
    them directly (bypassing ``backend="auto"``) must fail loudly, never
    silently run single-flip sweeps."""
    if getattr(config, "flip_mode", "single") != "single":
        raise ValueError(
            f"backend {name!r} runs single-flip updates (flip_mode="
            f"{config.flip_mode!r}); colored block updates are served by "
            "backend='colored'")


def _resolve_store(problem, config, *, fmt=None, store=None, caller: str):
    """The shared store-resolution contract of the fused-family paths: a
    prebuilt store passes through untouched (unless a tier override ``fmt``
    forces a rebuild — the fallback ladder must not resurrect the tier that
    just OOMed), everything else resolves ``config.coupling_format`` and
    runs the encoder once."""
    if store is None or fmt is not None:
        store = CouplingStore.build(problem.coupling_source,
                                    fmt or config.coupling_format)
    store.require(KERNEL_COUPLING_MODES, caller)
    return store


class FusedBackend(Backend):
    name = "fused"
    capabilities = Capabilities(
        edge_list=True, needs_mesh=False, supports_store=True,
        supports_resume=True, tier_fallback=True, fixed_fmt=None,
        summary="VMEM-resident Pallas sweep over the dense/bitplane/"
                "bitplane_hbm coupling tiers")

    def config_cls(self):
        return SolverConfig

    def matches_config(self, config) -> bool:
        return (isinstance(config, SolverConfig)
                and config.flip_mode == "single")

    def prepare(self, problem, config, *, mesh=None, fmt=None, store=None):
        return _resolve_store(problem, config, fmt=fmt, store=store,
                              caller=f"backend {self.name!r}")

    def run(self, problem, seed, config, *, mesh=None, store=None):
        from ..kernels import ops as _ops
        self.check_config(config)
        return _ops.fused_anneal(problem, seed, config, store=store)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        _require_single_flip(config, self.name)
        if fmt in ("bitplane_sharded", "bitplane_sharded_2d"):
            # The last rung of the tier ladder switches a fused solve onto
            # the spin-sharded driver — trajectory-identical by contract.
            if mesh is None:
                raise ValueError(f"the {fmt} tier needs a mesh")
            target = "sharded_2d" if fmt == "bitplane_sharded_2d" else "sharded"
            return get_backend(target).runner(
                problem, seed, config, mesh=mesh, chunk_steps=chunk_steps)
        store = self.prepare(problem, config, fmt=fmt, store=store)
        return FusedRunner(problem, seed, config, store, chunk_steps)


class ColoredBackend(Backend):
    name = "colored"
    capabilities = Capabilities(
        edge_list=True, needs_mesh=False, supports_store=False,
        supports_resume=True, tier_fallback=True, fixed_fmt=None,
        summary="graph-colored block updates — one conflict-graph color "
                "class per step, O(N/χ) flips on sparse instances")

    def config_cls(self):
        return SolverConfig

    def matches_config(self, config) -> bool:
        return (isinstance(config, SolverConfig)
                and config.flip_mode == "colored")

    def _check(self, config, store) -> None:
        if getattr(config, "flip_mode", None) != "colored":
            raise ValueError(
                f"backend 'colored' serves flip_mode='colored' configs, got "
                f"{getattr(config, 'flip_mode', None)!r}")
        if store is not None:
            # A prebuilt store was encoded from the ORIGINAL spin order; the
            # colored path runs in color-sorted order, so accepting it would
            # silently corrupt trajectories. The plan (coloring + permuted
            # store) is the colored path's memoization unit instead — pass it
            # to ops.colored_anneal directly.
            raise ValueError(
                "backend='colored' rebuilds its store in color-sorted spin "
                "order; a prebuilt CouplingStore (original order) cannot be "
                "reused — memoize the ops.colored_plan instead")

    def prepare(self, problem, config, *, mesh=None, fmt=None, store=None):
        from ..kernels import ops as _ops
        self._check(config, store)
        return _ops.colored_plan(problem,
                                 fmt if fmt is not None
                                 else config.coupling_format)

    def run(self, problem, seed, config, *, mesh=None, store=None):
        from ..kernels import ops as _ops
        self.check_config(config)
        self._check(config, store)
        return _ops.colored_anneal(problem, seed, config)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        if fmt in ("bitplane_sharded", "bitplane_sharded_2d"):
            raise ValueError(
                "the colored path has no spin-sharded tier — the tier "
                "ladder ends at bitplane_hbm for backend='colored'")
        plan = self.prepare(problem, config, fmt=fmt, store=store)
        return ColoredRunner(problem, seed, config, plan, chunk_steps)


class TemperingBackend(Backend):
    name = "tempering"
    capabilities = Capabilities(
        edge_list=True, needs_mesh=False, supports_store=True,
        supports_resume=True, tier_fallback=True, fixed_fmt=None,
        summary="fused parallel tempering (swap rounds over a temperature "
                "ladder)")

    def config_cls(self):
        return TemperingConfig

    def prepare(self, problem, config, *, mesh=None, fmt=None, store=None):
        return _resolve_store(problem, config, fmt=fmt, store=store,
                              caller=f"backend {self.name!r}")

    def run(self, problem, seed, config, *, mesh=None, store=None):
        from .tempering import solve_tempering
        self.check_config(config)
        return solve_tempering(problem, seed, config, store=store)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        store = self.prepare(problem, config, fmt=fmt, store=store)
        return TemperingRunner(problem, seed, config, store)


class ShardedBackend(Backend):
    name = "sharded"
    capabilities = Capabilities(
        edge_list=True, needs_mesh=True, supports_store=False,
        supports_resume=True, tier_fallback=False,
        fixed_fmt="bitplane_sharded",
        summary="spin-row-sharded planes across the mesh (capacity scales "
                "with aggregate HBM)")

    def config_cls(self):
        return SolverConfig

    def matches_config(self, config) -> bool:
        return (isinstance(config, SolverConfig)
                and config.flip_mode == "single")

    def prepare(self, problem, config, *, mesh=None, fmt=None, store=None):
        from ..distributed import solver_sharded as _ss
        if mesh is None:
            raise ValueError("backend='sharded' needs a mesh")
        return _ss.resolve_sharded_planes(problem, config, mesh)

    def run(self, problem, seed, config, *, mesh=None, store=None):
        from ..distributed import solver_sharded as _ss
        self.check_config(config)
        _require_single_flip(config, self.name)
        if mesh is None:
            raise ValueError("backend='sharded' needs a mesh")
        if store is not None:
            raise ValueError(
                "backend='sharded' builds per-device plane shards from the "
                "problem; a prebuilt CouplingStore serves the fused backend "
                "only")
        return _ss.solve_sharded(problem, seed, config, mesh)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        _require_single_flip(config, self.name)
        if mesh is None:
            raise ValueError("the bitplane_sharded tier needs a mesh")
        return ShardedRunner(problem, seed, config, mesh, chunk_steps,
                             backend=self.name)


class Sharded2DBackend(ShardedBackend):
    """The 2-D (replica groups × spin rows) instantiation of the sharded
    path: same driver, but the mesh must carry at least two axes — the last
    row-shards the planes within each group, the leading axes replicate
    planes across independent replica groups. Not auto-resolved (a plain
    ``SolverConfig`` + mesh resolves to ``"sharded"``, whose driver already
    serves multi-axis meshes natively); name it explicitly, or let the tier
    ladder escalate to it when the mesh is 2-D."""

    name = "sharded_2d"
    capabilities = Capabilities(
        edge_list=True, needs_mesh=True, supports_store=False,
        supports_resume=True, tier_fallback=False,
        fixed_fmt="bitplane_sharded_2d", auto=False,
        summary="(groups, rows) mesh: planes row-sharded within each "
                "replica group, replicated across groups — J capacity and "
                "replica throughput scale together")

    @staticmethod
    def _check_mesh(mesh) -> None:
        if mesh is None:
            raise ValueError("backend='sharded_2d' needs a (groups, rows) "
                             "mesh")
        if len(mesh.axis_names) < 2:
            raise ValueError(
                f"backend='sharded_2d' needs a mesh with >= 2 axes (leading "
                f"= replica groups, last = spin rows); got the 1-axis mesh "
                f"{tuple(mesh.axis_names)} — use backend='sharded' for 1-D "
                f"row sharding")

    def prepare(self, problem, config, *, mesh=None, fmt=None, store=None):
        self._check_mesh(mesh)
        return super().prepare(problem, config, mesh=mesh, fmt=fmt,
                               store=store)

    def run(self, problem, seed, config, *, mesh=None, store=None):
        self._check_mesh(mesh)
        return super().run(problem, seed, config, mesh=mesh, store=store)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        self._check_mesh(mesh)
        return super().runner(problem, seed, config, mesh=mesh,
                              chunk_steps=chunk_steps, fmt=fmt, store=store)


class DistributedBackend(Backend):
    name = "distributed"
    capabilities = Capabilities(
        edge_list=True, needs_mesh=True, supports_store=False,
        supports_resume=True, tier_fallback=False, fixed_fmt=None,
        summary="replica-parallel shard_map driver with elitist exchange "
                "(J replicated per device)")

    def config_cls(self):
        from ..distributed.solver_dist import DistSolverConfig
        return DistSolverConfig

    def run(self, problem, seed, config, *, mesh=None, store=None):
        from ..distributed.solver_dist import solve_distributed
        self.check_config(config)
        if mesh is None:
            raise ValueError("backend='distributed' needs a mesh")
        if store is not None:
            raise ValueError(
                "backend='distributed' builds its store per device; a "
                "prebuilt CouplingStore serves the fused backend only")
        return solve_distributed(problem, seed, config, mesh)

    def runner(self, problem, seed, config, *, mesh=None, chunk_steps=256,
               fmt=None, store=None):
        if mesh is None:
            raise ValueError("backend='distributed' needs a mesh")
        return DistRunner(problem, seed, config, mesh)


register(ReferenceBackend())
register(FusedBackend())
register(ColoredBackend())
register(TemperingBackend())
register(ShardedBackend())
register(Sharded2DBackend())
register(DistributedBackend())
