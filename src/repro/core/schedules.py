"""Programmable simulated-annealing temperature schedules (paper §II-C, Alg. 1, Fig. 15).

The hardware preloads a schedule {T_k}; here the schedule is a pure function
``T(t)`` evaluated inside the scanned MCMC step, so arbitrarily long runs cost
O(1) memory. Linear (paper Fig. 4), geometric, cosine (paper Fig. 15a) and
constant (fixed-temperature sampling, used by the stationarity tests) are provided.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

ScheduleFn = Callable[[jax.Array], jax.Array]  # step t in [0, K) -> temperature


@dataclasses.dataclass(frozen=True)
class Schedule:
    kind: str  # "linear" | "geometric" | "cosine" | "constant"
    t0: float  # initial temperature
    t1: float  # final temperature
    steps: int  # K

    def __call__(self, t: jax.Array) -> jax.Array:
        frac = jnp.minimum(jnp.asarray(t, jnp.float32) / max(self.steps - 1, 1), 1.0)
        if self.kind == "linear":
            return self.t0 + (self.t1 - self.t0) * frac
        if self.kind == "geometric":
            lo = max(self.t1, 1e-12)
            ratio = lo / max(self.t0, 1e-12)
            return jnp.float32(self.t0) * jnp.power(jnp.float32(ratio), frac)
        if self.kind == "cosine":
            return self.t1 + 0.5 * (self.t0 - self.t1) * (1.0 + jnp.cos(jnp.pi * frac) )
        if self.kind == "constant":
            return jnp.full_like(frac, self.t0)
        raise ValueError(f"unknown schedule kind {self.kind!r}")


def linear(t0: float, t1: float, steps: int) -> Schedule:
    return Schedule("linear", t0, t1, steps)


def geometric(t0: float, t1: float, steps: int) -> Schedule:
    return Schedule("geometric", t0, t1, steps)


def cosine(t0: float, t1: float, steps: int) -> Schedule:
    return Schedule("cosine", t0, t1, steps)


def constant(t: float, steps: int = 1) -> Schedule:
    return Schedule("constant", t, t, steps)
