"""Snowball core: the paper's contribution as composable JAX modules."""
from .ising import (  # noqa: F401
    IsingProblem, energy, local_fields, delta_energies,
    incremental_field_update, random_spins, brute_force_ground_state,
)
from .bitplane import (  # noqa: F401
    BitPlanes, encode_couplings, decode_couplings, pack_spins,
    local_fields_from_planes,
)
from .mcmc import ChainState, MCMCConfig, init_chain, step, rsa_step, rwa_step  # noqa: F401
from .pwl import (  # noqa: F401
    make_pwl_sigmoid, make_flip_probability, exact_flip_probability,
    pwl_flip_probability, pwl_error_bound,
)
from .schedules import Schedule, linear, geometric, cosine, constant  # noqa: F401
from .solver import SolverConfig, SolveResult, solve, solve_many  # noqa: F401
from . import tts  # noqa: F401
from . import placement  # noqa: F401
from .refine import greedy_descent  # noqa: F401
from .tempering import TemperingConfig, solve_tempering  # noqa: F401
