"""Parallel tempering (replica-exchange MCMC) — the annealing alternative the
paper discusses and deliberately avoids (§IV-A, [19], [34], [40]).

Implemented as a baseline so the paper's design choice is measurable: R
replicas at a geometric temperature ladder run the same dual-mode kernels;
every ``swap_every`` steps adjacent-temperature pairs exchange configurations
with the Metropolis swap probability

    P_swap = min(1, exp((1/T_i − 1/T_j)(E_i − E_j))).

The paper's argument — that maintaining swap acceptance needs many closely
spaced replicas as the system grows — shows up directly in the benchmark's
measured swap-acceptance column.

Two backends share the swap machinery: ``backend="reference"`` runs the
one-flip-per-XLA-op ``core.mcmc`` chains; ``backend="fused"`` runs each
between-swap phase as one VMEM-resident Pallas sweep with the ladder passed
as the kernel's per-replica ``(T, R)`` temperature tensor — swap phases land
exactly at sweep-chunk boundaries.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ising, mcmc, rng
from .pwl import make_flip_probability, make_pwl_sigmoid, pwl_table


@dataclasses.dataclass(frozen=True)
class TemperingConfig:
    num_steps: int
    t_min: float
    t_max: float
    num_replicas: int = 8        # temperature-ladder rungs
    swap_every: int = 10
    mode: str = "rsa"            # kernel for within-chain moves
    use_pwl: bool = True
    backend: str = "reference"   # "reference" | "fused"
    coupling_format: str = "auto"  # fused-backend J store; COUPLING_FORMATS
    #: Tempering moves are single-spin by construction (the swap-acceptance
    #: argument of §IV-A is about one-flip chains); the field exists so the
    #: knob is uniform across configs and "colored" is rejected loudly here
    #: instead of silently running single-flip chains.
    flip_mode: str = "single"    # "single" only

    @property
    def ladder(self) -> np.ndarray:
        return np.geomspace(self.t_max, self.t_min, self.num_replicas)


class TemperingResult(NamedTuple):
    best_energy: jax.Array       # (R,)
    best_spins: jax.Array        # (R, N)
    final_energy: jax.Array
    swap_acceptance: jax.Array   # () mean accepted swap fraction
    num_flips: jax.Array


def _swap_phase(state, energy_of: Callable, temps: jax.Array, base: jax.Array,
                round_idx: jax.Array, r: int):
    """Metropolis exchange of adjacent rungs (even pairs then odd pairs).

    ``state`` is any pytree whose leaves have a leading replica axis;
    ``energy_of(state)`` extracts the (R,) current energies. Shared by both
    backends so swap decisions consume identical RNG streams.
    """

    def try_pairs(state, parity, salt):
        e = energy_of(state)
        beta = 1.0 / temps
        # pair (i, i+1) for i ≡ parity (mod 2)
        idx = jnp.arange(r - 1)
        active = (idx % 2) == parity
        delta = (beta[idx] - beta[idx + 1]) * (e[idx] - e[idx + 1])
        key = rng.stream(base, rng.Salt.UNIFORMIZE, round_idx, salt)
        u = rng.uniform01(key, (r - 1,))
        accept = active & (u < jnp.minimum(jnp.exp(jnp.clip(delta, -80.0, 80.0)), 1.0))

        # Build a permutation that swaps accepted pairs.
        perm = jnp.arange(r)
        lo = idx
        hi = idx + 1
        perm = perm.at[lo].set(jnp.where(accept, hi, perm[lo]))
        perm = perm.at[hi].set(jnp.where(accept, lo, perm[hi]))
        swapped = jax.tree.map(lambda x: x[perm], state)
        return swapped, accept.sum(), active.sum()

    state, acc_e, n_e = try_pairs(state, 0, 0)
    state, acc_o, n_o = try_pairs(state, 1, 1)
    return state, (acc_e + acc_o, n_e + n_o)


def _solve_tempering_reference(problem: ising.IsingProblem, seed,
                               config: TemperingConfig) -> TemperingResult:
    n = problem.num_spins
    r = config.num_replicas
    temps = jnp.asarray(config.ladder, jnp.float32)
    fp = (make_flip_probability(make_pwl_sigmoid()) if config.use_pwl
          else make_flip_probability(None))
    mc = mcmc.MCMCConfig(mode=config.mode, flip_prob=fp)
    base = jax.random.fold_in(jax.random.key(0), jnp.asarray(seed, jnp.uint32))
    keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(jnp.arange(r))
    spins0 = jax.vmap(lambda k: ising.random_spins(rng.stream(k, rng.Salt.INIT), (n,)))(keys)
    states = jax.vmap(lambda s: mcmc.init_chain(problem, s))(spins0)

    def chain_steps(states, t0):
        def one(t, st):
            sk = jax.vmap(lambda k: rng.stream(k, t))(keys)
            new, _ = jax.vmap(lambda s, k, temp: mcmc.step(problem, s, k, temp, mc))(
                st, sk, temps)
            return new
        return jax.lax.fori_loop(t0, t0 + config.swap_every, one, states)

    num_rounds = max(config.num_steps // config.swap_every, 1)

    def round_body(carry, round_idx):
        states, acc, tot = carry
        states = chain_steps(states, round_idx * config.swap_every)
        states, (a, t) = _swap_phase(states, lambda st: st.energy, temps,
                                     base, round_idx, r)
        return (states, acc + a, tot + t), None

    (states, acc, tot), _ = jax.lax.scan(
        round_body, (states, jnp.int32(0), jnp.int32(0)), jnp.arange(num_rounds))
    return TemperingResult(
        best_energy=states.best_energy + problem.offset,
        best_spins=states.best_spins,
        final_energy=states.energy + problem.offset,
        swap_acceptance=acc.astype(jnp.float32) / jnp.maximum(tot, 1),
        num_flips=states.num_flips,
    )


def tempering_round_count(config: TemperingConfig) -> int:
    """Swap rounds per run — the chunk-unit count of the fused tempering
    trajectory (each round = one ``swap_every``-step sweep + swap phase)."""
    return max(config.num_steps // config.swap_every, 1)


def fused_tempering_round(state, acc, tot, base: jax.Array, round_idx,
                          config: TemperingConfig, store, *, interpret: bool):
    """One tempering round on the fused kernel: a ``swap_every``-step sweep
    chunk on the round's ``Salt.SWEEP`` stream, then the Metropolis swap
    phase. The single round body under ``_solve_tempering_fused``'s scan AND
    the resilient supervisor's per-round jit (``core.resilience``) — one
    definition keeps a resumed tempering trajectory bit-identical to the
    uninterrupted scan. ``state`` is the fused 6-tuple; ``acc``/``tot`` the
    running swap-acceptance counters."""
    from ..kernels import ops as _ops  # lazy: kernels.ops imports core.solver

    r = config.num_replicas
    temps = jnp.asarray(config.ladder, jnp.float32)
    tbl = pwl_table() if config.use_pwl else None
    temps_trs = jnp.broadcast_to(temps[None, :], (config.swap_every, r))
    state = _ops.fused_sweep_chunk(
        store.kernel_operand, state, rng.stream(base, rng.Salt.SWEEP, round_idx),
        config.swap_every, temps_trs, mode=config.mode, pwl_table=tbl,
        block_r=_ops.fit_block(r, 8), coupling=store.fmt, interpret=interpret)
    state, (a, t) = _swap_phase(state, lambda st: st[2], temps,
                                base, round_idx, r)
    return state, acc + a, tot + t


def _solve_tempering_fused(problem: ising.IsingProblem, seed,
                           config: TemperingConfig,
                           store) -> TemperingResult:
    """Fused backend: each between-swap phase is one VMEM-resident sweep with
    the temperature ladder as the kernel's per-replica ``(T, R)`` tensor.
    ``store`` is the resolved ``core.coupling.CouplingStore`` (dense J or
    packed planes; its format rides the pytree aux data, so it is static
    here) produced by the host-level dispatcher."""
    from ..kernels import ops as _ops  # lazy: kernels.ops imports core.solver

    r = config.num_replicas
    interpret = _ops.auto_interpret(None)
    base = jax.random.fold_in(jax.random.key(0), jnp.asarray(seed, jnp.uint32))
    init_state = _ops.fused_init_state(problem, base, r, interpret=interpret,
                                       planes=store.planes)
    num_rounds = tempering_round_count(config)

    def round_body(carry, round_idx):
        state, acc, tot = carry
        state, acc, tot = fused_tempering_round(
            state, acc, tot, base, round_idx, config, store,
            interpret=interpret)
        return (state, acc, tot), None

    init = (init_state, jnp.int32(0), jnp.int32(0))
    ((u, s, e, be, bs, nf), acc, tot), _ = jax.lax.scan(
        round_body, init, jnp.arange(num_rounds))
    return TemperingResult(
        best_energy=be + problem.offset,
        best_spins=bs.astype(ising.SPIN_DTYPE),
        final_energy=e + problem.offset,
        swap_acceptance=acc.astype(jnp.float32) / jnp.maximum(tot, 1),
        num_flips=nf,
    )


_solve_tempering_reference_jit = partial(
    jax.jit, static_argnames=("config",))(_solve_tempering_reference)
_solve_tempering_fused_jit = partial(
    jax.jit, static_argnames=("config",))(_solve_tempering_fused)


def solve_tempering(problem: ising.IsingProblem, seed,
                    config: TemperingConfig, *, store=None) -> TemperingResult:
    """Host-level dispatcher (the engines underneath are jitted): the fused
    path resolves ``config.coupling_format`` into a ``CouplingStore`` (one
    ``build`` call packs bit-planes from the concrete J — or from the edge
    list via the O(nnz) sparse encoder for dense-J-free problems) before
    entering jit.

    ``store`` takes a prebuilt ``CouplingStore`` so tempering restarts /
    repeated ladder sweeps of one instance skip the re-resolve→re-encode
    (fused backend only — the reference chains consume the dense J).
    """
    if config.flip_mode != "single":
        raise ValueError(
            f"tempering runs single-flip chains only (flip_mode="
            f"{config.flip_mode!r}); colored block updates are served by "
            "solve(..., backend='colored') on a SolverConfig")
    if config.backend == "fused":
        from .coupling import KERNEL_COUPLING_MODES, CouplingStore
        if store is None:
            store = CouplingStore.build(
                problem.coupling_source, config.coupling_format)
        else:
            store.require_num_spins(problem.num_spins, "solve_tempering")
            if (store.dense is not None
                    and store.dense is not problem.couplings):
                raise ValueError(
                    "prebuilt dense CouplingStore does not hold this "
                    "problem's couplings array — the init would run on one J "
                    "and the sweep on another; rebuild the store from "
                    "problem.couplings")
        store.require(KERNEL_COUPLING_MODES, "solve_tempering")
        return _solve_tempering_fused_jit(problem, seed, config, store)
    if store is not None:
        raise ValueError("a prebuilt CouplingStore serves the fused backend "
                         "only; backend='reference' always consumes the "
                         "dense J")
    if config.backend != "reference":
        raise ValueError(
            f"backend must be 'reference' or 'fused', got {config.backend!r}")
    if problem.couplings is None:
        raise ValueError(
            "backend='reference' tempering needs the dense J; edge-list "
            "(dense-J-free) problems are served by the fused backend")
    return _solve_tempering_reference_jit(problem, seed, config)
