"""Piecewise-linear approximation of the Glauber flip probability (paper §IV-B3a).

The hardware maps ``z = ΔE/T`` through a piecewise-linear lookup table to
approximate the logistic ``P_flip = 1/(1+exp(z)) = σ(-z)``, replacing the
transcendental with table lookups + fixed-point arithmetic. We reproduce the
same construction in float: uniform breakpoints on ``[-z_max, z_max]``, exact
σ at the knots, linear interpolation between, clamped tails. For S segments the
max error is bounded by ``max|σ''| (2 z_max / S)² / 8 ≈ 0.096 (2 z_max/S)²/8``.

Both the PWL and the exact logistic share one call signature so either can be
plugged into the MCMC engine (``flip_probability``); tests bound the PWL error
and benchmarks compare solution quality under both.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

FlipProbFn = Callable[[jax.Array, jax.Array], jax.Array]  # (delta_e, temperature) -> p


def _pwl_arrays(num_segments: int, z_max: float):
    """Shared LUT construction: (knots (S+1,), values (S+1,), slopes (S,))."""
    knots = np.linspace(-z_max, z_max, num_segments + 1).astype(np.float32)
    values = (1.0 / (1.0 + np.exp(-knots.astype(np.float64)))).astype(np.float32)
    slopes = (np.diff(values) / np.diff(knots)).astype(np.float32)
    return knots, values, slopes


def pwl_table(num_segments: int = 64, z_max: float = 8.0) -> jax.Array:
    """The LUT as a dense ``(S+1, 3)`` f32 array ``[knot, value, slope]`` (last
    slope row zero-padded) — the form the fused sweep kernel keeps in VMEM.
    Same construction as :func:`make_pwl_sigmoid`; the kernel evaluates it in
    intercept form (``kernels.common.flip_probability``), which agrees with
    the reference PWL to float ulps."""
    knots, values, slopes = _pwl_arrays(num_segments, z_max)
    return jnp.asarray(
        np.stack([knots, values, np.append(slopes, 0.0).astype(np.float32)], axis=1))


def make_pwl_sigmoid(num_segments: int = 64, z_max: float = 8.0) -> Callable[[jax.Array], jax.Array]:
    """σ(x) ≈ LUT with ``num_segments`` uniform linear pieces on [-z_max, z_max]."""
    knots, values, slopes = _pwl_arrays(num_segments, z_max)
    knots_j = jnp.asarray(knots)
    values_j = jnp.asarray(values)
    slopes_j = jnp.asarray(slopes)
    lo = float(values[0])
    hi = float(values[-1])
    step = float(knots[1] - knots[0])

    def pwl(x: jax.Array) -> jax.Array:
        x = x.astype(jnp.float32)
        seg = jnp.clip(jnp.floor((x + z_max) / step).astype(jnp.int32), 0, num_segments - 1)
        y = values_j[seg] + slopes_j[seg] * (x - knots_j[seg])
        y = jnp.where(x <= -z_max, lo, y)
        y = jnp.where(x >= z_max, hi, y)
        return y

    return pwl


def _greedy_flip_probability(delta_e: jax.Array) -> jax.Array:
    """T → 0⁺ limit (paper Fig. 3): p=1 downhill, 0.5 flat, 0 uphill."""
    return jnp.where(delta_e < 0, 1.0, jnp.where(delta_e == 0, 0.5, 0.0)).astype(jnp.float32)


def make_flip_probability(sigmoid_fn: Callable[[jax.Array], jax.Array] | None = None) -> FlipProbFn:
    """Build ``P_flip(ΔE, T) = σ(-ΔE/T)`` (Eq. 2) with T=0 handled greedily.

    ``sigmoid_fn=None`` uses the exact ``jax.nn.sigmoid``; pass a PWL from
    :func:`make_pwl_sigmoid` for the hardware-faithful path.
    """
    sig = jax.nn.sigmoid if sigmoid_fn is None else sigmoid_fn

    def flip_probability(delta_e: jax.Array, temperature: jax.Array) -> jax.Array:
        t = jnp.asarray(temperature, jnp.float32)
        safe_t = jnp.where(t > 0, t, 1.0)
        warm = sig(-delta_e.astype(jnp.float32) / safe_t)
        return jnp.where(t > 0, warm, _greedy_flip_probability(delta_e)).astype(jnp.float32)

    return flip_probability


exact_flip_probability: FlipProbFn = make_flip_probability(None)
pwl_flip_probability: FlipProbFn = make_flip_probability(make_pwl_sigmoid())


def pwl_error_bound(num_segments: int, z_max: float) -> float:
    """Analytic interpolation-error bound: max|σ''| h²/8, max|σ''| ≈ 0.09623."""
    h = 2.0 * z_max / num_segments
    return 0.09623 * h * h / 8.0
