"""Ising problem definitions and Hamiltonian (paper §II-B).

The Ising Hamiltonian over spins ``s ∈ {-1,+1}^N`` is

    H(s) = -Σ_{i<j} J_ij s_i s_j - Σ_i h_i s_i
         = -1/2 sᵀ J s - hᵀ s          (J symmetric, zero diagonal)

The *local field* at spin i is ``u_i = h_i + Σ_{j≠i} J_ij s_j`` and the flip
energy change is ``ΔE_i = H(s^(i→-i)) - H(s) = 2 s_i u_i`` (paper Eq. 2).
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property, partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

SPIN_DTYPE = jnp.int8


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeList:
    """Canonical sparse (COO / edge-list) couplings: the dense-J-free problem
    representation.

    Real benchmark instances (Gset Max-Cut, the paper's own evaluation set)
    are O(nnz) edge lists, not O(N²) matrices — storing them as a dense J
    costs a 1 GiB host allocation at N=16384 before the first flip. An
    ``EdgeList`` holds each undirected edge exactly once in canonical form:
    ``rows[k] < cols[k]`` (int32), integer ``weights`` (int64), sorted
    lexicographically, duplicates coalesced. The equivalent dense matrix is
    ``J[i, j] = J[j, i] = w`` for every entry — :meth:`to_dense` materializes
    it (tests/small problems only; the solve path never does).

    Construction goes through :meth:`create`, which defines the ingestion
    semantics explicitly: entries are symmetric-canonicalized (``(i, j)`` and
    ``(j, i)`` name the same edge), duplicates **sum** (scipy-COO
    convention — so an edge listed in both directions doubles), exact-zero
    coalesced weights are dropped, and self-loops raise (the encoders only
    warn on a nonzero diagonal, but an edge list with self-loops is almost
    always an ingestion bug, so the sparse front door refuses).

    Host-side numpy by design: the edge arrays feed the O(nnz) bit-plane
    encoder (``core.bitplane.encode_edges``) outside jit, and ride
    ``IsingProblem``'s pytree *aux* data (content-hashed, so jitted drivers
    cache correctly across repeated solves of one instance).
    """

    rows: np.ndarray     # (nnz,) int32, rows[k] < cols[k]
    cols: np.ndarray     # (nnz,) int32
    weights: np.ndarray  # (nnz,) int64, never zero
    num_spins: int

    @classmethod
    def create(cls, rows, cols, weights, num_spins: int) -> "EdgeList":
        """Canonicalize a raw COO triple (see class docstring for the exact
        duplicate / symmetric-entry semantics)."""
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        w = np.asarray(weights)
        if rows.ndim != 1 or rows.shape != cols.shape or rows.shape != w.shape:
            raise ValueError(
                f"edge arrays must be equal-length 1-D, got rows {rows.shape} "
                f"cols {cols.shape} weights {w.shape}")
        n = int(num_spins)
        if n <= 0:
            raise ValueError(f"num_spins must be positive, got {num_spins}")
        ri = rows.astype(np.int64)
        ci = cols.astype(np.int64)
        if not (np.array_equal(ri, rows) and np.array_equal(ci, cols)):
            raise ValueError("edge endpoints must be integers")
        if rows.size and (ri.min() < 0 or ci.min() < 0
                          or ri.max() >= n or ci.max() >= n):
            raise ValueError(f"edge endpoints out of range for N={n}")
        if np.any(ri == ci):
            raise ValueError("self-loop edges (i == i) are not representable "
                             "couplings; drop the diagonal before ingestion")
        wf = w.astype(np.float64)
        bad = np.flatnonzero(~np.isfinite(wf))
        if bad.size:
            k = int(bad[0])
            raise ValueError(
                f"edge weights must be finite: edge #{k} "
                f"({int(ri[k])}, {int(ci[k])}) has weight {float(w[k])!r}"
                + (f" (+{bad.size - 1} more non-finite)" if bad.size > 1
                   else ""))
        wi = np.rint(wf).astype(np.int64)
        bad = np.flatnonzero(wi != wf)
        if bad.size:
            k = int(bad[0])
            raise ValueError(
                "edge-list ingestion requires integer weights (pre-scale "
                f"first): edge #{k} ({int(ri[k])}, {int(ci[k])}) has weight "
                f"{float(w[k])!r}")
        lo = np.minimum(ri, ci)
        hi = np.maximum(ri, ci)
        order = np.lexsort((hi, lo))
        lo, hi, wi = lo[order], hi[order], wi[order]
        if lo.size:
            first = np.ones(lo.size, bool)
            first[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
            starts = np.flatnonzero(first)
            wi = np.add.reduceat(wi, starts)
            lo, hi = lo[starts], hi[starts]
            keep = wi != 0
            lo, hi, wi = lo[keep], hi[keep], wi[keep]
        return cls(rows=lo.astype(np.int32), cols=hi.astype(np.int32),
                   weights=wi, num_spins=n)

    @classmethod
    def from_dense(cls, J) -> "EdgeList":
        """Upper-triangle nonzeros of a symmetric zero-diagonal matrix
        (tests / migration convenience — the point of the class is to never
        need this direction at scale)."""
        J = np.asarray(J)
        if J.ndim != 2 or J.shape[0] != J.shape[1]:
            raise ValueError(f"J must be square, got {J.shape}")
        if not np.array_equal(J, J.T):
            raise ValueError("J must be symmetric")
        if np.any(np.diag(J) != 0):
            raise ValueError("J must have zero diagonal")
        r, c = np.nonzero(np.triu(J, 1))
        return cls.create(r, c, J[r, c], J.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.rows.size)

    @property
    def max_abs_weight(self) -> int:
        return int(np.abs(self.weights).max(initial=0))

    @property
    def nbytes(self) -> int:
        return int(self.rows.nbytes + self.cols.nbytes + self.weights.nbytes)

    def negated(self) -> "EdgeList":
        """The edge list of −J (e.g. the Max-Cut w → J = −w mapping)."""
        return EdgeList(rows=self.rows, cols=self.cols,
                        weights=-self.weights, num_spins=self.num_spins)

    def to_dense(self, dtype=np.float32) -> np.ndarray:
        """Materialize the (N, N) matrix — O(N²); tests and tiny N only."""
        J = np.zeros((self.num_spins, self.num_spins), dtype)
        J[self.rows, self.cols] = self.weights
        J[self.cols, self.rows] = self.weights
        return J

    @cached_property
    def _digest(self) -> bytes:
        h = hashlib.sha256()
        h.update(str(self.num_spins).encode())
        for a in (self.rows, self.cols, self.weights):
            h.update(a.tobytes())
        return h.digest()

    # Content-based identity: EdgeList rides IsingProblem's pytree aux data,
    # which jit hashes/compares for cache lookups — numpy arrays are neither
    # hashable nor unambiguously comparable, so both are defined here.
    def __eq__(self, other) -> bool:
        return (isinstance(other, EdgeList)
                and self.num_spins == other.num_spins
                and self._digest == other._digest)

    def __hash__(self) -> int:
        return hash((self.num_spins, self._digest))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IsingProblem:
    """An Ising instance: symmetric couplings ``J`` (zero diag) and fields ``h``.

    ``J`` may be stored dense (all-to-all coupled machine, paper §III-A;
    sparse problem graphs simply have zero entries — no minor embedding is
    ever needed, the paper's first design consideration) **or** as a
    canonical :class:`EdgeList` (``couplings=None``): the dense-J-free
    representation for instances whose O(N²) matrix would not even fit on one
    host. Edge-list problems are served by the plane-backed solve paths
    (``backend="fused"`` / ``solve_sharded``); the dense-oracle helpers below
    (``energy``/``local_fields``/the reference backend) require the dense J
    and raise a routing error otherwise.
    """

    couplings: Optional[jax.Array]  # (N, N) float32, symmetric, zero diagonal
    fields: jax.Array  # (N,) float32
    offset: float = 0.0  # constant energy offset (e.g. from Max-Cut mapping)
    edges: Optional[EdgeList] = None  # dense-J-free couplings (host-side COO)

    def tree_flatten(self):
        # ``edges`` is host-side numpy and rides the aux data (content-hashed,
        # see EdgeList.__hash__) so jitted drivers cache across repeat solves.
        return (self.couplings, self.fields), (self.offset, self.edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(couplings=children[0], fields=children[1], offset=aux[0],
                   edges=aux[1] if len(aux) > 1 else None)

    @property
    def num_spins(self) -> int:
        if self.couplings is not None:
            return self.couplings.shape[-1]
        return self.edges.num_spins

    @property
    def coupling_source(self):
        """What ``core.coupling.CouplingStore.build`` consumes: the edge list
        when the problem is dense-J-free, else the dense J."""
        return self.edges if self.couplings is None else self.couplings

    @staticmethod
    def validate(J: np.ndarray, h: np.ndarray) -> None:
        if J.ndim != 2 or J.shape[0] != J.shape[1]:
            raise ValueError(f"J must be square, got {J.shape}")
        if h.shape != (J.shape[0],):
            raise ValueError(f"h shape {h.shape} incompatible with J {J.shape}")
        # Finite checks first: a NaN anywhere would otherwise surface as the
        # misleading "J must be symmetric" (NaN != NaN under allclose).
        if not np.isfinite(J).all():
            i, j = np.argwhere(~np.isfinite(J))[0]
            raise ValueError(
                f"J must be finite: J[{i}, {j}] = {float(J[i, j])!r}")
        if not np.isfinite(h).all():
            (i,) = np.argwhere(~np.isfinite(h))[0]
            raise ValueError(f"h must be finite: h[{i}] = {float(h[i])!r}")
        if not np.allclose(J, J.T):
            raise ValueError("J must be symmetric")
        if not np.allclose(np.diag(J), 0.0):
            raise ValueError("J must have zero diagonal")

    @classmethod
    def create(cls, J, h=None, offset: float = 0.0, check: bool = True) -> "IsingProblem":
        J = np.asarray(J, dtype=np.float32)
        if h is None:
            h = np.zeros(J.shape[0], dtype=np.float32)
        h = np.asarray(h, dtype=np.float32)
        if check:
            cls.validate(J, h)
        return cls(couplings=jnp.asarray(J), fields=jnp.asarray(h), offset=float(offset))

    @classmethod
    def create_sparse(cls, edges: EdgeList, h=None,
                      offset: float = 0.0) -> "IsingProblem":
        """Dense-J-free instance from a canonical :class:`EdgeList` — the
        (N, N) f32 matrix is never materialized, here or anywhere downstream
        on the plane-backed solve path."""
        if not isinstance(edges, EdgeList):
            raise TypeError(f"create_sparse needs an EdgeList, got "
                            f"{type(edges).__name__} (EdgeList.create "
                            "canonicalizes raw COO arrays)")
        n = edges.num_spins
        if h is None:
            h = np.zeros(n, dtype=np.float32)
        h = np.asarray(h, dtype=np.float32)
        if h.shape != (n,):
            raise ValueError(f"h shape {h.shape} incompatible with N={n}")
        return cls(couplings=None, fields=jnp.asarray(h), offset=float(offset),
                   edges=edges)


def _require_dense(problem: IsingProblem, what: str) -> jax.Array:
    if problem.couplings is None:
        raise ValueError(
            f"{what} needs the dense (N, N) couplings, but this problem is "
            "edge-list-backed (dense-J-free). Use the plane-backed paths "
            "(backend='fused', solve_sharded) or materialize explicitly via "
            "problem.edges.to_dense() for small N.")
    return problem.couplings


def energy(problem: IsingProblem, spins: jax.Array) -> jax.Array:
    """H(s); ``spins`` is (..., N) in {-1,+1}. Returns (...,)."""
    _require_dense(problem, "ising.energy")
    s = spins.astype(jnp.float32)
    Js = jnp.einsum("ij,...j->...i", problem.couplings, s)
    pair = -0.5 * jnp.einsum("...i,...i->...", s, Js)
    field = -jnp.einsum("i,...i->...", problem.fields, s)
    return pair + field


def local_fields(problem: IsingProblem, spins: jax.Array) -> jax.Array:
    """u_i = h_i + Σ_j J_ij s_j, computed from scratch (paper Eq. 11)."""
    _require_dense(problem, "ising.local_fields")
    s = spins.astype(jnp.float32)
    return jnp.einsum("ij,...j->...i", problem.couplings, s) + problem.fields


def energy_from_fields(u_j: jax.Array, spins: jax.Array,
                       fields: jax.Array) -> jax.Array:
    """H(s) from precomputed pairwise local fields ``u^J = J s``.

    ``pair = -0.5 Σ_i s_i u^J_i`` and ``field = -Σ_i h_i s_i`` — the *same
    einsum contractions* as :func:`energy`, evaluated on ``u^J`` instead of
    ``J s``. When ``u^J`` is bit-identical to the dense matmul (the
    Hamming-weight accumulation on an integer J is — exact integer sums below
    2²⁴ in f32), the result is bitwise equal to the dense-path energy for
    *any* h, which is what keeps dense-fed and plane-fed trajectories exactly
    equal. This is the single e₀ assembly every dense-J-free init routes
    through (fused init, the sharded per-device init, and the distributed
    driver's plane-fed chain re-init).
    """
    s = spins.astype(jnp.float32)
    pair = -0.5 * jnp.einsum("...i,...i->...", s, u_j.astype(jnp.float32))
    field = -jnp.einsum("i,...i->...", fields, s)
    return pair + field


def delta_energies(problem: IsingProblem, spins: jax.Array, u: Optional[jax.Array] = None) -> jax.Array:
    """ΔE_i = 2 s_i u_i for every candidate single-spin flip (paper Eq. 2)."""
    if u is None:
        u = local_fields(problem, spins)
    return 2.0 * spins.astype(jnp.float32) * u


def incremental_field_update(J: jax.Array, u: jax.Array, j: jax.Array, s_old_j: jax.Array) -> jax.Array:
    """u'_i = u_i - 2 J_ij s_j_old after flipping spin j (paper Eq. 12/17).

    Θ(N) instead of the Θ(N²) from-scratch recompute; J symmetric so the row
    J[j] equals the column J[:, j] the hardware streams (DESIGN.md §2).
    """
    row = jnp.take(J, j, axis=0)  # (N,)
    return u - 2.0 * row * s_old_j.astype(u.dtype)


def random_spins(key: jax.Array, shape) -> jax.Array:
    """Uniform random ±1 spin configuration."""
    bits = jax.random.bernoulli(key, 0.5, shape)
    return jnp.where(bits, 1, -1).astype(SPIN_DTYPE)


@partial(jax.jit, static_argnames=("n",))
def _brute_force_impl(J, h, n):
    idx = jnp.arange(2**n)
    bits = (idx[:, None] >> jnp.arange(n)[None, :]) & 1
    spins = (2 * bits - 1).astype(jnp.float32)
    Js = spins @ J
    e = -0.5 * jnp.einsum("ki,ki->k", spins, Js) - spins @ h
    k = jnp.argmin(e)
    return e[k], spins[k].astype(SPIN_DTYPE), e


def brute_force_ground_state(problem: IsingProblem):
    """Exhaustive ground-state search (tests only; N ≤ ~20)."""
    n = problem.num_spins
    if n > 24:
        raise ValueError("brute force limited to N<=24")
    e, s, all_e = _brute_force_impl(problem.couplings, problem.fields, n)
    return float(e) + problem.offset, np.asarray(s), np.asarray(all_e) + problem.offset
