"""Ising problem definitions and Hamiltonian (paper §II-B).

The Ising Hamiltonian over spins ``s ∈ {-1,+1}^N`` is

    H(s) = -Σ_{i<j} J_ij s_i s_j - Σ_i h_i s_i
         = -1/2 sᵀ J s - hᵀ s          (J symmetric, zero diagonal)

The *local field* at spin i is ``u_i = h_i + Σ_{j≠i} J_ij s_j`` and the flip
energy change is ``ΔE_i = H(s^(i→-i)) - H(s) = 2 s_i u_i`` (paper Eq. 2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

SPIN_DTYPE = jnp.int8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IsingProblem:
    """An Ising instance: symmetric couplings ``J`` (zero diag) and fields ``h``.

    ``J`` is stored dense (all-to-all coupled machine, paper §III-A); sparse
    problem graphs simply have zero entries — no minor embedding is ever needed,
    which is the paper's first design consideration.
    """

    couplings: jax.Array  # (N, N) float32, symmetric, zero diagonal
    fields: jax.Array  # (N,) float32
    offset: float = 0.0  # constant energy offset (e.g. from Max-Cut mapping)

    def tree_flatten(self):
        return (self.couplings, self.fields), (self.offset,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(couplings=children[0], fields=children[1], offset=aux[0])

    @property
    def num_spins(self) -> int:
        return self.couplings.shape[-1]

    @staticmethod
    def validate(J: np.ndarray, h: np.ndarray) -> None:
        if J.ndim != 2 or J.shape[0] != J.shape[1]:
            raise ValueError(f"J must be square, got {J.shape}")
        if h.shape != (J.shape[0],):
            raise ValueError(f"h shape {h.shape} incompatible with J {J.shape}")
        if not np.allclose(J, J.T):
            raise ValueError("J must be symmetric")
        if not np.allclose(np.diag(J), 0.0):
            raise ValueError("J must have zero diagonal")

    @classmethod
    def create(cls, J, h=None, offset: float = 0.0, check: bool = True) -> "IsingProblem":
        J = np.asarray(J, dtype=np.float32)
        if h is None:
            h = np.zeros(J.shape[0], dtype=np.float32)
        h = np.asarray(h, dtype=np.float32)
        if check:
            cls.validate(J, h)
        return cls(couplings=jnp.asarray(J), fields=jnp.asarray(h), offset=float(offset))


def energy(problem: IsingProblem, spins: jax.Array) -> jax.Array:
    """H(s); ``spins`` is (..., N) in {-1,+1}. Returns (...,)."""
    s = spins.astype(jnp.float32)
    Js = jnp.einsum("ij,...j->...i", problem.couplings, s)
    pair = -0.5 * jnp.einsum("...i,...i->...", s, Js)
    field = -jnp.einsum("i,...i->...", problem.fields, s)
    return pair + field


def local_fields(problem: IsingProblem, spins: jax.Array) -> jax.Array:
    """u_i = h_i + Σ_j J_ij s_j, computed from scratch (paper Eq. 11)."""
    s = spins.astype(jnp.float32)
    return jnp.einsum("ij,...j->...i", problem.couplings, s) + problem.fields


def delta_energies(problem: IsingProblem, spins: jax.Array, u: Optional[jax.Array] = None) -> jax.Array:
    """ΔE_i = 2 s_i u_i for every candidate single-spin flip (paper Eq. 2)."""
    if u is None:
        u = local_fields(problem, spins)
    return 2.0 * spins.astype(jnp.float32) * u


def incremental_field_update(J: jax.Array, u: jax.Array, j: jax.Array, s_old_j: jax.Array) -> jax.Array:
    """u'_i = u_i - 2 J_ij s_j_old after flipping spin j (paper Eq. 12/17).

    Θ(N) instead of the Θ(N²) from-scratch recompute; J symmetric so the row
    J[j] equals the column J[:, j] the hardware streams (DESIGN.md §2).
    """
    row = jnp.take(J, j, axis=0)  # (N,)
    return u - 2.0 * row * s_old_j.astype(u.dtype)


def random_spins(key: jax.Array, shape) -> jax.Array:
    """Uniform random ±1 spin configuration."""
    bits = jax.random.bernoulli(key, 0.5, shape)
    return jnp.where(bits, 1, -1).astype(SPIN_DTYPE)


@partial(jax.jit, static_argnames=("n",))
def _brute_force_impl(J, h, n):
    idx = jnp.arange(2**n)
    bits = (idx[:, None] >> jnp.arange(n)[None, :]) & 1
    spins = (2 * bits - 1).astype(jnp.float32)
    Js = spins @ J
    e = -0.5 * jnp.einsum("ki,ki->k", spins, Js) - spins @ h
    k = jnp.argmin(e)
    return e[k], spins[k].astype(SPIN_DTYPE), e


def brute_force_ground_state(problem: IsingProblem):
    """Exhaustive ground-state search (tests only; N ≤ ~20)."""
    n = problem.num_spins
    if n > 24:
        raise ValueError("brute force limited to N<=24")
    e, s, all_e = _brute_force_impl(problem.couplings, problem.fields, n)
    return float(e) + problem.offset, np.asarray(s), np.asarray(all_e) + problem.offset
