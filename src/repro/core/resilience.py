"""Resilient solve supervisor: crash-safe checkpoint/resume, budgets, tiers.

Long anneals die — preemption, OOM, a deadline, a ctrl-C — and the paper's
TTS methodology (§V) only works if a killed trial can either finish later or
report an honest best-so-far. This module wraps every solve driver
(``core.solver.solve``, ``core.tempering.solve_tempering``,
``distributed.solver_dist.solve_distributed``,
``distributed.solver_sharded.solve_sharded``) in one chunk-granular
supervisor, :func:`run_resilient`:

* **Checkpoint/resume, bit-identical.** Every driver already advances its
  trajectory in chunks whose RNG is a pure function of ``(seed, chunk
  index)`` (the ``Salt.SWEEP`` streams / absolute-step keys) — no carried
  RNG state. The supervisor drives the *same* chunk bodies the monolithic
  scans use (``ops.anneal_chunk_step``, ``solver.run_reference_chunk``,
  ``tempering.fused_tempering_round``, ``solver_sharded.sharded_sweep_fn``,
  ``solver_dist.dist_resilient_fns``) one host-visible chunk at a time, and
  atomically snapshots the full chain state at chunk boundaries
  (``checkpoint.manager``: temp dir + rename + sha256). A restarted run
  reconstructs the exact chunk cadence from ``(config, chunk_steps)`` and
  replays the remaining chunks — the resumed trajectory is **bit-identical**
  to the uninterrupted one (asserted across every coupling tier by
  ``tests/test_resilience.py``).

* **Corruption containment.** A snapshot that fails its checksum (torn
  write, flipped bit, truncation) raises ``SnapshotCorruptError`` at
  restore; the supervisor falls back to the next-older snapshot, and to a
  fresh start when none survives. A ``run_dir`` whose snapshots belong to a
  *different* (problem, seed, config) is refused loudly — resuming someone
  else's trajectory would silently corrupt results.

* **Budgets.** :class:`BudgetConfig` bounds the run by wall-clock deadline,
  total sweep steps, or a target energy; checks happen between chunks and
  always return the best-so-far with a structured ``stop_reason``
  ("completed" | "deadline" | "max_steps" | "target" | "interrupted").
  ``KeyboardInterrupt`` is caught at the same granularity: the state is
  snapshotted and the partial result returned instead of a traceback.

* **Tier fallback.** With ``coupling_format="auto"``, an allocation failure
  (RESOURCE_EXHAUSTED / OOM) while building the coupling store or running a
  chunk retries at the next coupling tier — dense → bitplane →
  bitplane_hbm → bitplane_sharded (the last only when a mesh is supplied
  and the shard alignment holds) — restoring from the last snapshot, so
  completed work survives the downgrade. Because the tiers are
  trajectory-identical by contract, a downgraded run still produces
  bit-identical results. Downgrades are recorded on the result and in every
  subsequent snapshot. The distributed driver is excluded (its store is
  per-device by construction; losing a host is handled by replica
  independence, not by re-tiering).

Fault injection for tests rides on :func:`inject_faults` — a context-local
hook fired at the supervisor's seams ("store_build", "chunk_start",
"checkpoint_saved") so the harness (``tests/fault_injection.py``) can raise
synthetic OOMs or kill the process at randomized chunk boundaries.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import time
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ising, rng
from .coupling import KERNEL_COUPLING_MODES, CouplingStore, resolve_format
from .solver import (SolveResult, SolverConfig, _mcmc_config,
                     reference_init_state, run_reference_chunk)
from .tempering import (TemperingConfig, TemperingResult,
                        fused_tempering_round, tempering_round_count)
from ..checkpoint import manager as ckpt
from ..checkpoint.manager import SnapshotCorruptError

#: Structured stop reasons — the full closed set a ``ResilientResult`` can
#: carry.
STOP_COMPLETED = "completed"
STOP_DEADLINE = "deadline"
STOP_MAX_STEPS = "max_steps"
STOP_TARGET = "target"
STOP_INTERRUPTED = "interrupted"
STOP_REASONS = (STOP_COMPLETED, STOP_DEADLINE, STOP_MAX_STEPS, STOP_TARGET,
                STOP_INTERRUPTED)


@dataclasses.dataclass(frozen=True)
class BudgetConfig:
    """Between-chunk run bounds; every bound returns best-so-far, never an
    exception. ``target_energy`` compares against the ensemble-best energy
    *including* the problem offset (the user-facing value)."""
    deadline_seconds: Optional[float] = None
    max_steps: Optional[int] = None
    target_energy: Optional[float] = None


class ResilientResult(NamedTuple):
    result: object              # SolveResult | TemperingResult (best-so-far)
    stop_reason: str            # one of STOP_REASONS
    steps_done: int             # sweep steps actually advanced (incl. resumed)
    chunks_done: int            # chunk units completed
    total_chunks: int
    resumed_from_chunk: Optional[int]   # snapshot the run resumed at, or None
    downgrades: tuple           # ((from_fmt, to_fmt, at_chunk), ...)
    wall_seconds: float


# --------------------------------------------------------------------------
# Fault injection (tests only): a context-local hook at the supervisor seams.

_fault_hook: Optional[Callable] = None


@contextlib.contextmanager
def inject_faults(hook: Callable[[str, dict], None]):
    """Install ``hook(site, info)`` for the duration of the block. Sites:
    "store_build" (before a tier's store/runner build), "chunk_start"
    (before each chunk; ``info["chunk"]``), "checkpoint_saved" (after each
    snapshot). Whatever the hook raises propagates into the supervisor —
    raising an allocation-failure error at "store_build"/"chunk_start"
    exercises the tier-fallback path without a real OOM."""
    global _fault_hook
    prev = _fault_hook
    _fault_hook = hook
    try:
        yield
    finally:
        _fault_hook = prev


def _fault(site: str, **info):
    if _fault_hook is not None:
        _fault_hook(site, info)


# --------------------------------------------------------------------------
# Allocation-failure detection and the tier ladder.

_ALLOC_TOKENS = ("resource_exhausted", "out of memory", "failed to allocate",
                 "oom")


def is_allocation_failure(exc: BaseException) -> bool:
    """Whether ``exc`` looks like a memory-allocation failure (XLA
    RESOURCE_EXHAUSTED, allocator OOM, host ``MemoryError``) — the class of
    error the tier ladder can actually fix, as opposed to bugs it must
    propagate."""
    if isinstance(exc, MemoryError):
        return True
    msg = str(exc).lower()
    return any(tok in msg for tok in _ALLOC_TOKENS)


def next_tier(fmt: str, problem: ising.IsingProblem, mesh) -> Optional[str]:
    """The coupling tier to retry at after ``fmt`` hit an allocation
    failure, or None when the ladder ends: dense → bitplane (integral J
    only) → bitplane_hbm → bitplane_sharded (mesh present, shard-aligned)."""
    if fmt == "dense":
        if problem.couplings is not None:
            J = np.asarray(jax.device_get(problem.couplings))
            if not np.array_equal(J, np.rint(J)):
                return None         # fractional J has no packed tier
        return "bitplane"
    if fmt == "bitplane":
        return "bitplane_hbm"
    if fmt == "bitplane_hbm":
        if mesh is None:
            return None
        from ..kernels import common
        num_shards = 1
        for a in mesh.axis_names:
            num_shards *= mesh.shape[a]
        n = problem.num_spins
        if n % num_shards or (n // num_shards) % common.default_lane(n):
            return None             # unshardable problem: ladder ends
        return "bitplane_sharded"
    return None


# --------------------------------------------------------------------------
# Run identity: a resumable snapshot must belong to *this* run.

def problem_fingerprint(problem: ising.IsingProblem) -> str:
    """Content hash of the problem (couplings/edges + fields + offset) —
    written into every snapshot so a resume onto a different instance is
    refused instead of silently mixing trajectories."""
    h = hashlib.sha256()
    if problem.couplings is not None:
        J = np.ascontiguousarray(jax.device_get(problem.couplings))
        h.update(b"dense")
        h.update(repr(J.shape).encode())
        h.update(J.tobytes())
    else:
        h.update(b"edges")
        h.update(problem.edges._digest)
    fields = np.ascontiguousarray(jax.device_get(problem.fields))
    h.update(fields.tobytes())
    h.update(np.float64(problem.offset).tobytes())
    return h.hexdigest()


def run_signature(problem: ising.IsingProblem, seed, config, *, backend: str,
                  chunk_steps: int, mesh) -> str:
    """Hash of everything the chunk cadence and RNG streams depend on. The
    configs are frozen dataclasses of plain values, so their reprs are
    stable across processes."""
    mesh_desc = (None if mesh is None
                 else tuple((a, int(mesh.shape[a])) for a in mesh.axis_names))
    parts = "|".join([
        f"seed={int(seed)}", f"backend={backend}",
        f"chunk_steps={int(chunk_steps)}", f"config={config!r}",
        f"mesh={mesh_desc!r}",
        f"problem={problem_fingerprint(problem)}",
    ])
    return hashlib.sha256(parts.encode()).hexdigest()


# --------------------------------------------------------------------------
# Per-backend chunk runners. Each runner drives the SAME chunk body the
# monolithic driver scans over, one host-visible unit at a time; the state it
# carries across units is a pytree of device arrays that round-trips through
# the checkpoint losslessly.

@partial(jax.jit, static_argnames=("config", "interpret"))
def _fused_init(problem, seed, config: SolverConfig, store: CouplingStore,
                interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    return _ops.fused_init_state(problem, base, config.num_replicas,
                                 interpret=interpret, planes=store.planes)


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len", "gather",
                                   "interpret"))
def _fused_chunk(state, seed, c, store: CouplingStore, *,
                 config: SolverConfig, clen: int, chunk_len: int,
                 gather: str, interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    return _ops.anneal_chunk_step(store, state, base, c, clen=clen,
                                  chunk_len=chunk_len, config=config,
                                  gather=gather, block_r=8,
                                  interpret=interpret)


class _FusedRunner:
    """``solve(backend="fused")`` / ``fused_anneal``, chunk at a time."""

    backend = "fused"

    def __init__(self, problem, seed, config: SolverConfig,
                 store: CouplingStore, chunk_steps: int):
        from ..kernels import ops as _ops
        self.problem = problem
        self.config = config
        self.store = store
        self.fmt = store.fmt
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.interpret = _ops.auto_interpret(None)
        self.gather = _ops.anneal_gather(store, "dynamic", problem.num_spins)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        return _fused_init(self.problem, self.seed, self.config, self.store,
                           self.interpret)

    def run_chunk(self, state, k: int):
        return _fused_chunk(state, self.seed, jnp.int32(k), self.store,
                            config=self.config, clen=self.unit_len(k),
                            chunk_len=self.chunk_len, gather=self.gather,
                            interpret=self.interpret)

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        u, s, e, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = (jnp.asarray(np.stack(rows)) + off).astype(jnp.float32)
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(best_energy=be + off, best_spins=bs.astype(jnp.int8),
                           final_energy=e + off, num_flips=nf,
                           trace_energy=trace)


@partial(jax.jit, static_argnames=("config",))
def _reference_init(problem, seed, config: SolverConfig):
    states, _ = reference_init_state(problem, seed, config)
    return states


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len"))
def _reference_chunk(problem, states, seed, c, *, config: SolverConfig,
                     clen: int, chunk_len: int):
    # Replica keys are a pure function of the seed — recomputed per chunk so
    # the snapshot carries chain state only, never RNG state.
    base = jax.random.fold_in(jax.random.key(0), seed)
    keys = jax.vmap(lambda i: rng.stream(base, rng.Salt.REPLICA, i))(
        jnp.arange(config.num_replicas))
    return run_reference_chunk(problem, states, keys, c, clen=clen,
                               chunk_len=chunk_len, config=config,
                               mc=_mcmc_config(config))


class _ReferenceRunner:
    """``solve(backend="reference")``, chunk at a time. Every step is keyed
    by its absolute index, so *any* chunking composes to the same values as
    the monolithic loop — traced runs use the trace cadence, untraced runs
    the supervisor's ``chunk_steps``."""

    backend = "reference"
    fmt = "dense"

    def __init__(self, problem, seed, config: SolverConfig, chunk_steps: int):
        from ..kernels import ops as _ops
        if problem.couplings is None:
            raise ValueError(
                "backend='reference' needs the dense J; edge-list "
                "(dense-J-free) problems are served by backend='fused'")
        self.problem = problem
        self.config = config
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        return _reference_init(self.problem, self.seed, self.config)

    def run_chunk(self, states, k: int):
        return _reference_chunk(self.problem, states, self.seed,
                                jnp.int32(k), config=self.config,
                                clen=self.unit_len(k),
                                chunk_len=self.chunk_len)

    def best_energy(self, states) -> float:
        return float(jnp.min(states.best_energy)) + float(self.problem.offset)

    def trace_row(self, states):
        return states.best_energy

    def finalize(self, states, rows) -> SolveResult:
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = jnp.asarray(np.stack(rows)) + off
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(best_energy=states.best_energy + off,
                           best_spins=states.best_spins,
                           final_energy=states.energy + off,
                           num_flips=states.num_flips,
                           trace_energy=trace)


@partial(jax.jit, static_argnames=("config", "interpret"))
def _tempering_init(problem, seed, config: TemperingConfig,
                    store: CouplingStore, interpret: bool):
    from ..kernels import ops as _ops
    base = jax.random.fold_in(jax.random.key(0), seed)
    state = _ops.fused_init_state(problem, base, config.num_replicas,
                                  interpret=interpret, planes=store.planes)
    return (state, jnp.int32(0), jnp.int32(0))


@partial(jax.jit, static_argnames=("config", "interpret"))
def _tempering_round(carry, seed, round_idx, store: CouplingStore, *,
                     config: TemperingConfig, interpret: bool):
    state, acc, tot = carry
    base = jax.random.fold_in(jax.random.key(0), seed)
    return fused_tempering_round(state, acc, tot, base, round_idx, config,
                                 store, interpret=interpret)


class _TemperingRunner:
    """``solve_tempering(backend="fused")``, one swap round per unit. The
    carried state is ``(kernel 6-tuple, swap-accept, swap-total)`` so the
    acceptance statistic survives resume too."""

    backend = "tempering"

    def __init__(self, problem, seed, config: TemperingConfig,
                 store: CouplingStore):
        from ..kernels import ops as _ops
        if config.backend != "fused":
            raise ValueError(
                "run_resilient serves tempering's fused backend only — the "
                "reference chains run one flip per XLA op and have no "
                "chunked surface to checkpoint at; set "
                "TemperingConfig(backend='fused')")
        self.problem = problem
        self.config = config
        self.store = store
        self.fmt = store.fmt
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.interpret = _ops.auto_interpret(None)
        self.total_units = tempering_round_count(config)
        self.collect_trace = False
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        return self.config.swap_every

    def init(self):
        return _tempering_init(self.problem, self.seed, self.config,
                               self.store, self.interpret)

    def run_chunk(self, carry, k: int):
        return _tempering_round(carry, self.seed, jnp.int32(k), self.store,
                                config=self.config, interpret=self.interpret)

    def best_energy(self, carry) -> float:
        return float(jnp.min(carry[0][3])) + float(self.problem.offset)

    def trace_row(self, carry):
        return carry[0][3]

    def finalize(self, carry, rows) -> TemperingResult:
        (u, s, e, be, bs, nf), acc, tot = carry
        off = self.problem.offset
        return TemperingResult(
            best_energy=be + off,
            best_spins=bs.astype(ising.SPIN_DTYPE),
            final_energy=e + off,
            swap_acceptance=acc.astype(jnp.float32) / jnp.maximum(tot, 1),
            num_flips=nf)


@partial(jax.jit, static_argnames=("config", "clen", "chunk_len"))
def _sharded_chunk_inputs(seed, c, *, config: SolverConfig, clen: int,
                          chunk_len: int):
    # Replicated per-chunk uniforms + temps — the identical values
    # sharded_anneal_fn's local_anneal computes (replicated) on every device.
    r = config.num_replicas
    base = jax.random.fold_in(jax.random.key(0), seed)
    steps = c * chunk_len + jnp.arange(clen)
    temps = jax.vmap(config.schedule)(steps).astype(jnp.float32)
    temps = jnp.broadcast_to(temps[:, None], (clen, r))
    uniforms = rng.uniform01(rng.stream(base, rng.Salt.SWEEP, c),
                             (clen, r, 4))
    return uniforms, temps


@jax.jit
def _best_merge(be, bs, nf, ce, cs, cf):
    # ops.fused_sweep_chunk's best-so-far merge, on (possibly sharded) arrays.
    better = ce < be
    return (jnp.where(better, ce, be), jnp.where(better[:, None], cs, bs),
            nf + cf)


class _ShardedRunner:
    """``solve_sharded``, chunk at a time: init via ``sharded_init_fn``, the
    per-chunk sweep via ``sharded_sweep_fn``, the best merge identical to the
    in-scan one. State leaves keep their spin-axis shardings across the
    checkpoint round-trip (restore device_puts to the template shardings)."""

    backend = "sharded"
    fmt = "bitplane_sharded"

    def __init__(self, problem, seed, config: SolverConfig, mesh,
                 chunk_steps: int):
        from ..distributed import solver_sharded as _ss
        from ..kernels import ops as _ops
        self.problem = problem
        self.config = config
        self.mesh = mesh
        self.seed = jnp.asarray(seed, jnp.uint32)
        self.planes = _ss.resolve_sharded_planes(problem, config, mesh)
        n = problem.num_spins
        self._init_fn = _ss.sharded_init_fn(config, mesh, n)
        self._sweep_fn = _ss.sharded_sweep_fn(config, mesh, n)
        self.chunk_len, self.num_chunks, self.rem_steps = (
            _ops.anneal_chunk_plan(config, chunk_steps))
        self.total_units = self.num_chunks + (1 if self.rem_steps else 0)
        self.collect_trace = bool(config.trace_every)
        self.num_replicas = config.num_replicas

    def unit_len(self, k: int) -> int:
        if self.rem_steps and k == self.num_chunks:
            return self.rem_steps
        return self.chunk_len

    def init(self):
        from jax.sharding import NamedSharding, PartitionSpec
        seed_arr = jnp.asarray([self.seed], jnp.uint32)
        u0, s0, e0 = self._init_fn(self.planes, self.problem.fields, seed_arr)
        # num_flips replicated over the mesh like e0 — a default-device zeros
        # would commit the resume template's leaf to one device and clash
        # with the mesh-committed state in the merge.
        nf = jax.device_put(np.zeros((self.num_replicas,), np.int32),
                            NamedSharding(self.mesh, PartitionSpec()))
        return (u0, s0, e0, e0, s0, nf)

    def run_chunk(self, state, k: int):
        u, s, e, be, bs, nf = state
        uniforms, temps = _sharded_chunk_inputs(
            self.seed, jnp.int32(k), config=self.config,
            clen=self.unit_len(k), chunk_len=self.chunk_len)
        u, s, e, ce, cs, cf = self._sweep_fn(self.planes, u, s, e, uniforms,
                                             temps)
        be, bs, nf = _best_merge(be, bs, nf, ce, cs, cf)
        return (u, s, e, be, bs, nf)

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        u, s, e, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        if self.collect_trace and rows:
            trace = (jnp.asarray(np.stack(rows)) + off).astype(jnp.float32)
        else:
            trace = jnp.zeros((0, r), jnp.float32)
        return SolveResult(best_energy=be + off, best_spins=bs.astype(jnp.int8),
                           final_energy=e + off, num_flips=nf,
                           trace_energy=trace)


class _DistRunner:
    """``solve_distributed``, chunk at a time via
    ``solver_dist.dist_resilient_fns`` — same per-device RNG, chunk cadence,
    and elitist exchange as the monolithic scan. Excluded from the tier
    ladder (the store choice is per-device by construction)."""

    backend = "distributed"

    def __init__(self, problem, seed, config, mesh):
        from ..distributed import solver_dist as _sd
        self.problem = problem
        self.config = config
        init_fn, chunk_fn, setup = _sd.dist_resilient_fns(problem, config,
                                                          mesh)
        self._init_fn = init_fn
        self._chunk_fn = chunk_fn
        self.operands = _sd.dist_operands(problem, seed, setup)
        self.fmt = setup.store.fmt if setup.store is not None else "dense"
        self.chunk_len = setup.chunk
        self.total_units = setup.num_chunks
        self.collect_trace = True   # the dist trace is always on
        self.num_replicas = setup.r_total

    def unit_len(self, k: int) -> int:
        return self.chunk_len

    def init(self):
        return tuple(self._init_fn(*self.operands))

    def run_chunk(self, state, k: int):
        c_arr = jnp.asarray([k], jnp.int32)
        h, seed_arr = self.operands[0], self.operands[1]
        return tuple(self._chunk_fn(*state, h, seed_arr, c_arr,
                                    *self.operands[2:]))

    def best_energy(self, state) -> float:
        return float(jnp.min(state[3])) + float(self.problem.offset)

    def trace_row(self, state):
        return state[3]

    def finalize(self, state, rows) -> SolveResult:
        sp, fu, en, be, bs, nf = state
        off = self.problem.offset
        r = self.num_replicas
        trace = ((jnp.asarray(np.stack(rows)) + off) if rows
                 else jnp.zeros((0, r), jnp.float32))
        return SolveResult(best_energy=be + off, best_spins=bs,
                           final_energy=en + off, num_flips=nf,
                           trace_energy=trace)


# --------------------------------------------------------------------------
# Backend resolution + runner construction.

def _resolve_backend(config, backend: str, mesh) -> str:
    if backend != "auto":
        return backend
    from ..distributed.solver_dist import DistSolverConfig
    if isinstance(config, TemperingConfig):
        return "tempering"
    if isinstance(config, DistSolverConfig):
        return "distributed"
    if isinstance(config, SolverConfig):
        return "sharded" if mesh is not None else "fused"
    raise TypeError(f"unrecognized config type {type(config).__name__}")


def _build_runner(problem, seed, config, *, backend: str, mesh,
                  chunk_steps: int, fmt: Optional[str], store):
    """Build the chunk runner for one tier attempt. ``fmt`` is the tier
    override (None = as configured); "bitplane_sharded" switches a fused
    solve onto the spin-sharded driver."""
    if backend == "reference":
        return _ReferenceRunner(problem, seed, config, chunk_steps)
    if backend == "distributed":
        if mesh is None:
            raise ValueError("backend='distributed' needs a mesh")
        return _DistRunner(problem, seed, config, mesh)
    if backend == "sharded" or (backend == "fused"
                                and fmt == "bitplane_sharded"):
        if mesh is None:
            raise ValueError("the bitplane_sharded tier needs a mesh")
        return _ShardedRunner(problem, seed, config, mesh, chunk_steps)
    if backend == "fused":
        if store is None or fmt is not None:
            store = CouplingStore.build(problem.coupling_source,
                                        fmt or config.coupling_format)
        store.require(KERNEL_COUPLING_MODES, "run_resilient")
        return _FusedRunner(problem, seed, config, store, chunk_steps)
    if backend == "tempering":
        if store is None or fmt is not None:
            store = CouplingStore.build(problem.coupling_source,
                                        fmt or config.coupling_format)
        store.require(KERNEL_COUPLING_MODES, "run_resilient")
        return _TemperingRunner(problem, seed, config, store)
    raise ValueError(
        f"backend must be one of 'auto', 'fused', 'reference', 'tempering', "
        f"'sharded', 'distributed', got {backend!r}")


def _current_fmt(problem, config, backend: str, fmt: Optional[str]) -> str:
    if fmt is not None:
        return fmt
    if backend == "reference":
        return "dense"
    if backend == "sharded":
        return "bitplane_sharded"
    return resolve_format(getattr(config, "coupling_format", "auto"),
                          problem.coupling_source, problem.num_spins)


def _fallback_enabled(config, backend: str) -> bool:
    return (backend in ("fused", "tempering")
            and getattr(config, "coupling_format", None) == "auto")


# --------------------------------------------------------------------------
# Snapshot plumbing.

def _trace_template(runner, chunks: int):
    rows = chunks if runner.collect_trace else 0
    return np.zeros((rows, runner.num_replicas), np.float32)


def _save_snapshot(mgr: ckpt.CheckpointManager, runner, state, rows,
                   chunks_done: int, steps_done: int, signature: str,
                   fingerprint: str, downgrades):
    trace = (np.stack(rows).astype(np.float32) if rows
             else _trace_template(runner, 0))
    mgr.save(chunks_done, {"state": state, "trace": trace},
             extra={"signature": signature, "fingerprint": fingerprint,
                    "chunks_done": chunks_done, "steps_done": steps_done,
                    "fmt": runner.fmt, "backend": runner.backend,
                    "downgrades": [list(d) for d in downgrades]})


def _try_resume(run_dir: str, runner, signature: str, fingerprint: str,
                emit):
    """Newest-first walk over the snapshots in ``run_dir``: identity
    mismatches are refused loudly, corrupt snapshots are skipped with an
    event, and ``(None, ...)`` means no usable snapshot — start fresh.
    Returns ``(state, rows, chunks_done, steps_done, downgrades)``."""
    for step in reversed(ckpt.snapshot_steps(run_dir)):
        try:
            manifest = ckpt.read_manifest(run_dir, step)
        except SnapshotCorruptError as e:
            emit("snapshot_corrupt", {"step": step, "error": str(e)})
            continue
        extra = manifest.get("extra", {})
        if extra.get("fingerprint") not in (None, fingerprint):
            raise ValueError(
                f"run_dir {run_dir!r} holds snapshots of a different "
                f"problem (fingerprint mismatch at step_{step}) — refusing "
                f"to resume; point --run-dir at a fresh directory")
        if extra.get("signature") not in (None, signature):
            raise ValueError(
                f"run_dir {run_dir!r} holds snapshots of a different run "
                f"configuration (signature mismatch at step_{step}) — the "
                f"chunk cadence would diverge; refusing to resume")
        template = {"state": runner.init(),
                    "trace": _trace_template(runner, step)}
        try:
            tree = ckpt.restore(run_dir, step, template)
        except SnapshotCorruptError as e:
            emit("snapshot_corrupt", {"step": step, "error": str(e)})
            continue
        rows = [np.asarray(row) for row in np.asarray(tree["trace"])]
        downgrades = [tuple(d) for d in extra.get("downgrades", [])]
        emit("resume", {"chunk": step, "fmt": extra.get("fmt")})
        return (tree["state"], rows, int(extra.get("chunks_done", step)),
                int(extra.get("steps_done", 0)), downgrades)
    return None, [], 0, 0, []


def _check_budget(budget: BudgetConfig, runner, state, steps_done: int,
                  t_start: float) -> Optional[str]:
    if budget.target_energy is not None:
        if runner.best_energy(state) <= budget.target_energy:
            return STOP_TARGET
    if budget.max_steps is not None and steps_done >= budget.max_steps:
        return STOP_MAX_STEPS
    if (budget.deadline_seconds is not None
            and time.monotonic() - t_start >= budget.deadline_seconds):
        return STOP_DEADLINE
    return None


# --------------------------------------------------------------------------
# The supervisor.

def run_resilient(problem: ising.IsingProblem, seed, config,
                  run_dir: Optional[str] = None, *, backend: str = "auto",
                  mesh=None, budget: Optional[BudgetConfig] = None,
                  chunk_steps: int = 256, checkpoint_every: int = 1,
                  keep: int = 3, resume: bool = True,
                  on_event: Optional[Callable] = None,
                  store: Optional[CouplingStore] = None) -> ResilientResult:
    """Run any solve backend chunk-by-chunk with checkpointing, budgets, and
    tier fallback — bit-identical to the monolithic driver it wraps.

    ``backend="auto"`` dispatches on the config type: ``TemperingConfig`` →
    fused tempering, ``DistSolverConfig`` → ``solve_distributed`` (needs
    ``mesh``), ``SolverConfig`` → the fused anneal, or ``solve_sharded``
    when a ``mesh`` is supplied. ``backend="reference"`` selects the oracle
    scan engine explicitly. ``run_dir=None`` disables checkpointing (budgets
    and interrupts still work); with a directory, a snapshot is written
    every ``checkpoint_every`` completed chunks (``CheckpointManager``
    retention keeps the newest ``keep``) and ``resume=True`` continues from
    the newest *valid* snapshot — corrupt ones fall back to older,
    mismatched problem/config are refused with ``ValueError``.

    ``chunk_steps`` is the untraced chunk granularity (the resume/budget
    quantum); with ``trace_every`` set, chunks are the trace cadence, as in
    the monolithic drivers. It must be passed identically on resume — it is
    part of the run signature because the fused ``Salt.SWEEP`` streams are
    keyed per chunk. ``on_event(kind, info)`` observes "resume",
    "chunk", "snapshot", "snapshot_corrupt", "tier_downgrade", "stop".
    """
    t_start = time.monotonic()
    backend = _resolve_backend(config, backend, mesh)
    budget = budget or BudgetConfig()
    emit = on_event or (lambda kind, info: None)
    signature = run_signature(problem, seed, config, backend=backend,
                              chunk_steps=chunk_steps, mesh=mesh)
    fingerprint = problem_fingerprint(problem)
    mgr = (ckpt.CheckpointManager(run_dir, keep=keep)
           if run_dir is not None else None)
    downgrades: list = []
    fmt: Optional[str] = None
    resumed_from: Optional[int] = None

    def build(fmt):
        _fault("store_build",
               fmt=_current_fmt(problem, config, backend, fmt),
               backend=backend)
        return _build_runner(problem, seed, config, backend=backend,
                             mesh=mesh, chunk_steps=chunk_steps, fmt=fmt,
                             store=store)

    def downgrade_or_raise(exc, at_chunk: int):
        nonlocal fmt
        if not (_fallback_enabled(config, backend)
                and is_allocation_failure(exc)):
            raise exc
        cur = _current_fmt(problem, config, backend, fmt)
        nxt = next_tier(cur, problem, mesh)
        if nxt is None:
            raise exc
        downgrades.append((cur, nxt, at_chunk))
        emit("tier_downgrade", {"from": cur, "to": nxt, "chunk": at_chunk,
                                "error": str(exc)})
        fmt = nxt

    runner = None
    while runner is None:
        try:
            runner = build(fmt)
        except Exception as e:   # noqa: BLE001 — alloc-failure triage
            downgrade_or_raise(e, 0)

    while True:   # tier-retry loop around the chunk drive
        state, rows, k, steps_done = None, [], 0, 0
        try:
            if mgr is not None and resume:
                state, rows, k, steps_done, prior = _try_resume(
                    run_dir, runner, signature, fingerprint, emit)
                if state is not None:
                    resumed_from = k
                    # Downgrades recorded by the pre-crash attempt survive.
                    downgrades = prior + [d for d in downgrades
                                          if d not in prior]
            if state is None:
                state = runner.init()
            total = runner.total_units
            stop_reason = STOP_COMPLETED
            try:
                while k < total:
                    reason = _check_budget(budget, runner, state, steps_done,
                                           t_start)
                    if reason is not None:
                        stop_reason = reason
                        break
                    _fault("chunk_start", chunk=k, fmt=runner.fmt)
                    state = runner.run_chunk(state, k)
                    steps_done += runner.unit_len(k)
                    if runner.collect_trace:
                        rows.append(np.asarray(jax.device_get(
                            runner.trace_row(state))))
                    k += 1
                    emit("chunk", {"chunk": k, "total": total})
                    if mgr is not None and (k % checkpoint_every == 0
                                            or k == total):
                        _save_snapshot(mgr, runner, state, rows, k,
                                       steps_done, signature, fingerprint,
                                       downgrades)
                        emit("snapshot", {"chunk": k})
                        _fault("checkpoint_saved", chunk=k)
            except KeyboardInterrupt:
                stop_reason = STOP_INTERRUPTED
            if stop_reason != STOP_COMPLETED and mgr is not None and k > 0:
                # Budget/interrupt stop between snapshots: persist the
                # frontier so a later run continues instead of replaying.
                _save_snapshot(mgr, runner, state, rows, k, steps_done,
                               signature, fingerprint, downgrades)
            break
        except Exception as e:   # noqa: BLE001 — alloc-failure triage
            downgrade_or_raise(e, k)
            runner = None
            while runner is None:
                try:
                    runner = build(fmt)
                except Exception as e2:  # noqa: BLE001
                    downgrade_or_raise(e2, k)

    result = runner.finalize(state, rows)
    emit("stop", {"reason": stop_reason, "chunks_done": k,
                  "steps_done": steps_done})
    return ResilientResult(result=result, stop_reason=stop_reason,
                           steps_done=steps_done, chunks_done=k,
                           total_chunks=runner.total_units,
                           resumed_from_chunk=resumed_from,
                           downgrades=tuple(downgrades),
                           wall_seconds=time.monotonic() - t_start)
