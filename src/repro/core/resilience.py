"""Resilient solve supervisor: crash-safe checkpoint/resume, budgets, tiers.

Long anneals die — preemption, OOM, a deadline, a ctrl-C — and the paper's
TTS methodology (§V) only works if a killed trial can either finish later or
report an honest best-so-far. This module wraps every registered execution
path (``core.backend.BACKENDS`` — reference, fused, tempering, sharded,
distributed) in one chunk-granular supervisor, :func:`run_resilient`:

* **Checkpoint/resume, bit-identical.** Every backend already advances its
  trajectory in chunks whose RNG is a pure function of ``(seed, chunk
  index)`` (the ``Salt.SWEEP`` streams / absolute-step keys) — no carried
  RNG state. The supervisor drives each backend's chunk runner
  (``core.backend.Backend.runner`` — the *same* chunk bodies the monolithic
  scans use) one host-visible chunk at a time, and atomically snapshots the
  full chain state at chunk boundaries (``checkpoint.manager``: temp dir +
  rename + sha256). A restarted run reconstructs the exact chunk cadence
  from ``(config, chunk_steps)`` and replays the remaining chunks — the
  resumed trajectory is **bit-identical** to the uninterrupted one
  (asserted across every coupling tier by ``tests/test_resilience.py`` and
  for every registered backend by ``tests/test_backend_registry.py``).

* **Corruption containment.** A snapshot that fails its checksum (torn
  write, flipped bit, truncation) raises ``SnapshotCorruptError`` at
  restore; the supervisor falls back to the next-older snapshot, and to a
  fresh start when none survives. A ``run_dir`` whose snapshots belong to a
  *different* (problem, seed, config) is refused loudly — resuming someone
  else's trajectory would silently corrupt results.

* **Budgets.** :class:`BudgetConfig` bounds the run by wall-clock deadline,
  total sweep steps, or a target energy; checks happen between chunks and
  always return the best-so-far with a structured ``stop_reason``
  ("completed" | "deadline" | "max_steps" | "target" | "interrupted").
  ``KeyboardInterrupt`` is caught at the same granularity: the state is
  snapshotted and the partial result returned instead of a traceback.

* **Tier fallback.** With ``coupling_format="auto"``, an allocation failure
  (RESOURCE_EXHAUSTED / OOM) while building the coupling store or running a
  chunk retries at the next coupling tier — dense → bitplane →
  bitplane_hbm → bitplane_sharded / bitplane_sharded_2d (the last rung only
  when a mesh is supplied and the shard alignment holds on its last axis;
  the 2-D tier when the mesh carries replica-group axes) — restoring from
  the last snapshot, so
  completed work survives the downgrade. Because the tiers are
  trajectory-identical by contract, a downgraded run still produces
  bit-identical results. Downgrades are recorded on the result and in every
  subsequent snapshot. Which paths ride the ladder is a registry capability
  (``Capabilities.tier_fallback``); the distributed driver opts out (its
  store is per-device by construction; losing a host is handled by replica
  independence, not by re-tiering).

Fault injection for tests rides on :func:`inject_faults` — a context-local
hook fired at the supervisor's seams ("store_build", "chunk_start",
"checkpoint_saved") so the harness (``tests/fault_injection.py``) can raise
synthetic OOMs or kill the process at randomized chunk boundaries.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import time
from typing import Callable, NamedTuple, Optional

import jax
import numpy as np

from . import ising
from .backend import (current_fmt as _current_fmt, fallback_enabled
                      as _fallback_enabled, get_backend, resolve_backend)
from .coupling import CouplingStore
from ..checkpoint import manager as ckpt
from ..checkpoint.manager import SnapshotCorruptError

#: Structured stop reasons — the full closed set a ``ResilientResult`` can
#: carry.
STOP_COMPLETED = "completed"
STOP_DEADLINE = "deadline"
STOP_MAX_STEPS = "max_steps"
STOP_TARGET = "target"
STOP_INTERRUPTED = "interrupted"
STOP_REASONS = (STOP_COMPLETED, STOP_DEADLINE, STOP_MAX_STEPS, STOP_TARGET,
                STOP_INTERRUPTED)


@dataclasses.dataclass(frozen=True)
class BudgetConfig:
    """Between-chunk run bounds; every bound returns best-so-far, never an
    exception. ``target_energy`` compares against the ensemble-best energy
    *including* the problem offset (the user-facing value)."""
    deadline_seconds: Optional[float] = None
    max_steps: Optional[int] = None
    target_energy: Optional[float] = None


class ResilientResult(NamedTuple):
    result: object              # SolveResult | TemperingResult (best-so-far)
    stop_reason: str            # one of STOP_REASONS
    steps_done: int             # sweep steps actually advanced (incl. resumed)
    chunks_done: int            # chunk units completed
    total_chunks: int
    resumed_from_chunk: Optional[int]   # snapshot the run resumed at, or None
    downgrades: tuple           # ((from_fmt, to_fmt, at_chunk), ...)
    wall_seconds: float


# --------------------------------------------------------------------------
# Fault injection (tests only): a context-local hook at the supervisor seams.

_fault_hook: Optional[Callable] = None


@contextlib.contextmanager
def inject_faults(hook: Callable[[str, dict], None]):
    """Install ``hook(site, info)`` for the duration of the block. Sites:
    "store_build" (before a tier's store/runner build), "chunk_start"
    (before each chunk; ``info["chunk"]``), "checkpoint_saved" (after each
    snapshot). Whatever the hook raises propagates into the supervisor —
    raising an allocation-failure error at "store_build"/"chunk_start"
    exercises the tier-fallback path without a real OOM."""
    global _fault_hook
    prev = _fault_hook
    _fault_hook = hook
    try:
        yield
    finally:
        _fault_hook = prev


def _fault(site: str, **info):
    if _fault_hook is not None:
        _fault_hook(site, info)


# --------------------------------------------------------------------------
# Allocation-failure detection and the tier ladder.

_ALLOC_TOKENS = ("resource_exhausted", "out of memory", "failed to allocate",
                 "oom")


def is_allocation_failure(exc: BaseException) -> bool:
    """Whether ``exc`` looks like a memory-allocation failure (XLA
    RESOURCE_EXHAUSTED, allocator OOM, host ``MemoryError``) — the class of
    error the tier ladder can actually fix, as opposed to bugs it must
    propagate."""
    if isinstance(exc, MemoryError):
        return True
    msg = str(exc).lower()
    return any(tok in msg for tok in _ALLOC_TOKENS)


def next_tier(fmt: str, problem: ising.IsingProblem, mesh) -> Optional[str]:
    """The coupling tier to retry at after ``fmt`` hit an allocation
    failure, or None when the ladder ends: dense → bitplane (integral J
    only) → bitplane_hbm → bitplane_sharded / bitplane_sharded_2d (mesh
    present, shard-aligned; the 2-D tier when the mesh has replica-group
    axes — the planes row-shard over the **last** mesh axis only)."""
    if fmt == "dense":
        if problem.couplings is not None:
            J = np.asarray(jax.device_get(problem.couplings))
            if not np.array_equal(J, np.rint(J)):
                return None         # fractional J has no packed tier
        return "bitplane"
    if fmt == "bitplane":
        return "bitplane_hbm"
    if fmt == "bitplane_hbm":
        if mesh is None:
            return None
        from ..kernels import common
        num_rows = int(mesh.shape[mesh.axis_names[-1]])
        n = problem.num_spins
        if n % num_rows or (n // num_rows) % common.default_lane(n):
            return None             # unshardable problem: ladder ends
        return ("bitplane_sharded_2d" if len(mesh.axis_names) > 1
                else "bitplane_sharded")
    return None


# --------------------------------------------------------------------------
# Run identity: a resumable snapshot must belong to *this* run.

def problem_fingerprint(problem: ising.IsingProblem) -> str:
    """Content hash of the problem (couplings/edges + fields + offset) —
    written into every snapshot so a resume onto a different instance is
    refused instead of silently mixing trajectories."""
    h = hashlib.sha256()
    if problem.couplings is not None:
        J = np.ascontiguousarray(jax.device_get(problem.couplings))
        h.update(b"dense")
        h.update(repr(J.shape).encode())
        h.update(J.tobytes())
    else:
        h.update(b"edges")
        h.update(problem.edges._digest)
    fields = np.ascontiguousarray(jax.device_get(problem.fields))
    h.update(fields.tobytes())
    h.update(np.float64(problem.offset).tobytes())
    return h.hexdigest()


def run_signature(problem: ising.IsingProblem, seed, config, *, backend: str,
                  chunk_steps: int, mesh) -> str:
    """Hash of everything the chunk cadence and RNG streams depend on. The
    configs are frozen dataclasses of plain values, so their reprs are
    stable across processes."""
    mesh_desc = (None if mesh is None
                 else tuple((a, int(mesh.shape[a])) for a in mesh.axis_names))
    parts = "|".join([
        f"seed={int(seed)}", f"backend={backend}",
        f"chunk_steps={int(chunk_steps)}", f"config={config!r}",
        f"mesh={mesh_desc!r}",
        f"problem={problem_fingerprint(problem)}",
    ])
    return hashlib.sha256(parts.encode()).hexdigest()


# --------------------------------------------------------------------------
# Snapshot plumbing.

def _trace_template(runner, chunks: int):
    rows = chunks if runner.collect_trace else 0
    return np.zeros((rows, runner.num_replicas), np.float32)


def _save_snapshot(mgr: ckpt.CheckpointManager, runner, state, rows,
                   chunks_done: int, steps_done: int, signature: str,
                   fingerprint: str, downgrades):
    trace = (np.stack(rows).astype(np.float32) if rows
             else _trace_template(runner, 0))
    mgr.save(chunks_done, {"state": state, "trace": trace},
             extra={"signature": signature, "fingerprint": fingerprint,
                    "chunks_done": chunks_done, "steps_done": steps_done,
                    "fmt": runner.fmt, "backend": runner.backend,
                    "downgrades": [list(d) for d in downgrades]})


def _try_resume(run_dir: str, runner, signature: str, fingerprint: str,
                emit):
    """Newest-first walk over the snapshots in ``run_dir``: identity
    mismatches are refused loudly, corrupt snapshots are skipped with an
    event, and ``(None, ...)`` means no usable snapshot — start fresh.
    Returns ``(state, rows, chunks_done, steps_done, downgrades)``."""
    for step in reversed(ckpt.snapshot_steps(run_dir)):
        try:
            manifest = ckpt.read_manifest(run_dir, step)
        except SnapshotCorruptError as e:
            emit("snapshot_corrupt", {"step": step, "error": str(e)})
            continue
        extra = manifest.get("extra", {})
        if extra.get("fingerprint") not in (None, fingerprint):
            raise ValueError(
                f"run_dir {run_dir!r} holds snapshots of a different "
                f"problem (fingerprint mismatch at step_{step}) — refusing "
                f"to resume; point --run-dir at a fresh directory")
        if extra.get("signature") not in (None, signature):
            raise ValueError(
                f"run_dir {run_dir!r} holds snapshots of a different run "
                f"configuration (signature mismatch at step_{step}) — the "
                f"chunk cadence would diverge; refusing to resume")
        template = {"state": runner.init(),
                    "trace": _trace_template(runner, step)}
        try:
            tree = ckpt.restore(run_dir, step, template)
        except SnapshotCorruptError as e:
            emit("snapshot_corrupt", {"step": step, "error": str(e)})
            continue
        rows = [np.asarray(row) for row in np.asarray(tree["trace"])]
        downgrades = [tuple(d) for d in extra.get("downgrades", [])]
        emit("resume", {"chunk": step, "fmt": extra.get("fmt")})
        return (tree["state"], rows, int(extra.get("chunks_done", step)),
                int(extra.get("steps_done", 0)), downgrades)
    return None, [], 0, 0, []


def _check_budget(budget: BudgetConfig, runner, state, steps_done: int,
                  t_start: float) -> Optional[str]:
    if budget.target_energy is not None:
        if runner.best_energy(state) <= budget.target_energy:
            return STOP_TARGET
    if budget.max_steps is not None and steps_done >= budget.max_steps:
        return STOP_MAX_STEPS
    if (budget.deadline_seconds is not None
            and time.monotonic() - t_start >= budget.deadline_seconds):
        return STOP_DEADLINE
    return None


# --------------------------------------------------------------------------
# The supervisor.

def run_resilient(problem: ising.IsingProblem, seed, config,
                  run_dir: Optional[str] = None, *, backend: str = "auto",
                  mesh=None, budget: Optional[BudgetConfig] = None,
                  chunk_steps: int = 256, checkpoint_every: int = 1,
                  keep: int = 3, resume: bool = True,
                  on_event: Optional[Callable] = None,
                  store: Optional[CouplingStore] = None) -> ResilientResult:
    """Run any registered backend chunk-by-chunk with checkpointing,
    budgets, and tier fallback — bit-identical to the monolithic driver it
    wraps.

    ``backend`` names any ``core.backend.BACKENDS`` entry; ``"auto"``
    resolves one from the config type (``TemperingConfig`` → fused
    tempering, ``DistSolverConfig`` → ``solve_distributed`` — needs
    ``mesh`` — ``SolverConfig`` → the fused anneal, or ``solve_sharded``
    when a ``mesh`` is supplied). ``backend="reference"`` selects the oracle
    scan engine explicitly. ``run_dir=None`` disables checkpointing (budgets
    and interrupts still work); with a directory, a snapshot is written
    every ``checkpoint_every`` completed chunks (``CheckpointManager``
    retention keeps the newest ``keep``) and ``resume=True`` continues from
    the newest *valid* snapshot — corrupt ones fall back to older,
    mismatched problem/config are refused with ``ValueError``.

    ``chunk_steps`` is the untraced chunk granularity (the resume/budget
    quantum); with ``trace_every`` set, chunks are the trace cadence, as in
    the monolithic drivers. It must be passed identically on resume — it is
    part of the run signature because the fused ``Salt.SWEEP`` streams are
    keyed per chunk. ``on_event(kind, info)`` observes "resume",
    "chunk", "snapshot", "snapshot_corrupt", "tier_downgrade", "stop".
    """
    t_start = time.monotonic()
    backend = resolve_backend(config, backend, mesh)
    budget = budget or BudgetConfig()
    emit = on_event or (lambda kind, info: None)
    signature = run_signature(problem, seed, config, backend=backend,
                              chunk_steps=chunk_steps, mesh=mesh)
    fingerprint = problem_fingerprint(problem)
    mgr = (ckpt.CheckpointManager(run_dir, keep=keep)
           if run_dir is not None else None)
    downgrades: list = []
    fmt: Optional[str] = None
    resumed_from: Optional[int] = None

    def build(fmt):
        _fault("store_build",
               fmt=_current_fmt(problem, config, backend, fmt),
               backend=backend)
        return get_backend(backend).runner(
            problem, seed, config, mesh=mesh, chunk_steps=chunk_steps,
            fmt=fmt, store=store)

    def downgrade_or_raise(exc, at_chunk: int):
        nonlocal fmt
        if not (_fallback_enabled(config, backend)
                and is_allocation_failure(exc)):
            raise exc
        cur = _current_fmt(problem, config, backend, fmt)
        nxt = next_tier(cur, problem, mesh)
        if nxt is None:
            raise exc
        downgrades.append((cur, nxt, at_chunk))
        emit("tier_downgrade", {"from": cur, "to": nxt, "chunk": at_chunk,
                                "error": str(exc)})
        fmt = nxt

    runner = None
    while runner is None:
        try:
            runner = build(fmt)
        except Exception as e:   # noqa: BLE001 — alloc-failure triage
            downgrade_or_raise(e, 0)

    while True:   # tier-retry loop around the chunk drive
        state, rows, k, steps_done = None, [], 0, 0
        try:
            if mgr is not None and resume:
                state, rows, k, steps_done, prior = _try_resume(
                    run_dir, runner, signature, fingerprint, emit)
                if state is not None:
                    resumed_from = k
                    # Downgrades recorded by the pre-crash attempt survive.
                    downgrades = prior + [d for d in downgrades
                                          if d not in prior]
            if state is None:
                state = runner.init()
            total = runner.total_units
            stop_reason = STOP_COMPLETED
            try:
                while k < total:
                    reason = _check_budget(budget, runner, state, steps_done,
                                           t_start)
                    if reason is not None:
                        stop_reason = reason
                        break
                    _fault("chunk_start", chunk=k, fmt=runner.fmt)
                    state = runner.run_chunk(state, k)
                    steps_done += runner.unit_len(k)
                    if runner.collect_trace:
                        rows.append(np.asarray(jax.device_get(
                            runner.trace_row(state))))
                    k += 1
                    emit("chunk", {"chunk": k, "total": total})
                    if mgr is not None and (k % checkpoint_every == 0
                                            or k == total):
                        _save_snapshot(mgr, runner, state, rows, k,
                                       steps_done, signature, fingerprint,
                                       downgrades)
                        emit("snapshot", {"chunk": k})
                        _fault("checkpoint_saved", chunk=k)
            except KeyboardInterrupt:
                stop_reason = STOP_INTERRUPTED
            if stop_reason != STOP_COMPLETED and mgr is not None and k > 0:
                # Budget/interrupt stop between snapshots: persist the
                # frontier so a later run continues instead of replaying.
                _save_snapshot(mgr, runner, state, rows, k, steps_done,
                               signature, fingerprint, downgrades)
            break
        except Exception as e:   # noqa: BLE001 — alloc-failure triage
            downgrade_or_raise(e, k)
            runner = None
            while runner is None:
                try:
                    runner = build(fmt)
                except Exception as e2:  # noqa: BLE001
                    downgrade_or_raise(e2, k)

    result = runner.finalize(state, rows)
    emit("stop", {"reason": stop_reason, "chunks_done": k,
                  "steps_done": steps_done})
    return ResilientResult(result=result, stop_reason=stop_reason,
                           steps_done=steps_done, chunks_done=k,
                           total_chunks=runner.total_units,
                           resumed_from_chunk=resumed_from,
                           downgrades=tuple(downgrades),
                           wall_seconds=time.monotonic() - t_start)
