"""Ising-based balanced graph partitioning for device placement (paper §II-A).

The paper motivates graph partitioning by "load balancing and communication
minimization in parallel scientific computing" — exactly the MoE
expert→device placement problem in this framework. Given a symmetric traffic
matrix ``C`` (bytes exchanged between experts when placed on *different*
devices), a balanced D-way partition minimizing cross-device traffic is found
by recursive bisection, each bisection solved with the Snowball dual-mode
solver:

    minimize  Σ_{i<j} C_ij · [s_i ≠ s_j]  +  λ (Σ_i m_i s_i)²

Ising form: J_ij = C_ij/2 − λ m_i m_j (ferromagnetic on heavy edges pulls
co-activated experts together; the balance penalty is antiferromagnetic and
uniform), h = 0 for equal loads m ≡ 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import solver as solver_lib
from .ising import IsingProblem
from .schedules import geometric


@dataclasses.dataclass(frozen=True)
class PlacementResult:
    assignment: np.ndarray      # (E,) int device index in [0, D)
    cut_bytes: float            # total cross-device traffic
    imbalance: float            # max device load / mean load − 1
    num_devices: int


def _bisect(C: np.ndarray, loads: np.ndarray, balance_weight: float, seed: int,
            steps: int, replicas: int) -> np.ndarray:
    n = C.shape[0]
    if n == 1:
        return np.array([1], np.int8)
    scale = max(float(np.abs(C).max()), 1e-9)
    lam = balance_weight * scale
    m = loads / max(loads.mean(), 1e-9)
    J = C / 2.0 - lam * np.outer(m, m)
    np.fill_diagonal(J, 0.0)
    problem = IsingProblem.create(J=J.astype(np.float32))
    t0 = max(float(np.abs(J).sum(1).max()), 1.0)
    cfg = solver_lib.SolverConfig(
        num_steps=steps, schedule=geometric(t0, t0 * 1e-3, steps), mode="rwa",
        num_replicas=replicas, use_pwl=True)
    result = solver_lib.solve(problem, seed, cfg)
    best = int(np.argmin(np.asarray(result.best_energy)))
    return np.asarray(result.best_spins)[best]


def cut_bytes(C: np.ndarray, assignment: np.ndarray) -> float:
    a = np.asarray(assignment)
    mask = a[:, None] != a[None, :]
    return float(np.triu(np.asarray(C) * mask, 1).sum())


def place(C: np.ndarray, num_devices: int, loads: np.ndarray | None = None,
          balance_weight: float = 0.75, seed: int = 0, steps: int = 2000,
          replicas: int = 8) -> PlacementResult:
    """Recursive-bisection D-way placement (D must be a power of two)."""
    C = np.asarray(C, np.float64)
    n = C.shape[0]
    if num_devices & (num_devices - 1):
        raise ValueError("num_devices must be a power of two (recursive bisection)")
    if loads is None:
        loads = np.ones(n)
    assignment = np.zeros(n, np.int64)
    groups = [np.arange(n)]
    level = 0
    while len(groups) < num_devices:
        next_groups = []
        for g, idx in enumerate(groups):
            spins = _bisect(C[np.ix_(idx, idx)], loads[idx], balance_weight,
                            seed + 1000 * level + g, steps, replicas)
            left = idx[spins > 0]
            right = idx[spins < 0]
            if left.size == 0 or right.size == 0:  # degenerate balance: split evenly
                half = idx.size // 2
                left, right = idx[:half], idx[half:]
            next_groups.extend([left, right])
        groups = next_groups
        level += 1
    for d, idx in enumerate(groups):
        assignment[idx] = d
    device_loads = np.array([loads[assignment == d].sum() for d in range(num_devices)])
    imb = float(device_loads.max() / max(device_loads.mean(), 1e-9) - 1.0)
    return PlacementResult(assignment=assignment, cut_bytes=cut_bytes(C, assignment),
                           imbalance=imb, num_devices=num_devices)


def expert_traffic_matrix(router_probs: np.ndarray) -> np.ndarray:
    """Co-activation traffic proxy from router probabilities (T, E): experts
    co-selected for the same token exchange activations during combine."""
    p = np.asarray(router_probs, np.float64)
    C = p.T @ p
    np.fill_diagonal(C, 0.0)
    return C
