"""Signed bit-plane representation of the coupling matrix (paper §IV-B1).

    J_ij = Σ_{b=0}^{B-1} 2^b (B_b⁺(i,j) − B_b⁻(i,j))            (Eq. 13)

Planes are 1-bit and packed 32 couplers per ``uint32`` word (the FPGA packs 64;
32 keeps ``lax.population_count`` on the widest native CPU/TPU integer lane).
Precision grows memory *linearly* in B while the datapath stays 1-bit — the
paper's third design consideration. The local-field initialization uses the
Hamming-weight identities (Eq. 14–16):

    m_P = popcount(P_word)        o_P = popcount(P_word & x_word)
    Σ_{j∈word, B⁺=1} s_j = 2 o_P − m_P     (and analogously for B⁻)

so ``u_i^(J) = Σ_b Σ_w 2^b [(2o_P − m_P) − (2o_N − m_N)]``.

This module is the pure-jnp oracle; ``repro.kernels.bitplane_field`` is the
Pallas/TPU kernel that tiles the same math through VMEM.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BitPlanes:
    """Packed signed bit-planes of an integer coupling matrix.

    ``pos``/``neg``: (B, N, W) uint32 with W = ceil(N / 32); bit ``j % 32`` of
    word ``j // 32`` in row i of plane b holds B_b^±(i, j). J symmetric ⇒ the
    row-major and column-major layouts of the paper coincide; ``planes.pos[b]``
    serves both the streaming init (rows) and the incremental update (columns).
    """

    pos: jax.Array
    neg: jax.Array
    num_spins: int

    def tree_flatten(self):
        return (self.pos, self.neg), (self.num_spins,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(pos=children[0], neg=children[1], num_spins=aux[0])

    @property
    def num_planes(self) -> int:
        return self.pos.shape[0]

    @property
    def num_words(self) -> int:
        return self.pos.shape[-1]

    @property
    def nbytes(self) -> int:
        return int(self.pos.size + self.neg.size) * 4


def _pack_bits(bits: np.ndarray, num_words: int | None = None) -> np.ndarray:
    """Pack a (..., N) {0,1} array into (..., W) uint32, LSB-first.

    ``num_words`` pads the packed axis with zero words beyond ceil(N/32) —
    tile alignment for the HBM-streamed row DMAs; zero words decode to zero
    couplers, so padding never changes the represented matrix."""
    n = bits.shape[-1]
    w = -(-n // WORD_BITS)
    if num_words is None:
        num_words = w
    elif num_words < w:
        raise ValueError(f"num_words={num_words} < ceil({n}/32)={w}")
    pad = num_words * WORD_BITS - n
    if pad:
        bits = np.concatenate([bits, np.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1)
    words = bits.reshape(bits.shape[:-1] + (-1, WORD_BITS)).astype(np.uint64)
    shifts = (np.uint64(1) << np.arange(WORD_BITS, dtype=np.uint64))
    return (words * shifts).sum(axis=-1).astype(np.uint32)


def encode_couplings(J: np.ndarray, num_planes: int,
                     align_words: int = 1) -> BitPlanes:
    """Sign-magnitude bit-plane encoding of an integer matrix (Eq. 13).

    Requires |J_ij| < 2**num_planes; raises otherwise (the hardware would
    saturate — we refuse instead so tests catch precision misconfiguration).
    Requires J symmetric: :class:`BitPlanes` stores rows only and every
    consumer (the streaming init and the fused sweep's incremental row fetch)
    reads row j where the math wants column j — an asymmetric J would encode
    fine and then silently produce wrong incremental updates, so we validate
    here. A nonzero diagonal merely warns (self-coupling J_ii contributes a
    spin-independent constant to ΔE bookkeeping but is almost always a
    problem-construction bug).

    ``align_words`` rounds the packed word axis W up to a multiple (zero-bit
    padding): the HBM-streamed sweep path DMAs whole (B, 1, W) rows per step,
    so W should land on the TPU lane tile (128 words) for full-width copies.
    Padding is representation-invisible — every decoder truncates to N.
    """
    J = np.asarray(J)
    if not np.isfinite(J).all():
        i, j = np.argwhere(~np.isfinite(np.atleast_2d(J)))[0]
        raise ValueError(
            f"bit-plane encoding requires finite couplings: "
            f"J[{i}, {j}] = {float(np.atleast_2d(J)[i, j])!r}")
    Ji = np.rint(J).astype(np.int64)
    if not np.array_equal(Ji, J):
        bad = np.argwhere(np.atleast_2d(Ji != J))[0]
        i, j = int(bad[0]), int(bad[1])
        raise ValueError(
            "bit-plane encoding requires integer couplings (pre-scale "
            f"first): J[{i}, {j}] = {float(np.atleast_2d(J)[i, j])!r}")
    if Ji.ndim != 2 or Ji.shape[0] != Ji.shape[1]:
        raise ValueError(f"J must be square, got {Ji.shape}")
    if not np.array_equal(Ji, Ji.T):
        raise ValueError(
            "bit-plane encoding requires a symmetric J: packed planes store "
            "rows that double as columns in the incremental update")
    if np.any(np.diag(Ji) != 0):
        warnings.warn("bit-plane encoding of a J with nonzero diagonal "
                      "(self-couplings); flip updates will fold J_ii into u",
                      stacklevel=2)
    limit = 1 << num_planes
    if np.abs(Ji).max(initial=0) >= limit:
        i, j = np.argwhere(np.abs(Ji) >= limit)[0]
        raise ValueError(
            f"|J|max={np.abs(Ji).max()} needs more than {num_planes} planes "
            f"(first offender J[{i}, {j}] = {Ji[i, j]})")
    if align_words < 1:
        raise ValueError(f"align_words must be >= 1, got {align_words}")
    n = Ji.shape[0]
    w = -(-n // WORD_BITS)
    num_words = -(-w // align_words) * align_words
    mag = np.abs(Ji)
    sign_pos = Ji > 0
    sign_neg = Ji < 0
    pos_planes = []
    neg_planes = []
    for b in range(num_planes):
        bit = ((mag >> b) & 1).astype(np.uint8)
        pos_planes.append(_pack_bits(bit * sign_pos, num_words))
        neg_planes.append(_pack_bits(bit * sign_neg, num_words))
    return BitPlanes(
        pos=jnp.asarray(np.stack(pos_planes)),
        neg=jnp.asarray(np.stack(neg_planes)),
        num_spins=n,
    )


def edge_plane_words(edges, num_planes: int, align_words: int = 1,
                     row_range: "tuple[int, int] | None" = None
                     ) -> "tuple[np.ndarray, np.ndarray]":
    """O(nnz) sparse → packed-plane encoding: the numpy word arrays for (a row
    slice of) the planes of a canonical :class:`repro.core.ising.EdgeList`.

    Never materializes an (N, N) anything — work and temporaries are O(nnz)
    (each undirected edge scatters its bit into rows i and j) plus the output
    plane words themselves. ``row_range=(lo, hi)`` keeps only plane rows
    [lo, hi) with row indices rebased to lo — the per-device build of the
    spin-sharded tier, where device d encodes *only its own slab* and the
    full (B, N, W) store never exists on any single host. Returns
    ``(pos, neg)`` as (B, hi-lo, W) uint32; slicing commutes with encoding
    (bits land per (row, word) independently), which the row-slab tests
    assert against the dense encoder.
    """
    n = edges.num_spins
    lo_row, hi_row = (0, n) if row_range is None else row_range
    if not 0 <= lo_row <= hi_row <= n:
        raise ValueError(f"row_range {row_range} out of bounds for N={n}")
    limit = 1 << num_planes
    amax = int(np.abs(edges.weights).max(initial=0))
    if amax >= limit:
        k = int(np.argmax(np.abs(edges.weights)))
        raise ValueError(
            f"|J|max={amax} needs more than {num_planes} planes (first "
            f"offender edge #{k} ({int(edges.rows[k])}, "
            f"{int(edges.cols[k])}) with weight {int(edges.weights[k])})")
    if align_words < 1:
        raise ValueError(f"align_words must be >= 1, got {align_words}")
    w_min = -(-n // WORD_BITS)
    num_words = -(-w_min // align_words) * align_words
    # Symmetrize: each canonical (i < j, w) entry sets bit j in row i and
    # bit i in row j — exactly the dense encoder's J[i,j] = J[j,i] = w.
    r2 = np.concatenate([edges.rows, edges.cols]).astype(np.int64)
    c2 = np.concatenate([edges.cols, edges.rows]).astype(np.int64)
    w2 = np.concatenate([edges.weights, edges.weights])
    if row_range is not None:
        keep = (r2 >= lo_row) & (r2 < hi_row)
        r2, c2, w2 = r2[keep], c2[keep], w2[keep]
    r2 = r2 - lo_row
    word = c2 // WORD_BITS
    bit = (np.uint32(1) << (c2 % WORD_BITS).astype(np.uint32))
    mag = np.abs(w2)
    shape = (num_planes, hi_row - lo_row, num_words)
    pos = np.zeros(shape, np.uint32)
    neg = np.zeros(shape, np.uint32)
    for b in range(num_planes):
        has_bit = ((mag >> b) & 1) == 1
        for plane, sel in ((pos, w2 > 0), (neg, w2 < 0)):
            m = has_bit & sel
            np.bitwise_or.at(plane[b], (r2[m], word[m]), bit[m])
    return pos, neg


def encode_edges(edges, num_planes: int | None = None,
                 align_words: int = 1) -> BitPlanes:
    """Sparse counterpart of :func:`encode_couplings`: canonical edge list →
    packed :class:`BitPlanes`, O(nnz) work, dense-J-free. Plane-for-plane
    bit-identical to ``encode_couplings(edges.to_dense(), ...)`` (symmetry
    and the zero diagonal hold by EdgeList construction, so no dense-side
    validation pass is needed — or possible — here)."""
    if num_planes is None:
        num_planes = max(1, edges.max_abs_weight.bit_length())
    pos, neg = edge_plane_words(edges, num_planes, align_words)
    return BitPlanes(pos=jnp.asarray(pos), neg=jnp.asarray(neg),
                     num_spins=edges.num_spins)


def decode_couplings(planes: BitPlanes) -> np.ndarray:
    """Inverse of :func:`encode_couplings` (exact; used by round-trip tests)."""
    pos = np.asarray(planes.pos)
    neg = np.asarray(planes.neg)
    n = planes.num_spins
    out = np.zeros((n, n), dtype=np.int64)
    for b in range(planes.num_planes):
        for arr, sgn in ((pos[b], 1), (neg[b], -1)):
            bits = ((arr[..., :, None] >> np.arange(WORD_BITS, dtype=np.uint32)) & 1).astype(np.int64)
            bits = bits.reshape(n, -1)[:, :n]
            out += sgn * (1 << b) * bits
    return out


def pack_spins(spins: jax.Array, num_words: int | None = None) -> jax.Array:
    """Encode ±1 spins as bits x_j=(s_j+1)/2 packed into uint32 words (§IV-B).

    The bit is derived with an explicit ``s_j > 0`` predicate rather than
    ``(s_j + 1) // 2``: floor division is not dtype-uniform for ±1 spins
    (float ``//`` yields floats and int rounding conventions differ), while
    the predicate is exact for every spin dtype in use (int8/int32/f32/bf16).

    ``num_words`` pads with zero words past ceil(N/32) so spin words line up
    with tile-aligned (padded) coupling planes in the Hamming-weight math —
    a zero spin word ANDed against a zero plane word contributes nothing.
    """
    x = (spins > 0).astype(jnp.uint32)
    n = x.shape[-1]
    w = -(-n // WORD_BITS)
    if num_words is None:
        num_words = w
    elif num_words < w:
        raise ValueError(f"num_words={num_words} < ceil({n}/32)={w}")
    pad = num_words * WORD_BITS - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros(x.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    words = x.reshape(x.shape[:-1] + (-1, WORD_BITS))
    shifts = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (words * shifts).sum(axis=-1).astype(jnp.uint32)


def local_fields_from_planes(planes: BitPlanes, spins: jax.Array) -> jax.Array:
    """u_i^(J) from packed planes via Hamming-weight accumulation (Eq. 14–16).

    ``spins``: (..., N) ±1. Returns (..., N) float32. Pure-jnp oracle for the
    Pallas kernel; also the reference implementation for the popcount math.
    Spin words are packed to the planes' (possibly tile-padded) word count.
    """
    xw = pack_spins(spins, planes.num_words)  # (..., W)
    popc = jax.lax.population_count
    # (B, N, W) plane words against (..., 1, W) spin words.
    xw_b = xw[..., None, :]

    def per_plane(carry, bw):
        pos_b, neg_b = bw  # (N, W) each
        m_p = popc(pos_b).astype(jnp.int32).sum(-1)  # (N,)
        m_n = popc(neg_b).astype(jnp.int32).sum(-1)
        o_p = popc(pos_b & xw_b).astype(jnp.int32).sum(-1)  # (..., N)
        o_n = popc(neg_b & xw_b).astype(jnp.int32).sum(-1)
        contrib = (2 * o_p - m_p) - (2 * o_n - m_n)  # (..., N)
        return carry, contrib

    _, contribs = jax.lax.scan(per_plane, 0, (planes.pos, planes.neg))
    weights = jnp.float32(2.0) ** jnp.arange(planes.num_planes, dtype=jnp.float32)
    # contribs: (B, ..., N) -> weighted sum over planes.
    return jnp.tensordot(weights, contribs.astype(jnp.float32), axes=(0, 0))
