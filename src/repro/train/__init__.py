from .step import TrainState, lm_loss, make_train_step  # noqa: F401
from .loop import TrainLoopConfig, train_loop  # noqa: F401
