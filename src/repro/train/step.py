"""Train step: loss, gradient accumulation (microbatching), optimizer update.

Loss is next-token (or masked-prediction) cross-entropy computed against
(possibly vocab-sharded) logits; labels < 0 are ignored (encoder masking and
padding). Microbatching scans over grad-accumulation slices so the peak
activation footprint is ``1/num_microbatches`` of the global batch — the knob
that fits nemotron-4-340b's train_4k activations on v5e (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import forward
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt_state: object
    step: jax.Array


def lm_loss(cfg: ModelConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    """Mean CE over valid label positions + MoE aux. Returns (loss, metrics)."""
    kwargs = {}
    if "tokens" in batch:
        kwargs["tokens"] = batch["tokens"]
    if "embeddings" in batch:
        kwargs["embeddings"] = batch["embeddings"]
    out = forward(cfg, params, **kwargs)
    logits = out.logits.astype(jnp.float32)
    labels = batch["labels"]
    if labels.shape[1] != logits.shape[1]:  # next-token on same-length stream
        logits = logits[:, : labels.shape[1]]
    valid = labels >= 0
    safe_labels = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # One-hot einsum instead of take_along_axis: a gather along the
    # vocab-sharded dim would force GSPMD to all-gather the logits
    # (~40 GiB/device for 152k vocab at train_4k); the einsum contracts the
    # sharded dim into a partial-sum + all-reduce instead.
    onehot = jax.nn.one_hot(safe_labels, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    token_ce = (lse - picked) * valid.astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1)
    ce = token_ce.sum() / denom
    loss = ce + out.aux_loss
    return loss, {"ce": ce, "aux": out.aux_loss,
                  "tokens": denom.astype(jnp.float32)}


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    lr_fn: Optional[Callable] = None,
                    num_microbatches: int = 1,
                    donate: bool = True,
                    param_shardings=None,
                    gathered_shardings=None):
    """Build the jitted train step. Batch leading dim must divide microbatches.

    ``param_shardings`` (optional pytree of NamedSharding congruent with
    params): GSPMD's backward-of-scan gradient accumulators otherwise lose the
    FSDP/TP sharding and replicate stacked-layer grads (~30 GiB/device for a
    7B model) — the explicit constraint pins them to the param layout.

    ``gathered_shardings`` (optional): shardings with the FSDP (`data`) axis
    removed. When given, params are cast to the compute dtype and
    all-gathered ONCE per step *outside* the microbatch loop, instead of
    re-gathered every microbatch — an ``num_microbatches×`` reduction of the
    dominant all-gather traffic (EXPERIMENTS.md §Perf hillclimb #1).
    """

    def constrain_grads(grads):
        if param_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s) if s is not None else g,
            grads, param_shardings)

    def split_mb(batch):
        def r(x):
            b = x.shape[0]
            return x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])
        return {k: r(v) for k, v in batch.items()}

    compute_dtype = jnp.dtype(cfg.compute_dtype)

    def gather_once(params):
        """bf16-cast + FSDP-unshard the params once per step (hoisted out of
        the microbatch loop by construction)."""
        if gathered_shardings is None:
            return params
        return jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(
                p.astype(compute_dtype) if p.dtype == jnp.float32 else p, s)
            if s is not None else p.astype(compute_dtype),
            params, gathered_shardings)

    def grads_and_metrics(params, batch):
        fwd_params = gather_once(params)
        if num_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, batch), has_aux=True)(fwd_params)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, constrain_grads(grads), metrics
        mbs = split_mb(batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, mb), has_aux=True)(fwd_params)
            grads_acc = constrain_grads(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads))
            return (loss_acc + loss, grads_acc), metrics

        zero_grads = constrain_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grads), metrics = jax.lax.scan(body, (jnp.float32(0.0), zero_grads), mbs)
        inv = 1.0 / num_microbatches
        grads = constrain_grads(jax.tree.map(lambda g: g * inv, grads))
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum * inv, grads, last_metrics

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        loss, grads, metrics = grads_and_metrics(state.params, batch)
        lr = lr_fn(state.step) if lr_fn is not None else None
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt_state, opt, lr=lr)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        if lr is not None:
            metrics["lr"] = lr
        return TrainState(params=new_params, opt_state=new_opt,
                          step=state.step + 1), metrics

    donate_args = (0,) if donate else ()
    return jax.jit(train_step, donate_argnums=donate_args)


def init_train_state(cfg: ModelConfig, params: dict, opt: AdamWConfig) -> TrainState:
    return TrainState(params=params, opt_state=adamw_init(params, opt),
                      step=jnp.int32(0))
