"""Training loop with checkpoint/restart, deterministic resume, and straggler-
tolerant semantics.

Fault tolerance in practice:
  * every ``checkpoint_every`` steps the full TrainState is saved atomically
    (optionally async);
  * on start, ``--resume`` restores the latest checkpoint and the data
    pipeline *skips ahead* by step count (batches are pure functions of
    (seed, step) — no replay log needed);
  * a ``failure_hook`` lets tests inject a crash mid-run and verify the
    restart converges to the identical trajectory (bitwise, given the same
    mesh), which is the property that matters at 1000-node scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import DataConfig, SyntheticLMData
from ..models import init_params, model_specs
from ..models.config import ModelConfig
from ..optim import AdamWConfig
from ..optim.schedule import linear_warmup_cosine
from .step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    async_checkpoint: bool = False
    num_microbatches: int = 1
    log_every: int = 10
    seed: int = 0
    base_lr: float = 3e-4
    warmup_steps: int = 20
    state_dtype: str = "float32"


def train_loop(cfg: ModelConfig, data_cfg: DataConfig, loop: TrainLoopConfig,
               resume: bool = False,
               failure_hook: Optional[Callable[[int], None]] = None,
               log_fn: Callable[[str], None] = print) -> tuple[TrainState, list[dict]]:
    """Run the loop; returns (final_state, metric history)."""
    opt = AdamWConfig(learning_rate=loop.base_lr, state_dtype=loop.state_dtype)
    lr_fn = linear_warmup_cosine(loop.base_lr, loop.warmup_steps, loop.steps)
    specs = model_specs(cfg)
    params = init_params(specs, jax.random.key(loop.seed))
    state = init_train_state(cfg, params, opt)

    manager = None
    if loop.checkpoint_dir:
        manager = CheckpointManager(loop.checkpoint_dir, keep=loop.keep_checkpoints,
                                    async_save=loop.async_checkpoint)
        if resume:
            restored, at = manager.restore(state)
            if restored is not None:
                state = restored
                log_fn(f"[resume] restored checkpoint at step {at}")

    data = SyntheticLMData(cfg, data_cfg)
    step_fn = make_train_step(cfg, opt, lr_fn, num_microbatches=loop.num_microbatches)

    history: list[dict] = []
    start = int(state.step)
    t0 = time.time()
    for step in range(start, loop.steps):
        if failure_hook is not None:
            failure_hook(step)  # may raise to simulate preemption
        batch = data.batch(step)
        state, metrics = step_fn(state, batch)
        if step % loop.log_every == 0 or step == loop.steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            log_fn(f"[train] step={step} loss={m['loss']:.4f} "
                   f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.3f}")
        if manager and (step + 1) % loop.checkpoint_every == 0:
            manager.save(step + 1, state)
    if manager:
        manager.save(loop.steps, state)
        manager.wait()
    return state, history
