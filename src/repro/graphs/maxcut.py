"""Max-Cut ↔ Ising mapping (paper §II-A/B, Fig. 1).

For edge weights w_ij, the cut weight of the bipartition induced by spins s is
``w(δ(S)) = Σ_{i<j} w_ij (1 − s_i s_j)/2``. Minimizing the Ising Hamiltonian
with ``J_ij = −w_ij`` (h = 0) maximizes the cut:

    H(s) = −Σ_{i<j} J_ij s_i s_j = Σ_{i<j} w_ij s_i s_j
         = Σ w_ij − 2·cut(s)   ⇒   cut(s) = (Σ w_ij − H(s)) / 2
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.ising import IsingProblem


@dataclasses.dataclass(frozen=True)
class MaxCutInstance:
    """Dense symmetric weight matrix with zero diagonal."""

    weights: np.ndarray  # (N, N) float32
    name: str = "maxcut"
    best_known: float | None = None

    @property
    def num_vertices(self) -> int:
        return self.weights.shape[0]

    @property
    def num_edges(self) -> int:
        return int(np.count_nonzero(np.triu(self.weights, 1)))

    @property
    def total_weight(self) -> float:
        return float(np.triu(self.weights, 1).sum())

    @property
    def density(self) -> float:
        n = self.num_vertices
        return 2.0 * self.num_edges / (n * (n - 1))


def maxcut_to_ising(instance: MaxCutInstance) -> IsingProblem:
    """J = −w, h = 0. ``energy + offset`` returns −cut directly, so the solver's
    ``best_energy`` is −(cut value): minimize energy ⇔ maximize cut."""
    w = np.asarray(instance.weights, np.float32)
    total = float(np.triu(w, 1).sum())
    # H(s) = Σ_{i<j} w_ij s_i s_j ;  cut = (total − H)/2  ⇒  −cut = (H − total)/2.
    # Encode via J' = −w/2 …? Keep exact ints: scale J by 1 and apply affine at
    # readout instead — offset holds −total/2 and energies halve at readout.
    return IsingProblem.create(J=-w, h=None, offset=0.0)


def maxcut_edges_to_ising(weight_edges) -> IsingProblem:
    """Dense-J-free counterpart of :func:`maxcut_to_ising`: a canonical
    ``EdgeList`` of edge weights w → the J = −w Ising instance as an
    edge-list-backed :class:`IsingProblem` (h = 0, offset 0 — identical
    readout convention to the dense mapping, so ``best_energy`` is −cut
    up to the same affine). The (N, N) matrix is never materialized; the
    solvers' plane-backed paths ingest the edges directly in O(nnz)."""
    from ..core.ising import EdgeList

    if not isinstance(weight_edges, EdgeList):
        raise TypeError(f"maxcut_edges_to_ising needs an EdgeList of weights, "
                        f"got {type(weight_edges).__name__}")
    return IsingProblem.create_sparse(weight_edges.negated())


def cut_value(instance: MaxCutInstance, spins) -> float:
    """Cut weight of the bipartition induced by ±1 spins."""
    s = np.asarray(spins, np.float32)
    w = np.asarray(instance.weights, np.float32)
    if s.ndim == 1:
        return float(np.sum(np.triu(w, 1) * (1.0 - np.outer(s, s))) / 2.0)
    return np.array([cut_value(instance, row) for row in s])


def cut_from_energy(instance: MaxCutInstance, ising_energy) -> np.ndarray:
    """cut = (Σw − H)/2 for H from the J=−w encoding."""
    return (instance.total_weight - np.asarray(ising_energy)) / 2.0


def energy_from_cut(instance: MaxCutInstance, cut) -> np.ndarray:
    return instance.total_weight - 2.0 * np.asarray(cut)
