"""Other NP-hard encodings from the paper's motivation (§II-A, Lucas [38]).

* Balanced graph partitioning (min-cut with balance penalty) — also the
  engine behind `core.placement`.
* Number partitioning: split {a_i} into two sets with equal sums;
  H = (Σ a_i s_i)² ⇒ J_ij = −2 a_i a_j, ground energy −Σa² iff a perfect
  partition exists.
"""
from __future__ import annotations

import numpy as np

from ..core.ising import IsingProblem


def graph_partitioning_to_ising(weights: np.ndarray,
                                balance_weight: float) -> IsingProblem:
    """min Σ_{i<j} w_ij [s_i≠s_j] + λ(Σ s_i)² as an Ising instance."""
    w = np.asarray(weights, np.float64)
    n = w.shape[0]
    J = w / 2.0 - 2.0 * balance_weight
    np.fill_diagonal(J, 0.0)
    # cut = Σ w/2 − Σ_{i<j} (w/2) s_i s_j ; balance = λ(n + Σ_{i≠j} s_i s_j)
    offset = np.triu(w, 1).sum() / 2.0 + balance_weight * n
    return IsingProblem.create(J=J.astype(np.float32), offset=float(offset))


def partition_cost(weights: np.ndarray, spins, balance_weight: float) -> float:
    s = np.asarray(spins, np.float64)
    w = np.asarray(weights, np.float64)
    cut = float(np.triu(w * (s[:, None] != s[None, :]), 1).sum())
    return cut + balance_weight * float(s.sum()) ** 2


def number_partitioning_to_ising(values) -> IsingProblem:
    """H(s) = (Σ a_i s_i)² − Σ a_i² (so a perfect partition has H = 0...
    encoded via J_ij = −2 a_i a_j with offset Σ a_i²)."""
    a = np.asarray(values, np.float64)
    J = -2.0 * np.outer(a, a)
    np.fill_diagonal(J, 0.0)
    return IsingProblem.create(J=J.astype(np.float32), offset=float(np.sum(a * a)))


def partition_residue(values, spins) -> float:
    """|Σ_{S} a − Σ_{S̄} a| for the bipartition induced by spins."""
    a = np.asarray(values, np.float64)
    s = np.asarray(spins, np.float64)
    return abs(float(np.sum(a * s)))
