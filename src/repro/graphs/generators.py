"""Synthetic problem-graph generators matching Table I's topology families.

Gset instances are Erdős–Rényi / small-world / torus graphs with ±1 edge
weights; K2000 is the complete graph with uniform ±1 couplings. The real Gset
files are not redistributable in this offline container, so benchmarks use
these statistically matched generators (same |V|, |E| target, topology family,
signed unit weights) — noted in EXPERIMENTS.md. A parser for the real files is
in :mod:`repro.graphs.gset`.
"""
from __future__ import annotations

import numpy as np

from .maxcut import MaxCutInstance


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


def _signed_weights(rng: np.random.Generator, mask: np.ndarray) -> np.ndarray:
    """Uniform ±1 weights on the upper-triangular edge mask, symmetrized."""
    n = mask.shape[0]
    signs = rng.choice(np.array([-1.0, 1.0], np.float32), size=(n, n))
    w = np.triu(mask, 1) * signs
    return (w + w.T).astype(np.float32)


def erdos_renyi(n: int, num_edges: int, seed: int = 0, signed: bool = True,
                name: str = "er") -> MaxCutInstance:
    """G(n, m): exactly ``num_edges`` uniform random edges (G6/G61 family)."""
    rng = _rng(seed)
    iu = np.triu_indices(n, 1)
    total = iu[0].size
    pick = rng.choice(total, size=min(num_edges, total), replace=False)
    mask = np.zeros((n, n), np.float32)
    mask[iu[0][pick], iu[1][pick]] = 1.0
    mask = mask + mask.T
    w = _signed_weights(rng, mask) if signed else (np.triu(mask, 1) + np.triu(mask, 1).T)
    return MaxCutInstance(weights=w, name=name)


def sparse_bipolar_edges(n: int, num_edges: int, seed: int = 0):
    """G(n, m) with ±1 weights as a canonical ``core.ising.EdgeList`` —
    dense-J-free from birth: endpoints are sampled directly (O(m) memory, no
    (n, n) mask, so it scales to the N=16k+ ingestion benchmarks the dense
    generators cannot touch). Pairs are sampled with replacement then
    deduplicated *before* signing, so weights stay exactly ±1 and the
    realized edge count is ≤ ``num_edges`` — the Gset-like sparse regime
    m ≪ n² where that gap is negligible."""
    from ..core.ising import EdgeList

    rng = _rng(seed)
    i = rng.integers(0, n, size=num_edges, dtype=np.int64)
    j = rng.integers(0, n - 1, size=num_edges, dtype=np.int64)
    j = np.where(j >= i, j + 1, j)  # uniform over off-diagonal pairs
    key = np.unique(np.minimum(i, j) * np.int64(n) + np.maximum(i, j))
    w = rng.choice(np.array([-1, 1], np.int64), size=key.size)
    return EdgeList.create(key // n, key % n, w, n)


def torus_grid_edges(rows: int, cols: int, seed: int = 0,
                     signed: bool = True):
    """2D periodic torus (G11/G62 family) as a canonical
    ``core.ising.EdgeList`` — the deterministic known-χ instance for the
    colored execution mode: with both dimensions even the torus is
    bipartite, so ``graphs.coloring.greedy_coloring`` returns exactly two
    color classes of N/2 spins each (the checkerboard), and a colored sweep
    flips O(N/2) spins per step. Dense-J-free from birth (O(N) edges, no
    (N, N) mask — scales to the N=16k benches). Edge weights are ±1 drawn
    from the same PCG64 stream family as the dense generators (``signed=
    False`` gives the uniform ferromagnet, weight +1)."""
    from ..core.ising import EdgeList

    if rows < 3 or cols < 3:
        raise ValueError(f"torus needs rows, cols >= 3, got {rows}x{cols} "
                         "(smaller dims collapse wrap-around edges)")
    rng = _rng(seed)
    n = rows * cols
    idx = np.arange(n, dtype=np.int64)
    r, c = idx // cols, idx % cols
    down = ((r + 1) % rows) * cols + c
    right = r * cols + (c + 1) % cols
    i = np.concatenate([idx, idx])
    j = np.concatenate([down, right])
    w = (rng.choice(np.array([-1, 1], np.int64), size=i.size) if signed
         else np.ones(i.size, np.int64))
    return EdgeList.create(i, j, w, n)


def small_world(n: int, k: int, rewire_p: float = 0.1, seed: int = 0,
                signed: bool = True, name: str = "sw") -> MaxCutInstance:
    """Watts–Strogatz ring lattice with rewiring (G18/G64 family)."""
    rng = _rng(seed)
    mask = np.zeros((n, n), np.float32)
    for d in range(1, k // 2 + 1):
        idx = np.arange(n)
        mask[idx, (idx + d) % n] = 1.0
    # Rewire each lattice edge with probability rewire_p.
    edges = np.argwhere(mask > 0)
    for (i, j) in edges:
        if rng.random() < rewire_p:
            mask[i, j] = 0.0
            tgt = int(rng.integers(n))
            while tgt == i:
                tgt = int(rng.integers(n))
            a, b = min(i, tgt), max(i, tgt)
            mask[a, b] = 1.0
    mask = np.triu(mask + mask.T, 1)
    mask = ((mask + mask.T) > 0).astype(np.float32)
    w = _signed_weights(rng, mask) if signed else np.triu(mask, 1) + np.triu(mask, 1).T
    return MaxCutInstance(weights=w, name=name)


def torus_grid(rows: int, cols: int, seed: int = 0, signed: bool = True,
               name: str = "torus") -> MaxCutInstance:
    """2D torus (periodic grid), the G11/G62 family."""
    rng = _rng(seed)
    n = rows * cols
    mask = np.zeros((n, n), np.float32)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for (rr, cc) in (((r + 1) % rows, c), (r, (c + 1) % cols)):
                j = rr * cols + cc
                if i != j:
                    a, b = min(i, j), max(i, j)
                    mask[a, b] = 1.0
    mask = mask + mask.T
    w = _signed_weights(rng, mask) if signed else np.triu(mask, 1) + np.triu(mask, 1).T
    return MaxCutInstance(weights=w, name=name)


def complete_bipolar(n: int, seed: int = 0, name: str = "K") -> MaxCutInstance:
    """Complete graph with J_ij ∈ {−1,+1} uniform — the paper's K2000 (§V-A2)."""
    rng = _rng(seed)
    mask = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    w = _signed_weights(rng, mask)
    return MaxCutInstance(weights=w, name=f"{name}{n}")


def ground_state_planted_grid(rows: int, cols: int, seed: int = 0,
                              name: str = "planted") -> tuple[MaxCutInstance, np.ndarray]:
    """Ferromagnetic torus with a planted bipartition (known optimum), used by
    tests in the spirit of paper Fig. 4's known-optimum instance."""
    rng = _rng(seed)
    inst = torus_grid(rows, cols, seed=seed, signed=False, name=name)
    planted = rng.choice(np.array([-1, 1], np.int8), size=rows * cols)
    # Gauge transform w_ij = -w0_ij p_i p_j: H(s) = -Σ w0 (p⊙s)_i (p⊙s)_j is
    # minimized exactly at s = ±p, so the max cut is attained at the plant.
    w = (-inst.weights * np.outer(planted, planted)).astype(np.float32)
    np.fill_diagonal(w, 0.0)
    from .maxcut import cut_value

    planted_inst = MaxCutInstance(weights=w, name=name)
    best = float(cut_value(planted_inst, planted))
    return MaxCutInstance(weights=w, name=name, best_known=best), planted
