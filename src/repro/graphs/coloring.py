"""Conflict-graph coloring for parallel spin updates (ROADMAP item 3).

Two spins that share no coupling have independent flip energetics: flipping
one cannot change the other's local field, so an entire *color class* of the
conflict graph (the coupling graph itself — vertices are spins, edges are
nonzero couplings) can be updated simultaneously with exact Gibbs semantics
(Aadit et al., arXiv:2110.02481). The colored execution mode
(``SolverConfig(flip_mode="colored")``) schedules one class per kernel step,
scaling the paper's asynchronous updates from 1 to O(N/χ) flips per step on
sparse instances.

This module is the host-side ingest pass: pure numpy over the canonical COO
edges (dense-J-free — the (N, N) matrix is never formed for ``EdgeList``
inputs), deterministic, and cheap (O(N + nnz)). The resulting
:class:`Coloring` is content-hashed like ``core.ising.EdgeList`` so it can
ride jit static arguments / cache keys, and :func:`greedy_coloring` memoizes
per edge-list digest so repeated solves of one instance pay the pass once.

Algorithm: a BFS proper 2-coloring is attempted first (components scanned in
vertex-id order), so every bipartite conflict graph — torus/grid lattices,
trees, even cycles — gets the optimal χ = 2 regardless of what a greedy
vertex order would produce. Non-bipartite graphs fall back to greedy
smallest-available-color in vertex-id order (χ ≤ maxdeg + 1; a dense clique
degenerates to N singleton classes, i.e. colored mode gracefully collapses
to single-flip work per step). Determinism under edge *permutation* is
inherited from ``EdgeList.create``'s canonical ordering: the algorithm only
consumes the adjacency structure, which is permutation-invariant.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property
from typing import Optional, Union

import numpy as np

from ..core.ising import EdgeList


@dataclasses.dataclass(frozen=True, eq=False)
class Coloring:
    """A proper coloring of the conflict graph, with the color-sorted layout
    the colored sweep kernel consumes.

    ``colors[i]`` is vertex i's class; ``perm`` is the stable color-sorted
    vertex order (``perm[k]`` = original vertex at permuted slot ``k``), so
    class ``c`` occupies the contiguous permuted range
    ``[offsets[c], offsets[c+1])``. Content-based identity (like
    ``EdgeList``): two colorings of equal content hash/compare equal, so a
    ``Coloring`` can key jit caches and memo tables.
    """

    colors: np.ndarray    # (N,) int32 proper coloring, classes 0..χ-1
    perm: np.ndarray      # (N,) int32 stable color-sorted vertex order
    offsets: np.ndarray   # (χ+1,) int64 class boundaries in perm order
    num_spins: int

    @property
    def num_classes(self) -> int:
        return int(self.offsets.size - 1)

    @property
    def class_sizes(self) -> np.ndarray:
        """(χ,) int64 members per color class — the per-class size stats
        surfaced by launch/bench summaries (flips/step is bounded by the
        scheduled class's size; the mean size is the O(N/χ) headline)."""
        return np.diff(self.offsets)

    @property
    def max_class_size(self) -> int:
        return int(self.class_sizes.max(initial=0))

    @cached_property
    def inverse_perm(self) -> np.ndarray:
        """(N,) int32 with ``inverse_perm[perm[k]] = k`` — maps permuted
        spin vectors back to original vertex order."""
        inv = np.empty_like(self.perm)
        inv[self.perm] = np.arange(self.perm.size, dtype=self.perm.dtype)
        return inv

    def validate_against(self, edges: EdgeList) -> None:
        """Assert the proper-coloring invariant: no edge joins same-color
        endpoints (the exactness precondition of parallel class updates)."""
        bad = self.colors[edges.rows] == self.colors[edges.cols]
        if bad.any():
            k = int(np.flatnonzero(bad)[0])
            raise AssertionError(
                f"edge ({int(edges.rows[k])}, {int(edges.cols[k])}) joins "
                f"two color-{int(self.colors[edges.rows[k]])} vertices")

    @cached_property
    def _digest(self) -> bytes:
        h = hashlib.sha256()
        h.update(str(self.num_spins).encode())
        h.update(self.colors.tobytes())
        return h.digest()

    def __eq__(self, other) -> bool:
        return (isinstance(other, Coloring)
                and self.num_spins == other.num_spins
                and self._digest == other._digest)

    def __hash__(self) -> int:
        return hash((self.num_spins, self._digest))


def _adjacency(rows: np.ndarray, cols: np.ndarray, n: int):
    """CSR neighbor lists from canonical COO: ``nbrs[starts[v]:starts[v+1]]``
    are v's neighbors, each in ascending order (counting sort over the
    doubled edge set — O(N + nnz), no (N, N) anything)."""
    src = np.concatenate([rows, cols]).astype(np.int64)
    dst = np.concatenate([cols, rows]).astype(np.int64)
    order = np.lexsort((dst, src))
    nbrs = dst[order]
    deg = np.bincount(src, minlength=n)
    starts = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=starts[1:])
    return nbrs, starts


def _try_bipartite(nbrs: np.ndarray, starts: np.ndarray,
                   n: int) -> Optional[np.ndarray]:
    """BFS proper 2-coloring in vertex-id component order, or None if any
    odd cycle exists. Isolated vertices take color 0."""
    colors = np.full(n, -1, np.int32)
    for root in range(n):
        if colors[root] >= 0:
            continue
        colors[root] = 0
        frontier = [root]
        while frontier:
            nxt = []
            for v in frontier:
                cv = colors[v]
                for u in nbrs[starts[v]:starts[v + 1]]:
                    if colors[u] < 0:
                        colors[u] = 1 - cv
                        nxt.append(int(u))
                    elif colors[u] == cv:
                        return None
            frontier = nxt
    return colors


def _greedy(nbrs: np.ndarray, starts: np.ndarray, n: int) -> np.ndarray:
    """Smallest-available-color greedy in vertex-id order (χ ≤ maxdeg+1)."""
    colors = np.full(n, -1, np.int32)
    for v in range(n):
        taken = colors[nbrs[starts[v]:starts[v + 1]]]
        taken = set(int(c) for c in taken if c >= 0)
        c = 0
        while c in taken:
            c += 1
        colors[v] = c
    return colors


def _finalize(colors: np.ndarray, n: int) -> Coloring:
    num_classes = int(colors.max(initial=-1)) + 1 if n else 1
    num_classes = max(num_classes, 1)
    perm = np.argsort(colors, kind="stable").astype(np.int32)
    counts = np.bincount(colors, minlength=num_classes).astype(np.int64)
    offsets = np.zeros(num_classes + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return Coloring(colors=colors, perm=perm, offsets=offsets, num_spins=n)


_COLORING_MEMO: dict[EdgeList, Coloring] = {}


def greedy_coloring(source: Union[EdgeList, np.ndarray],
                    num_spins: Optional[int] = None) -> Coloring:
    """Deterministic proper coloring of the conflict graph of ``source``.

    ``source`` is a canonical :class:`~repro.core.ising.EdgeList` (the
    dense-J-free ingest path — memoized per content digest) or a dense
    symmetric J whose nonzero structure defines the edges (tests / small
    dense problems; the matrix is only *read*, never copied). Bipartite
    graphs always get χ = 2 (BFS pass); otherwise greedy in vertex order.
    Every class is guaranteed non-empty and classes are numbered
    0..χ-1 in first-use order.
    """
    if isinstance(source, EdgeList):
        cached = _COLORING_MEMO.get(source)
        if cached is not None:
            return cached
        n = source.num_spins
        rows, cols = source.rows, source.cols
    else:
        J = np.asarray(source)
        if J.ndim != 2 or J.shape[0] != J.shape[1]:
            raise ValueError(f"dense coloring source must be square, "
                             f"got {J.shape}")
        n = J.shape[0]
        rows, cols = np.nonzero(np.triu(J, 1))
    if num_spins is not None and int(num_spins) != n:
        raise ValueError(f"num_spins={num_spins} != source N={n}")
    nbrs, starts = _adjacency(rows, cols, n)
    colors = _try_bipartite(nbrs, starts, n)
    if colors is None:
        colors = _greedy(nbrs, starts, n)
    out = _finalize(colors, n)
    if isinstance(source, EdgeList):
        _COLORING_MEMO[source] = out
    return out
