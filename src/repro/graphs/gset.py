"""Gset benchmark file format parser (paper §V-A2, [59]).

Format: first line ``|V| |E|``; then one line per edge ``i j w`` (1-indexed).
A small embedded sample (a 10-vertex signed graph in exact Gset syntax) keeps
the parser tested offline; point :func:`parse_gset` at real downloaded files
(e.g. web.stanford.edu/~yyye/yyye/Gset/G6) to reproduce Table II on the
original instances.
"""
from __future__ import annotations

import io

import numpy as np

from .maxcut import MaxCutInstance

GSET_SAMPLE = """10 14
1 2 1
1 3 -1
2 4 1
3 4 1
4 5 -1
5 6 1
6 7 1
6 8 -1
7 9 1
8 9 1
8 10 -1
9 10 1
2 7 1
3 8 -1
"""


def parse_gset(source, name: str = "gset") -> MaxCutInstance:
    """Parse a Gset file from a path, file object, or literal string."""
    if isinstance(source, str) and "\n" in source:
        fh = io.StringIO(source)
    elif hasattr(source, "read"):
        fh = source
    else:
        fh = open(source)
    try:
        header = fh.readline().split()
        n, m = int(header[0]), int(header[1])
        w = np.zeros((n, n), np.float32)
        count = 0
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            i, j, wt = int(parts[0]) - 1, int(parts[1]) - 1, float(parts[2])
            w[i, j] = wt
            w[j, i] = wt
            count += 1
        if count != m:
            raise ValueError(f"Gset header declared {m} edges, file had {count}")
        return MaxCutInstance(weights=w, name=name)
    finally:
        fh.close()
