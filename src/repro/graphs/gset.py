"""Gset benchmark file format parser (paper §V-A2, [59]).

Format: first line ``|V| |E|``; then one line per edge ``i j w`` (1-indexed).
A small embedded sample (a 10-vertex signed graph in exact Gset syntax) keeps
the parser tested offline; point :func:`parse_gset` at real downloaded files
(e.g. web.stanford.edu/~yyye/yyye/Gset/G6) to reproduce Table II on the
original instances.
"""
from __future__ import annotations

import io

import numpy as np

from .maxcut import MaxCutInstance

GSET_SAMPLE = """10 14
1 2 1
1 3 -1
2 4 1
3 4 1
4 5 -1
5 6 1
6 7 1
6 8 -1
7 9 1
8 9 1
8 10 -1
9 10 1
2 7 1
3 8 -1
"""


def _open(source):
    if isinstance(source, str) and "\n" in source:
        return io.StringIO(source)
    if hasattr(source, "read"):
        return source
    return open(source)


def parse_gset(source, name: str = "gset") -> MaxCutInstance:
    """Parse a Gset file from a path, file object, or literal string into a
    dense weight matrix (small/medium instances; for large instances use
    :func:`parse_gset_edges`, which never materializes (N, N))."""
    fh = _open(source)
    try:
        header = fh.readline().split()
        n, m = int(header[0]), int(header[1])
        w = np.zeros((n, n), np.float32)
        count = 0
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            i, j, wt = int(parts[0]) - 1, int(parts[1]) - 1, float(parts[2])
            w[i, j] = wt
            w[j, i] = wt
            count += 1
        if count != m:
            raise ValueError(f"Gset header declared {m} edges, file had {count}")
        return MaxCutInstance(weights=w, name=name)
    finally:
        fh.close()


def parse_gset_edges(source):
    """Dense-J-free Gset parser: the same file format as :func:`parse_gset`
    but returning a canonical ``core.ising.EdgeList`` of the edge *weights*
    w — O(nnz) memory, no (N, N) array ever. Feed it through
    ``repro.graphs.maxcut.maxcut_edges_to_ising`` for the J = −w Ising
    instance the solvers consume (the full sparse→plane ingestion pipeline
    for real benchmark instances).

    A file listing the same undirected edge twice (either orientation) is
    rejected: ``EdgeList`` sums duplicates while the dense parser's
    assignment is last-wins, so a duplicated line is the one input on which
    the two parsers would silently describe different instances — and in a
    well-formed Gset file it is always a data error."""
    from ..core.ising import EdgeList

    fh = _open(source)
    try:
        header = fh.readline().split()
        n, m = int(header[0]), int(header[1])
        rows, cols, weights = [], [], []
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            rows.append(int(parts[0]) - 1)
            cols.append(int(parts[1]) - 1)
            weights.append(float(parts[2]))
        if len(rows) != m:
            raise ValueError(
                f"Gset header declared {m} edges, file had {len(rows)}")
        edges = EdgeList.create(np.asarray(rows), np.asarray(cols),
                                np.asarray(weights), n)
        if edges.nnz != len(rows):
            raise ValueError(
                f"Gset file lists {len(rows)} edges but only {edges.nnz} "
                "distinct undirected pairs survive coalescing — duplicate "
                "edge lines are malformed (the dense parser would keep the "
                "last, the edge-list path would sum them)")
        return edges
    finally:
        fh.close()
