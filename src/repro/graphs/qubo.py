"""QUBO ↔ Ising conversions (paper §II-B; Lucas-style mappings).

QUBO: minimize xᵀQx over x ∈ {0,1}ⁿ. Substituting x = (s+1)/2:

    xᵀQx = 1/4 Σ_ij Q_ij (s_i+1)(s_j+1)
         = 1/4 sᵀQs + 1/2 (Q 1)ᵀ s·sym + const

yielding Ising J_ij = −(Q_ij + Q_ji)/4 (i≠j), h_i = −(Σ_j (Q_ij+Q_ji)/4 + Q_ii/2),
offset = Σ_ij Q_ij/4 + tr(Q)/4 such that qubo(x) == ising_energy(s) + offset.
"""
from __future__ import annotations

import numpy as np

from ..core.ising import IsingProblem


def qubo_to_ising(Q: np.ndarray) -> IsingProblem:
    Q = np.asarray(Q, np.float64)
    n = Q.shape[0]
    S = (Q + Q.T) / 2.0  # symmetrized; diagonal handled separately
    off_diag = S - np.diag(np.diag(S))
    # x_i x_j = (1 + s_i + s_j + s_i s_j)/4 for i≠j ; x_i^2 = x_i = (1+s_i)/2.
    J = -off_diag / 2.0  # pair term: Σ_{i<j} (S_ij/2) s_i s_j = -Σ J_ij s_i s_j
    h = -(off_diag.sum(axis=1) + np.diag(S)) / 2.0
    offset = off_diag.sum() / 4.0 + np.diag(S).sum() / 2.0
    np.fill_diagonal(J, 0.0)
    return IsingProblem.create(J=J.astype(np.float32), h=h.astype(np.float32),
                               offset=float(offset))


def ising_to_qubo(problem: IsingProblem) -> tuple[np.ndarray, float]:
    """Inverse map: returns (Q, offset) with xᵀQx + offset == H(s) + problem.offset."""
    J = np.asarray(problem.couplings, np.float64)
    h = np.asarray(problem.fields, np.float64)
    # s = 2x − 1: −Σ_{i<j} J_ij s_i s_j − Σ h_i s_i
    #   = −Σ_{i<j} J_ij (4 x_i x_j − 2x_i − 2x_j + 1) − Σ h_i (2x_i − 1)
    Q = -2.0 * J  # off-diagonal: −4 J_ij/2 per unordered pair split symmetrically
    lin = 2.0 * J.sum(axis=1) - 2.0 * h
    Q = Q + np.diag(lin)
    offset = -J[np.triu_indices_from(J, 1)].sum() + h.sum()
    return Q, float(offset + problem.offset)


def qubo_energy(Q: np.ndarray, x: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    return float(x @ np.asarray(Q, np.float64) @ x)
