from .maxcut import MaxCutInstance, maxcut_to_ising, maxcut_edges_to_ising, cut_value  # noqa: F401
from .generators import (erdos_renyi, small_world, torus_grid,  # noqa: F401
                         complete_bipolar, sparse_bipolar_edges)
from .qubo import qubo_to_ising, ising_to_qubo  # noqa: F401
from .gset import parse_gset, parse_gset_edges, GSET_SAMPLE  # noqa: F401
