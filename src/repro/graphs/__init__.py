from .maxcut import MaxCutInstance, maxcut_to_ising, maxcut_edges_to_ising, cut_value  # noqa: F401
from .generators import (erdos_renyi, small_world, torus_grid,  # noqa: F401
                         torus_grid_edges, complete_bipolar,
                         sparse_bipolar_edges)
from .coloring import Coloring, greedy_coloring  # noqa: F401
from .qubo import qubo_to_ising, ising_to_qubo  # noqa: F401
from .gset import parse_gset, parse_gset_edges, GSET_SAMPLE  # noqa: F401
