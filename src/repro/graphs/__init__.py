from .maxcut import MaxCutInstance, maxcut_to_ising, cut_value  # noqa: F401
from .generators import erdos_renyi, small_world, torus_grid, complete_bipolar  # noqa: F401
from .qubo import qubo_to_ising, ising_to_qubo  # noqa: F401
from .gset import parse_gset, GSET_SAMPLE  # noqa: F401
