"""Loop-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE (verified:
a 10-iteration scan of matmuls reports 1× the matmul flops), which silently
undercounts any scanned program — scan-over-layers, flash-attention KV loops,
microbatch accumulation. This walker re-derives flops / bytes / collective
wire-bytes from the compiled HLO **with loop multipliers** taken from XLA's
``backend_config={"known_trip_count":{"n":...}}`` annotations.

Accounting rules (mirroring HloCostAnalysis conventions):
  * flops: ``dot`` ops only (2 × prod(result dims) × prod(contracting dims));
    elementwise flops are ignored — matmul-dominated models, standard MFU
    practice. Dots inside fusions are counted.
  * bytes: per instruction at computation top level: result + operand bytes.
    Fusion-internal instructions are NOT counted (the fusion node's operands/
    results are, exactly like XLA).
  * collectives: ring-algorithm wire bytes (see analysis.py), × loop
    multiplier of the computation they appear in.
  * while: body cost × known_trip_count (1 if unannotated); cond ignored.
  * conditional: all branches counted once (upper bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "u1": 1, "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP = re.compile(r'known_trip_count...?.n.:."?(\d+)')
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute", "all-reduce-start", "all-gather-start",
                   "collective-permute-start", "reduce-scatter-start",
                   "all-to-all-start"}


def _shape_list(segment: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_TOKEN.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dtype, shape))
    return out


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dtype, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    shapes: Dict[str, List[Tuple[str, Tuple[int, ...]]]]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and stripped.endswith("{"):
            m = _COMP_HEADER.match(stripped)
            if m:
                current = Computation(m.group(1), [], {})
                comps[current.name] = current
            continue
        if stripped == "}":
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_seg, op, rest = m.groups()
        result_shapes = _shape_list(type_seg)
        # operands: up to the closing paren at depth 0 of `rest`
        depth = 1
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_names = _OPERAND.findall(rest[:end])
        instr = Instruction(name=name, op=op, result_shapes=result_shapes,
                            operands=operand_names, line=stripped)
        current.instructions.append(instr)
        current.shapes[name] = result_shapes
    return comps


_OP_NAME = re.compile(r'op_name="[^"/]*/([^"]*)"')
# Scope buckets for the per-cell memory profile (first match wins).
_SCOPE_MARKERS = ("chunked_attention", "decode_attention", "_wkv_scan",
                  "moe_ffn", "mamba_block", "mlp", "_logits", "lm_loss",
                  "adamw", "rope", "norm")


def _scope_of(line: str) -> str:
    m = _OP_NAME.search(line)
    if not m:
        return "other"
    path = m.group(1)
    for marker in _SCOPE_MARKERS:
        if marker in path:
            return marker
    parts = path.split("/")
    return parts[-2] if len(parts) > 1 else parts[-1]


@dataclasses.dataclass
class LoopAwareCost:
    flops: float = 0.0
    bytes: float = 0.0        # conservative: every top-level op's operands+results
    bytes_fused: float = 0.0  # TPU-fusion-optimistic: see _FUSED_BYTE_OPS below
    wire_bytes: float = 0.0
    collective_bytes_by_op: dict = dataclasses.field(default_factory=dict)
    scope_bytes: dict = dataclasses.field(default_factory=dict)   # fused-mode bytes by scope
    scope_flops: dict = dataclasses.field(default_factory=dict)
    max_trip_product: float = 1.0


# Fusion-optimistic byte accounting (the TPU roofline memory term): the Mosaic/
# XLA-TPU pipeline fuses elementwise chains into producer/consumer HLOs, so
# surviving HBM traffic happens at matmul/reduction/data-movement boundaries.
# CPU-compiled HLO leaves elementwise ops unfused, which makes the conservative
# count a ~50× overestimate of TPU traffic. Rules:
#   dot/convolution/reduce/sort   -> operands + results
#   gather / dynamic-slice        -> result (+ index bytes, negligible)
#   scatter / dynamic-update-slice-> update operand only (in-place on TPU)
#   collectives                   -> result
#   fusion nodes                  -> counted iff their body contains one of the
#                                    above (e.g. a softmax-reduce fusion)
_FUSED_MAJOR = {"dot", "convolution", "reduce", "reduce-window", "sort"}
_FUSED_RESULT_ONLY = {"gather", "dynamic-slice"}
_FUSED_UPDATE_ONLY = {"scatter", "dynamic-update-slice"}


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    result = instr.result_shapes[0] if instr.result_shapes else ("f32", ())
    n_result = 1
    for d in result[1]:
        n_result *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs_name = instr.operands[0] if instr.operands else None
    lhs_shapes = comp.shapes.get(lhs_name)
    contract = 1
    if lhs_shapes:
        lhs_shape = lhs_shapes[0][1]
        for cd in cdims:
            if cd < len(lhs_shape):
                contract *= lhs_shape[cd]
    return 2.0 * n_result * contract


def _collective_wire_bytes(instr: Instruction, default_group: int) -> Tuple[str, float]:
    kind = instr.op.replace("-start", "")
    b = _bytes_of(instr.result_shapes)
    g = default_group
    m = _GROUPS_RE.search(instr.line)
    if m:
        g = len(m.group(1).split(","))
    else:
        m2 = _GROUPS_V2_RE.search(instr.line)
        if m2:
            g = int(m2.group(2))
    g = max(g, 1)
    if kind == "all-reduce":
        wire = 2.0 * b * (g - 1) / g
    elif kind == "all-gather":
        wire = b * (g - 1) / g
    elif kind == "reduce-scatter":
        wire = b * (g - 1)
    elif kind == "all-to-all":
        wire = b * (g - 1) / g
    else:  # collective-permute
        wire = float(b)
    return kind, wire


def analyze(text: str, default_group: int = 1) -> LoopAwareCost:
    comps = parse_module(text)
    cost = LoopAwareCost()
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line.replace("ENTRY ", "").strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: computation referenced by none
        called = set()
        for c in comps.values():
            for i in c.instructions:
                called.update(_CALLS.findall(i.line))
        candidates = [n for n in comps if n not in called]
        entry = candidates[-1] if candidates else next(iter(comps), None)
    if entry is None or entry not in comps:
        return cost

    fusion_like = {"fusion"}
    seen_stack = set()
    # cache: does computation (transitively) contain a major-byte op?
    has_major_cache: Dict[str, bool] = {}

    def has_major(comp_name: str) -> bool:
        if comp_name in has_major_cache:
            return has_major_cache[comp_name]
        has_major_cache[comp_name] = False  # cycle guard
        comp = comps.get(comp_name)
        found = False
        if comp is not None:
            for instr in comp.instructions:
                if (instr.op in _FUSED_MAJOR or instr.op in _FUSED_RESULT_ONLY
                        or instr.op in _FUSED_UPDATE_ONLY
                        or instr.op in _COLLECTIVE_OPS):
                    found = True
                    break
                mc = _CALLS.search(instr.line)
                if mc and has_major(mc.group(1)):
                    found = True
                    break
        has_major_cache[comp_name] = found
        return found

    def fused_bytes_for(instr: Instruction, comp: Computation) -> float:
        op = instr.op
        if op in _FUSED_MAJOR:
            operand_bytes = sum(_bytes_of(comp.shapes.get(o, [])) for o in instr.operands)
            return _bytes_of(instr.result_shapes) + operand_bytes
        if op in _FUSED_RESULT_ONLY:
            return float(_bytes_of(instr.result_shapes))
        if op in _FUSED_UPDATE_ONLY:
            # in-place on TPU: traffic = the update operand (operand index 1)
            if len(instr.operands) > 1:
                return float(_bytes_of(comp.shapes.get(instr.operands[1], [])))
            return float(_bytes_of(instr.result_shapes))
        if op in fusion_like:
            mc = re.search(r"calls=%?([\w.\-]+)", instr.line)
            if mc and has_major(mc.group(1)):
                operand_bytes = sum(_bytes_of(comp.shapes.get(o, []))
                                    for o in instr.operands)
                return _bytes_of(instr.result_shapes) + operand_bytes
        return 0.0

    def walk(comp_name: str, mult: float, count_bytes: bool):
        if comp_name not in comps or comp_name in seen_stack:
            return
        seen_stack.add(comp_name)
        comp = comps[comp_name]
        cost.max_trip_product = max(cost.max_trip_product, mult)
        for instr in comp.instructions:
            op = instr.op
            if op == "while":
                trip = 1
                m = _TRIP.search(instr.line)
                if m:
                    trip = int(m.group(1))
                body = None
                mb = re.search(r"body=%?([\w.\-]+)", instr.line)
                if mb:
                    body = mb.group(1)
                if count_bytes:
                    cost.bytes += mult * (_bytes_of(instr.result_shapes))
                if body:
                    walk(body, mult * trip, count_bytes)
                continue
            if op == "conditional":
                for b in _COND_BRANCHES.findall(instr.line):
                    for branch in _OPERAND.findall(b):
                        walk(branch, mult, count_bytes)
                continue
            if op in fusion_like:
                if count_bytes:
                    operand_bytes = sum(
                        _bytes_of(comp.shapes.get(o, [])) for o in instr.operands)
                    cost.bytes += mult * (_bytes_of(instr.result_shapes) + operand_bytes)
                    fb = mult * fused_bytes_for(instr, comp)
                    cost.bytes_fused += fb
                    if fb:
                        sc = _scope_of(instr.line)
                        cost.scope_bytes[sc] = cost.scope_bytes.get(sc, 0.0) + fb
                mc = re.search(r"calls=%?([\w.\-]+)", instr.line)
                if mc:
                    walk(mc.group(1), mult, count_bytes=False)  # flops only
                continue
            if op in ("call", "async-start", "async-done"):
                mc = _CALLS.search(instr.line)
                if mc:
                    walk(mc.group(1), mult, count_bytes)
                continue
            if op in _COLLECTIVE_OPS:
                kind, wire = _collective_wire_bytes(instr, default_group)
                cost.wire_bytes += mult * wire
                cost.collective_bytes_by_op[kind] = (
                    cost.collective_bytes_by_op.get(kind, 0.0) + mult * wire)
                if count_bytes:
                    cost.bytes += mult * _bytes_of(instr.result_shapes)
                    cost.bytes_fused += mult * _bytes_of(instr.result_shapes)
                continue
            if op == "dot":
                df = mult * _dot_flops(instr, comp)
                cost.flops += df
                sc = _scope_of(instr.line)
                cost.scope_flops[sc] = cost.scope_flops.get(sc, 0.0) + df
            if count_bytes and op not in ("parameter", "constant", "tuple",
                                          "get-tuple-element", "bitcast"):
                operand_bytes = sum(
                    _bytes_of(comp.shapes.get(o, [])) for o in instr.operands)
                cost.bytes += mult * (_bytes_of(instr.result_shapes) + operand_bytes)
                fb = mult * fused_bytes_for(instr, comp)
                cost.bytes_fused += fb
                if fb:
                    sc = _scope_of(instr.line)
                    cost.scope_bytes[sc] = cost.scope_bytes.get(sc, 0.0) + fb
        seen_stack.discard(comp_name)

    walk(entry, 1.0, True)
    return cost
