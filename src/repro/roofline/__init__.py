from .analysis import (HW, CellReport, analyze_compiled, collective_bytes,  # noqa: F401
                       format_report_table)
