"""Three-term roofline analysis from compiled dry-run artifacts (deliverable g).

Terms (seconds), per (arch × shape × mesh):

    t_compute    = device_FLOPs / peak_FLOPs_per_chip
    t_memory     = device_bytes / HBM_bw_per_chip
    t_collective = wire_bytes_per_device / ICI_bw_per_chip

``compiled.cost_analysis()`` on the SPMD-partitioned module reports
**per-device** flops/bytes (calibrated empirically: a 1024³ matmul on 4
devices reports global/4), so terms divide by *per-chip* peaks — equivalent to
the global/(chips·peak) formulation.

Collective bytes are parsed from the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
result shape and replica-group size, converted to ring-algorithm wire bytes
per device:
    all-reduce       2·B·(G−1)/G
    all-gather       B_result·(G−1)/G
    reduce-scatter   B_result·(G−1)        (operand = G·result)
    all-to-all       B·(G−1)/G
    collective-permute  B
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e hardware constants (per chip).
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (conservative single-link figure)
    "hbm_bytes": 16 * 1024**3,   # capacity
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    op_bytes: dict = dataclasses.field(default_factory=dict)
    op_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, kind: str, b: float):
        self.wire_bytes += b
        self.op_bytes[kind] = self.op_bytes.get(kind, 0.0) + b
        self.op_counts[kind] = self.op_counts.get(kind, 0) + 1


def collective_bytes(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    """Ring-algorithm wire bytes per device, summed over all collective ops."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        head, _, rest = stripped.partition("=")
        op = None
        for kind in _COLLECTIVES:
            # match the op name token, e.g. "all-reduce(" or "all-gather-start("
            if re.search(rf"\b{kind}(-start)?\(", rest):
                op = kind
                break
        if op is None:
            continue
        result_bytes = _shape_bytes(rest.split("(")[0])
        if result_bytes == 0:
            continue
        g = _group_size(stripped, default_group)
        if op == "all-reduce":
            wire = 2.0 * result_bytes * (g - 1) / max(g, 1)
        elif op == "all-gather":
            wire = result_bytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = result_bytes * (g - 1)
        elif op == "all-to-all":
            wire = result_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = float(result_bytes)
        stats.add(op, wire)
    return stats


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    device_flops: float
    device_bytes: float
    wire_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float          # 6·N·D (or 2·N·D inference) GLOBAL
    useful_ratio: float         # model_flops / global HLO flops
    memory_per_device: dict
    collective_ops: dict
    scope_bytes: dict = dataclasses.field(default_factory=dict)
    scope_flops: dict = dataclasses.field(default_factory=dict)
    note: str = ""

    @property
    def step_time(self) -> float:
        """Roofline step time (max of the three terms — perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        total_peak = self.num_devices * HW["peak_flops_bf16"]
        return self.model_flops / (self.step_time * total_peak) if self.step_time else 0.0


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     num_devices: int, model_flops: float,
                     hlo_text: Optional[str] = None, note: str = "") -> CellReport:
    from . import hlo_cost

    ca = compiled.cost_analysis() or {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # Loop-aware walker: XLA's cost_analysis counts while bodies once, which
    # undercounts every scanned program (see hlo_cost module docstring).
    walked = hlo_cost.analyze(text, default_group=num_devices)
    dev_flops = walked.flops or float(ca.get("flops", 0.0))
    # Memory term uses the fusion-optimistic count (TPU target fuses
    # elementwise chains; CPU-compiled HLO does not — see hlo_cost).
    dev_bytes = walked.bytes_fused or walked.bytes or float(ca.get("bytes accessed", 0.0))
    stats = CollectiveStats(wire_bytes=walked.wire_bytes,
                            op_bytes=walked.collective_bytes_by_op)
    t_comp = dev_flops / HW["peak_flops_bf16"]
    t_mem = dev_bytes / HW["hbm_bw"]
    t_coll = stats.wire_bytes / HW["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mem = compiled.memory_analysis()
    mem_dict = {
        "arguments": int(getattr(mem, "argument_size_in_bytes", 0)),
        "outputs": int(getattr(mem, "output_size_in_bytes", 0)),
        "temps": int(getattr(mem, "temp_size_in_bytes", 0)),
        "aliased": int(getattr(mem, "alias_size_in_bytes", 0)),
    }
    global_flops = dev_flops * num_devices
    return CellReport(
        arch=arch, shape=shape, mesh=mesh_name, num_devices=num_devices,
        device_flops=dev_flops, device_bytes=dev_bytes,
        wire_bytes=stats.wire_bytes,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
        memory_per_device=mem_dict, collective_ops=dict(stats.op_bytes),
        scope_bytes=dict(sorted(walked.scope_bytes.items(),
                                key=lambda kv: -kv[1])[:10]),
        scope_flops=dict(sorted(walked.scope_flops.items(),
                                key=lambda kv: -kv[1])[:10]),
        note=note,
    )


def apply_flash_substitution(report: CellReport, *, head_dim: int, causal: bool,
                             block_q: int = 512, block_k: int = 512) -> CellReport:
    """Model replacing the jnp chunked attention with the Pallas flash kernel
    (repro.kernels.flash_attention) in a compiled cell.

    Per (block_q × block_k) tile the jnp path moves ≈ 3 f32 traversals of the
    score tile through HBM (dot result, exp/mask fusion, p operand of the pv
    dot) plus the bf16 q/k/v/o streams; the kernel keeps the tile in VMEM so
    only the streams survive. The ratio is applied to the walker-measured
    attention-scope bytes (loop/remat/microbatch multipliers cancel). Causal
    cells also drop the ~2× rectangle-vs-triangle FLOP waste (the kernel's
    loop bound stops at the diagonal; the jnp path computes all tiles).
    """
    attn_bytes = report.scope_bytes.get("chunked_attention", 0.0)
    attn_flops = report.scope_flops.get("chunked_attention", 0.0)
    if attn_bytes == 0 and attn_flops == 0:
        return report
    score_traffic = 3.0 * 4.0 * block_q * block_k
    streams = 2.0 * (block_q + block_k) * head_dim * 2.0
    ratio = streams / (score_traffic + streams)
    if causal:
        ratio *= 0.5
    new_bytes = report.device_bytes - attn_bytes * (1.0 - ratio)
    new_flops = report.device_flops - (attn_flops * 0.5 if causal else 0.0)
    t_comp = new_flops / HW["peak_flops_bf16"]
    t_mem = new_bytes / HW["hbm_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": report.t_collective}
    global_flops = new_flops * report.num_devices
    return dataclasses.replace(
        report, device_flops=new_flops, device_bytes=new_bytes,
        t_compute=t_comp, t_memory=t_mem,
        bottleneck=max(terms, key=terms.get),
        useful_ratio=(report.model_flops / global_flops) if global_flops else 0.0,
        note=(report.note + " +flash-attn-kernel").strip(),
    )


def format_report_table(reports: list[CellReport]) -> str:
    header = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
              "bottleneck | useful | roofline MFU | HBM/dev (GiB) |\n"
              "|---|---|---|---|---|---|---|---|---|---|")
    rows = [header]
    for r in reports:
        hbm = (r.memory_per_device["arguments"] + r.memory_per_device["outputs"]
               + r.memory_per_device["temps"] - r.memory_per_device["aliased"])
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute*1e3:.2f} | "
            f"{r.t_memory*1e3:.2f} | {r.t_collective*1e3:.2f} | {r.bottleneck} | "
            f"{r.useful_ratio:.2f} | {r.mfu*100:.1f}% | {hbm/2**30:.2f} |")
    return "\n".join(rows)
