"""Quickstart: solve a Max-Cut instance with Snowball's dual-mode MCMC.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.snowball import default_solver
from repro.core.solver import solve
from repro.graphs import complete_bipolar, maxcut_to_ising
from repro.graphs.maxcut import cut_from_energy


def main():
    # K64: complete graph, J ∈ {−1,+1} — a miniature of the paper's K2000.
    inst = complete_bipolar(64, seed=0)
    problem = maxcut_to_ising(inst)

    for mode in ("rsa", "rwa"):
        config = default_solver(num_spins=64, num_steps=4000, mode=mode,
                                num_replicas=8)
        result = solve(problem, seed=0, config=config)
        best = float(np.min(np.asarray(result.best_energy)))
        cut = float(cut_from_energy(inst, best))
        print(f"mode={mode:3s}  best_energy={best:8.1f}  cut={cut:6.0f}  "
              f"flips/replica={np.asarray(result.num_flips).mean():.0f}")


if __name__ == "__main__":
    main()
