"""Batched serving example: prefill a batch of prompts into the KV/state
caches, then greedy-decode continuations — the serve-side driver.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-7b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, init_decode_cache, init_params, model_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced config on CPU
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = init_params(model_specs(cfg), jax.random.key(0))
    max_len = args.prompt_len + args.tokens
    cache = init_decode_cache(cfg, args.batch, max_len=max_len)

    prompts = jax.random.randint(jax.random.key(1), (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)

    step = jax.jit(lambda p, c, t, tok: decode_step(cfg, p, c, t, tokens=tok))

    #

    # Prefill: chunked through the decode path (fills KV/state caches).
    t0 = time.perf_counter()
    logits, cache = step(params, cache, jnp.int32(0), prompts)
    logits.block_until_ready()
    prefill_s = time.perf_counter() - t0

    # Greedy decode.
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, cache = step(params, cache, jnp.int32(args.prompt_len + i), tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0

    gen = np.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {prefill_s*1e3:.1f} ms")
    print(f"decode {args.tokens} toks: {decode_s*1e3:.1f} ms "
          f"({decode_s/max(args.tokens-1,1)*1e3:.2f} ms/tok incl. batch)")
    for row in gen[:2]:
        print("sample:", row[:16].tolist(), "...")


if __name__ == "__main__":
    main()
