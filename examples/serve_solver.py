"""Solver-as-a-service example: a multi-tenant burst of Max-Cut requests
through the batched, cache-warm :class:`repro.serve.SolverService`.

    PYTHONPATH=src python examples/serve_solver.py

Walks the front end's three levers (DESIGN.md §Serving layer): a burst of
same-instance requests fuses into one replica-stacked launch, a repeat
tenant's solve reuses the content-hash-cached coupling store (zero
re-encodes), and a target-energy request the service has already beaten
is answered straight from the warm-start cache — no launch at all.
"""
import dataclasses

import numpy as np

from repro.configs.snowball import default_solver
from repro.core.resilience import BudgetConfig
from repro.graphs import complete_bipolar, maxcut_to_ising
from repro.graphs.maxcut import cut_from_energy
from repro.serve import ServeConfig, SolveRequest, SolverService


def main():
    # Two tenants share one K200 instance; a third brings its own K128.
    k200 = complete_bipolar(200, seed=0)
    k128 = complete_bipolar(128, seed=1)
    shared = maxcut_to_ising(k200)
    private = maxcut_to_ising(k128)
    config = dataclasses.replace(
        default_solver(num_spins=200, num_steps=2000, num_replicas=4),
        coupling_format="bitplane")

    service = SolverService(ServeConfig())

    # A burst: three seed-free requests on the shared instance stack into
    # one fused launch (12 replicas side by side); the private instance
    # launches separately. One drain serves all four tenants.
    tickets = [service.submit(SolveRequest(shared, config)) for _ in range(3)]
    tickets.append(service.submit(SolveRequest(
        private, dataclasses.replace(config, num_steps=1500))))
    results = service.drain()
    for t in tickets:
        r = results[t]
        inst = k200 if r.result.best_spins.shape[-1] == 200 else k128
        best = float(np.min(np.asarray(r.result.best_energy)))
        print(f"request {t}: plan={r.batched:6s} store_hit={r.store_hit!s:5s} "
              f"cut={float(cut_from_energy(inst, best)):6.0f} "
              f"wall={r.wall_seconds:.2f}s")

    # A repeat tenant: same instance content (fresh arrays) — the coupling
    # store comes from the LRU cache, so the solve re-encodes nothing.
    repeat = service.solve(maxcut_to_ising(complete_bipolar(200, seed=0)),
                           config, seed=42)
    print(f"repeat tenant: store_hit={repeat.store_hit} "
          f"(cache: {service.stores.hits} hits / {service.stores.misses} "
          "misses)")

    # A budgeted request whose target the service has already reached is
    # answered from the warm-start cache without launching anything.
    best_seen = min(float(np.min(np.asarray(results[t].result.best_energy)))
                    for t in tickets[:3])
    cached = service.solve(shared, config,
                           budget=BudgetConfig(target_energy=best_seen + 50))
    print(f"cached target: stop_reason={cached.stop_reason} "
          f"energy={float(cached.result.best_energy[0]):.1f} "
          f"launches={service.stats['launches']}")

    print(f"stats: {service.stats}")


if __name__ == "__main__":
    main()
