"""The paper's technique as a framework feature: Ising-based MoE expert
placement (balanced graph partitioning, paper §II-A motivation).

1. Run a short training burst of the granite-moe smoke model and collect
   router co-activation statistics.
2. Build the expert traffic matrix (bytes exchanged if co-activated experts
   live on different devices).
3. Solve the balanced partition with Snowball's dual-mode solver (recursive
   bisection) and compare cross-device traffic vs the default round-robin
   placement that EP sharding would use.

    PYTHONPATH=src python examples/expert_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import placement
from repro.data import DataConfig, SyntheticLMData
from repro.models import forward, init_params, model_specs


def collect_router_stats(cfg, params, data, steps=4):
    """Mean expert load + sampled co-activation from forward passes."""
    probs = []
    for step in range(steps):
        batch = data.batch(step)
        out = forward(cfg, params, tokens=batch["tokens"])
        probs.append(np.asarray(out.expert_load))  # (n_moe_blocks, E)
    return np.concatenate(probs, axis=0)


def main():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    params = init_params(model_specs(cfg), jax.random.key(0))
    data = SyntheticLMData(cfg, DataConfig(seed=0, global_batch=4, seq_len=64))

    loads = collect_router_stats(cfg, params, data)
    # Traffic proxy: co-activation of experts weighted by their loads.
    C = placement.expert_traffic_matrix(loads)
    E = C.shape[0]
    D = 4  # devices along the EP axis

    round_robin = np.arange(E) % D
    rr_cut = placement.cut_bytes(C, round_robin)
    result = placement.place(C, num_devices=D, seed=0, steps=2000, replicas=8)

    print(f"experts={E} devices={D}")
    print(f"round-robin cross-device traffic : {rr_cut:10.4f}")
    print(f"snowball placement traffic       : {result.cut_bytes:10.4f} "
          f"({100 * (1 - result.cut_bytes / max(rr_cut, 1e-9)):.1f}% less)")
    print(f"load imbalance                   : {result.imbalance*100:.1f}%")
    print(f"assignment: {result.assignment.tolist()}")


if __name__ == "__main__":
    main()
