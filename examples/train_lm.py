"""End-to-end training driver: train an LM on the synthetic pipeline with
checkpoint/restart, cosine LR, grad clipping, and (optionally) 8-bit Adam.

Presets:
    tiny  (default) — ~8M params, 300 steps: finishes on this CPU container.
    100m            — ~100M-param qwen2-family config, few hundred steps: the
                      deployable driver for a real accelerator box.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 300
    PYTHONPATH=src python examples/train_lm.py --resume   # restart after kill
"""
import argparse

from repro.data import DataConfig
from repro.models.config import ModelConfig
from repro.train import TrainLoopConfig, train_loop

PRESETS = {
    "tiny": ModelConfig(
        name="tiny-lm", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=704, vocab_size=2048,
        norm="rmsnorm", activation="silu", gated_mlp=True,
        seq_chunk_q=64, seq_chunk_kv=64),
    "100m": ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
        norm="rmsnorm", activation="silu", gated_mlp=True, qkv_bias=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/snowball_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--state-dtype", choices=("float32", "bfloat16", "int8"),
                    default="float32")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}×{args.seq}")
    loop = TrainLoopConfig(
        steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir, num_microbatches=args.microbatches,
        log_every=10, base_lr=args.lr, warmup_steps=min(50, args.steps // 5),
        state_dtype=args.state_dtype, async_checkpoint=True)
    data = DataConfig(seed=0, global_batch=args.batch, seq_len=args.seq)
    state, history = train_loop(cfg, data, loop, resume=args.resume)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"done: loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
