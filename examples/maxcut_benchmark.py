"""Max-Cut benchmark walkthrough: Gset-family instance, all engines, TTS.

Compares the paper-faithful scan solver (RSA/RWA, PWL logistic), the exact-
sigmoid SA baseline ("Neal"), and the fused Pallas sweep backend, then
estimates TTS(0.99) from independent runs (paper Eq. 32).

    PYTHONPATH=src python examples/maxcut_benchmark.py
"""
import time

import numpy as np

from repro.configs.snowball import default_solver
from repro.core import tts
from repro.core.solver import SolverConfig, solve, solve_many
from repro.graphs import erdos_renyi, maxcut_to_ising
from repro.graphs.maxcut import cut_from_energy
from repro.kernels import fused_anneal


def main():
    inst = erdos_renyi(200, 4800, seed=6, name="G6-mini")  # G6 family, ÷4 scale
    problem = maxcut_to_ising(inst)
    steps, replicas = 5000, 8

    engines = {
        "neal (exact sigmoid RSA)": lambda: solve(
            problem, 0, SolverConfig(**{**default_solver(200, steps, "rsa", replicas).__dict__,
                                        "use_pwl": False})),
        "snowball RSA (pwl)": lambda: solve(
            problem, 0, default_solver(200, steps, "rsa", replicas)),
        "snowball RWA (pwl)": lambda: solve(
            problem, 0, default_solver(200, steps, "rwa", replicas)),
        "snowball RWA (fused kernel)": lambda: fused_anneal(
            problem, 0, default_solver(200, steps, "rwa", replicas)),
    }
    best_cut = {}
    for name, fn in engines.items():
        t0 = time.perf_counter()
        res = fn()
        res.best_energy.block_until_ready()
        dt = time.perf_counter() - t0
        cut = float(cut_from_energy(inst, float(np.min(np.asarray(res.best_energy)))))
        best_cut[name] = cut
        print(f"{name:32s} cut={cut:7.0f}  wall={dt:6.2f}s")

    # TTS(0.99): 16 independent RWA runs, threshold = 97% of best seen.
    cfg = default_solver(200, steps, "rwa", num_replicas=1)
    t0 = time.perf_counter()
    runs = solve_many(problem, np.arange(16), cfg)
    runs.best_energy.block_until_ready()
    per_run_ms = (time.perf_counter() - t0) / 16 * 1e3
    cuts = cut_from_energy(inst, np.asarray(runs.best_energy).reshape(-1))
    report = tts.estimate(-cuts, threshold=-0.97 * cuts.max(), time_per_run=per_run_ms)
    print(f"TTS(0.99) = {report.tts:.1f} ms  (P_a={report.success_probability:.2f}, "
          f"t_a={per_run_ms:.1f} ms, {report.num_runs} runs)")


if __name__ == "__main__":
    main()
