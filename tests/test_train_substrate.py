"""Training substrate: optimizer (incl. 8-bit states), data determinism,
checkpoint/restart, microbatching equivalence, loss decrease."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.data import DataConfig, SyntheticLMData
from repro.configs import get_config
from repro.models import init_params, model_specs
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import QTensor, _dequantize, _quantize, state_bytes
from repro.train import TrainLoopConfig, train_loop
from repro.train.step import init_train_state, lm_loss, make_train_step


def _tiny_cfg():
    return get_config("qwen2-7b", smoke=True)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    for shape in [(17,), (8, 300), (3, 5, 257)]:
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 3.0
        q = _quantize(x)
        back = _dequantize(q, shape)
        rel = float(jnp.max(jnp.abs(back - x))) / float(jnp.max(jnp.abs(x)))
        assert rel < 1.0 / 100  # 8-bit absmax: ≤ ~1/127 of block max


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_reduces_quadratic_loss(state_dtype):
    params = {"w": jnp.asarray([2.0, -3.0, 1.5]), "b": jnp.asarray(4.0)}
    cfg = AdamWConfig(learning_rate=0.05, weight_decay=0.0, state_dtype=state_dtype)
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_int8_states_are_4x_smaller():
    params = {"w": jnp.zeros((256, 1024), jnp.float32)}
    s32 = adamw_init(params, AdamWConfig(state_dtype="float32"))
    s8 = adamw_init(params, AdamWConfig(state_dtype="int8"))
    assert state_bytes(s8) < 0.3 * state_bytes(s32)


def test_grad_clipping_caps_update():
    params = {"w": jnp.asarray([0.0])}
    cfg = AdamWConfig(learning_rate=1.0, grad_clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    huge = {"w": jnp.asarray([1e6])}
    _, _, metrics = adamw_update(params, huge, state, cfg)
    assert float(metrics["clip_factor"]) == pytest.approx(1e-6, rel=1e-3)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_skip_ahead():
    cfg = _tiny_cfg()
    d = SyntheticLMData(cfg, DataConfig(seed=7, global_batch=4, seq_len=16))
    b1 = d.batch(10)
    b2 = d.batch(10)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = d.batch(11)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # host sharding covers the global batch disjointly
    shards = [d.host_shard(b1, i, 2) for i in range(2)]
    stacked = np.concatenate([np.asarray(s["tokens"]) for s in shards])
    np.testing.assert_array_equal(stacked, np.asarray(b1["tokens"]))


def test_encoder_data_has_masked_labels():
    cfg = get_config("hubert-xlarge", smoke=True)
    d = SyntheticLMData(cfg, DataConfig(seed=0, global_batch=2, seq_len=32))
    b = d.batch(0)
    labels = np.asarray(b["labels"])
    assert "embeddings" in b and b["embeddings"].shape == (2, 32, cfg.d_model)
    assert (labels == -1).any() and (labels >= 0).any()


# ---------------------------------------------------------------------------
# Train step & loop
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_microbatching_matches_full_batch_grads():
    cfg = _tiny_cfg()
    params = init_params(model_specs(cfg), jax.random.key(0))
    d = SyntheticLMData(cfg, DataConfig(seed=0, global_batch=4, seq_len=16))
    batch = d.batch(0)
    opt = AdamWConfig(learning_rate=0.0)  # lr=0: isolate grads via metrics

    def grads_of(num_mb):
        from repro.train.step import make_train_step
        state = init_train_state(cfg, params, opt)
        step = make_train_step(cfg, opt, num_microbatches=num_mb, donate=False)
        _, metrics = step(state, batch)
        return float(metrics["grad_norm"]), float(metrics["loss"])

    g1, l1 = grads_of(1)
    g4, l4 = grads_of(4)
    assert g1 == pytest.approx(g4, rel=3e-2)
    assert l1 == pytest.approx(l4, rel=3e-2)


def test_train_loop_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    loop = TrainLoopConfig(steps=30, checkpoint_every=1000, log_every=1,
                           base_lr=1e-2, warmup_steps=5)
    _, history = train_loop(cfg, DataConfig(seed=0, global_batch=4, seq_len=16),
                            loop, log_fn=lambda s: None)
    first = np.mean([h["loss"] for h in history[:3]])
    last = np.mean([h["loss"] for h in history[-3:]])
    assert last < first, f"loss did not decrease: {first} -> {last}"


@pytest.mark.slow
def test_checkpoint_restart_resumes_identically(tmp_path):
    """Simulated preemption: crash at step 12, resume, final state must equal
    an uninterrupted run bit-for-bit (deterministic data + stateless RNG)."""
    cfg = _tiny_cfg()
    data = DataConfig(seed=3, global_batch=4, seq_len=16)
    ckpt = str(tmp_path / "ckpt")
    loop = TrainLoopConfig(steps=20, checkpoint_every=5, checkpoint_dir=ckpt,
                           log_every=100, base_lr=1e-3)

    class Boom(RuntimeError):
        pass

    def bomb(step):
        if step == 12:
            raise Boom()

    with pytest.raises(Boom):
        train_loop(cfg, data, loop, failure_hook=bomb, log_fn=lambda s: None)
    assert latest_step(ckpt) == 10  # last atomic checkpoint before the crash

    resumed, _ = train_loop(cfg, data, loop, resume=True, log_fn=lambda s: None)
    clean, _ = train_loop(cfg, data, TrainLoopConfig(
        steps=20, checkpoint_every=1000, log_every=100, base_lr=1e-3),
        log_fn=lambda s: None)
    for a, b in zip(jax.tree.leaves(resumed.params), jax.tree.leaves(clean.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.latest() == 4
    assert sorted(os.listdir(tmp_path)) == ["step_3", "step_4"]
    restored, at = mgr.restore(tree)
    assert at == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_handles_qtensor_state(tmp_path):
    params = {"w": jnp.ones((4, 300))}
    opt = AdamWConfig(state_dtype="int8")
    state = adamw_init(params, opt)
    params2, state2, _ = adamw_update(params, {"w": jnp.ones((4, 300)) * 0.1},
                                      state, opt)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": params2, "opt": state2})
    restored, _ = mgr.restore({"params": params2, "opt": state2})
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves({"params": params2, "opt": state2})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
