"""Conflict-graph coloring invariants (``graphs.coloring``) — the exactness
preconditions of the colored execution mode.

The load-bearing property is *properness*: no edge may join two same-color
vertices, because the colored sweep flips a whole class at once and that is
exact block Gibbs only when class members share no coupling. The rest pins
the contract the solver plumbing relies on: determinism under edge
permutation (via ``EdgeList.create``'s canonical ordering), χ = 2 on
bipartite instances (torus/grid — the BFS pass, not greedy luck), graceful
collapse to singleton classes on dense cliques, and the perm/offsets layout
the kernel schedule is built from.
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.ising import EdgeList
from repro.graphs import torus_grid_edges
from repro.graphs.coloring import Coloring, greedy_coloring


def _er_edges(n: int, m: int, seed: int) -> EdgeList:
    rng = np.random.default_rng(seed)
    i = rng.integers(0, n, size=m)
    j = rng.integers(0, n, size=m)
    keep = i != j
    w = rng.choice([-2, -1, 1, 2], size=m)
    return EdgeList.create(i[keep], j[keep], w[keep], n)


def _assert_layout(col: Coloring):
    """perm/offsets/class_sizes are one consistent color-sorted layout."""
    n = col.num_spins
    assert sorted(col.perm.tolist()) == list(range(n))
    assert col.inverse_perm[col.perm].tolist() == list(range(n))
    assert col.offsets[0] == 0 and col.offsets[-1] == n
    assert (col.class_sizes > 0).all(), "every class is non-empty"
    assert col.max_class_size == col.class_sizes.max()
    for c in range(col.num_classes):
        members = col.perm[col.offsets[c]:col.offsets[c + 1]]
        assert (col.colors[members] == c).all()


@given(st.integers(min_value=2, max_value=40),
       st.integers(min_value=0, max_value=160),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_no_edge_joins_same_color_endpoints(n, m, seed):
    edges = _er_edges(n, m, seed)
    col = greedy_coloring(edges)
    col.validate_against(edges)  # raises on any monochromatic edge
    assert (col.colors[edges.rows] != col.colors[edges.cols]).all()
    _assert_layout(col)


@given(st.integers(min_value=3, max_value=30),
       st.integers(min_value=1, max_value=120),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_deterministic_under_edge_permutation(n, m, seed):
    """Feeding the same edge set in any order yields the identical coloring:
    ``EdgeList.create`` canonicalizes the COO order, and the pass consumes
    only the (permutation-invariant) adjacency structure."""
    edges = _er_edges(n, m, seed)
    rng = np.random.default_rng(seed + 1)
    p = rng.permutation(edges.rows.size)
    # Shuffle and also swap endpoint orientation on half the edges.
    flip = rng.random(edges.rows.size) < 0.5
    i = np.where(flip, edges.cols, edges.rows)[p]
    j = np.where(flip, edges.rows, edges.cols)[p]
    shuffled = EdgeList.create(i, j, edges.weights[p], n)
    assert shuffled == edges
    a, b = greedy_coloring(edges), greedy_coloring(shuffled)
    assert a == b  # content-hash identity
    np.testing.assert_array_equal(a.colors, b.colors)
    np.testing.assert_array_equal(a.perm, b.perm)
    np.testing.assert_array_equal(a.offsets, b.offsets)


@given(st.integers(min_value=2, max_value=8),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=20, deadline=None)
def test_even_torus_is_two_colored(half_rows, half_cols):
    """Even×even tori are bipartite; the BFS pass must find exactly the
    χ = 2 checkerboard (a greedy vertex order would not always)."""
    rows, cols = 2 * half_rows, 2 * half_cols
    edges = torus_grid_edges(rows, cols, seed=rows * 100 + cols)
    col = greedy_coloring(edges)
    assert col.num_classes == 2
    # The checkerboard split is exactly half/half.
    assert col.class_sizes.tolist() == [rows * cols // 2, rows * cols // 2]
    col.validate_against(edges)
    _assert_layout(col)


@given(st.integers(min_value=2, max_value=14))
@settings(max_examples=12, deadline=None)
def test_clique_degenerates_to_singletons(n):
    """A dense clique has χ = N: colored mode collapses gracefully to one
    flip of work per step (each class a single vertex)."""
    iu = np.triu_indices(n, 1)
    edges = EdgeList.create(iu[0], iu[1], np.ones(iu[0].size, np.int64), n)
    col = greedy_coloring(edges)
    assert col.num_classes == n
    assert col.class_sizes.tolist() == [1] * n
    assert col.max_class_size == 1
    _assert_layout(col)


def test_dense_source_matches_edge_list_source():
    edges = _er_edges(24, 60, seed=9)
    from_dense = greedy_coloring(np.asarray(edges.to_dense()))
    from_edges = greedy_coloring(edges)
    assert from_dense == from_edges


def test_memoized_per_edge_list_digest():
    edges = _er_edges(16, 30, seed=4)
    same_content = EdgeList.create(edges.rows, edges.cols, edges.weights, 16)
    assert greedy_coloring(edges) is greedy_coloring(same_content)


def test_odd_cycle_is_not_two_colored():
    n = 5  # C5: chromatic number 3
    i = np.arange(n)
    edges = EdgeList.create(i, (i + 1) % n, np.ones(n, np.int64), n)
    col = greedy_coloring(edges)
    assert col.num_classes == 3
    col.validate_against(edges)


def test_isolated_vertices_take_color_zero():
    edges = EdgeList.create([0], [1], [1], 5)
    col = greedy_coloring(edges)
    assert col.num_classes == 2
    assert (col.colors[2:] == 0).all()
    _assert_layout(col)


def test_num_spins_mismatch_raises():
    edges = _er_edges(8, 10, seed=0)
    with pytest.raises(ValueError, match="num_spins"):
        greedy_coloring(edges, num_spins=9)


def test_non_square_dense_source_raises():
    with pytest.raises(ValueError, match="square"):
        greedy_coloring(np.zeros((3, 4)))
