"""End-to-end behaviour tests: the paper's full pipeline (problem → anneal →
solution) and the framework's full pipeline (data → train → checkpoint →
serve) exercised through the public APIs only."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.snowball import GSET_TABLE1, K2000, default_solver
from repro.core import tts
from repro.core.solver import solve
from repro.data import DataConfig
from repro.graphs import complete_bipolar, erdos_renyi, maxcut_to_ising
from repro.graphs.maxcut import cut_from_energy, cut_value
from repro.kernels import fused_anneal
from repro.models import decode_step, init_decode_cache
from repro.train import TrainLoopConfig, train_loop


def test_snowball_end_to_end_maxcut():
    """Paper pipeline: K_N instance → dual-mode anneal → cut + TTS estimate."""
    inst = complete_bipolar(96, seed=7)
    problem = maxcut_to_ising(inst)
    cfg = default_solver(96, 3000, mode="rwa", num_replicas=8)
    res = solve(problem, 0, cfg)
    cuts = cut_from_energy(inst, np.asarray(res.best_energy))
    # Every replica's reported energy is consistent with its spins.
    for c, s in zip(cuts, np.asarray(res.best_spins)):
        assert cut_value(inst, s) == pytest.approx(float(c), abs=1e-2)
    report = tts.estimate(-cuts, threshold=-0.95 * cuts.max(), time_per_run=1.0)
    assert report.success_probability > 0
    # Beyond-paper engine agrees on quality on the same instance.
    fused = fused_anneal(problem, 0, cfg)
    fused_best = float(cut_from_energy(inst, float(jnp.min(fused.best_energy))))
    assert fused_best >= 0.93 * cuts.max()


def test_benchmark_instance_catalogue_matches_table1():
    names = {b.name: b for b in GSET_TABLE1}
    assert names["G6"].num_edges == 19176 and names["G6"].num_vertices == 800
    assert names["G62"].topology == "torus"
    assert K2000.num_edges == 2000 * 1999 // 2
    assert K2000.target_cut == 33000.0


@pytest.mark.slow
def test_lm_train_then_serve_roundtrip(tmp_path):
    """Framework pipeline: train a smoke model with checkpointing, restore,
    then decode from the trained weights."""
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    loop = TrainLoopConfig(steps=8, checkpoint_every=8, log_every=100,
                           checkpoint_dir=str(tmp_path), base_lr=1e-3)
    state, history = train_loop(cfg, DataConfig(seed=0, global_batch=2, seq_len=32),
                                loop, log_fn=lambda s: None)
    assert np.isfinite(history[-1]["loss"])
    cache = init_decode_cache(cfg, batch=2, max_len=8)
    toks = jnp.zeros((2, 1), jnp.int32)
    for t in range(4):
        logits, cache = decode_step(cfg, state.params, cache, jnp.int32(t), tokens=toks)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
