"""Bit-plane codec + Hamming-weight local-field math (paper §IV-B1, Eq. 13-16)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips only @given tests when absent

from repro.core import bitplane, ising


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 40), st.integers(1, 12))
def test_encode_decode_roundtrip(seed, n, num_planes):
    rng = np.random.default_rng(seed)
    limit = (1 << num_planes) - 1
    J = rng.integers(-limit, limit + 1, size=(n, n)).astype(np.int64)
    J = np.triu(J, 1)
    J = J + J.T
    planes = bitplane.encode_couplings(J, num_planes)
    back = bitplane.decode_couplings(planes)
    np.testing.assert_array_equal(back, J)


def test_encode_rejects_overflow():
    J = np.zeros((4, 4))
    J[0, 1] = J[1, 0] = 4  # needs 3 planes
    with pytest.raises(ValueError, match="planes"):
        bitplane.encode_couplings(J, 2)
    with pytest.raises(ValueError, match="integer"):
        bitplane.encode_couplings(J * 0.3, 8)


def test_encode_rejects_non_finite_naming_entry():
    J = np.zeros((4, 4))
    J[0, 2] = J[2, 0] = np.inf
    with pytest.raises(ValueError, match=r"finite couplings: J\[0, 2\]"):
        bitplane.encode_couplings(J, 3)
    J[0, 2] = J[2, 0] = np.nan
    with pytest.raises(ValueError, match=r"J\[0, 2\] = nan"):
        bitplane.encode_couplings(J, 3)


def test_encode_overflow_names_offending_entry():
    J = np.zeros((4, 4))
    J[1, 3] = J[3, 1] = 9  # needs 4 planes
    with pytest.raises(ValueError, match=r"J\[1, 3\] = 9"):
        bitplane.encode_couplings(J, 3)


def test_edge_plane_words_overflow_names_offending_edge():
    from repro.core import ising
    edges = ising.EdgeList.create([0, 1], [1, 2], [1, 9], 4)
    with pytest.raises(ValueError, match=r"\(1, 2\) with weight 9"):
        bitplane.edge_plane_words(edges, 3)


def test_encode_rejects_asymmetric():
    """BitPlanes rows double as columns in the incremental update, so an
    asymmetric J must be refused at encode time — not silently produce wrong
    u updates downstream."""
    J = np.zeros((4, 4))
    J[0, 1] = 2  # J[1, 0] left at 0
    with pytest.raises(ValueError, match="symmetric"):
        bitplane.encode_couplings(J, 3)
    with pytest.raises(ValueError, match="square"):
        bitplane.encode_couplings(np.zeros((3, 4)), 3)


def test_encode_warns_on_nonzero_diagonal():
    J = np.eye(4) * 2
    with pytest.warns(UserWarning, match="diagonal"):
        planes = bitplane.encode_couplings(J, 3)
    np.testing.assert_array_equal(bitplane.decode_couplings(planes), J)


@pytest.mark.parametrize("dtype", [np.int8, np.int32, np.float32, np.float64,
                                   jnp.bfloat16])
def test_pack_spins_dtype_roundtrip(dtype):
    """The `spins > 0` bit derivation is exact for every spin dtype in use
    (float floor-division semantics must never leak into the packing)."""
    g = np.random.default_rng(7)
    s = np.where(g.random(70) < 0.5, 1, -1)
    packed = np.asarray(bitplane.pack_spins(jnp.asarray(s).astype(dtype)))
    assert packed.dtype == np.uint32 and packed.shape == (3,)
    bits = (packed[np.arange(70) // 32] >> (np.arange(70) % 32)) & 1
    np.testing.assert_array_equal(bits, (s + 1) // 2)


def test_pack_spins_bits():
    s = np.array([1, -1, 1, 1] + [-1] * 60 + [1, 1], np.int8)  # 66 spins -> 3 words
    packed = np.asarray(bitplane.pack_spins(jnp.asarray(s)))
    assert packed.shape == (3,)
    x = (s + 1) // 2
    for j, bit in enumerate(x):
        assert (packed[j // 32] >> (j % 32)) & 1 == bit


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 70), st.integers(1, 8))
def test_hamming_weight_local_fields_match_dense(seed, n, num_planes):
    """Eq. 14-16: popcount accumulation == dense J @ s."""
    rng = np.random.default_rng(seed)
    limit = (1 << num_planes) - 1
    J = rng.integers(-limit, limit + 1, size=(n, n)).astype(np.int64)
    J = np.triu(J, 1)
    J = J + J.T
    planes = bitplane.encode_couplings(J, num_planes)
    s = np.where(rng.random(n) < 0.5, 1, -1).astype(np.int8)
    u = np.asarray(bitplane.local_fields_from_planes(planes, jnp.asarray(s)))
    ref = J.astype(np.float64) @ s
    np.testing.assert_allclose(u, ref, rtol=0, atol=1e-3)


def test_local_fields_batched_replicas():
    rng = np.random.default_rng(0)
    n, r = 48, 5
    J = rng.integers(-3, 4, size=(n, n))
    J = np.triu(J, 1)
    J = J + J.T
    planes = bitplane.encode_couplings(J, 3)
    s = np.where(rng.random((r, n)) < 0.5, 1, -1).astype(np.int8)
    u = np.asarray(bitplane.local_fields_from_planes(planes, jnp.asarray(s)))
    assert u.shape == (r, n)
    np.testing.assert_allclose(u, s.astype(np.float64) @ J.T, atol=1e-3)


def test_encode_align_words_pads_invisibly():
    """Tile alignment for the HBM-streamed row DMAs: ``align_words`` rounds W
    up with zero bits, and every consumer — decode round-trip, Hamming-weight
    local fields, word-count bookkeeping — is padding-blind."""
    rng = np.random.default_rng(3)
    n, b = 70, 3  # ceil(70/32) = 3 words -> padded to 128
    J = rng.integers(-7, 8, size=(n, n)).astype(np.int64)
    J = np.triu(J, 1)
    J = J + J.T
    plain = bitplane.encode_couplings(J, b)
    padded = bitplane.encode_couplings(J, b, align_words=128)
    assert plain.num_words == 3 and padded.num_words == 128
    assert padded.pos.shape == (b, n, 128)
    np.testing.assert_array_equal(bitplane.decode_couplings(padded), J)
    np.testing.assert_array_equal(np.asarray(padded.pos[..., :3]),
                                  np.asarray(plain.pos))
    assert not np.asarray(padded.pos[..., 3:]).any()
    s = np.where(rng.random(n) < 0.5, 1, -1).astype(np.int8)
    np.testing.assert_array_equal(
        np.asarray(bitplane.local_fields_from_planes(padded, jnp.asarray(s))),
        np.asarray(bitplane.local_fields_from_planes(plain, jnp.asarray(s))))


def test_pack_spins_num_words_pads_with_zero_words():
    s = np.where(np.random.default_rng(1).random(70) < 0.5, 1, -1)
    base = np.asarray(bitplane.pack_spins(jnp.asarray(s)))
    padded = np.asarray(bitplane.pack_spins(jnp.asarray(s), num_words=8))
    assert padded.shape == (8,)
    np.testing.assert_array_equal(padded[:3], base)
    assert not padded[3:].any()
    with pytest.raises(ValueError, match="num_words"):
        bitplane.pack_spins(jnp.asarray(s), num_words=2)


def test_encode_rejects_bad_alignment():
    with pytest.raises(ValueError, match="align_words"):
        bitplane.encode_couplings(np.zeros((4, 4)), 2, align_words=0)


def test_memory_scales_linearly_in_planes():
    """Paper's scalability claim: bytes grow linearly with precision B."""
    J = np.zeros((64, 64))
    sizes = [bitplane.encode_couplings(J, b).nbytes for b in (1, 2, 4, 8)]
    assert sizes[1] == 2 * sizes[0] and sizes[3] == 8 * sizes[0]
