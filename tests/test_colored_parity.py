"""Colored-sweep parity: the graph-colored Pallas kernel is trajectory-exact
against its jnp oracle (``kernels.ref.colored_sweep``) on every coupling tier,
and the colored driver's results are independent of the single-flip selection
knobs (mode/uniformized) — class membership replaces spin selection, so those
knobs must not enter colored semantics at all. This is the exactness anchor
of DESIGN.md §Graph-colored parallel flips: colored trajectories deliberately
diverge from the single-flip oracle, so correctness is kernel-vs-colored-
oracle parity here plus the Boltzmann-law check in
``test_statistical_correctness.py`` (-m slow)."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ising
from repro.core.coupling import CouplingStore
from repro.core.pwl import pwl_table
from repro.core.schedules import geometric, linear
from repro.core.solver import SolverConfig, solve
from repro.graphs import sparse_bipolar_edges, torus_grid_edges
from repro.graphs.coloring import greedy_coloring
from repro.kernels import ops, ref
from repro.kernels.sweep import colored_sweep as colored_kernel

NAMES = ("fields", "spins", "energy", "best_energy", "best_spins",
         "num_flips", "rows_fetched")


def _plan_and_state(edges, r, t, seed, fmt):
    """Permuted plan + a consistent (u0, s0, e0) ensemble + chunk operands."""
    n = edges.num_spins
    h = np.round(np.linspace(-2, 2, n)).astype(np.float32)
    prob = ising.IsingProblem.create_sparse(edges, h=h)
    plan = ops.ColoredPlan(greedy_coloring(edges), prob, fmt)
    g = np.random.default_rng(seed)
    J = np.asarray(plan.problem.edges.to_dense())
    s0 = np.where(g.random((r, n)) < 0.5, 1.0, -1.0).astype(np.float32)
    hp = np.asarray(plan.problem.fields)
    u0 = (s0 @ J.T + hp[None, :]).astype(np.float32)
    e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)
          - s0 @ hp).astype(np.float32)
    unif = g.random((t, r, plan.window)).astype(np.float32)
    temps = np.broadcast_to(
        np.geomspace(2.5, 0.05, t).astype(np.float32)[:, None], (t, r)).copy()
    sched = np.asarray(ops.colored_class_schedule(
        plan.wstarts, plan.offsets, plan.sizes, jnp.arange(t)))
    return plan, tuple(map(jnp.asarray, (u0, s0, e0, unif, temps, sched)))


EDGE_SETS = {
    # Bipartite torus: χ=2, lane-aligned class offsets (the fast path).
    "torus": lambda: torus_grid_edges(8, 8, seed=5),
    # Non-bipartite ER: greedy χ>2 with ragged, non-lane-aligned offsets —
    # exercises the window clamp and the validity mask.
    "er": lambda: sparse_bipolar_edges(96, 400, seed=11),
}


@pytest.mark.parametrize("coupling", ["dense", "bitplane", "bitplane_hbm"])
@pytest.mark.parametrize("graph", sorted(EDGE_SETS))
@pytest.mark.parametrize("use_pwl", [False, True])
def test_colored_kernel_matches_oracle_exactly(coupling, graph, use_pwl):
    edges = EDGE_SETS[graph]()
    fmt = "bitplane" if coupling == "dense" else coupling
    plan, (u0, s0, e0, unif, temps, sched) = _plan_and_state(
        edges, r=8, t=24, seed=3, fmt=fmt)
    tbl = pwl_table() if use_pwl else None
    if coupling == "dense":
        operand = jnp.asarray(plan.problem.edges.to_dense())
        oracle_operand = operand
    else:
        operand = CouplingStore.build(plan.problem.edges,
                                      coupling).kernel_operand
        oracle_operand = operand
    got = colored_kernel(operand, u0, s0, e0, unif, temps, sched, tbl,
                         coupling=coupling, block_r=4, interpret=True)
    want = ref.colored_sweep(oracle_operand, u0, s0, e0, unif, temps, sched,
                             tbl, block_r=4)
    for name, a, b in zip(NAMES, got, want):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            err_msg=f"{coupling}/{graph}/pwl={use_pwl}:{name}")
    # Flips per step are bounded by the scheduled class's size, and the
    # coalesced row count never exceeds total flips (one fetch serves all
    # replicas accepting a member).
    nf, rf = np.asarray(got[5]), np.asarray(got[6])
    assert (rf <= nf).all() or nf.sum() == 0
    assert nf.max() <= int(np.asarray(sched)[:, 2].sum())


def test_colored_kernel_zero_temperature_is_monotone():
    """T=0 colored steps are greedy (flip iff ΔE < 0 … with the flat-move
    coin): chain energy must never increase, and kernel == oracle."""
    edges = EDGE_SETS["torus"]()
    plan, (u0, s0, e0, unif, temps, sched) = _plan_and_state(
        edges, r=4, t=16, seed=9, fmt="bitplane")
    temps = jnp.zeros_like(temps)
    operand = CouplingStore.build(plan.problem.edges,
                                  "bitplane").kernel_operand
    got = colored_kernel(operand, u0, s0, e0, unif, temps, sched,
                         coupling="bitplane", block_r=4, interpret=True)
    want = ref.colored_sweep(operand, u0, s0, e0, unif, temps, sched,
                             block_r=4)
    for name, a, b in zip(NAMES, got, want):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32), err_msg=name)
    assert (np.asarray(got[2]) <= np.asarray(e0) + 1e-4).all()


def test_colored_kernel_warm_start_parity():
    """State threaded through consecutive chunks (the driver's scan shape)
    stays trajectory-exact — including the carried best-so-far."""
    edges = EDGE_SETS["er"]()
    plan, (u0, s0, e0, unif, temps, sched) = _plan_and_state(
        edges, r=8, t=12, seed=1, fmt="bitplane_hbm")
    operand = plan.store.kernel_operand
    ks, os_ = (u0, s0, e0), (u0, s0, e0)
    for c in range(3):
        un = jnp.asarray(
            np.random.default_rng(50 + c).random(unif.shape), jnp.float32)
        got = colored_kernel(operand, *ks, un, temps, sched,
                             coupling="bitplane_hbm", block_r=4,
                             interpret=True)
        want = ref.colored_sweep(operand, *os_, un, temps, sched, block_r=4)
        ks, os_ = got[:3], want[:3]
    for name, a, b in zip(NAMES, got, want):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32), err_msg=name)


@pytest.mark.parametrize("mode,uniformized", [
    ("rsa", False), ("rwa", False), ("rwa", True)])
def test_colored_driver_is_mode_independent(mode, uniformized):
    """Acceptance criterion: colored results are bit-identical across
    rsa/rwa/uniformized — the selection knobs don't enter colored semantics
    (the kernel takes no mode argument), so any knob combination must yield
    the same trajectory as the rwa baseline."""
    edges = torus_grid_edges(6, 8, seed=2)
    prob = ising.IsingProblem.create_sparse(edges)
    base = SolverConfig(240, linear(3.0, 0.1, 240), mode="rwa",
                        num_replicas=4, trace_every=40, flip_mode="colored")
    cfg = dataclasses.replace(base, mode=mode, uniformized=uniformized)
    want = solve(prob, 11, base, backend="colored")
    got = solve(prob, 11, cfg, backend="colored")
    for name in ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy"):
        np.testing.assert_array_equal(np.asarray(getattr(want, name)),
                                      np.asarray(getattr(got, name)),
                                      err_msg=f"{mode}/{uniformized}:{name}")


def test_colored_driver_bookkeeping_and_tiers():
    """End-to-end colored_anneal: reported best energies match the spins
    they claim (on the ORIGINAL problem — the color permutation must
    round-trip), the trace is monotone, and the VMEM/HBM plane tiers agree
    bit-identically (the store is a layout choice, never a chain change)."""
    edges = sparse_bipolar_edges(128, 512, seed=7)
    prob = ising.IsingProblem.create_sparse(edges, offset=2.5)
    cfg = SolverConfig(600, geometric(4.0, 0.05, 600), num_replicas=4,
                       trace_every=100, flip_mode="colored",
                       coupling_format="bitplane")
    res = ops.colored_anneal(prob, 3, cfg)
    recomputed = np.asarray(ising.energy(
        ising.IsingProblem.create(jnp.asarray(edges.to_dense())),
        res.best_spins)) + 2.5  # ising.energy excludes the constant offset
    np.testing.assert_allclose(np.asarray(res.best_energy), recomputed,
                               atol=1e-2)
    trace = np.asarray(res.trace_energy)
    assert trace.shape == (6, 4) and np.isfinite(trace).all()
    assert (np.diff(trace, axis=0) <= 1e-6).all()
    assert (np.asarray(res.num_flips) > 0).all()
    assert (np.asarray(res.rows_fetched) >= 0).all()
    hbm = ops.colored_anneal(prob, 3, dataclasses.replace(
        cfg, coupling_format="bitplane_hbm"))
    for name in ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy", "rows_fetched"):
        np.testing.assert_array_equal(np.asarray(getattr(res, name)),
                                      np.asarray(getattr(hbm, name)),
                                      err_msg=name)


def test_colored_routing_guards():
    """Colored configs reaching single-flip paths fail loudly, and vice
    versa — no silent mode mismatch anywhere in the dispatch surface."""
    from repro.core.tempering import TemperingConfig, solve_tempering

    edges = torus_grid_edges(4, 4, seed=0)
    prob = ising.IsingProblem.create_sparse(edges)
    dense_prob = ising.IsingProblem.create(jnp.asarray(edges.to_dense()))
    colored = SolverConfig(16, linear(1.0, 0.1, 16), num_replicas=2,
                           flip_mode="colored")
    single = dataclasses.replace(colored, flip_mode="single")
    with pytest.raises(ValueError, match="colored"):
        ops.fused_anneal(prob, 0, colored)
    with pytest.raises(ValueError, match="colored"):
        solve(dense_prob, 0, colored, backend="reference")
    with pytest.raises(ValueError, match="flip_mode"):
        ops.colored_anneal(prob, 0, single)
    with pytest.raises(ValueError, match="colored"):
        solve(prob, 0, single, backend="colored")
    with pytest.raises(ValueError, match="single-flip"):
        solve_tempering(dense_prob, 0, TemperingConfig(
            num_steps=16, t_min=0.1, t_max=1.0, num_replicas=2,
            flip_mode="colored"))
    # A prebuilt store is original-order; the colored backend must refuse it.
    store = CouplingStore.build(edges, "bitplane")
    with pytest.raises(ValueError, match="color-sorted"):
        solve(prob, 0, colored, backend="colored", store=store)


def test_colored_plan_reuse_matches_fresh_build():
    edges = torus_grid_edges(6, 6, seed=4)
    prob = ising.IsingProblem.create_sparse(edges)
    cfg = SolverConfig(120, linear(2.0, 0.1, 120), num_replicas=2,
                       flip_mode="colored")
    plan = ops.colored_plan(prob, "bitplane")
    a = ops.colored_anneal(prob, 5, cfg, plan=plan)
    b = ops.colored_anneal(prob, 5, cfg, coupling="bitplane")
    for name in ("best_energy", "best_spins", "num_flips"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)
