"""Pallas flash-attention kernel vs the jnp chunked-attention oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import chunked_attention

# jax 0.4.x's Pallas interpreter cannot discharge this kernel's masked loads
# (`_load_discharge_rule` hits an AttributeError on integer indexers) — broken
# since the repo seed, on every test in this module. Keyed on the jax version
# so an upgrade that fixes the interpreter turns these back into real tests
# (strict=False: an xpass is reported, not failed) while keeping tier-1 green
# and real regressions visible today.
_JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:2])
pytestmark = pytest.mark.xfail(
    condition=_JAX_VERSION < (0, 5),
    reason="pallas interpret-mode _load_discharge_rule AttributeError on "
           f"jax {jax.__version__} (pre-existing since seed)",
    strict=False,
)


def _qkv(seed, b, hq, hkv, s, d, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
    (2, 6, 2, 256, 64, 64, 64),
    (1, 4, 4, 128, 32, 32, 64),   # MHA
    (2, 8, 1, 128, 64, 64, 32),   # MQA
    (1, 2, 2, 192, 16, 64, 64),   # non-power-of-two seq
])
def test_flash_matches_oracle(causal, b, hq, hkv, s, d, bq, bk):
    q, k, v = _qkv(b + s, b, hq, hkv, s, d)
    got = flash_attention(q, k, v, causal, 1.0 / d**0.5, bq, bk, True)
    want = chunked_attention(q, k, v, causal=causal, q_chunk=bq, kv_chunk=bk,
                             scale=1.0 / d**0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_dtypes(dtype):
    q, k, v = _qkv(0, 2, 4, 2, 128, 64, dtype)
    got = flash_attention(q, k, v, True, 0.125, 64, 64, True)
    want = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64,
                             scale=0.125)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_gradients_match_oracle():
    q, k, v = _qkv(3, 1, 4, 2, 128, 32)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 0.2, 64, 64, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(chunked_attention(q, k, v, causal=True, q_chunk=64,
                                         kv_chunk=64, scale=0.2) ** 2)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_model_forward_with_flash_matches_chunked():
    import dataclasses
    from repro.configs import get_config
    from repro.models import forward, init_params, model_specs

    cfg = get_config("qwen2-7b", smoke=True)
    cfg_flash = dataclasses.replace(cfg, attn_impl="flash")
    params = init_params(model_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    a = forward(cfg, params, tokens=toks).logits.astype(jnp.float32)
    b = forward(cfg_flash, params, tokens=toks).logits.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-2)
