"""Spin-sharded coupling tiers (`bitplane_sharded` / `_2d`): six-way parity.

The row-sharded plane store is a memory-*placement* choice, never a chain
change: `solve_sharded` on a D-device mesh must return bit-identical
`SolveResult`s to `solve(backend="fused")` under every single-device coupling
tier — dense, VMEM bit-planes, and HBM-streamed planes — on the same
seed/config; and the 2-D (replica groups × rows) mesh must match them all
again (dense == bitplane == bitplane_hbm == bitplane_sharded ==
sharded-from-edges == sharded_2d), including a chunked+checkpointed
`run_resilient` drive of the 2-D path. The multi-device cases run in a
forced-device-count subprocess (via the shared conftest harness, which also
pre-builds 2-D meshes from a `mesh_shape`) so the parity tier runs in tier-1
on this CPU box rather than only on real pods; the D=1 mesh cases run
in-process.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising
from repro.core.schedules import geometric
from repro.core.solver import SolverConfig, solve
from repro.distributed.solver_sharded import solve_sharded

RESULT_FIELDS = ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy")


def _int_problem(seed, n, amax=3):
    g = np.random.default_rng(seed)
    J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -amax, amax)
    J = np.triu(J, 1)
    return ising.IsingProblem.create(J=J + J.T)


def test_six_way_coupling_parity_on_2x2_mesh(forced_device_mesh):
    """dense == bitplane == bitplane_hbm == bitplane_sharded (1-D, D=4) ==
    sharded-from-edges == sharded_2d (2×2 groups×rows), exactly, across
    RWA / uniformized-RWA / RSA — the acceptance gate of both sharded
    tiers. The 2-D cell also replays chunked + checkpointed through
    ``run_resilient(backend="sharded_2d")`` bit-identically. Runs every
    config in one subprocess to amortize the jax start; the conftest
    harness pre-builds the 2×2 ``mesh``."""
    out = forced_device_mesh("""
        import dataclasses, tempfile
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import ising
        from repro.core.ising import EdgeList
        from repro.core.schedules import geometric
        from repro.core.solver import SolverConfig, solve
        from repro.core.resilience import run_resilient
        from repro.distributed.solver_sharded import solve_sharded

        assert jax.device_count() == 4
        mesh_2d = mesh                      # (groups=2, rows=2) from conftest
        assert tuple(mesh_2d.axis_names) == ("groups", "rows")
        mesh_1d = Mesh(np.array(jax.devices()), ("spins",))
        n = 512
        g = np.random.default_rng(11)
        J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
        J = np.triu(J, 1)
        J = J + J.T
        prob = ising.IsingProblem.create(J=J)
        # The same instance ingested dense-J-free: the sharded solve builds
        # per-device plane slabs straight from the O(nnz) edges and inits
        # u0/e0 plane-natively on the shard — trajectories must STILL be
        # bit-identical to every dense-ingested tier.
        prob_edges = ising.IsingProblem.create_sparse(EdgeList.from_dense(J))
        fields = ("best_energy", "best_spins", "final_energy", "num_flips",
                  "trace_energy")
        for mode, uniformized in (("rwa", False), ("rwa", True), ("rsa", False)):
            cfg = SolverConfig(num_steps=96, schedule=geometric(4.0, 0.05, 96),
                               mode=mode, uniformized=uniformized,
                               num_replicas=4, trace_every=24)
            results = {fmt: solve(prob, 5,
                                  dataclasses.replace(cfg, coupling_format=fmt),
                                  backend="fused")
                       for fmt in ("dense", "bitplane", "bitplane_hbm")}
            results["bitplane_sharded"] = solve_sharded(prob, 5, cfg, mesh_1d)
            results["bitplane_sharded_edges"] = solve_sharded(
                prob_edges, 5, cfg, mesh_1d)
            results["bitplane_sharded_2d"] = solve_sharded(prob, 5, cfg,
                                                           mesh_2d)
            base = results["dense"]
            for fmt in ("bitplane", "bitplane_hbm", "bitplane_sharded",
                        "bitplane_sharded_edges", "bitplane_sharded_2d"):
                for name in fields:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(base, name)),
                        np.asarray(getattr(results[fmt], name)),
                        err_msg=f"{mode}/u{uniformized}/{fmt}:{name}")
            print("PARITY", mode, uniformized,
                  float(jnp.min(results["bitplane_sharded_2d"].best_energy)))
        # Chunked + checkpointed resilient drive of the 2-D path: the same
        # trajectory, bit for bit, through run_resilient's snapshot loop.
        cfg = SolverConfig(num_steps=96, schedule=geometric(4.0, 0.05, 96),
                           mode="rwa", num_replicas=4, trace_every=24)
        with tempfile.TemporaryDirectory() as run_dir:
            res = run_resilient(prob, 5, cfg, run_dir, backend="sharded_2d",
                                mesh=mesh_2d, chunk_steps=24)
        mono = solve_sharded(prob, 5, cfg, mesh_2d)
        for name in fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(mono, name)),
                np.asarray(getattr(res.result, name)),
                err_msg=f"resilient:{name}")
        print("SIX-WAY OK")
    """, mesh_shape=(2, 2))
    assert "SIX-WAY OK" in out


def test_sharded_step_emits_collectives_but_no_dot_general(forced_device_mesh):
    """The jaxpr pin, extended across the mesh: the sharded *step*
    (``sharded_sweep_fn`` — the per-step engine without the one-time init)
    must move data with collectives (psum row-tile broadcast + all_gather'd
    block sums) and must not reintroduce any quadratic contraction — the
    O(N)/step incremental-update contract survives sharding. The full anneal
    additionally runs the plane-native sharded init, whose one-time O(R·N)
    e₀ einsum is allowed — the pin separates the two surfaces."""
    out = forced_device_mesh("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.coupling import CouplingStore
        from repro.core.schedules import geometric
        from repro.core.solver import SolverConfig
        from repro.distributed.solver_sharded import (sharded_anneal_fn,
                                                      sharded_sweep_fn)

        assert jax.device_count() == 2
        n, r, steps = 512, 4, 6
        g = np.random.default_rng(3)
        J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
        J = np.triu(J, 1)
        store = CouplingStore.build(J + J.T, "bitplane_sharded")
        cfg = SolverConfig(num_steps=48, schedule=geometric(4.0, 0.05, 48),
                           mode="rwa", num_replicas=r, trace_every=24)
        mesh = Mesh(np.array(jax.devices()), ("spins",))
        step = sharded_sweep_fn(cfg, mesh, n)
        txt = str(jax.make_jaxpr(step)(
            store.planes, jnp.zeros((r, n), jnp.float32),
            jnp.ones((r, n), jnp.float32), jnp.zeros((r,), jnp.float32),
            jnp.zeros((steps, r, 4), jnp.float32),
            jnp.ones((steps, r), jnp.float32)))
        assert "psum" in txt, "row broadcast / lane combine must psum"
        assert "all_gather" in txt, "block sums must all_gather"
        assert "dot_general" not in txt, "no quadratic contraction in the step"
        # The full anneal (init inside) still moves data collectively.
        fn = sharded_anneal_fn(cfg, mesh, n)
        txt = str(jax.make_jaxpr(fn)(
            store.planes, jnp.zeros((n,), jnp.float32),
            jnp.zeros((1,), jnp.uint32)))
        assert "psum" in txt and "all_gather" in txt
        print("JAXPR PIN OK")
    """, n_devices=2)
    assert "JAXPR PIN OK" in out


def test_sharded_2d_step_collectives_are_group_scoped(forced_device_mesh):
    """The 2-D jaxpr pin: on a (groups, rows) mesh every hot-path collective
    in the *step* (``sharded_sweep_fn``) must be scoped to the group's rows
    sub-axis — ``psum`` / ``all_gather`` name ``'rows'`` and never
    ``'groups'`` (no cross-group traffic per step; groups touch the grid
    only at init and result gather) — and no ``dot_general`` may appear on
    either mesh axis."""
    out = forced_device_mesh("""
        import re
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.coupling import CouplingStore
        from repro.core.schedules import geometric
        from repro.core.solver import SolverConfig
        from repro.distributed.solver_sharded import (sharded_anneal_fn,
                                                      sharded_sweep_fn)

        n, r, steps = 512, 4, 6
        g = np.random.default_rng(3)
        J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
        J = np.triu(J, 1)
        store = CouplingStore.build(J + J.T, "bitplane_sharded_2d")
        cfg = SolverConfig(num_steps=48, schedule=geometric(4.0, 0.05, 48),
                           mode="rwa", num_replicas=r, trace_every=24)
        step = sharded_sweep_fn(cfg, mesh, n)
        txt = str(jax.make_jaxpr(step)(
            store.planes, jnp.zeros((r, n), jnp.float32),
            jnp.ones((r, n), jnp.float32), jnp.zeros((r,), jnp.float32),
            jnp.zeros((steps, r, 4), jnp.float32),
            jnp.ones((steps, r), jnp.float32)))
        # Match the quoted axis names inside each collective's params —
        # 'groups' the axis, not the axis_index_groups=None param name.
        colls = re.findall(r"(?:psum|all_gather)\\[[^\\]]*\\]", txt)
        assert colls, "the 2-D step must move data with collectives"
        for c in colls:
            assert "'rows'" in c, f"collective not rows-scoped: {c}"
            assert "'groups'" not in c, f"cross-group collective on hot path: {c}"
        assert "dot_general" not in txt, "no quadratic contraction in the step"
        # The full 2-D anneal (init inside) is group-scoped on the hot
        # path too — its only 'groups' use is the axis_index that places
        # each group's replica block, never a collective.
        fn = sharded_anneal_fn(cfg, mesh, n)
        txt = str(jax.make_jaxpr(fn)(
            store.planes, jnp.zeros((n,), jnp.float32),
            jnp.zeros((1,), jnp.uint32)))
        colls = re.findall(r"(?:psum|all_gather)\\[[^\\]]*\\]", txt)
        assert colls and all("'groups'" not in c for c in colls)
        print("JAXPR 2D PIN OK")
    """, mesh_shape=(2, 2))
    assert "JAXPR 2D PIN OK" in out


def test_sharded_matches_fused_on_single_device_mesh():
    """D=1 degenerate mesh in-process: the collective path with trivial
    combines must still be trajectory-exact vs the fused driver (fast
    default-tier coverage that needs no subprocess)."""
    prob = _int_problem(11, 128)
    cfg = SolverConfig(num_steps=96, schedule=geometric(4.0, 0.05, 96),
                       mode="rwa", num_replicas=4, trace_every=24)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
    sharded = solve_sharded(prob, 5, cfg, mesh)
    fused = solve(prob, 5, dataclasses.replace(cfg, coupling_format="bitplane"),
                  backend="fused")
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(fused, name)),
                                      np.asarray(getattr(sharded, name)),
                                      err_msg=name)
    # Energy bookkeeping stays exact through the collectives.
    recomputed = np.asarray(ising.energy(prob, sharded.best_spins))
    np.testing.assert_allclose(np.asarray(sharded.best_energy), recomputed,
                               atol=1e-2)


def test_sharded_prepacked_planes_match_rebuild():
    """The benchmark path: pre-packed tile-aligned planes passed as
    ``coupling=`` skip the re-encode without changing the trajectory."""
    from repro.core.coupling import CouplingStore, encode_planes
    from jax.sharding import Mesh

    prob = _int_problem(7, 128)
    cfg = SolverConfig(num_steps=64, schedule=geometric(4.0, 0.1, 64),
                       mode="rsa", num_replicas=4, trace_every=0,
                       coupling_format="bitplane_sharded")
    mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
    planes = encode_planes(prob.couplings, fmt="bitplane_sharded")
    assert planes.num_words % 128 == 0  # tile-aligned like the HBM tier
    via_planes = solve_sharded(prob, 2, cfg, mesh, coupling=planes)
    rebuilt = solve_sharded(prob, 2, cfg, mesh)
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(rebuilt, name)),
                                      np.asarray(getattr(via_planes, name)),
                                      err_msg=name)
    # Per-shard accounting: row-sharding divides the plane bytes evenly.
    store = CouplingStore.from_planes(planes, "bitplane_sharded")
    assert store.plane_bytes_per_shard(2) * 2 == planes.nbytes


def test_sharded_driver_validates_inputs():
    from jax.sharding import Mesh

    prob = _int_problem(3, 128)
    mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
    cfg = SolverConfig(num_steps=8, schedule=geometric(1.0, 0.1, 8),
                       num_replicas=2)
    # A single-device format on the sharded driver is a config error ...
    with pytest.raises(ValueError, match="bitplane_sharded"):
        solve_sharded(prob, 0, dataclasses.replace(cfg, coupling_format="dense"),
                      mesh)
    # ... and the sharded format on the single-device drivers points back,
    # including the pre-packed-planes fast path (no silent downgrade to the
    # VMEM tier).
    with pytest.raises(ValueError, match="solve_sharded"):
        solve(prob, 0,
              dataclasses.replace(cfg, coupling_format="bitplane_sharded"),
              backend="fused")
    from repro.core.coupling import encode_planes
    from repro.kernels import ops
    planes = encode_planes(prob.couplings, fmt="bitplane_sharded")
    with pytest.raises(ValueError, match="solve_sharded"):
        ops.fused_anneal(
            prob, 0,
            dataclasses.replace(cfg, coupling_format="bitplane_sharded"),
            coupling=planes)
    # Fractional J cannot back a plane store.
    g = np.random.default_rng(0)
    J = np.triu(g.normal(size=(64, 64)), 1) + 0.5
    J = np.triu(J, 1)
    frac = ising.IsingProblem.create(J=J + J.T)
    with pytest.raises(ValueError, match="integer"):
        solve_sharded(frac, 0, cfg, mesh)
    # The 2-D format name demands a mesh that actually has group axes.
    with pytest.raises(ValueError, match="bitplane_sharded_2d"):
        solve_sharded(
            prob, 0,
            dataclasses.replace(cfg, coupling_format="bitplane_sharded_2d"),
            mesh)


def test_sharded_divisibility_errors_are_actionable(forced_device_mesh):
    """Satellite bugfix: an N that does not split over the row axis used to
    be a silent assumption; now both the 1-D and 2-D paths (dense and
    edge-ingested alike) raise an error naming N, the mesh shape, and the
    nearest valid row-shard counts, and a replica count that does not split
    over the groups names the valid group counts."""
    out = forced_device_mesh("""
        import dataclasses
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import ising
        from repro.core.ising import EdgeList
        from repro.core.schedules import geometric
        from repro.core.solver import SolverConfig
        from repro.distributed.solver_sharded import (
            nearest_row_shard_counts, shard_planes_from_edges, solve_sharded)

        mesh_2d = mesh                     # (groups=2, rows=2) from conftest
        mesh_1d = Mesh(np.array(jax.devices()), ("spins",))
        cfg = SolverConfig(num_steps=8, schedule=geometric(1.0, 0.1, 8),
                           num_replicas=4)

        def expect(fn, *needles):
            try:
                fn()
            except ValueError as e:
                for needle in needles:
                    assert needle in str(e), (needle, str(e))
            else:
                raise AssertionError("no ValueError raised")

        def prob_of(n):
            g = np.random.default_rng(0)
            J = np.clip(np.rint(g.normal(size=(n, n))), -3, 3)
            J = np.triu(J, 1)
            return ising.IsingProblem.create(J=J + J.T)

        # 1-D: N=513 does not split over the 4 row shards; the error names
        # N, the mesh shape, and the nearest valid shard counts.
        p = prob_of(513)
        expect(lambda: solve_sharded(p, 0, cfg, mesh_1d),
               "N=513", "(spins=4)", "nearest valid row-shard counts",
               "(3, 1, 9)")
        # 2-D: the rows (last) axis is what must divide.
        expect(lambda: solve_sharded(p, 0, cfg, mesh_2d),
               "N=513", "(groups=2, rows=2)", "'rows'",
               "nearest valid row-shard counts")
        # Divides, but breaks the selection-block (lane) alignment: N=192
        # over 4 row shards is 48 per shard vs lane 96.
        expect(lambda: solve_sharded(prob_of(192), 0, cfg, mesh_1d),
               "roulette", "lane 96", "(2, 1)")
        # The edge-ingestion (dense-J-free) path raises the same error.
        edges = EdgeList.from_dense(np.asarray(jax.device_get(p.couplings)))
        expect(lambda: shard_planes_from_edges(edges, mesh_1d),
               "N=513", "(spins=4)", "nearest valid")
        # Replica blocks must split over the groups too.
        cfg3 = dataclasses.replace(cfg, num_replicas=3)
        expect(lambda: solve_sharded(prob_of(512), 0, cfg3, mesh_2d),
               "num_replicas=3", "(groups=2, rows=2)", "divisible by 2")
        assert nearest_row_shard_counts(513, 4) == (3, 1, 9)
        print("DIVISIBILITY OK")
    """, mesh_shape=(2, 2))
    assert "DIVISIBILITY OK" in out
