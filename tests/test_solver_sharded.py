"""Spin-sharded coupling tier (`bitplane_sharded`): four-way exact parity.

The row-sharded plane store is a memory-*placement* choice, never a chain
change: `solve_sharded` on a D-device mesh must return bit-identical
`SolveResult`s to `solve(backend="fused")` under every single-device coupling
tier — dense, VMEM bit-planes, and HBM-streamed planes — on the same
seed/config. The D=2 cases run in a forced-device-count subprocess (via the
shared conftest harness) so the parity tier runs in tier-1 on this CPU box
rather than only on real pods; the D=1 mesh cases run in-process.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising
from repro.core.schedules import geometric
from repro.core.solver import SolverConfig, solve
from repro.distributed.solver_sharded import solve_sharded

RESULT_FIELDS = ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy")


def _int_problem(seed, n, amax=3):
    g = np.random.default_rng(seed)
    J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -amax, amax)
    J = np.triu(J, 1)
    return ising.IsingProblem.create(J=J + J.T)


def test_four_way_coupling_parity_on_two_device_mesh(forced_device_mesh):
    """dense == bitplane == bitplane_hbm == bitplane_sharded (D=2), exactly,
    across RWA / uniformized-RWA / RSA — the acceptance gate of the sharded
    tier. Runs every config in one subprocess to amortize the jax start."""
    out = forced_device_mesh("""
        import dataclasses
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import ising
        from repro.core.ising import EdgeList
        from repro.core.schedules import geometric
        from repro.core.solver import SolverConfig, solve
        from repro.distributed.solver_sharded import solve_sharded

        assert jax.device_count() == 2
        n = 512
        g = np.random.default_rng(11)
        J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
        J = np.triu(J, 1)
        J = J + J.T
        prob = ising.IsingProblem.create(J=J)
        # The same instance ingested dense-J-free: the sharded solve builds
        # per-device plane slabs straight from the O(nnz) edges and inits
        # u0/e0 plane-natively on the shard — trajectories must STILL be
        # bit-identical to every dense-ingested tier.
        prob_edges = ising.IsingProblem.create_sparse(EdgeList.from_dense(J))
        mesh = Mesh(np.array(jax.devices()), ("spins",))
        fields = ("best_energy", "best_spins", "final_energy", "num_flips",
                  "trace_energy")
        for mode, uniformized in (("rwa", False), ("rwa", True), ("rsa", False)):
            cfg = SolverConfig(num_steps=96, schedule=geometric(4.0, 0.05, 96),
                               mode=mode, uniformized=uniformized,
                               num_replicas=4, trace_every=24)
            results = {fmt: solve(prob, 5,
                                  dataclasses.replace(cfg, coupling_format=fmt),
                                  backend="fused")
                       for fmt in ("dense", "bitplane", "bitplane_hbm")}
            results["bitplane_sharded"] = solve_sharded(prob, 5, cfg, mesh)
            results["bitplane_sharded_edges"] = solve_sharded(
                prob_edges, 5, cfg, mesh)
            base = results["dense"]
            for fmt in ("bitplane", "bitplane_hbm", "bitplane_sharded",
                        "bitplane_sharded_edges"):
                for name in fields:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(base, name)),
                        np.asarray(getattr(results[fmt], name)),
                        err_msg=f"{mode}/u{uniformized}/{fmt}:{name}")
            print("PARITY", mode, uniformized,
                  float(jnp.min(results["bitplane_sharded"].best_energy)))
        print("FOUR-WAY OK")
    """, n_devices=2)
    assert "FOUR-WAY OK" in out


def test_sharded_step_emits_collectives_but_no_dot_general(forced_device_mesh):
    """The jaxpr pin, extended across the mesh: the sharded *step*
    (``sharded_sweep_fn`` — the per-step engine without the one-time init)
    must move data with collectives (psum row-tile broadcast + all_gather'd
    block sums) and must not reintroduce any quadratic contraction — the
    O(N)/step incremental-update contract survives sharding. The full anneal
    additionally runs the plane-native sharded init, whose one-time O(R·N)
    e₀ einsum is allowed — the pin separates the two surfaces."""
    out = forced_device_mesh("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.coupling import CouplingStore
        from repro.core.schedules import geometric
        from repro.core.solver import SolverConfig
        from repro.distributed.solver_sharded import (sharded_anneal_fn,
                                                      sharded_sweep_fn)

        assert jax.device_count() == 2
        n, r, steps = 512, 4, 6
        g = np.random.default_rng(3)
        J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
        J = np.triu(J, 1)
        store = CouplingStore.build(J + J.T, "bitplane_sharded")
        cfg = SolverConfig(num_steps=48, schedule=geometric(4.0, 0.05, 48),
                           mode="rwa", num_replicas=r, trace_every=24)
        mesh = Mesh(np.array(jax.devices()), ("spins",))
        step = sharded_sweep_fn(cfg, mesh, n)
        txt = str(jax.make_jaxpr(step)(
            store.planes, jnp.zeros((r, n), jnp.float32),
            jnp.ones((r, n), jnp.float32), jnp.zeros((r,), jnp.float32),
            jnp.zeros((steps, r, 4), jnp.float32),
            jnp.ones((steps, r), jnp.float32)))
        assert "psum" in txt, "row broadcast / lane combine must psum"
        assert "all_gather" in txt, "block sums must all_gather"
        assert "dot_general" not in txt, "no quadratic contraction in the step"
        # The full anneal (init inside) still moves data collectively.
        fn = sharded_anneal_fn(cfg, mesh, n)
        txt = str(jax.make_jaxpr(fn)(
            store.planes, jnp.zeros((n,), jnp.float32),
            jnp.zeros((1,), jnp.uint32)))
        assert "psum" in txt and "all_gather" in txt
        print("JAXPR PIN OK")
    """, n_devices=2)
    assert "JAXPR PIN OK" in out


def test_sharded_matches_fused_on_single_device_mesh():
    """D=1 degenerate mesh in-process: the collective path with trivial
    combines must still be trajectory-exact vs the fused driver (fast
    default-tier coverage that needs no subprocess)."""
    prob = _int_problem(11, 128)
    cfg = SolverConfig(num_steps=96, schedule=geometric(4.0, 0.05, 96),
                       mode="rwa", num_replicas=4, trace_every=24)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
    sharded = solve_sharded(prob, 5, cfg, mesh)
    fused = solve(prob, 5, dataclasses.replace(cfg, coupling_format="bitplane"),
                  backend="fused")
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(fused, name)),
                                      np.asarray(getattr(sharded, name)),
                                      err_msg=name)
    # Energy bookkeeping stays exact through the collectives.
    recomputed = np.asarray(ising.energy(prob, sharded.best_spins))
    np.testing.assert_allclose(np.asarray(sharded.best_energy), recomputed,
                               atol=1e-2)


def test_sharded_prepacked_planes_match_rebuild():
    """The benchmark path: pre-packed tile-aligned planes passed as
    ``coupling=`` skip the re-encode without changing the trajectory."""
    from repro.core.coupling import CouplingStore, encode_planes
    from jax.sharding import Mesh

    prob = _int_problem(7, 128)
    cfg = SolverConfig(num_steps=64, schedule=geometric(4.0, 0.1, 64),
                       mode="rsa", num_replicas=4, trace_every=0,
                       coupling_format="bitplane_sharded")
    mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
    planes = encode_planes(prob.couplings, fmt="bitplane_sharded")
    assert planes.num_words % 128 == 0  # tile-aligned like the HBM tier
    via_planes = solve_sharded(prob, 2, cfg, mesh, coupling=planes)
    rebuilt = solve_sharded(prob, 2, cfg, mesh)
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(rebuilt, name)),
                                      np.asarray(getattr(via_planes, name)),
                                      err_msg=name)
    # Per-shard accounting: row-sharding divides the plane bytes evenly.
    store = CouplingStore.from_planes(planes, "bitplane_sharded")
    assert store.plane_bytes_per_shard(2) * 2 == planes.nbytes


def test_sharded_driver_validates_inputs():
    from jax.sharding import Mesh

    prob = _int_problem(3, 128)
    mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
    cfg = SolverConfig(num_steps=8, schedule=geometric(1.0, 0.1, 8),
                       num_replicas=2)
    # A single-device format on the sharded driver is a config error ...
    with pytest.raises(ValueError, match="bitplane_sharded"):
        solve_sharded(prob, 0, dataclasses.replace(cfg, coupling_format="dense"),
                      mesh)
    # ... and the sharded format on the single-device drivers points back,
    # including the pre-packed-planes fast path (no silent downgrade to the
    # VMEM tier).
    with pytest.raises(ValueError, match="solve_sharded"):
        solve(prob, 0,
              dataclasses.replace(cfg, coupling_format="bitplane_sharded"),
              backend="fused")
    from repro.core.coupling import encode_planes
    from repro.kernels import ops
    planes = encode_planes(prob.couplings, fmt="bitplane_sharded")
    with pytest.raises(ValueError, match="solve_sharded"):
        ops.fused_anneal(
            prob, 0,
            dataclasses.replace(cfg, coupling_format="bitplane_sharded"),
            coupling=planes)
    # Fractional J cannot back a plane store.
    g = np.random.default_rng(0)
    J = np.triu(g.normal(size=(64, 64)), 1) + 0.5
    J = np.triu(J, 1)
    frac = ising.IsingProblem.create(J=J + J.T)
    with pytest.raises(ValueError, match="integer"):
        solve_sharded(frac, 0, cfg, mesh)
