"""Distribution tests: shard_map solver parity, compressed grads, pipeline
parallelism, logical sharding rules. Multi-device cases run in subprocesses
(XLA device count locks at first jax init; conftest must keep 1 device) via
the shared ``conftest.run_with_forced_devices`` harness."""
import numpy as np
import pytest
from conftest import run_with_forced_devices as run_with_devices

from repro.models.sharding import ShardingRules


def test_sharding_rules_spec_dedup_and_mesh_filter():
    from jax.sharding import PartitionSpec as P

    rules = ShardingRules()
    # batch consumes pod+data; a later name mapped to data must drop it.
    spec = rules.spec("batch", "seq", "embed_w", mesh_axes=("pod", "data", "model"))
    assert spec[0] == ("pod", "data")
    assert spec[2] is None  # embed_w -> data already used
    # single-pod mesh: "pod" filtered out (P normalizes 1-tuples to strings)
    spec2 = rules.spec("batch", mesh_axes=("data", "model"))
    assert spec2 == P("data")


@pytest.mark.slow
def test_distributed_solver_matches_quality_and_is_deterministic():
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core import ising, SolverConfig
        from repro.core.schedules import geometric
        from repro.distributed.solver_dist import DistSolverConfig, solve_distributed
        from repro.launch.mesh import make_host_mesh
        from repro.graphs import complete_bipolar, maxcut_to_ising

        mesh = make_host_mesh(model_parallel=2, pods=2)  # (2,2,2) pod/data/model
        inst = complete_bipolar(48, seed=3)
        prob = maxcut_to_ising(inst)
        base = SolverConfig(num_steps=1024, schedule=geometric(8.0, 0.05, 1024),
                            mode='rwa', num_replicas=1, trace_every=64)
        for backend in ('reference', 'fused'):
            cfg = DistSolverConfig(base=base, replicas_per_device=2,
                                   exchange_every=4, backend=backend)
            r1 = solve_distributed(prob, 7, cfg, mesh)
            r2 = solve_distributed(prob, 7, cfg, mesh)
            assert r1.best_energy.shape == (16,)   # 8 devices x 2 replicas
            np.testing.assert_array_equal(np.asarray(r1.best_energy), np.asarray(r2.best_energy))
            # energies bookkeeping exact
            e = ising.energy(prob, r1.best_spins)
            np.testing.assert_allclose(np.asarray(r1.best_energy), np.asarray(e), atol=1e-2)
            assert float(r1.ensemble_best) < 0
        print('BEST', float(r1.ensemble_best))
    """)
    best = float(out.strip().split()[-1])
    assert best < 0  # found a negative-energy (positive-cut) state


@pytest.mark.slow
def test_compressed_training_matches_uncompressed_loss():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import shard_map_compat
        from repro.distributed.compress import init_compression, compressed_psum_grads

        mesh = jax.make_mesh((8,), ('data',))
        key = jax.random.key(0)
        w_true = jax.random.normal(key, (16,))
        X = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
        y = X @ w_true

        def loss(w, xb, yb):
            return jnp.mean((xb @ w - yb) ** 2)

        def run(compressed):
            w = jnp.zeros(16)
            ef = init_compression({'w': w})
            for step in range(150):
                def local(xb, yb, w, ef_buf):
                    g = jax.grad(loss)(w, xb, yb)
                    if compressed:
                        gg, new_ef = compressed_psum_grads(
                            {'w': g}, ef_buf, axis='data')
                        return gg['w'], new_ef
                    return jax.lax.pmean(g, 'data'), ef_buf
                fn = jax.jit(shard_map_compat(local, mesh=mesh,
                    in_specs=(P('data'), P('data'), P(), P()),
                    out_specs=(P(), P())))
                g, ef = fn(X, y, w, ef)
                w = w - 0.1 * g
            return float(loss(w, X, y))

        l_plain = run(False)
        l_comp = run(True)
        print('PLAIN', l_plain, 'COMP', l_comp)
        assert l_comp < 1e-3, l_comp
        assert abs(l_comp - l_plain) < 1e-3
    """)
    assert "PLAIN" in out


def test_pipeline_matches_sequential():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import shard_map_compat
        from repro.distributed.pipeline import pipeline_apply, bubble_fraction

        P_STAGES, M, MB, D = 4, 8, 2, 16
        mesh = jax.make_mesh((P_STAGES,), ('pp',))
        key = jax.random.key(0)
        stage_w = jax.random.normal(key, (P_STAGES, D, D)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (M, MB, D))

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        def pipelined(stage_w, x):
            return pipeline_apply(stage_fn, stage_w[0], x, axis='pp')

        fn = jax.jit(shard_map_compat(pipelined, mesh=mesh,
                                      in_specs=(P('pp'), P()), out_specs=P()))
        got = fn(stage_w, x)
        want = x
        for i in range(P_STAGES):
            want = stage_fn(stage_w[i], want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
        assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
        print('PIPELINE OK')
    """, n_devices=4)


@pytest.mark.slow
def test_sharded_model_forward_matches_single_device():
    """GSPMD-distributed forward == single-device forward (same params/tokens)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import (model_specs, init_params, forward, use_sharding,
                                  ShardingRules, param_shardings)
        from repro.launch.mesh import make_host_mesh

        cfg = get_config('qwen2-7b', smoke=True)
        specs = model_specs(cfg)
        params = init_params(specs, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
        plain = forward(cfg, params, tokens=toks).logits.astype(jnp.float32)

        mesh = make_host_mesh(model_parallel=4)  # (2 data, 4 model)
        rules = ShardingRules()
        shardings = param_shardings(specs, mesh, rules)
        sh_params = jax.device_put(params, shardings)
        with use_sharding(mesh, rules):
            dist = jax.jit(lambda p, t: forward(cfg, p, tokens=t).logits)(sh_params, toks)
        err = float(jnp.max(jnp.abs(plain - dist.astype(jnp.float32))))
        print('ERR', err)
        assert err < 0.05, err
    """)
    assert "ERR" in out


@pytest.mark.slow
def test_decode_with_seq_sharded_cache_matches_unsharded():
    """Flash-decoding analogue: KV cache length sharded over `model`;
    distributed softmax combine must equal single-device attention."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import (model_specs, init_params, forward, use_sharding,
                                  ShardingRules, init_decode_cache, decode_step)
        from repro.launch.mesh import make_host_mesh
        from repro.configs.shapes import InputShape
        from repro.launch.abstracts import abstract_cache, rules_for

        cfg = get_config('qwen2-7b', smoke=True)
        params = init_params(model_specs(cfg), jax.random.key(0))
        B, L = 2, 32
        toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab_size)
        # Reference: plain decode on one device.
        cache = init_decode_cache(cfg, B, max_len=L)
        ref = []
        for t in range(L):
            lg, cache = decode_step(cfg, params, cache, jnp.int32(t), tokens=toks[:, t:t+1])
            ref.append(np.asarray(lg[:, 0], np.float32))

        mesh = make_host_mesh(model_parallel=4)
        rules = ShardingRules(kv_heads=None, cache_seq='model')
        cache2 = init_decode_cache(cfg, B, max_len=L)
        with use_sharding(mesh, rules):
            step = jax.jit(lambda p, c, t, tok: decode_step(cfg, p, c, t, tokens=tok))
            got = []
            for t in range(L):
                lg, cache2 = step(params, cache2, jnp.int32(t), toks[:, t:t+1])
                got.append(np.asarray(lg[:, 0], np.float32))
        err = max(np.abs(a - b).max() for a, b in zip(ref, got))
        print('DECODE ERR', err)
        assert err < 0.05, err
    """)
