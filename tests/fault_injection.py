"""Fault-injection harness for the resilient-solve tests.

Three fault families, one helper each, shared by the tier-1 smoke subset and
the randomized ``-m slow`` matrix (``tests/test_fault_injection.py``) and by
the in-process resilience tests (``tests/test_resilience.py``):

* **Process kill at a chunk boundary** — :func:`kill_after_chunk_hook` (in
  process, via ``on_event``) and :func:`resilient_subprocess_code` (a script
  for ``benchmarks.subproc.run_forced_device_subprocess`` that runs
  ``run_resilient`` on a forced multi-device mesh and ``os._exit``\\ s with
  :data:`KILL_EXIT_CODE` right after snapshot ``k`` — a hard death, no
  finally blocks, like a preemption).

* **Snapshot corruption** — :func:`corrupt_snapshot` flips a byte, truncates
  the array archive, or mangles the manifest of an on-disk snapshot.

* **Synthetic allocation failure** — :func:`fake_oom` builds the
  RESOURCE_EXHAUSTED-shaped error XLA raises on a real OOM, for
  ``repro.core.resilience.inject_faults`` hooks.

Deliberately jax-free at import time so pytest collection stays cheap.
"""
from __future__ import annotations

import json
import os

#: Exit code of a harness-killed run — distinct from 0 (success) and 1
#: (python exception) so the tests can assert the death was the injected one.
KILL_EXIT_CODE = 7


class SimulatedCrash(BaseException):
    """In-process stand-in for a hard process death. Derives from
    BaseException so it escapes both the supervisor's graceful
    ``except KeyboardInterrupt`` and its tier-fallback ``except Exception``
    triage — exactly like a SIGKILL, nothing downstream of the raise runs."""


def fake_oom(nbytes: int = 1 << 40) -> RuntimeError:
    """An allocation-failure error shaped like XLA's, for inject_faults
    hooks; ``resilience.is_allocation_failure`` must classify it."""
    return RuntimeError(
        f"RESOURCE_EXHAUSTED: Out of memory while trying to allocate "
        f"{nbytes} bytes.")


def kill_after_chunk_hook(chunk: int, exc=SimulatedCrash):
    """An ``on_event`` callback that raises ``exc`` right after snapshot
    ``chunk`` is written — the in-process stand-in for a death at a chunk
    boundary (the snapshot exists, nothing after it does)."""
    def hook(kind, info):
        if kind == "snapshot" and info["chunk"] == chunk:
            raise exc()
    return hook


def oom_once_hook(site: str, at_chunk: int | None = None,
                  fmts: tuple = ()):
    """An ``inject_faults`` hook raising one synthetic OOM at ``site``
    ("store_build" fires per tier build and matches on ``fmts``;
    "chunk_start" fires once at ``at_chunk``)."""
    fired = []

    def hook(s, info):
        if s != site:
            return
        if site == "store_build" and info.get("fmt") in fmts:
            raise fake_oom()
        if site == "chunk_start" and not fired and info["chunk"] == at_chunk:
            fired.append(True)
            raise fake_oom()
    return hook


def corrupt_snapshot(run_dir: str, step: int, how: str = "flip") -> str:
    """Damage snapshot ``step_<step>`` under ``run_dir``. ``how``:
    "flip" (one byte of arrays.npz inverted — the checksum must catch it),
    "truncate" (arrays.npz cut to 10 bytes — a torn write),
    "manifest" (manifest.json replaced with junk), or
    "legacy_empty" (arrays.npz emptied *and* ``arrays_sha256`` stripped from
    an otherwise-valid manifest — a torn write on a pre-checksum snapshot,
    so recovery must survive ``np.load``'s raw ``EOFError`` with no checksum
    to catch it first). Returns the damaged path."""
    snap = os.path.join(run_dir, f"step_{step}")
    if how == "manifest":
        path = os.path.join(snap, "manifest.json")
        with open(path, "w") as fh:
            fh.write("{not json")
        return path
    path = os.path.join(snap, "arrays.npz")
    if how == "truncate":
        with open(path, "r+b") as fh:
            fh.truncate(10)
        return path
    if how == "legacy_empty":
        mpath = os.path.join(snap, "manifest.json")
        with open(mpath) as fh:
            manifest = json.load(fh)
        manifest.pop("arrays_sha256", None)
        with open(mpath, "w") as fh:
            json.dump(manifest, fh)
        with open(path, "r+b") as fh:
            fh.truncate(0)
        return path
    if how == "flip":
        size = os.path.getsize(path)
        with open(path, "r+b") as fh:
            fh.seek(size // 2)
            b = fh.read(1)
            fh.seek(size // 2)
            fh.write(bytes([b[0] ^ 0xFF]))
        return path
    raise ValueError(f"how must be 'flip' | 'truncate' | 'manifest' | "
                     f"'legacy_empty', got {how!r}")


def resilient_subprocess_code(*, run_dir: str, seed: int = 5, n: int = 256,
                              num_steps: int = 60, trace_every: int = 20,
                              num_replicas: int = 4,
                              kill_after_chunk: int | None = None,
                              expect_resumed_from: int | None = None,
                              n_devices: int = 2,
                              mesh_shape: tuple | None = None) -> str:
    """Source for a forced-``n_devices`` subprocess that drives the
    spin-sharded tier through ``run_resilient`` on a deterministic problem.

    With ``kill_after_chunk`` the process ``os._exit``\\ s with
    :data:`KILL_EXIT_CODE` immediately after that snapshot lands — a hard
    kill at a chunk boundary. Without it the run completes and prints
    ``RESULT <json>`` holding the solve digest (best energies / spin sums /
    trace) plus ``resumed_from`` — the parent compares digests between an
    uninterrupted run and a killed-then-resumed pair for bit-identity.

    ``mesh_shape`` switches the mesh layout: None keeps the classic 1-D
    ``("spins",)`` mesh over ``n_devices``; a multi-element shape (e.g.
    ``(2, 2)``) builds the 2-D (groups, rows) mesh and drives the
    ``bitplane_sharded_2d`` tier — the caller must force
    ``prod(mesh_shape)`` devices.
    """
    if mesh_shape is not None and len(mesh_shape) > 1:
        n_devices = 1
        for s in mesh_shape:
            n_devices *= int(s)
        mesh_line = (f"mesh = Mesh(np.array(jax.devices())"
                     f".reshape({tuple(mesh_shape)!r}), ('groups', 'rows'))")
        fmt = "bitplane_sharded_2d"
    else:
        mesh_line = 'mesh = Mesh(np.array(jax.devices()), ("spins",))'
        fmt = "bitplane_sharded"
    kill = ("\n"
            f"def _ev(kind, info):\n"
            f"    if kind == 'snapshot' and info['chunk'] == {kill_after_chunk}:\n"
            f"        os._exit({KILL_EXIT_CODE})\n"
            if kill_after_chunk is not None else "\ndef _ev(kind, info):\n    pass\n")
    expect = ("" if expect_resumed_from is None else
              f"assert res.resumed_from_chunk == {expect_resumed_from}, "
              f"res.resumed_from_chunk\n")
    return f"""
import os, json
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import ising, schedules
from repro.core.solver import SolverConfig
from repro.core.resilience import run_resilient

assert jax.device_count() == {n_devices}
g = np.random.default_rng(1)
n = {n}
J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -3, 3)
J = np.triu(J, 1); J = J + J.T
h = g.normal(size=(n,)).astype(np.float32)
problem = ising.IsingProblem.create(J, h, offset=0.5)
{mesh_line}
cfg = SolverConfig(num_steps={num_steps},
                   schedule=schedules.linear(3.0, 0.1, {num_steps}),
                   num_replicas={num_replicas}, trace_every={trace_every},
                   coupling_format="{fmt}")
{kill}
res = run_resilient(problem, {seed}, cfg, run_dir={run_dir!r}, mesh=mesh,
                    on_event=_ev)
{expect}assert res.stop_reason == "completed", res.stop_reason
r = res.result
print("RESULT " + json.dumps({{
    "best_energy": np.asarray(r.best_energy).tolist(),
    "best_spin_sum": np.asarray(r.best_spins).astype(int).sum(axis=1).tolist(),
    "final_energy": np.asarray(r.final_energy).tolist(),
    "num_flips": np.asarray(r.num_flips).tolist(),
    "trace": np.asarray(r.trace_energy).tolist(),
    "resumed_from": res.resumed_from_chunk,
}}))
"""


def parse_result(stdout: str) -> dict:
    """The ``RESULT <json>`` digest printed by a harness subprocess."""
    for line in stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"no RESULT line in subprocess stdout:\n{stdout}")
