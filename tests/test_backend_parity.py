"""Backend-parity suite: the fused Pallas sweep is the production engine and
must agree with its jnp oracle *exactly* (shared selection math ⇒ identical
trajectories), and the fused drivers (solve / tempering / distributed) must
return finite, monotone-nonincreasing best-energy traces with reference-
identical trace shape/dtype/cadence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane, ising, rng
from repro.core.pwl import pwl_table
from repro.core.schedules import geometric
from repro.core.solver import SolverConfig, solve
from repro.core.tempering import TemperingConfig, solve_tempering
from repro.kernels import ref
from repro.kernels.sweep import mcmc_sweep as sweep_kernel


def _sym(seed, n, integer=False, scale=1.0):
    g = np.random.default_rng(seed)
    J = g.normal(size=(n, n)) * scale
    if integer:
        J = np.rint(J)
    J = np.triu(J, 1)
    return (J + J.T).astype(np.float32)


def _inputs(seed, r, n, t, temps=None):
    g = np.random.default_rng(seed)
    J = _sym(seed + 1, n)
    s0 = np.where(g.random((r, n)) < 0.5, 1.0, -1.0).astype(np.float32)
    u0 = (s0 @ J.T).astype(np.float32)
    e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)).astype(np.float32)
    unif = g.random((t, r, 4)).astype(np.float32)
    if temps is None:
        temps = np.broadcast_to(
            np.geomspace(2.5, 0.05, t).astype(np.float32)[:, None], (t, r)).copy()
    return tuple(map(jnp.asarray, (J, u0, s0, e0, unif, temps)))


NAMES = ("fields", "spins", "energy", "best_energy", "best_spins", "num_flips")

VARIANTS = {
    "warm": dict(),                       # T > 0, exact sigmoid
    "zero_t": dict(zero_t=True),          # greedy limit
    "degenerate": dict(degenerate=True),  # W = 0 fallback / null transition
    "uniformized": dict(uniformized=True),
    "pwl": dict(pwl=True),
}


@pytest.mark.parametrize("mode", ["rsa", "rwa"])
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_fused_matches_oracle_exactly(mode, variant):
    # Trajectory-exactness is size-independent, so the default tier runs a
    # small instance; the full-size sweep lives in
    # test_fused_matches_oracle_exactly_large behind -m slow.
    opts = VARIANTS[variant]
    if mode == "rsa" and variant in ("degenerate", "uniformized"):
        pytest.skip("RWA-only variant")
    r, n, t = 8, 64, 48
    if opts.get("degenerate"):
        # All-ferromagnetic at the all-up state, T=0 ⇒ every ΔE > 0 ⇒ W = 0.
        J = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
        s0 = np.ones((r, n), np.float32)
        u0 = (s0 @ J.T).astype(np.float32)
        e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)).astype(np.float32)
        unif = np.random.default_rng(0).random((t, r, 4)).astype(np.float32)
        temps = np.zeros((t, r), np.float32)
        args = tuple(map(jnp.asarray, (J, u0, s0, e0, unif, temps)))
    elif opts.get("zero_t"):
        args = _inputs(7, r, n, t, temps=np.zeros((t, r), np.float32))
    else:
        args = _inputs(7, r, n, t)
    tbl = pwl_table() if opts.get("pwl") else None
    uniformized = bool(opts.get("uniformized")) and mode == "rwa"
    got = sweep_kernel(*args, tbl, mode=mode, uniformized=uniformized,
                       block_r=4, interpret=True)
    want = ref.mcmc_sweep(*args, tbl, mode=mode, uniformized=uniformized)
    for name, a, b in zip(NAMES, got, want):
        # Shared selection math ⇒ trajectory-exact agreement, not just close.
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32),
                                      err_msg=f"{mode}/{variant}:{name}")


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["rsa", "rwa"])
def test_fused_matches_oracle_exactly_large(mode):
    """Full-size parity sweep (N=512, multi-block R) — slow tier."""
    r, n, t = 16, 512, 64
    args = _inputs(7, r, n, t)
    got = sweep_kernel(*args, mode=mode, block_r=8, interpret=True)
    want = ref.mcmc_sweep(*args, mode=mode)
    for name, a, b in zip(NAMES, got, want):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32),
                                      err_msg=f"{mode}:{name}")


BITPLANE_VARIANTS = {
    "warm": dict(),
    "zero_t": dict(zero_t=True),
    "uniformized": dict(uniformized=True),
    "pwl": dict(pwl=True),
}


@pytest.mark.parametrize("mode", ["rsa", "rwa"])
@pytest.mark.parametrize("variant", sorted(BITPLANE_VARIANTS))
def test_fused_bitplane_matches_oracle_exactly(mode, variant):
    """The packed bit-plane coupling path (kernel `coupling="bitplane"`) is
    trajectory-exact against the jnp oracle fed the same planes, and the
    planes-fed oracle is trajectory-exact against the dense-J oracle — so
    the packed store changes memory layout only, never the chain."""
    opts = BITPLANE_VARIANTS[variant]
    if mode == "rsa" and variant == "uniformized":
        pytest.skip("RWA-only variant")
    r, n, t, b = 8, 96, 48, 3
    g = np.random.default_rng(13)
    J = np.clip(np.rint(g.normal(size=(n, n)) * 2.0), -7, 7)
    J = np.triu(J, 1)
    J = J + J.T
    planes = bitplane.encode_couplings(J, b)
    s0 = np.where(g.random((r, n)) < 0.5, 1.0, -1.0).astype(np.float32)
    u0 = (s0 @ J.T).astype(np.float32)
    e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)).astype(np.float32)
    unif = g.random((t, r, 4)).astype(np.float32)
    temps = (np.zeros((t, r), np.float32) if opts.get("zero_t") else
             np.broadcast_to(np.geomspace(2.5, 0.05, t).astype(np.float32)[:, None],
                             (t, r)).copy())
    state = tuple(map(jnp.asarray, (u0, s0, e0, unif, temps)))
    tbl = pwl_table() if opts.get("pwl") else None
    uniformized = bool(opts.get("uniformized"))
    got = sweep_kernel(planes, *state, tbl, mode=mode, uniformized=uniformized,
                       coupling="bitplane", block_r=4, interpret=True)
    want = ref.mcmc_sweep(planes, *state, tbl, mode=mode,
                          uniformized=uniformized)
    want_dense = ref.mcmc_sweep(jnp.asarray(J, jnp.float32), *state, tbl,
                                mode=mode, uniformized=uniformized)
    for name, a, b_, c in zip(NAMES, got, want, want_dense):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b_, np.float32),
                                      err_msg=f"{mode}/{variant}:{name} kernel-vs-oracle")
        np.testing.assert_array_equal(np.asarray(b_, np.float32),
                                      np.asarray(c, np.float32),
                                      err_msg=f"{mode}/{variant}:{name} planes-vs-dense")


def _three_way_matrix(n, r, t, *, b=2, block_r=4, warm_chunks=2):
    """Dense-kernel vs VMEM-bitplane vs HBM-streamed-bitplane vs both oracles,
    exercising warm-start (state threaded through ``warm_chunks`` consecutive
    sweeps), the PWL LUT, and per-replica temperature ladders. Every pair must
    agree trajectory-exactly (assert_array_equal) — the coupling store is a
    memory-layout choice, never a chain change."""
    g = np.random.default_rng(97)
    J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -(2 ** b - 1), 2 ** b - 1)
    J = np.triu(J, 1)
    J = (J + J.T).astype(np.float32)
    planes = bitplane.encode_couplings(J, b)
    planes_hbm = ops_mod().encode_for_sweep(J, b, fmt="bitplane_hbm")
    s0 = np.where(g.random((r, n)) < 0.5, 1.0, -1.0).astype(np.float32)
    u0 = (s0 @ J.T).astype(np.float32)
    e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)).astype(np.float32)
    # Per-replica geometric ladders, distinct per replica (tempering's shape).
    ladder = np.geomspace(4.0, 0.1, r).astype(np.float32)
    temps = np.broadcast_to(ladder[None, :], (t, r)).copy()
    tbl = pwl_table()

    backends = {
        "dense": dict(couplings=jnp.asarray(J), coupling="dense"),
        "bitplane": dict(couplings=planes, coupling="bitplane"),
        "bitplane_hbm": dict(couplings=planes_hbm, coupling="bitplane_hbm"),
    }
    state0 = tuple(map(jnp.asarray, (u0, s0, e0)))
    outs = {}
    for name, kw in backends.items():
        state = state0
        for c in range(warm_chunks):  # chunk c>0 warm-starts from chunk c-1
            unif = jnp.asarray(
                np.random.default_rng(1000 + c).random((t, r, 4)), jnp.float32)
            got = sweep_kernel(kw["couplings"], *state, unif,
                               jnp.asarray(temps), tbl, mode="rwa",
                               coupling=kw["coupling"], block_r=block_r,
                               interpret=True)
            state = got[:3]
        outs[name] = got
    oracle_state = state0
    for c in range(warm_chunks):
        unif = jnp.asarray(
            np.random.default_rng(1000 + c).random((t, r, 4)), jnp.float32)
        want = ref.mcmc_sweep(planes, *oracle_state, unif, jnp.asarray(temps),
                              tbl, mode="rwa")
        want_dense = ref.mcmc_sweep(jnp.asarray(J), *oracle_state, unif,
                                    jnp.asarray(temps), tbl, mode="rwa")
        oracle_state = want[:3]
    for name in NAMES:
        i = NAMES.index(name)
        base = np.asarray(outs["dense"][i], np.float32)
        for other in ("bitplane", "bitplane_hbm"):
            np.testing.assert_array_equal(
                base, np.asarray(outs[other][i], np.float32),
                err_msg=f"dense-vs-{other}:{name}")
        np.testing.assert_array_equal(base, np.asarray(want[i], np.float32),
                                      err_msg=f"kernel-vs-planes-oracle:{name}")
        np.testing.assert_array_equal(base, np.asarray(want_dense[i], np.float32),
                                      err_msg=f"kernel-vs-dense-oracle:{name}")


def ops_mod():
    from repro.kernels import ops
    return ops


def test_three_way_coupling_parity_small():
    """Default tier: the full dense/VMEM-plane/HBM-plane matrix at a shrunk
    size (trajectory-exactness is size-independent; the full past-the-wall
    size runs behind -m slow)."""
    _three_way_matrix(n=640, r=8, t=16)


@pytest.mark.slow
def test_three_way_coupling_parity_past_vmem_wall():
    """Full-size matrix at N just past BITPLANE_VMEM_MAX_N — the size class
    where, on real TPUs, only the HBM-streamed store fits on-chip memory
    (interpret mode has no VMEM ceiling, so all three paths still run and
    must agree exactly)."""
    n = ops_mod().BITPLANE_VMEM_MAX_N + 192  # 8192: past the wall, lane-tiled
    _three_way_matrix(n=n, r=2, t=6, block_r=2, warm_chunks=2)


def test_sweep_bitplane_rejects_mismatches():
    r, n, t = 4, 64, 8
    g = np.random.default_rng(3)
    J = np.rint(np.triu(g.normal(size=(n, n)), 1))
    J = J + J.T
    planes = bitplane.encode_couplings(J, 4)
    s0 = jnp.ones((r, n), jnp.float32)
    u0 = jnp.asarray(s0 @ jnp.asarray(J, jnp.float32).T)
    e0 = jnp.zeros((r,), jnp.float32)
    unif = jnp.zeros((t, r, 4), jnp.float32)
    temps = jnp.ones((t, r), jnp.float32)
    with pytest.raises(ValueError, match="onehot"):
        sweep_kernel(planes, u0, s0, e0, unif, temps, coupling="bitplane",
                     gather="onehot", interpret=True)
    with pytest.raises(TypeError, match="BitPlanes"):
        sweep_kernel(jnp.asarray(J, jnp.float32), u0, s0, e0, unif, temps,
                     coupling="bitplane", interpret=True)
    with pytest.raises(ValueError, match="coupling"):
        sweep_kernel(planes, u0, s0, e0, unif, temps, coupling="packed",
                     interpret=True)
    # The HBM-streamed tier enforces the same contracts as the VMEM tier.
    with pytest.raises(TypeError, match="BitPlanes"):
        sweep_kernel(jnp.asarray(J, jnp.float32), u0, s0, e0, unif, temps,
                     coupling="bitplane_hbm", interpret=True)
    with pytest.raises(ValueError, match="onehot"):
        sweep_kernel(planes, u0, s0, e0, unif, temps, coupling="bitplane_hbm",
                     gather="onehot", interpret=True)


def test_sweep_block_r_clamps_to_divisor():
    """R=12 with block_r=8 must fall back to the largest divisor (6), not
    raise — and the clamped run stays trajectory-exact vs the oracle."""
    r, n, t = 12, 64, 16
    args = _inputs(21, r, n, t)
    got = sweep_kernel(*args, mode="rwa", block_r=8, interpret=True)
    want = ref.mcmc_sweep(*args, mode="rwa")
    for name, a, b in zip(NAMES, got, want):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32), err_msg=name)


def test_site_index_derivation_is_canonical():
    """Kernel/oracle site picks route through core.rng's canonical helper."""
    keys = [jax.random.fold_in(jax.random.key(0), i) for i in range(64)]
    for n in (7, 96, 4096):
        via_index = np.array([int(rng.uniform_index(k, n)) for k in keys])
        via_uniform = np.array(
            [int(rng.index_from_uniform(rng.uniform01(k), n)) for k in keys])
        np.testing.assert_array_equal(via_index, via_uniform)
        assert via_index.min() >= 0 and via_index.max() < n


def test_sweep_salt_is_disjoint():
    """The fused chunk stream must not collide with any sequential-engine salt."""
    assert rng.Salt.SWEEP not in {rng.Salt.SITE, rng.Salt.ACCEPT,
                                  rng.Salt.ROULETTE, rng.Salt.UNIFORMIZE,
                                  rng.Salt.INIT, rng.Salt.REPLICA,
                                  rng.Salt.PROBLEM}
    base = jax.random.key(1)
    a = rng.uniform01(rng.stream(base, rng.Salt.SWEEP, 0), (8,))
    b = rng.uniform01(rng.stream(base, rng.Salt.ROULETTE, 0), (8,))
    assert not np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode,uniformized,use_pwl", [
    ("rsa", False, False), ("rwa", False, True), ("rwa", True, False),
])
def test_solve_fused_backend_quality_and_trace(mode, uniformized, use_pwl):
    prob = ising.IsingProblem.create(J=_sym(5, 12, integer=True, scale=2.0))
    e_star, _, _ = ising.brute_force_ground_state(prob)
    cfg = SolverConfig(num_steps=1024, schedule=geometric(6.0, 0.02, 1024),
                       mode=mode, uniformized=uniformized, use_pwl=use_pwl,
                       num_replicas=8, trace_every=128)
    fused = solve(prob, 3, cfg, backend="fused")
    reference = solve(prob, 3, cfg, backend="reference")
    # Identical trace contract across backends (shape, dtype, cadence).
    assert fused.trace_energy.shape == reference.trace_energy.shape == (8, 8)
    assert fused.trace_energy.dtype == reference.trace_energy.dtype == jnp.float32
    trace = np.asarray(fused.trace_energy)
    assert np.isfinite(trace).all()
    assert (np.diff(trace, axis=0) <= 1e-6).all(), "best-energy trace must be monotone"
    assert float(jnp.min(fused.best_energy)) == pytest.approx(e_star, abs=1e-2)
    # Bookkeeping: reported energies match the spins they claim.
    recomputed = np.asarray(ising.energy(prob, fused.best_spins))
    np.testing.assert_allclose(np.asarray(fused.best_energy), recomputed, atol=1e-2)
    assert np.all(np.asarray(fused.num_flips) >= 0)


def test_solve_fused_trace_disabled_matches_reference_contract():
    prob = ising.IsingProblem.create(J=_sym(6, 10, integer=True, scale=2.0))
    cfg = SolverConfig(num_steps=128, schedule=geometric(4.0, 0.05, 128),
                       mode="rwa", num_replicas=4, trace_every=0)
    fused = solve(prob, 0, cfg, backend="fused")
    reference = solve(prob, 0, cfg, backend="reference")
    assert fused.trace_energy.shape == reference.trace_energy.shape == (0, 4)
    assert fused.trace_energy.dtype == reference.trace_energy.dtype == jnp.float32


@pytest.mark.parametrize("num_steps", [100, 360])
def test_solve_fused_runs_exactly_num_steps(num_steps):
    """Untraced fused runs must not round num_steps to a chunk multiple —
    RWA at T>0 is rejection-free, so num_flips counts executed steps."""
    prob = ising.IsingProblem.create(J=_sym(2, 10, integer=True, scale=2.0))
    cfg = SolverConfig(num_steps=num_steps,
                       schedule=geometric(6.0, 0.5, num_steps),
                       mode="rwa", num_replicas=4, trace_every=0)
    fused = solve(prob, 0, cfg, backend="fused")
    np.testing.assert_array_equal(np.asarray(fused.num_flips),
                                  np.full(4, num_steps))


def test_solve_rejects_unknown_backend():
    prob = ising.IsingProblem.create(J=_sym(6, 8))
    cfg = SolverConfig(num_steps=8, schedule=geometric(1.0, 0.1, 8))
    with pytest.raises(ValueError, match="backend"):
        solve(prob, 0, cfg, backend="mystery")


def test_tempering_fused_backend():
    prob = ising.IsingProblem.create(J=_sym(1, 12, integer=True, scale=2.0))
    e_star, _, _ = ising.brute_force_ground_state(prob)
    cfg = TemperingConfig(num_steps=1600, t_min=0.05, t_max=8.0,
                          num_replicas=8, swap_every=10, backend="fused")
    res = solve_tempering(prob, 0, cfg)
    assert float(jnp.min(res.best_energy)) == pytest.approx(e_star, abs=1e-2)
    recomputed = np.asarray(ising.energy(prob, res.best_spins))
    np.testing.assert_allclose(np.asarray(res.best_energy), recomputed, atol=1e-2)
    assert 0.0 <= float(res.swap_acceptance) <= 1.0
    assert np.all(np.asarray(res.num_flips) > 0)
    assert np.isfinite(np.asarray(res.final_energy)).all()


def test_distributed_fused_backend_single_device():
    """Fused chunked sweeps inside shard_map (single-device mesh in-process;
    the multi-device path runs in test_distributed's subprocesses)."""
    from jax.sharding import Mesh
    from repro.distributed.solver_dist import DistSolverConfig, solve_distributed

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    prob = ising.IsingProblem.create(J=_sym(9, 32, integer=True, scale=1.5))
    base = SolverConfig(num_steps=256, schedule=geometric(6.0, 0.05, 256),
                        mode="rwa", num_replicas=1, trace_every=64)
    cfg = DistSolverConfig(base=base, replicas_per_device=4,
                           exchange_every=4, backend="fused")
    r1 = solve_distributed(prob, 7, cfg, mesh)
    r2 = solve_distributed(prob, 7, cfg, mesh)
    np.testing.assert_array_equal(np.asarray(r1.best_energy),
                                  np.asarray(r2.best_energy))
    recomputed = np.asarray(ising.energy(prob, r1.best_spins))
    np.testing.assert_allclose(np.asarray(r1.best_energy), recomputed, atol=1e-2)
    trace = np.asarray(r1.trace_energy)
    assert trace.shape == (4, 4) and np.isfinite(trace).all()
    assert (np.diff(trace, axis=0) <= 1e-6).all()


def test_solve_fused_bitplane_format_matches_dense_exactly():
    """`coupling_format="bitplane"`/`"bitplane_hbm"` change the J store, not
    the chain: the fused driver returns bit-identical results for an
    integer-J problem (plane-decoded rows and the popcount u₀ init are exact
    in f32, and the streamed rows decode through the same expansion)."""
    prob = ising.IsingProblem.create(J=_sym(5, 12, integer=True, scale=2.0))
    cfg = SolverConfig(num_steps=1024, schedule=geometric(6.0, 0.02, 1024),
                       mode="rwa", num_replicas=8, trace_every=128)
    dense = solve(prob, 3, dataclasses.replace(cfg, coupling_format="dense"),
                  backend="fused")
    for fmt in ("bitplane", "bitplane_hbm"):
        packed = solve(prob, 3, dataclasses.replace(cfg, coupling_format=fmt),
                       backend="fused")
        for name in ("best_energy", "best_spins", "final_energy", "num_flips",
                     "trace_energy"):
            np.testing.assert_array_equal(np.asarray(getattr(dense, name)),
                                          np.asarray(getattr(packed, name)),
                                          err_msg=f"{fmt}:{name}")


def test_coupling_format_auto_resolution():
    """"auto" packs only past the f32 VMEM crossover and only for integral J;
    explicit "bitplane" under a jax trace (no host J to encode) raises."""
    from repro.kernels import ops

    J_int = np.asarray(_sym(8, 16, integer=True, scale=2.0))
    J_frac = J_int + np.triu(np.full((16, 16), 0.5), 1) + np.tril(np.full((16, 16), 0.5), -1)
    assert ops.resolve_coupling_format("auto", J_int, 16) == "dense"
    assert ops.resolve_coupling_format(
        "auto", J_int, ops.DENSE_COUPLING_MAX_N + 1) == "bitplane"
    assert ops.resolve_coupling_format(
        "auto", J_frac, ops.DENSE_COUPLING_MAX_N + 1) == "dense"
    # Past the packed-VMEM wall "auto" escalates to the HBM-streamed tier.
    assert ops.resolve_coupling_format(
        "auto", J_int, ops.BITPLANE_VMEM_MAX_N) == "bitplane"
    assert ops.resolve_coupling_format(
        "auto", J_int, ops.BITPLANE_VMEM_MAX_N + 1) == "bitplane_hbm"
    assert ops.resolve_coupling_format("bitplane_hbm", J_int, 64) == "bitplane_hbm"
    # Integral but huge magnitudes: 2·B ≥ 32 bits/coupler would not shrink J,
    # so "auto" must stay dense rather than pack a bigger-than-f32 store.
    assert ops.resolve_coupling_format(
        "auto", J_int * np.float32(2.0 ** 15),
        ops.DENSE_COUPLING_MAX_N + 1) == "dense"
    assert ops.resolve_coupling_format("dense", J_int, 4096) == "dense"
    with pytest.raises(ValueError, match="coupling"):
        ops.resolve_coupling_format("packed", J_int, 16)

    def traced(J):
        return ops.resolve_coupling_format("bitplane", J, 4096)

    with pytest.raises(ValueError, match="concrete"):
        jax.make_jaxpr(traced)(jnp.asarray(J_int))
    # "auto" under trace quietly stays dense (never inspects values).
    assert jax.make_jaxpr(
        lambda J: jnp.zeros(()) if ops.resolve_coupling_format(
            "auto", J, 4096) == "dense" else jnp.ones(()))(
        jnp.asarray(J_int)) is not None


def test_coupling_store_build_is_the_single_dispatch_point():
    """The CouplingStore subsystem (core.coupling): build() resolves + packs
    in one call, the registry spans all four tiers, stores are pytrees with
    static formats, and per-shard byte accounting divides the plane store."""
    from repro.core import coupling as cs

    J = _sym(8, 64, integer=True, scale=2.0)
    assert cs.COUPLING_FORMATS == ("auto", "dense", "bitplane",
                                   "bitplane_hbm", "bitplane_sharded",
                                   "bitplane_sharded_2d")
    assert cs.KERNEL_COUPLING_MODES == ("dense", "bitplane", "bitplane_hbm")
    dense = cs.CouplingStore.build(jnp.asarray(J), "dense")
    assert dense.fmt == "dense" and dense.planes is None
    assert dense.kernel_operand is dense.dense
    assert dense.nbytes == 64 * 64 * 4
    packed = cs.CouplingStore.build(J, "bitplane")
    assert packed.fmt == "bitplane" and packed.dense is None
    assert packed.kernel_operand is packed.planes
    # HBM/sharded tiers tile-pad the word axis per the registry.
    for fmt in ("bitplane_hbm", "bitplane_sharded"):
        store = cs.CouplingStore.build(J, fmt)
        assert store.planes.num_words % cs.STREAM_ALIGN_WORDS == 0
        assert store.plane_bytes_per_shard(2) * 2 == store.planes.nbytes
    # Stores are pytrees whose format is aux data (static under jit).
    leaves, treedef = jax.tree_util.tree_flatten(packed)
    again = jax.tree_util.tree_unflatten(treedef, leaves)
    assert again.fmt == "bitplane" and again.num_spins == 64
    # require() is the driver-side registry check with a routing hint.
    with pytest.raises(ValueError, match="solve_sharded"):
        cs.CouplingStore.build(J, "bitplane_sharded").require(
            cs.KERNEL_COUPLING_MODES, "fused_anneal")


def test_sharded_format_is_explicit_only_and_rejected_by_kernel_drivers():
    """"auto" never resolves to the sharded tier (it needs a mesh), an
    explicit sharded format under a trace raises the concrete-J error, and
    each single-device driver rejects the sharded store with a pointer at
    the spin-parallel driver."""
    from repro.kernels import ops

    J_int = np.asarray(_sym(8, 16, integer=True, scale=2.0))
    assert ops.resolve_coupling_format(
        "bitplane_sharded", J_int, 16) == "bitplane_sharded"
    huge = ops.BITPLANE_VMEM_MAX_N * 4
    assert ops.resolve_coupling_format("auto", J_int, huge) == "bitplane_hbm"

    def traced(J):
        return ops.resolve_coupling_format("bitplane_sharded", J, 4096)

    with pytest.raises(ValueError, match="concrete"):
        jax.make_jaxpr(traced)(jnp.asarray(J_int))

    prob = ising.IsingProblem.create(J=_sym(5, 12, integer=True, scale=2.0))
    cfg = SolverConfig(num_steps=8, schedule=geometric(1.0, 0.1, 8),
                       num_replicas=2, coupling_format="bitplane_sharded")
    with pytest.raises(ValueError, match="solve_sharded"):
        solve(prob, 0, cfg, backend="fused")
    tcfg = TemperingConfig(num_steps=8, t_min=0.1, t_max=1.0, num_replicas=2,
                           backend="fused", coupling_format="bitplane_sharded")
    with pytest.raises(ValueError, match="solve_sharded"):
        solve_tempering(prob, 0, tcfg)


def test_distributed_fused_planes_do_not_ship_dense_couplings():
    """Satellite contract: with a plane-backed store the dense J never enters
    shard_map (the runner closes over the encoded planes; chain inits run
    off the planes too) — and the plane-fed chain init is value-identical to
    the dense one."""
    from jax.sharding import Mesh
    from repro.core.coupling import CouplingStore
    from repro.core import mcmc
    from repro.distributed.solver_dist import (_init_chain_from_planes,
                                               DistSolverConfig,
                                               solve_distributed)

    prob = ising.IsingProblem.create(J=_sym(9, 32, integer=True, scale=1.5),
                                     h=np.linspace(-1, 1, 32).astype(np.float32))
    store = CouplingStore.build(prob.couplings, "bitplane")
    spins = np.where(np.random.default_rng(0).random(32) < 0.5, 1, -1)
    spins = jnp.asarray(spins, jnp.int8)
    via_planes = _init_chain_from_planes(store.planes, prob.fields, spins)
    via_dense = mcmc.init_chain(prob, spins)
    for name in mcmc.ChainState._fields:
        np.testing.assert_array_equal(np.asarray(getattr(via_planes, name)),
                                      np.asarray(getattr(via_dense, name)),
                                      err_msg=name)
    # End-to-end: the bitplane-format distributed solve (which no longer
    # receives J as an operand) still matches its dense-format twin exactly.
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    base = SolverConfig(num_steps=128, schedule=geometric(6.0, 0.05, 128),
                        mode="rwa", num_replicas=1, trace_every=32)
    results = {}
    for fmt in ("dense", "bitplane"):
        cfg = DistSolverConfig(
            base=dataclasses.replace(base, coupling_format=fmt),
            replicas_per_device=4, exchange_every=2, backend="fused")
        results[fmt] = solve_distributed(prob, 7, cfg, mesh)
    np.testing.assert_array_equal(np.asarray(results["dense"].best_energy),
                                  np.asarray(results["bitplane"].best_energy))
    np.testing.assert_array_equal(np.asarray(results["dense"].trace_energy),
                                  np.asarray(results["bitplane"].trace_energy))


def test_fused_anneal_accepts_prepacked_planes_and_rejects_onehot():
    """Callers may pass ready BitPlanes as `coupling` (skips the O(N²·B)
    re-encode — the benchmark path), and an explicit onehot gather on the
    packed store surfaces the kernel's dense-only error instead of being
    silently overridden."""
    from repro.kernels import ops

    prob = ising.IsingProblem.create(J=_sym(5, 12, integer=True, scale=2.0))
    cfg = SolverConfig(num_steps=256, schedule=geometric(6.0, 0.05, 256),
                       mode="rwa", num_replicas=4)
    planes = ops.encode_for_sweep(prob.couplings)
    via_planes = ops.fused_anneal(prob, 3, cfg, coupling=planes)
    via_format = ops.fused_anneal(prob, 3, cfg, coupling="bitplane")
    np.testing.assert_array_equal(np.asarray(via_planes.best_energy),
                                  np.asarray(via_format.best_energy))
    with pytest.raises(ValueError, match="onehot"):
        ops.fused_anneal(prob, 3, cfg, coupling="bitplane", gather="onehot")


def test_tempering_fused_bitplane_matches_dense():
    prob = ising.IsingProblem.create(J=_sym(1, 12, integer=True, scale=2.0))
    base = dict(num_steps=1200, t_min=0.05, t_max=8.0, num_replicas=8,
                swap_every=10, backend="fused")
    dense = solve_tempering(prob, 0, TemperingConfig(**base, coupling_format="dense"))
    packed = solve_tempering(prob, 0, TemperingConfig(**base, coupling_format="bitplane"))
    np.testing.assert_array_equal(np.asarray(dense.best_energy),
                                  np.asarray(packed.best_energy))
    np.testing.assert_array_equal(np.asarray(dense.num_flips),
                                  np.asarray(packed.num_flips))


def test_distributed_fused_bitplane_matches_dense():
    from jax.sharding import Mesh
    from repro.distributed.solver_dist import DistSolverConfig, solve_distributed

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    prob = ising.IsingProblem.create(J=_sym(9, 32, integer=True, scale=1.5))
    base = SolverConfig(num_steps=256, schedule=geometric(6.0, 0.05, 256),
                        mode="rwa", num_replicas=1, trace_every=64)
    results = {}
    for fmt in ("dense", "bitplane"):
        cfg = DistSolverConfig(
            base=dataclasses.replace(base, coupling_format=fmt),
            replicas_per_device=4, exchange_every=4, backend="fused")
        results[fmt] = solve_distributed(prob, 7, cfg, mesh)
    np.testing.assert_array_equal(np.asarray(results["dense"].best_energy),
                                  np.asarray(results["bitplane"].best_energy))
    np.testing.assert_array_equal(np.asarray(results["dense"].trace_energy),
                                  np.asarray(results["bitplane"].trace_energy))
