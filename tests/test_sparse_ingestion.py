"""Dense-J-free ingestion: sparse COO/edge-list → packed planes → solve.

The contract under test (ISSUE 5 tentpole): an instance given as an edge
list is solved end-to-end — ingestion, plane packing, u₀/e₀ init, every
fused/sharded tier — **without any (N, N) array ever existing**, and with
trajectories bit-identical to the same instance ingested densely. Plus the
satellite contracts: the prebuilt-``CouplingStore`` memoization for repeated
solves, and the plane-native init's einsum-identity against the dense init.
"""
import dataclasses
import tracemalloc

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips @given tests when absent

from repro.core import bitplane, coupling, ising
from repro.core.ising import EdgeList
from repro.core.schedules import geometric
from repro.core.solver import SolverConfig, solve
from repro.core.tempering import TemperingConfig, solve_tempering

RESULT_FIELDS = ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy")


def _sym_int(seed, n, amax=3):
    g = np.random.default_rng(seed)
    J = np.clip(np.rint(g.normal(size=(n, n)) * 1.5), -amax, amax)
    J = np.triu(J, 1)
    return J + J.T


def _accumulated_dense(rows, cols, w, n):
    """The documented ingestion semantics as straight-line code: every raw
    entry adds w to J[i, j] *and* J[j, i] (so duplicates and both-direction
    listings sum)."""
    J = np.zeros((n, n), np.int64)
    for i, j, wt in zip(rows, cols, w):
        J[i, j] += wt
        J[j, i] += wt
    return J


class TestEdgeList:
    def test_canonicalizes_coalesces_and_drops_zeros(self):
        rows = [0, 2, 1, 2, 0, 4, 3]
        cols = [2, 0, 3, 0, 1, 3, 4]
        w = [1, 2, -3, -1, 2, 1, -1]  # (0,2) thrice; (3,4) twice, cancelling
        e = EdgeList.create(rows, cols, w, 5)
        np.testing.assert_array_equal(e.to_dense(np.int64),
                                      _accumulated_dense(rows, cols, w, 5))
        assert (e.rows < e.cols).all()           # canonical orientation
        assert e.nnz == 3                        # coalesced, zero-sum dropped
        assert e.max_abs_weight == 3
        # Deterministic canonical order -> content-equal regardless of input
        # permutation (the identity jit caches on).
        perm = EdgeList.create(rows[::-1], cols[::-1], w[::-1], 5)
        assert perm == e and hash(perm) == hash(e)
        assert e != EdgeList.create([0], [1], [1], 5)

    def test_rejects_bad_input(self):
        with pytest.raises(ValueError, match="self-loop"):
            EdgeList.create([1], [1], [2], 4)
        with pytest.raises(ValueError, match="out of range"):
            EdgeList.create([0], [4], [1], 4)
        with pytest.raises(ValueError, match="integer"):
            EdgeList.create([0], [1], [0.5], 4)
        with pytest.raises(ValueError, match="equal-length"):
            EdgeList.create([0, 1], [1], [1], 4)
        with pytest.raises(ValueError, match="num_spins"):
            EdgeList.create([], [], [], 0)

    def test_from_dense_round_trip(self):
        J = _sym_int(3, 40)
        e = EdgeList.from_dense(J)
        np.testing.assert_array_equal(e.to_dense(), J.astype(np.float32))
        with pytest.raises(ValueError, match="symmetric"):
            EdgeList.from_dense(np.triu(J, 1) + np.eye(40) * 0)
        with pytest.raises(ValueError, match="diagonal"):
            EdgeList.from_dense(np.eye(4))

    def test_negated(self):
        e = EdgeList.create([0, 1], [1, 2], [2, -1], 3)
        np.testing.assert_array_equal(e.negated().to_dense(), -e.to_dense())


class TestSparseEncoder:
    @pytest.mark.parametrize("dtype", [np.int8, np.int32, np.int64,
                                       np.float32, np.float64])
    def test_matches_dense_encoder_bit_for_bit(self, dtype):
        """COO → planes must be plane-for-plane identical to dense → planes
        of the equivalent matrix, for every weight dtype ingestion accepts."""
        J = _sym_int(7, 70)  # 70 spins: 3 words, exercises the tail word
        e = EdgeList.from_dense(J.astype(dtype))
        sparse = bitplane.encode_edges(e)
        dense = bitplane.encode_couplings(J, sparse.num_planes)
        np.testing.assert_array_equal(np.asarray(sparse.pos),
                                      np.asarray(dense.pos))
        np.testing.assert_array_equal(np.asarray(sparse.neg),
                                      np.asarray(dense.neg))
        np.testing.assert_array_equal(bitplane.decode_couplings(sparse),
                                      J.astype(np.int64))

    def test_align_words_and_forced_planes(self):
        J = _sym_int(9, 70)
        e = EdgeList.from_dense(J)
        padded = bitplane.encode_edges(e, num_planes=4, align_words=128)
        ref = bitplane.encode_couplings(J, 4, align_words=128)
        assert padded.num_words == 128 and padded.num_planes == 4
        np.testing.assert_array_equal(np.asarray(padded.pos),
                                      np.asarray(ref.pos))
        np.testing.assert_array_equal(np.asarray(padded.neg),
                                      np.asarray(ref.neg))

    def test_row_range_slices_commute_with_encoding(self):
        """Per-device slab encoding (the sharded init path): encoding a row
        range equals slicing the full encode."""
        e = EdgeList.from_dense(_sym_int(11, 96))
        pos_full, neg_full = bitplane.edge_plane_words(e, 2)
        for lo, hi in ((0, 48), (48, 96), (32, 64), (10, 10)):
            pos, neg = bitplane.edge_plane_words(e, 2, row_range=(lo, hi))
            np.testing.assert_array_equal(pos, pos_full[:, lo:hi])
            np.testing.assert_array_equal(neg, neg_full[:, lo:hi])
        with pytest.raises(ValueError, match="row_range"):
            bitplane.edge_plane_words(e, 2, row_range=(10, 200))

    def test_encoder_validates(self):
        e = EdgeList.create([0], [1], [5], 4)
        with pytest.raises(ValueError, match="planes"):
            bitplane.encode_edges(e, num_planes=2)
        with pytest.raises(ValueError, match="align_words"):
            bitplane.encode_edges(e, align_words=0)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 80), st.integers(0, 200),
           st.integers(1, 10))
    def test_property_round_trip(self, seed, n, nnz, num_planes):
        """Random raw COO (duplicates, both orientations, mixed signs) →
        EdgeList → planes → decode equals the accumulated dense matrix."""
        g = np.random.default_rng(seed)
        limit = (1 << num_planes) - 1
        rows = g.integers(0, n, size=nnz)
        cols = (rows + 1 + g.integers(0, n - 1, size=nnz)) % n  # never a loop
        w = g.integers(-3, 4, size=nnz)
        J = _accumulated_dense(rows, cols, w, n)
        if np.abs(J).max(initial=0) > limit:
            num_planes = int(np.abs(J).max()).bit_length()
        e = EdgeList.create(rows, cols, w, n)
        planes = bitplane.encode_edges(e, num_planes=num_planes)
        np.testing.assert_array_equal(bitplane.decode_couplings(planes), J)


class TestCouplingStoreFromEdges:
    def test_auto_resolves_to_plane_tiers_and_dense_is_refused(self):
        e = EdgeList.from_dense(_sym_int(1, 32))
        assert coupling.resolve_format("auto", e, 32) == "bitplane"
        assert coupling.resolve_format(
            None, e, coupling.BITPLANE_VMEM_MAX_N + 1) == "bitplane_hbm"
        with pytest.raises(ValueError, match="dense-J-free"):
            coupling.resolve_format("dense", e, 32)
        with pytest.raises(ValueError, match="dense-J-free"):
            coupling.CouplingStore.build(e, "dense")
        with pytest.raises(ValueError, match="format"):
            coupling.resolve_format("nope", e, 32)
        store = coupling.CouplingStore.build(e, "auto")
        assert store.fmt == "bitplane" and store.dense is None
        assert store.num_spins == 32

    def test_build_from_edges_never_materializes_dense_at_scale(self, monkeypatch):
        """The acceptance gate at N=16384: building the store from edges must
        run the O(nnz) encoder only — the dense encoder and ``to_dense`` are
        poisoned, and the measured host peak must be plane-scale (tens of
        MiB), nowhere near the 1 GiB (N, N) f32."""
        from repro.graphs import sparse_bipolar_edges

        n = 16384
        e = sparse_bipolar_edges(n, 4 * n, seed=0)
        assert e.max_abs_weight == 1  # B=1 planes, the 16x-vs-f32 regime

        def poisoned(*a, **k):
            raise AssertionError("dense path touched during sparse ingestion")
        monkeypatch.setattr(bitplane, "encode_couplings", poisoned)
        monkeypatch.setattr(coupling, "encode_couplings", poisoned)
        monkeypatch.setattr(EdgeList, "to_dense", poisoned)
        store, stats = coupling.timed_build(e, "bitplane_hbm")
        assert store.fmt == "bitplane_hbm" and store.dense is None
        planes = store.planes
        assert planes.num_spins == n and planes.num_words % 128 == 0
        dense_bytes = n * n * 4
        assert stats["peak_bytes"] < dense_bytes // 4, stats
        assert stats["seconds"] > 0
        # Plane-only footprint: the store itself is ~64 MiB at B=1.
        assert planes.nbytes == 2 * planes.num_planes * n * planes.num_words * 4
        assert planes.nbytes < dense_bytes // 8

    def test_measure_host_build_reports_peak(self):
        _, stats = coupling.measure_host_build(
            lambda: np.zeros(1 << 22, np.uint8).sum())
        assert stats["peak_bytes"] >= 1 << 22
        assert stats["seconds"] > 0


def _cfg(fmt="auto", mode="rwa", steps=96):
    return SolverConfig(num_steps=steps, schedule=geometric(4.0, 0.05, steps),
                        mode=mode, num_replicas=4, trace_every=24,
                        coupling_format=fmt)


class TestDenseFreeSolvePath:
    def test_plane_native_init_is_einsum_identical_with_noninteger_h(self):
        """u₀/e₀ parity vs the dense einsum init, nonzero (non-integer!) h:
        the plane path computes u^(J) by popcount (exact integers) and routes
        e₀ through ``energy_from_fields`` — the identical einsum — so every
        element of the init state is bitwise equal."""
        import jax
        from repro.kernels import ops

        J = _sym_int(5, 48)
        h = np.linspace(-1.3, 0.9, 48).astype(np.float32)
        prob = ising.IsingProblem.create(J=J, h=h)
        planes = coupling.encode_planes(J)
        base = jax.random.fold_in(jax.random.key(0), jnp.uint32(7))
        dense_init = ops.fused_init_state(prob, base, 4, interpret=True)
        plane_init = ops.fused_init_state(prob, base, 4, interpret=True,
                                          planes=planes)
        for k, (a, b) in enumerate(zip(dense_init, plane_init)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"state[{k}]")
        # And energy_from_fields == ising.energy on the dense-computed u^J.
        s = np.where(np.random.default_rng(1).random((3, 48)) < 0.5, 1.0, -1.0)
        s = jnp.asarray(s, jnp.float32)
        u_j = jnp.einsum("ij,...j->...i", prob.couplings, s)
        np.testing.assert_array_equal(
            np.asarray(ising.energy_from_fields(u_j, s, prob.fields)),
            np.asarray(ising.energy(prob, s)))

    @pytest.mark.parametrize("fmt", ["auto", "bitplane", "bitplane_hbm"])
    def test_solve_from_edges_matches_dense_exactly(self, fmt):
        J = _sym_int(13, 64)
        h = np.linspace(-1, 1, 64).astype(np.float32)
        edges = EdgeList.from_dense(J)
        p_dense = ising.IsingProblem.create(J=J, h=h)
        p_edges = ising.IsingProblem.create_sparse(edges, h=h)
        assert p_edges.num_spins == 64 and p_edges.coupling_source is edges
        # The dense twin runs the same plane tier so the J store matches.
        plane_fmt = "bitplane" if fmt == "auto" else fmt
        r_dense = solve(p_dense, 5,
                        dataclasses.replace(_cfg(plane_fmt)), backend="fused")
        r_edges = solve(p_edges, 5, _cfg(fmt), backend="fused")
        for name in RESULT_FIELDS:
            np.testing.assert_array_equal(np.asarray(getattr(r_dense, name)),
                                          np.asarray(getattr(r_edges, name)),
                                          err_msg=f"{fmt}:{name}")

    def test_sharded_solve_from_edges_matches_fused(self):
        import jax
        from jax.sharding import Mesh
        from repro.distributed.solver_sharded import solve_sharded

        J = _sym_int(17, 128)
        p_edges = ising.IsingProblem.create_sparse(EdgeList.from_dense(J))
        p_dense = ising.IsingProblem.create(J=J)
        mesh = Mesh(np.array(jax.devices()[:1]), ("spins",))
        sharded = solve_sharded(p_edges, 3, _cfg(), mesh)
        fused = solve(p_dense, 3, _cfg("bitplane"), backend="fused")
        for name in RESULT_FIELDS:
            np.testing.assert_array_equal(np.asarray(getattr(fused, name)),
                                          np.asarray(getattr(sharded, name)),
                                          err_msg=name)

    def test_tempering_from_edges_matches_dense(self):
        J = _sym_int(19, 48)
        base = dict(num_steps=600, t_min=0.05, t_max=6.0, num_replicas=8,
                    swap_every=10, backend="fused")
        dense = solve_tempering(
            ising.IsingProblem.create(J=J), 0,
            TemperingConfig(**base, coupling_format="bitplane"))
        sparse = solve_tempering(
            ising.IsingProblem.create_sparse(EdgeList.from_dense(J)), 0,
            TemperingConfig(**base, coupling_format="auto"))
        np.testing.assert_array_equal(np.asarray(dense.best_energy),
                                      np.asarray(sparse.best_energy))
        np.testing.assert_array_equal(np.asarray(dense.num_flips),
                                      np.asarray(sparse.num_flips))

    def test_distributed_from_edges_matches_dense(self):
        import jax
        from jax.sharding import Mesh
        from repro.distributed.solver_dist import (DistSolverConfig,
                                                   solve_distributed)

        J = _sym_int(23, 32)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        base = SolverConfig(num_steps=128, schedule=geometric(6.0, 0.05, 128),
                            mode="rwa", num_replicas=1, trace_every=32)
        results = {}
        for name, prob, fmt in (
                ("dense", ising.IsingProblem.create(J=J), "bitplane"),
                ("edges", ising.IsingProblem.create_sparse(
                    EdgeList.from_dense(J)), "auto")):
            cfg = DistSolverConfig(
                base=dataclasses.replace(base, coupling_format=fmt),
                replicas_per_device=4, exchange_every=2, backend="fused")
            results[name] = solve_distributed(prob, 7, cfg, mesh)
        np.testing.assert_array_equal(np.asarray(results["dense"].best_energy),
                                      np.asarray(results["edges"].best_energy))
        np.testing.assert_array_equal(
            np.asarray(results["dense"].trace_energy),
            np.asarray(results["edges"].trace_energy))

    def test_reference_paths_raise_routing_errors(self):
        p = ising.IsingProblem.create_sparse(EdgeList.from_dense(_sym_int(2, 16)))
        with pytest.raises(ValueError, match="reference"):
            solve(p, 0, _cfg(), backend="reference")
        with pytest.raises(ValueError, match="dense"):
            ising.energy(p, jnp.ones((16,), jnp.int8))
        with pytest.raises(ValueError, match="dense"):
            ising.local_fields(p, jnp.ones((16,), jnp.int8))
        with pytest.raises(ValueError, match="reference"):
            solve_tempering(p, 0, TemperingConfig(
                num_steps=20, t_min=0.1, t_max=2.0, backend="reference"))
        import jax
        from jax.sharding import Mesh
        from repro.distributed.solver_dist import (DistSolverConfig,
                                                   solve_distributed)
        with pytest.raises(ValueError, match="reference"):
            solve_distributed(p, 0, DistSolverConfig(base=_cfg()),
                              Mesh(np.array(jax.devices()[:1]), ("data",)))


class TestPrebuiltStoreMemoization:
    def test_solve_and_tempering_reuse_the_store(self, monkeypatch):
        """The memoization contract: a prebuilt store makes repeated solves
        encode exactly zero times; without it every solve re-encodes."""
        calls = {"n": 0}
        real = coupling.encode_couplings

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)
        # coupling.py binds the encoder at import; patch its reference — the
        # one CouplingStore.build actually calls.
        monkeypatch.setattr(coupling, "encode_couplings", counting)

        J = _sym_int(29, 48)
        prob = ising.IsingProblem.create(J=J)
        store = coupling.CouplingStore.build(J, "bitplane")
        assert calls["n"] == 1
        r1 = solve(prob, 5, _cfg("bitplane"), backend="fused", store=store)
        r2 = solve(prob, 5, _cfg("bitplane"), backend="fused", store=store)
        t1 = solve_tempering(prob, 0, TemperingConfig(
            num_steps=100, t_min=0.1, t_max=4.0, backend="fused",
            coupling_format="bitplane"), store=store)
        assert calls["n"] == 1, "prebuilt store must skip every re-encode"
        plain = solve(prob, 5, _cfg("bitplane"), backend="fused")
        assert calls["n"] == 2, "store-less solve re-resolves and re-encodes"
        for name in RESULT_FIELDS:
            np.testing.assert_array_equal(np.asarray(getattr(r1, name)),
                                          np.asarray(getattr(r2, name)))
            np.testing.assert_array_equal(np.asarray(getattr(r1, name)),
                                          np.asarray(getattr(plain, name)))
        assert np.isfinite(float(t1.best_energy.min()))

    def test_solve_many_reuses_the_store_across_every_lane(self, monkeypatch):
        """The batch entry point honors the same contract: one prebuilt
        store serves every vmapped seed lane with zero re-encodes, and each
        lane is bit-identical to the same seed solved alone."""
        from repro.core.solver import solve_many

        calls = {"n": 0}
        real = coupling.encode_couplings

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)
        monkeypatch.setattr(coupling, "encode_couplings", counting)

        J = _sym_int(29, 48)
        prob = ising.IsingProblem.create(J=J)
        store = coupling.CouplingStore.build(J, "bitplane")
        assert calls["n"] == 1
        seeds = (5, 6, 7)
        batch = solve_many(prob, seeds, _cfg("bitplane"), backend="fused",
                           store=store)
        assert calls["n"] == 1, "solve_many(store=) must never re-encode"
        for i, s in enumerate(seeds):
            solo = solve(prob, s, _cfg("bitplane"), backend="fused",
                         store=store)
            for name in RESULT_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(batch, name))[i],
                    np.asarray(getattr(solo, name)))

    def test_store_contracts(self):
        J = _sym_int(31, 32)
        prob = ising.IsingProblem.create(J=J)
        store = coupling.CouplingStore.build(J, "bitplane")
        with pytest.raises(ValueError, match="not both"):
            from repro.kernels import ops
            ops.fused_anneal(prob, 0, _cfg("bitplane"), store=store,
                             coupling="bitplane")
        with pytest.raises(ValueError, match="N="):
            solve(ising.IsingProblem.create(J=_sym_int(1, 16)), 0,
                  _cfg("bitplane"), backend="fused", store=store)
        with pytest.raises(ValueError, match="fused backend"):
            solve(prob, 0, _cfg(), backend="reference", store=store)
        with pytest.raises(ValueError, match="fused backend"):
            solve_tempering(prob, 0, TemperingConfig(
                num_steps=20, t_min=0.1, t_max=2.0), store=store)
        # A dense store must hold THIS problem's couplings: init runs on the
        # problem's J, the sweep on the store's — a same-N stranger would
        # silently corrupt trajectories, so it is identity-checked.
        other = ising.IsingProblem.create(J=_sym_int(2, 32))
        dense_store = coupling.CouplingStore.build(other.couplings, "dense")
        with pytest.raises(ValueError, match="couplings array"):
            solve(prob, 0, _cfg("dense"), backend="fused", store=dense_store)
        with pytest.raises(ValueError, match="couplings array"):
            solve_tempering(prob, 0, TemperingConfig(
                num_steps=20, t_min=0.1, t_max=2.0, backend="fused"),
                store=dense_store)
        # ...and the same-problem dense store passes.
        own = coupling.CouplingStore.build(prob.couplings, "dense")
        solve(prob, 0, dataclasses.replace(_cfg("dense"), num_steps=8,
                                           trace_every=0),
              backend="fused", store=own)
