"""Dual-mode MCMC chain-law tests (paper §IV-A, Alg. 1).

The strongest checks available without hardware: (1) RSA's empirical
long-run distribution matches the Gibbs distribution π_T on an exhaustive
state space (detailed balance + ergodicity ⇒ unique stationary distribution,
paper Eq. 6-9); (2) the uniformized RWA variant is likewise Gibbs-invariant
(§IV-B3c); (3) plain RWA is rejection-free (always flips when W>0);
(4) incremental energy/field bookkeeping stays consistent over long runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising, mcmc, rng, solver
from repro.core.pwl import exact_flip_probability
from repro.core.schedules import constant, geometric


def _tiny_problem(seed=0, n=4):
    rngl = np.random.default_rng(seed)
    J = np.rint(rngl.normal(size=(n, n)) * 1.5)
    J = np.triu(J, 1)
    J = J + J.T
    h = np.rint(rngl.normal(size=n))
    return ising.IsingProblem.create(J=J, h=h)


def _gibbs(problem, T):
    _, _, all_e = ising.brute_force_ground_state(problem)
    w = np.exp(-(all_e - all_e.min()) / T)
    return w / w.sum()


def _spins_to_index(spins):
    bits = (np.asarray(spins) + 1) // 2
    return (bits * (1 << np.arange(bits.shape[-1]))).sum(-1)


def _run_chain_histogram(problem, config, T, num_steps, seed=0, burn_in=2000):
    n = problem.num_spins
    key = jax.random.key(seed)
    state = mcmc.init_chain(problem, ising.random_spins(rng.stream(key, rng.Salt.INIT), (n,)))

    def body(state, t):
        new_state, _ = mcmc.step(problem, state, rng.stream(key, t), jnp.float32(T), config)
        return new_state, new_state.spins

    _, spins_trace = jax.lax.scan(body, state, jnp.arange(num_steps))
    idx = _spins_to_index(np.asarray(spins_trace[burn_in:]))
    hist = np.bincount(idx, minlength=2**n).astype(np.float64)
    return hist / hist.sum()


@pytest.mark.parametrize("temperature", [1.0, 2.5])
def test_rsa_converges_to_gibbs(temperature):
    """Detailed balance of the sequential kernel (paper Eq. 6-9)."""
    problem = _tiny_problem(seed=1, n=4)
    cfg = mcmc.MCMCConfig(mode="rsa", flip_prob=exact_flip_probability)
    emp = _run_chain_histogram(problem, cfg, temperature, num_steps=120_000)
    gibbs = _gibbs(problem, temperature)
    tv = 0.5 * np.abs(emp - gibbs).sum()
    assert tv < 0.05, f"total variation {tv:.3f} too large"


@pytest.mark.slow
def test_uniformized_rwa_converges_to_gibbs():
    """Uniformized roulette-wheel chain leaves π_T invariant (§IV-B3c)."""
    problem = _tiny_problem(seed=2, n=4)
    cfg = mcmc.MCMCConfig(mode="rwa", uniformized=True, flip_prob=exact_flip_probability)
    emp = _run_chain_histogram(problem, cfg, 1.5, num_steps=200_000)
    gibbs = _gibbs(problem, 1.5)
    tv = 0.5 * np.abs(emp - gibbs).sum()
    assert tv < 0.06, f"total variation {tv:.3f} too large"


@pytest.mark.slow
def test_rwa_is_rejection_free_when_weights_positive():
    """Plain roulette-wheel flips exactly one spin per step (W > 0 at T > 0)."""
    problem = _tiny_problem(seed=3, n=6)
    cfg = mcmc.MCMCConfig(mode="rwa", uniformized=False, flip_prob=exact_flip_probability)
    key = jax.random.key(0)
    state = mcmc.init_chain(problem, ising.random_spins(key, (6,)))
    flips = 0
    for t in range(200):
        new_state, info = mcmc.step(problem, state, rng.stream(key, t), jnp.float32(1.0), cfg)
        changed = int(np.sum(np.asarray(new_state.spins) != np.asarray(state.spins)))
        assert changed == 1 and bool(info.accepted)
        state = new_state
        flips += changed
    assert int(state.num_flips) == flips == 200


def test_rwa_fallback_on_degenerate_weights():
    """Alg. 1 lines 9-14: W == 0 (greedy T=0 at a local optimum) falls back to
    random-scan, which also rejects uphill moves — so the state must not change
    but the step must still be well-defined (no NaN, valid site)."""
    # All-ferromagnetic: at the all-up state every flip is uphill; at T=0 the
    # greedy flip probability is 0 for all sites -> W = 0.
    n = 5
    J = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    problem = ising.IsingProblem.create(J=J)
    cfg = mcmc.MCMCConfig(mode="rwa", uniformized=False, flip_prob=exact_flip_probability)
    state = mcmc.init_chain(problem, jnp.ones(n, jnp.int8))
    key = jax.random.key(1)
    for t in range(20):
        state, info = mcmc.step(problem, state, rng.stream(key, t), jnp.float32(0.0), cfg)
        assert not bool(info.accepted)
    assert np.all(np.asarray(state.spins) == 1)
    assert np.isfinite(float(state.energy))


def test_uniformized_rwa_null_transition_on_degenerate():
    n = 5
    J = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    problem = ising.IsingProblem.create(J=J)
    cfg = mcmc.MCMCConfig(mode="rwa", uniformized=True, flip_prob=exact_flip_probability)
    state = mcmc.init_chain(problem, jnp.ones(n, jnp.int8))
    state2, info = mcmc.step(problem, state, jax.random.key(2), jnp.float32(0.0), cfg)
    assert not bool(info.accepted)
    assert np.all(np.asarray(state2.spins) == np.asarray(state.spins))


@pytest.mark.parametrize("mode", ["rsa", "rwa"])
def test_long_run_energy_bookkeeping(mode):
    """Incrementally tracked energy == recomputed H(s) after thousands of steps."""
    problem = _tiny_problem(seed=4, n=16)
    cfg = solver.SolverConfig(num_steps=5000, schedule=geometric(5.0, 0.01, 5000),
                              mode=mode, num_replicas=3, use_pwl=False)
    res = solver.solve(problem, 7, cfg)
    recomputed = np.asarray(ising.energy(problem, res.best_spins))
    np.testing.assert_allclose(np.asarray(res.best_energy), recomputed, rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("mode,uniformized", [("rsa", False), ("rwa", False), ("rwa", True)])
def test_solver_finds_small_ground_state(mode, uniformized):
    problem = _tiny_problem(seed=5, n=10)
    e_star, _, _ = ising.brute_force_ground_state(problem)
    cfg = solver.SolverConfig(num_steps=4000, schedule=geometric(6.0, 0.02, 4000),
                              mode=mode, uniformized=uniformized, num_replicas=8)
    res = solver.solve(problem, 0, cfg)
    assert float(res.ensemble_best) == pytest.approx(e_star, abs=1e-2)


def test_deterministic_given_seed():
    """Stateless RNG ⇒ bit-identical reruns (paper §IV-B3d)."""
    problem = _tiny_problem(seed=6, n=12)
    cfg = solver.SolverConfig(num_steps=500, schedule=geometric(4.0, 0.1, 500),
                              mode="rwa", num_replicas=4)
    r1 = solver.solve(problem, 42, cfg)
    r2 = solver.solve(problem, 42, cfg)
    np.testing.assert_array_equal(np.asarray(r1.best_spins), np.asarray(r2.best_spins))
    np.testing.assert_array_equal(np.asarray(r1.best_energy), np.asarray(r2.best_energy))
    # Different seeds explore differently: compare trajectories at constant
    # high temperature (no convergence to a shared optimum).
    hot = dataclasses.replace(cfg, schedule=constant(50.0, 500))
    h1 = solver.solve(problem, 42, hot)
    h2 = solver.solve(problem, 43, hot)
    assert not np.array_equal(np.asarray(h1.final_energy), np.asarray(h2.final_energy))
