"""Reuse-aware coalesced row fetch (ROADMAP item 4).

The HBM-streamed kernel tier and the spin-sharded driver fetch each step's
*unique* selected coupling rows exactly once (``kernels.common.coalesce_rows``)
and broadcast the decoded row to every replica that picked it. The decoded
row is a function of the site alone, so coalescing can never move a
trajectory — these tests force known duplicate-selection structures
(all replicas on one row; two groups; all-distinct) across
{rsa, rwa, uniformized-rwa} and assert (a) bit-identical trajectories vs the
uncoalesced oracles and (b) the rows-fetched counter matches the forced
duplicate structure exactly.

Forcing mechanics: replicas are fully independent and deterministic given
(state, uniforms), so replicas given identical initial spins and identical
per-step uniform streams select identical sites forever — grouping replicas
this way forces duplicates in *every* mode, including the state-dependent
roulette modes where the site stream cannot be dictated directly. For rsa the
site uniform stream is the site (Eq. 22: j = floor(u·N)), so arbitrary
distinct patterns can be forced as well.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bitplane import encode_couplings
from repro.kernels import common, ref
from repro.kernels.sweep import mcmc_sweep

N = 256
R = 8
T = 64

MODES = [("rsa", False), ("rwa", False), ("rwa", True)]


def _coupling():
    g = np.random.default_rng(3)
    J = np.clip(np.rint(g.normal(size=(N, N)) * 1.5), -3, 3)
    J = np.triu(J, 1)
    J = J + J.T
    return J


def _grouped_state(J, groups, seed=0):
    """(u0, s0, e0) with replicas sharing a group sharing identical spins."""
    g = np.random.default_rng(seed)
    n_groups = max(groups) + 1
    s_g = np.where(g.random((n_groups, N)) < 0.5, 1.0, -1.0)
    s0 = s_g[np.asarray(groups)].astype(np.float32)
    u0 = (J @ s0.T).T.astype(np.float32)
    e0 = (-0.5 * np.einsum("rn,rn->r", u0, s0)).astype(np.float32)
    return jnp.asarray(u0), jnp.asarray(s0), jnp.asarray(e0)


def _grouped_uniforms(groups, seed=1):
    """(T, R, 4) uniforms identical within each replica group."""
    g = np.random.default_rng(seed)
    n_groups = max(groups) + 1
    u_g = g.random((T, n_groups, 4)).astype(np.float32)
    return jnp.asarray(u_g[:, np.asarray(groups), :])


def _run(J, u0, s0, e0, uniforms, *, mode, uniformized, coalesce=True,
         block_r=8):
    planes = encode_couplings(J, 2, align_words=128)
    temps = jnp.full((uniforms.shape[0], u0.shape[0]), 1.0, jnp.float32)
    return mcmc_sweep(planes, u0, s0, e0, uniforms, temps, mode=mode,
                      uniformized=uniformized, coupling="bitplane_hbm",
                      block_r=block_r, coalesce=coalesce, interpret=True)


def _assert_trajectory_equal(J, u0, s0, e0, uniforms, got, *, mode,
                             uniformized):
    temps = jnp.full((uniforms.shape[0], u0.shape[0]), 1.0, jnp.float32)
    want = ref.mcmc_sweep(jnp.asarray(J, jnp.float32), u0, s0, e0, uniforms,
                          temps, mode=mode, uniformized=uniformized)
    for name, a, b in zip(("u", "s", "e", "be", "bs", "nf"), want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


# --------------------------------------------------- the fetch plan itself

def test_coalesce_rows_matches_python_oracle():
    g = np.random.default_rng(0)
    for _ in range(200):
        r = int(g.integers(1, 12))
        j = g.integers(0, 7, size=r).astype(np.int32)
        nu, usite, uo, fetched = jax.jit(common.coalesce_rows)(jnp.asarray(j))
        nu, usite, uo, fetched = map(np.asarray, (nu, usite, uo, fetched))
        uniq = list(dict.fromkeys(j.tolist()))   # first-occurrence order
        assert nu == len(uniq)
        assert (usite[:nu] == np.array(uniq)).all()
        assert (usite[nu:] == j[0]).all()        # tail parked on a valid site
        for ri, site in enumerate(j):
            assert uo[ri] < nu and usite[uo[ri]] == site
        seen, want = set(), []
        for site in j.tolist():
            want.append(0 if site in seen else 1)
            seen.add(site)
        assert (fetched == np.array(want)).all()  # lowest-index attribution
        assert fetched.sum() == nu


# ------------------------------------------- streamed kernel, forced groups

@pytest.mark.parametrize("mode,uniformized", MODES)
def test_identical_replicas_fetch_one_row_per_step(mode, uniformized):
    """All R replicas share init + uniforms ⇒ they pick one row per step in
    every mode ⇒ the coalesced stream DMAs exactly T rows, not R·T — while
    the trajectory stays bit-identical to the uncoalesced jnp oracle."""
    J = _coupling()
    groups = [0] * R
    u0, s0, e0 = _grouped_state(J, groups)
    uniforms = _grouped_uniforms(groups)
    got = _run(J, u0, s0, e0, uniforms, mode=mode, uniformized=uniformized)
    _assert_trajectory_equal(J, u0, s0, e0, uniforms, got, mode=mode,
                             uniformized=uniformized)
    rf = np.asarray(got[6])
    assert rf.sum() == T
    assert (rf[1:] == 0).all()       # all fetches attributed to replica 0


@pytest.mark.parametrize("mode,uniformized", MODES)
def test_two_replica_groups_fetch_at_most_two_rows_per_step(mode,
                                                            uniformized):
    """Two groups of four ⇒ at most two unique rows per step. The exact
    expected traffic comes from a 2-replica run of one representative per
    group (replicas are independent, so representatives replay their group's
    trajectory exactly): both runs must count the same unique sites."""
    J = _coupling()
    groups = [0, 0, 0, 0, 1, 1, 1, 1]
    u0, s0, e0 = _grouped_state(J, groups)
    uniforms = _grouped_uniforms(groups)
    got = _run(J, u0, s0, e0, uniforms, mode=mode, uniformized=uniformized)
    _assert_trajectory_equal(J, u0, s0, e0, uniforms, got, mode=mode,
                             uniformized=uniformized)
    rf = np.asarray(got[6])
    assert T <= rf.sum() <= 2 * T
    assert (rf[[1, 2, 3, 5, 6, 7]] == 0).all()  # only group leaders fetch
    reps = jnp.asarray([0, 4])
    rep = _run(J, u0[reps], s0[reps], e0[reps],
               uniforms[:, np.asarray([0, 4]), :], mode=mode,
               uniformized=uniformized, block_r=2)
    assert rf.sum() == np.asarray(rep[6]).sum()


def test_all_distinct_rsa_sites_fetch_every_row():
    """rsa sites forced pairwise-distinct per step (the site uniform *is*
    the site) ⇒ zero reuse ⇒ the coalesced counter must equal the
    uncoalesced R·T exactly, and the trajectory still matches the oracle."""
    J = _coupling()
    u0, s0, e0 = _grouped_state(J, list(range(R)))
    g = np.random.default_rng(2)
    uniforms = g.random((T, R, 4)).astype(np.float32)
    for t in range(T):
        sites = g.choice(N, size=R, replace=False)
        uniforms[t, :, 0] = (sites + 0.5) / N
    uniforms = jnp.asarray(uniforms)
    got = _run(J, u0, s0, e0, uniforms, mode="rsa", uniformized=False)
    _assert_trajectory_equal(J, u0, s0, e0, uniforms, got, mode="rsa",
                             uniformized=False)
    rf = np.asarray(got[6])
    assert (rf == T).all()           # every replica fetched its own row
    assert rf.sum() == R * T


def test_all_one_row_forced_rsa_sites():
    """rsa with every replica forced onto the same (per-step random) site —
    the all-one-row case driven through the site stream rather than through
    replica identity, so replica *states* differ while selections collide."""
    J = _coupling()
    u0, s0, e0 = _grouped_state(J, list(range(R)))
    g = np.random.default_rng(4)
    uniforms = g.random((T, R, 4)).astype(np.float32)
    sites = g.integers(0, N, size=T)
    uniforms[:, :, 0] = ((sites + 0.5) / N)[:, None]
    uniforms = jnp.asarray(uniforms)
    got = _run(J, u0, s0, e0, uniforms, mode="rsa", uniformized=False)
    _assert_trajectory_equal(J, u0, s0, e0, uniforms, got, mode="rsa",
                             uniformized=False)
    assert np.asarray(got[6]).sum() == T


# ------------------------------------------ sharded driver, forced 2-device

def test_sharded_coalesced_matches_uncoalesced_oracle(forced_device_mesh):
    """On the forced 2-device mesh: ``sharded_sweep_fn(coalesce=True)`` is
    bit-identical to the uncoalesced psum-per-replica oracle in all three
    modes, the uncoalesced counter is exactly R·T, and forced duplicate
    groups (identical replicas / two groups) reduce the coalesced counter to
    the duplicate structure."""
    code = """
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import schedules
    from repro.core.bitplane import encode_couplings, BitPlanes
    from repro.core.solver import SolverConfig
    from repro.distributed.solver_sharded import sharded_sweep_fn

    N, R, T = 256, 8, 48
    g = np.random.default_rng(3)
    J = np.clip(np.rint(g.normal(size=(N, N)) * 1.5), -3, 3)
    J = np.triu(J, 1); J = J + J.T
    planes = encode_couplings(J, 2, align_words=128)
    mesh = Mesh(np.array(jax.devices()[:2]), ("spins",))
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(None, "spins", None))
    planes = BitPlanes(pos=jax.device_put(planes.pos, sharding),
                       neg=jax.device_put(planes.neg, sharding),
                       num_spins=N)

    def state(groups, seed=0):
        gg = np.random.default_rng(seed)
        s_g = np.where(gg.random((max(groups) + 1, N)) < .5, 1., -1.)
        s0 = s_g[np.asarray(groups)].astype(np.float32)
        u0 = (J @ s0.T).T.astype(np.float32)
        e0 = (-0.5 * np.einsum('rn,rn->r', u0, s0)).astype(np.float32)
        return jnp.asarray(u0), jnp.asarray(s0), jnp.asarray(e0)

    def uniforms(groups, seed=1):
        gg = np.random.default_rng(seed)
        u_g = gg.random((T, max(groups) + 1, 4)).astype(np.float32)
        return jnp.asarray(u_g[:, np.asarray(groups), :])

    temps = jnp.full((T, R), 1.0, jnp.float32)
    for mode, uni in (("rsa", False), ("rwa", False), ("rwa", True)):
        cfg = SolverConfig(num_steps=T,
                           schedule=schedules.linear(3.0, 0.1, T),
                           mode=mode, uniformized=uni, num_replicas=R,
                           coupling_format="bitplane_sharded")
        fn_c = sharded_sweep_fn(cfg, mesh, N, coalesce=True)
        fn_u = sharded_sweep_fn(cfg, mesh, N, coalesce=False)
        for groups, max_unique in (([0] * R, 1),
                                   ([0, 0, 0, 0, 1, 1, 1, 1], 2),
                                   (list(range(R)), R)):
            u0, s0, e0 = state(groups)
            unif = uniforms(groups)
            got = fn_c(planes, u0, s0, e0, unif, temps)
            want = fn_u(planes, u0, s0, e0, unif, temps)
            for name, a, b in zip(("u", "s", "e", "be", "bs", "nf"),
                                  want, got):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f"{mode} {name}")
            rf_c = np.asarray(got[6]); rf_u = np.asarray(want[6])
            assert rf_u.sum() == R * T, rf_u
            assert rf_c.sum() <= max_unique * T, (groups, rf_c)
            n_groups = max(groups) + 1
            assert rf_c.sum() >= min(n_groups, 1) * T
            leaders = sorted({groups.index(x) for x in set(groups)})
            others = [r for r in range(R) if r not in leaders]
            if others:
                assert (rf_c[np.asarray(others)] == 0).all()
    print("SHARDED COALESCE OK")
    """
    out = forced_device_mesh(code, n_devices=2)
    assert "SHARDED COALESCE OK" in out
