"""Import shim: property tests skip — individually — when hypothesis is absent.

``from hypothesis_compat import given, settings, st`` instead of importing
hypothesis directly. With hypothesis installed this re-exports the real API;
without it, ``@given`` replaces the test with a skip-marked stub so only the
property tests skip and the rest of the module still runs (a module-level
``pytest.importorskip`` would silently drop every test in the file).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # pragma: no cover - exercised on hypothesis-less hosts

    class _AnyStrategy:
        """Accepts any strategy construction (st.integers(...), st.floats(...))."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            return stub
        return decorate

    def settings(*args, **kwargs):
        return lambda fn: fn
