"""Beyond-core extensions: parallel tempering baseline, greedy 1-opt
refinement, graph/number partitioning encodings."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ising
from repro.core.refine import greedy_descent
from repro.core.solver import solve
from repro.core.tempering import TemperingConfig, solve_tempering
from repro.configs.snowball import default_solver
from repro.graphs import complete_bipolar, maxcut_to_ising
from repro.graphs.partitioning import (graph_partitioning_to_ising,
                                       number_partitioning_to_ising,
                                       partition_cost, partition_residue)


def _rough_problem(seed=0, n=12):
    rng = np.random.default_rng(seed)
    J = np.rint(rng.normal(size=(n, n)) * 2)
    J = np.triu(J, 1)
    J = J + J.T
    return ising.IsingProblem.create(J=J)


def test_parallel_tempering_finds_ground_state():
    problem = _rough_problem(1, 12)
    e_star, _, _ = ising.brute_force_ground_state(problem)
    cfg = TemperingConfig(num_steps=4000, t_min=0.05, t_max=8.0,
                          num_replicas=8, swap_every=10)
    res = solve_tempering(problem, 0, cfg)
    assert float(jnp.min(res.best_energy)) == pytest.approx(e_star, abs=1e-2)
    # bookkeeping consistent
    recomputed = np.asarray(ising.energy(problem, res.best_spins))
    np.testing.assert_allclose(np.asarray(res.best_energy), recomputed, atol=1e-2)
    assert 0.0 <= float(res.swap_acceptance) <= 1.0


def test_parallel_tempering_swaps_happen():
    problem = _rough_problem(2, 16)
    cfg = TemperingConfig(num_steps=2000, t_min=0.1, t_max=4.0,
                          num_replicas=8, swap_every=5)
    res = solve_tempering(problem, 3, cfg)
    assert float(res.swap_acceptance) > 0.05  # geometric ladder keeps exchange alive


def test_greedy_descent_reaches_local_optimum_and_never_hurts():
    problem = _rough_problem(3, 20)
    key = jax.random.key(0)
    spins = ising.random_spins(key, (6, 20))
    e0 = np.asarray(ising.energy(problem, spins))
    refined, e1 = greedy_descent(problem, spins)
    e1 = np.asarray(e1)
    assert (e1 <= e0 + 1e-4).all()
    # 1-opt local optimality: no single flip improves
    de = np.asarray(ising.delta_energies(problem, refined))
    assert (de >= -1e-3).all()
    # energies consistent
    np.testing.assert_allclose(e1, np.asarray(ising.energy(problem, refined)),
                               rtol=1e-4, atol=1e-2)


def test_greedy_descent_after_anneal_improves_or_ties():
    inst = complete_bipolar(64, seed=9)
    problem = maxcut_to_ising(inst)
    res = solve(problem, 0, default_solver(64, 800, "rwa", num_replicas=4))
    _, refined_e = greedy_descent(problem, res.best_spins)
    assert (np.asarray(refined_e) <= np.asarray(res.best_energy) + 1e-3).all()


def test_number_partitioning_encoding():
    values = [4, 5, 6, 7, 8]  # perfect partition: {4,5,6} vs {7,8}
    problem = number_partitioning_to_ising(values)
    e, s, _ = ising.brute_force_ground_state(problem)
    assert e == pytest.approx(0.0, abs=1e-3)  # H + offset = residue² = 0
    assert partition_residue(values, s) == pytest.approx(0.0, abs=1e-6)
    # solver finds it too
    res = solve(problem, 0, default_solver(5, 2000, "rwa", num_replicas=8))
    assert float(jnp.min(res.best_energy)) == pytest.approx(0.0, abs=1e-3)


def test_graph_partitioning_encoding_balances():
    rng = np.random.default_rng(4)
    n = 12
    w = np.triu(rng.random((n, n)) < 0.4, 1).astype(np.float64)
    w = w + w.T
    lam = 2.0
    problem = graph_partitioning_to_ising(w, balance_weight=lam)
    e, s, _ = ising.brute_force_ground_state(problem)
    # Ising energy + offset equals the explicit cost
    assert e == pytest.approx(partition_cost(w, s, lam), rel=1e-4, abs=1e-3)
    # the optimum at this λ is balanced
    assert abs(int(np.sum(s))) <= 2
