"""Tier-1 wiring for ``benchmarks.run --check``: the committed perf anchor
must always validate, and the checker must actually have teeth — perf-touching
PRs regress ``BENCH_solver_perf.json`` and this gate is what stops a silently
slower fused engine (or a hand-mangled history) from landing."""
import copy
import json
import os

import pytest

from benchmarks.run import BENCH_JSON, check_bench_history

pytestmark = pytest.mark.skipif(
    not os.path.exists(BENCH_JSON),
    reason="BENCH_solver_perf.json not present (fresh checkout before any "
           "benchmark run)")


def _load():
    with open(BENCH_JSON) as f:
        return json.load(f)


def test_committed_bench_json_is_healthy():
    payload = _load()
    assert check_bench_history(payload) == []


def test_committed_history_has_hbm_streamed_point():
    """The scaling story is anchored by recorded sizes each VMEM tier cannot
    reach: the N=4096 packed point and the N=16384 HBM-streamed point."""
    payload = _load()
    results = payload["results"]
    assert "N16384" in results, sorted(results)
    point = results["N16384"]["rsa"]
    assert point["num_planes"] >= 1
    # The streamed store must be the only tier that fits: dense f32 is 1 GiB,
    # VMEM planes 4x the 16 MiB budget.
    assert point["j_bytes_dense_f32"] == 16384 * 16384 * 4
    assert point["j_bytes_vmem_planes"] > 16 * 2 ** 20
    assert point["bitplane_hbm_us_per_step"] > 0


def test_check_flags_missing_fields():
    payload = _load()
    broken = copy.deepcopy(payload)
    del broken["history"]
    assert any("history" in e for e in check_bench_history(broken))
    broken = copy.deepcopy(payload)
    del broken["history"][-1]["run_id"]
    assert any("run_id" in e for e in check_bench_history(broken))


def test_check_flags_duplicate_run_ids():
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"].append(copy.deepcopy(broken["history"][-1]))
    assert any("duplicate" in e for e in check_bench_history(broken))


def test_check_reports_non_dict_history_entry_instead_of_crashing():
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"].insert(0, "oops")
    errors = check_bench_history(broken)  # must not raise
    assert any("not an object" in e for e in errors)


def test_rerecording_a_stamp_replaces_instead_of_duplicating():
    """A rerun with the same --run-id (or two unstamped scratch runs) must
    keep the history --check-clean: write_bench_json replaces the prior
    entry for that stamp rather than appending a colliding duplicate."""
    import benchmarks.bench_solver_perf as bsp

    out = {(512, "rsa", "baseline"): 10.0, (512, "rsa", "fused"): 5.0}
    path = os.path.join(os.path.dirname(BENCH_JSON), "_tmp_bench_test.json")
    orig = bsp.BENCH_JSON
    bsp.BENCH_JSON = path
    try:
        bsp.write_bench_json(out, run_id=None)
        bsp.write_bench_json(out, run_id=None)      # second unstamped run
        bsp.write_bench_json(out, run_id="pr-x")
        bsp.write_bench_json(out, run_id="pr-x")    # re-recorded stamp
        with open(path) as f:
            payload = json.load(f)
        stamps = [h["run_id"] for h in payload["history"]]
        assert stamps == ["unstamped", "pr-x"]
        assert check_bench_history(payload) == []
    finally:
        bsp.BENCH_JSON = orig
        if os.path.exists(path):
            os.remove(path)


def test_committed_history_has_spin_sharded_point():
    """The fourth coupling tier is anchored too: the N=16384 sharded point
    must exist and its per-device plane bytes must be exactly half the
    single-device streamed store (D=2 — the aggregate-HBM capacity claim
    as an identity on recorded bytes)."""
    payload = _load()
    results = payload["results"]
    assert "N16384_sharded" in results, sorted(results)
    cell = results["N16384_sharded"]["rsa"]
    assert cell["num_devices"] == 2
    assert cell["plane_bytes_per_device"] * 2 == cell["plane_bytes_total"]
    assert (cell["plane_bytes_per_device"] * 2
            == results["N16384"]["rsa"]["j_bytes_hbm_planes"])
    assert cell["sharded_us_per_step"] > 0
    assert cell["row_broadcast_words_per_step"] > 0


def test_check_flags_broken_sharded_points():
    """--check knows the sharded schema: uneven per-device byte splits, a
    store that is not the single-device planes divided across the mesh, and
    sub-2-device 'sharding' all fail the gate."""
    from benchmarks.run import check_sharded_points

    good = {
        "N16384": {"rsa": {"j_bytes_hbm_planes": 1000}},
        "N16384_sharded": {"rsa": {
            "num_devices": 2, "plane_bytes_per_device": 500,
            "plane_bytes_total": 1000, "sharded_us_per_step": 3.0}},
    }
    assert check_sharded_points(good) == []
    uneven = copy.deepcopy(good)
    uneven["N16384_sharded"]["rsa"]["plane_bytes_per_device"] = 400
    assert any("divide the store evenly" in e
               for e in check_sharded_points(uneven))
    mismatched = copy.deepcopy(good)
    mismatched["N16384"]["rsa"]["j_bytes_hbm_planes"] = 800
    assert any("divided 2 ways" in e for e in check_sharded_points(mismatched))
    single = copy.deepcopy(good)
    single["N16384_sharded"]["rsa"].update(num_devices=1,
                                           plane_bytes_per_device=1000)
    assert any(">= 2 devices" in e for e in check_sharded_points(single))
    incomplete = {"N16384_sharded": {"rsa": {"num_devices": 2}}}
    assert any("needs integer" in e for e in check_sharded_points(incomplete))
    # ...and the full checker routes through the same validation.
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"][-1]["results"].update(copy.deepcopy(uneven))
    broken["results"] = broken["history"][-1]["results"]
    assert any("divide the store evenly" in e
               for e in check_bench_history(broken))


def test_committed_history_has_sharded_2d_point():
    """The 2-D mesh tier is anchored too: the N=16384 2x2 cell must exist
    with byte-identical 1-D/2-D best energies, per-device plane bytes equal
    to total/rows (capacity scales with the rows axis, groups replicate),
    and both layouts' throughput recorded in the same run."""
    payload = _load()
    results = payload["results"]
    assert "N16384_sharded_2d" in results, sorted(results)
    cell = results["N16384_sharded_2d"]["rsa"]
    assert cell["num_groups"] >= 2 and cell["rows_per_group"] >= 2
    assert cell["num_devices"] == cell["num_groups"] * cell["rows_per_group"]
    assert (cell["plane_bytes_per_device_2d"] * cell["rows_per_group"]
            == cell["plane_bytes_total"])
    assert (cell["plane_bytes_per_device_1d"] * cell["num_devices"]
            == cell["plane_bytes_total"])
    assert cell["plane_bytes_per_device_2d"] < cell["plane_bytes_total"]
    assert cell["best_energy_1d"] == cell["best_energy_2d"]
    assert cell["us_per_step_1d"] > 0 and cell["us_per_step_2d"] > 0
    assert cell["replica_steps_per_sec_2d"] > 0
    # One packed store, two accountings: the plain sharded cell at the same
    # N must record the identical total.
    assert (cell["plane_bytes_total"]
            == results["N16384_sharded"]["rsa"]["plane_bytes_total"])


def test_check_flags_broken_sharded_2d_points():
    """--check knows the 2-D schema: a rows split that does not divide the
    store, energies that diverge between layouts, a degenerate 1-axis
    'mesh', a total disagreeing with the plain sharded cell, and missing
    columns all fail the gate."""
    from benchmarks.run import check_sharded_2d_points

    good = {
        "N16384_sharded": {"rsa": {"plane_bytes_total": 1000}},
        "N16384_sharded_2d": {"rsa": {
            "num_devices": 4, "num_groups": 2, "rows_per_group": 2,
            "plane_bytes_total": 1000, "plane_bytes_per_device_1d": 250,
            "plane_bytes_per_device_2d": 500,
            "us_per_step_1d": 4.0, "us_per_step_2d": 3.0,
            "replica_steps_per_sec_1d": 10.0,
            "replica_steps_per_sec_2d": 19.0,
            "best_energy_1d": [-5.0, -4.0], "best_energy_2d": [-5.0, -4.0]}},
    }
    assert check_sharded_2d_points(good) == []
    uneven = copy.deepcopy(good)
    uneven["N16384_sharded_2d"]["rsa"]["plane_bytes_per_device_2d"] = 400
    assert any("rows axis must divide the store" in e
               for e in check_sharded_2d_points(uneven))
    diverged = copy.deepcopy(good)
    diverged["N16384_sharded_2d"]["rsa"]["best_energy_2d"] = [-5.0, -3.0]
    assert any("byte-identical" in e
               for e in check_sharded_2d_points(diverged))
    degenerate = copy.deepcopy(good)
    degenerate["N16384_sharded_2d"]["rsa"].update(
        num_groups=1, num_devices=2, plane_bytes_per_device_1d=500)
    assert any("degenerates to 1-D" in e
               for e in check_sharded_2d_points(degenerate))
    mismatched = copy.deepcopy(good)
    mismatched["N16384_sharded"]["rsa"]["plane_bytes_total"] = 800
    assert any("same packed store" in e
               for e in check_sharded_2d_points(mismatched))
    incomplete = {"N16384_sharded_2d": {"rsa": {"num_devices": 4}}}
    assert any("needs integer" in e
               for e in check_sharded_2d_points(incomplete))
    # ...and the full checker routes through the same validation.
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"][-1]["results"].update(copy.deepcopy(uneven))
    broken["results"] = broken["history"][-1]["results"]
    assert any("rows axis must divide the store" in e
               for e in check_bench_history(broken))


def test_committed_history_has_sparse_ingest_point():
    """The dense-J-free ingestion anchor: the N=16384 sparse-ingest cell must
    exist, its sparse setup must undercut the recorded dense detour, and its
    build peak must sit under the (N, N) f32 it never materializes."""
    payload = _load()
    results = payload["results"]
    assert "N16384_sparse_ingest" in results, sorted(results)
    cell = results["N16384_sparse_ingest"]["rsa"]
    assert cell["nnz"] > 0
    assert cell["setup_seconds"] <= cell["setup_seconds_dense_ingest"]
    assert cell["peak_j_build_bytes"] < cell["j_bytes_dense_f32"]
    assert cell["j_bytes_dense_f32"] == 16384 * 16384 * 4
    assert cell["sparse_solve_us_per_step"] > 0
    # The single-engine plane points carry their own setup accounting too.
    for key in ("N4096", "N16384"):
        point = results[key]["rsa"]
        assert point["setup_seconds"] > 0
        assert point["peak_j_build_bytes"] > 0


def test_check_flags_broken_ingestion_points():
    """--check knows the sparse-ingest schema: missing columns, a sparse
    setup slower than the dense detour, and a build peak at/over the dense
    f32 footprint all fail the gate."""
    from benchmarks.run import check_ingestion_points

    good = {
        "N16384_sparse_ingest": {"rsa": {
            "nnz": 131072, "j_bytes_dense_f32": 16384 * 16384 * 4,
            "setup_seconds": 0.5, "setup_seconds_dense_ingest": 20.0,
            "peak_j_build_bytes": 70 << 20,
            "peak_j_build_bytes_dense_ingest": 5 << 30,
            "sparse_solve_us_per_step": 100.0}},
    }
    assert check_ingestion_points(good) == []
    slow = copy.deepcopy(good)
    slow["N16384_sparse_ingest"]["rsa"]["setup_seconds"] = 30.0
    assert any("must not cost more" in e for e in check_ingestion_points(slow))
    fat = copy.deepcopy(good)
    fat["N16384_sparse_ingest"]["rsa"]["peak_j_build_bytes"] = 2 << 30
    assert any("dense-J-free" in e for e in check_ingestion_points(fat))
    incomplete = {"N16384_sparse_ingest": {"rsa": {"nnz": 4}}}
    assert any("needs positive numeric" in e
               for e in check_ingestion_points(incomplete))
    # ...and the full checker routes through the same validation.
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"][-1]["results"].update(copy.deepcopy(slow))
    broken["results"] = broken["history"][-1]["results"]
    assert any("must not cost more" in e for e in check_bench_history(broken))


def test_committed_history_has_row_traffic_point():
    """The reuse-aware fetch anchor: the multi-replica row-traffic cell must
    exist, its iid unique-row fetches must land strictly under the R·T
    uncoalesced traffic, its collapsed-ensemble fetches at or under one row
    per group-step, and coalescing must not have lost the within-run timing
    comparison at R ≥ 8."""
    payload = _load()
    results = payload["results"]
    key = next((k for k in results if k.endswith("_row_traffic")), None)
    assert key is not None, sorted(results)
    cell = results[key]["rwa"]
    rt = cell["num_replicas"] * cell["num_steps"]
    assert cell["replica_steps"] == rt
    assert cell["num_replicas"] >= 8
    assert 0 < cell["rows_fetched_iid"] < rt
    assert 0 < cell["rows_fetched_ensemble"] <= cell["num_groups"] * cell["num_steps"]
    assert cell["uncoalesced_rows_fetched"] == rt
    assert cell["coalesced_us_per_step"] <= cell["uncoalesced_us_per_step"]


def test_check_flags_broken_row_traffic_points():
    """--check knows the row-traffic schema: a counter at/over the R·T
    uncoalesced traffic (no reuse recovered), fetches above one row per
    replica-step (counter broken), an ensemble point over its group-step
    budget, a coalesced sweep slower than the uncoalesced one, and missing
    columns all fail the gate."""
    from benchmarks.run import check_row_traffic_points

    good = {
        "N512_row_traffic": {"rwa": {
            "num_replicas": 16, "num_steps": 64, "replica_steps": 1024,
            "num_groups": 4, "rows_fetched_iid": 1000,
            "rows_fetched_ensemble": 250, "uncoalesced_rows_fetched": 1024,
            "coalesced_us_per_step": 50.0,
            "uncoalesced_us_per_step": 80.0}},
    }
    assert check_row_traffic_points(good) == []
    no_reuse = copy.deepcopy(good)
    no_reuse["N512_row_traffic"]["rwa"]["rows_fetched_iid"] = 1024
    assert any("no birthday-rate reuse" in e
               for e in check_row_traffic_points(no_reuse))
    over = copy.deepcopy(good)
    over["N512_row_traffic"]["rwa"]["rows_fetched_ensemble"] = 1100
    errors = check_row_traffic_points(over)
    assert any("never fetch more than one row per replica" in e
               for e in errors)
    grouped = copy.deepcopy(good)
    grouped["N512_row_traffic"]["rwa"]["rows_fetched_ensemble"] = 400
    assert any("group-step" in e for e in check_row_traffic_points(grouped))
    slow = copy.deepcopy(good)
    slow["N512_row_traffic"]["rwa"]["coalesced_us_per_step"] = 90.0
    assert any("must not lose to fetch-per-replica" in e
               for e in check_row_traffic_points(slow))
    mismatched = copy.deepcopy(good)
    mismatched["N512_row_traffic"]["rwa"]["replica_steps"] = 999
    assert any("replica_steps" in e
               for e in check_row_traffic_points(mismatched))
    incomplete = {"N512_row_traffic": {"rwa": {"num_replicas": 16}}}
    assert any("needs positive numeric" in e
               for e in check_row_traffic_points(incomplete))
    # ...and the full checker routes through the same validation.
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"][-1]["results"].update(copy.deepcopy(no_reuse))
    broken["results"] = broken["history"][-1]["results"]
    assert any("no birthday-rate reuse" in e
               for e in check_bench_history(broken))


def test_committed_history_has_colored_point():
    """The graph-colored throughput anchor: the N=16384 colored cell must
    exist, colored flips/sec must land strictly above the single-flip
    engine's recorded in the same run, and the per-step ensemble flip count
    must respect the largest color class."""
    payload = _load()
    results = payload["results"]
    assert "N16384_colored" in results, sorted(results)
    cell = results["N16384_colored"]["rsa"]
    assert cell["num_color_classes"] >= 2
    assert cell["colored_flips_per_sec"] > cell["single_flips_per_sec"]
    per_step = cell["colored_flips"] / (cell["colored_steps"]
                                        * cell["num_replicas"])
    assert 1.0 < per_step <= cell["max_class_size"]
    assert cell["colored_us_per_flip"] < cell["single_us_per_flip"]
    assert cell["steps_to_target_colored"] <= cell["colored_steps"]


def test_check_flags_broken_colored_points():
    """--check knows the colored schema: colored throughput at/under the
    single-flip engine's, a per-step flip count above the largest class, a
    degenerate one-class coloring, and missing columns all fail the gate."""
    from benchmarks.run import check_colored_points

    good = {
        "N16384_colored": {"rsa": {
            "num_replicas": 4, "num_color_classes": 11,
            "max_class_size": 2932, "single_steps": 48, "colored_steps": 44,
            "single_flips": 90, "colored_flips": 88000,
            "single_flips_per_sec": 700.0, "colored_flips_per_sec": 30000.0,
            "single_us_per_flip": 1400.0, "colored_us_per_flip": 33.0}},
    }
    assert check_colored_points(good) == []
    slow = copy.deepcopy(good)
    slow["N16384_colored"]["rsa"]["colored_flips_per_sec"] = 600.0
    assert any("multiply flip throughput" in e
               for e in check_colored_points(slow))
    oversize = copy.deepcopy(good)
    oversize["N16384_colored"]["rsa"]["colored_flips"] = 4 * 44 * 3000
    assert any("outside the scheduled class" in e
               for e in check_colored_points(oversize))
    degenerate = copy.deepcopy(good)
    degenerate["N16384_colored"]["rsa"]["num_color_classes"] = 1
    assert any("proves nothing" in e for e in check_colored_points(degenerate))
    incomplete = {"N16384_colored": {"rsa": {"num_replicas": 4}}}
    assert any("needs positive numeric" in e
               for e in check_colored_points(incomplete))
    # ...and the full checker routes through the same validation.
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"][-1]["results"].update(copy.deepcopy(slow))
    broken["results"] = broken["history"][-1]["results"]
    assert any("multiply flip throughput" in e
               for e in check_bench_history(broken))


def test_check_flags_diverged_top_level_results():
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["results"] = {"N1": {}}
    assert any("mirror" in e for e in check_bench_history(broken))


def test_committed_payload_checksum_verifies():
    from benchmarks.run import verify_checksum

    payload = _load()
    assert "checksum" in payload
    assert verify_checksum(payload) == []


def test_checksum_catches_tampering():
    from benchmarks.run import verify_checksum

    broken = copy.deepcopy(_load())
    broken["history"][-1]["results"]["N512"]["rsa"]["fused_us_per_step"] = 0.1
    assert any("checksum mismatch" in e for e in verify_checksum(broken))
    # Legacy files written before checksums were stamped still verify.
    legacy = copy.deepcopy(_load())
    del legacy["checksum"]
    assert verify_checksum(legacy) == []


def test_write_bench_payload_is_atomic_and_stamped(tmp_path):
    """write_bench_payload must go through a temp file + rename (no torn
    half-written JSON visible at the target path) and stamp a checksum
    that verifies on reload."""
    from benchmarks.run import verify_checksum, write_bench_payload

    path = str(tmp_path / "bench.json")
    payload = copy.deepcopy(_load())
    write_bench_payload(payload, path)
    with open(path) as f:
        reloaded = json.load(f)
    assert verify_checksum(reloaded) == []
    assert check_bench_history(reloaded) == []
    # Nothing but the final file may remain — no orphaned temp artifacts.
    assert os.listdir(tmp_path) == ["bench.json"]


def test_check_flags_fused_regression():
    payload = _load()
    broken = copy.deepcopy(payload)
    cell = {"baseline_us_per_step": 100.0, "fused_us_per_step": 131.0,
            "fused_speedup": 100.0 / 131.0}
    broken["history"][-1]["results"]["N512"]["rsa"] = cell
    broken["results"] = broken["history"][-1]["results"]
    errors = check_bench_history(broken)
    assert any("regression gate" in e for e in errors), errors
    # ...and the gate is a gate, not a tripwire for noise: 1.29x passes.
    cell["fused_us_per_step"] = 129.0
    assert check_bench_history(broken) == []


def test_committed_history_has_serve_point():
    """The serving layer is anchored too: the serve cell must exist, its
    warm pass must have re-encoded nothing while the cold pass encoded at
    least once, and its batched throughput must hold at or above the
    sequential baseline recorded in the same run."""
    payload = _load()
    results = payload["results"]
    key = next((k for k in results if k.endswith("_serve")), None)
    assert key is not None, sorted(results)
    cell = results[key]["rsa"]
    assert cell["warm_encode_calls"] == 0
    assert cell["cold_encode_calls"] >= 1
    assert cell["batched_solves_per_sec"] >= cell["sequential_solves_per_sec"]
    assert cell["batched_launches"] < cell["sequential_launches"]
    assert cell["batched_p99_latency_s"] > 0


def test_check_flags_broken_serve_points():
    """--check knows the serve schema: a warm pass that re-encodes, a cold
    pass that never encoded (a vacuous zero), batched throughput under the
    sequential baseline, and missing columns all fail the gate."""
    from benchmarks.run import check_serve_points

    good = {
        "N48_serve": {"rsa": {
            "batched_solves_per_sec": 300.0,
            "sequential_solves_per_sec": 200.0,
            "batched_p50_latency_s": 0.03, "batched_p99_latency_s": 0.04,
            "sequential_p50_latency_s": 0.04,
            "sequential_p99_latency_s": 0.05,
            "cold_encode_calls": 3, "warm_encode_calls": 0}},
    }
    assert check_serve_points(good) == []
    leaky = copy.deepcopy(good)
    leaky["N48_serve"]["rsa"]["warm_encode_calls"] = 2
    assert any("skip the resolve" in e for e in check_serve_points(leaky))
    vacuous = copy.deepcopy(good)
    vacuous["N48_serve"]["rsa"]["cold_encode_calls"] = 0
    assert any("proves nothing" in e for e in check_serve_points(vacuous))
    slow = copy.deepcopy(good)
    slow["N48_serve"]["rsa"]["batched_solves_per_sec"] = 150.0
    assert any("must not lose" in e for e in check_serve_points(slow))
    incomplete = {"N48_serve": {"rsa": {"batched_solves_per_sec": 1.0}}}
    assert any("needs positive numeric" in e
               for e in check_serve_points(incomplete))
    # ...and the full checker routes through the same validation.
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"][-1]["results"].update(copy.deepcopy(leaky))
    broken["results"] = broken["history"][-1]["results"]
    assert any("skip the resolve" in e for e in check_bench_history(broken))
