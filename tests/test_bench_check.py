"""Tier-1 wiring for ``benchmarks.run --check``: the committed perf anchor
must always validate, and the checker must actually have teeth — perf-touching
PRs regress ``BENCH_solver_perf.json`` and this gate is what stops a silently
slower fused engine (or a hand-mangled history) from landing."""
import copy
import json
import os

import pytest

from benchmarks.run import BENCH_JSON, check_bench_history

pytestmark = pytest.mark.skipif(
    not os.path.exists(BENCH_JSON),
    reason="BENCH_solver_perf.json not present (fresh checkout before any "
           "benchmark run)")


def _load():
    with open(BENCH_JSON) as f:
        return json.load(f)


def test_committed_bench_json_is_healthy():
    payload = _load()
    assert check_bench_history(payload) == []


def test_committed_history_has_hbm_streamed_point():
    """The scaling story is anchored by recorded sizes each VMEM tier cannot
    reach: the N=4096 packed point and the N=16384 HBM-streamed point."""
    payload = _load()
    results = payload["results"]
    assert "N16384" in results, sorted(results)
    point = results["N16384"]["rsa"]
    assert point["num_planes"] >= 1
    # The streamed store must be the only tier that fits: dense f32 is 1 GiB,
    # VMEM planes 4x the 16 MiB budget.
    assert point["j_bytes_dense_f32"] == 16384 * 16384 * 4
    assert point["j_bytes_vmem_planes"] > 16 * 2 ** 20
    assert point["bitplane_hbm_us_per_step"] > 0


def test_check_flags_missing_fields():
    payload = _load()
    broken = copy.deepcopy(payload)
    del broken["history"]
    assert any("history" in e for e in check_bench_history(broken))
    broken = copy.deepcopy(payload)
    del broken["history"][-1]["run_id"]
    assert any("run_id" in e for e in check_bench_history(broken))


def test_check_flags_duplicate_run_ids():
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"].append(copy.deepcopy(broken["history"][-1]))
    assert any("duplicate" in e for e in check_bench_history(broken))


def test_check_reports_non_dict_history_entry_instead_of_crashing():
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["history"].insert(0, "oops")
    errors = check_bench_history(broken)  # must not raise
    assert any("not an object" in e for e in errors)


def test_rerecording_a_stamp_replaces_instead_of_duplicating():
    """A rerun with the same --run-id (or two unstamped scratch runs) must
    keep the history --check-clean: write_bench_json replaces the prior
    entry for that stamp rather than appending a colliding duplicate."""
    import benchmarks.bench_solver_perf as bsp

    out = {(512, "rsa", "baseline"): 10.0, (512, "rsa", "fused"): 5.0}
    path = os.path.join(os.path.dirname(BENCH_JSON), "_tmp_bench_test.json")
    orig = bsp.BENCH_JSON
    bsp.BENCH_JSON = path
    try:
        bsp.write_bench_json(out, run_id=None)
        bsp.write_bench_json(out, run_id=None)      # second unstamped run
        bsp.write_bench_json(out, run_id="pr-x")
        bsp.write_bench_json(out, run_id="pr-x")    # re-recorded stamp
        with open(path) as f:
            payload = json.load(f)
        stamps = [h["run_id"] for h in payload["history"]]
        assert stamps == ["unstamped", "pr-x"]
        assert check_bench_history(payload) == []
    finally:
        bsp.BENCH_JSON = orig
        if os.path.exists(path):
            os.remove(path)


def test_check_flags_diverged_top_level_results():
    payload = _load()
    broken = copy.deepcopy(payload)
    broken["results"] = {"N1": {}}
    assert any("mirror" in e for e in check_bench_history(broken))


def test_check_flags_fused_regression():
    payload = _load()
    broken = copy.deepcopy(payload)
    cell = {"baseline_us_per_step": 100.0, "fused_us_per_step": 131.0,
            "fused_speedup": 100.0 / 131.0}
    broken["history"][-1]["results"]["N512"]["rsa"] = cell
    broken["results"] = broken["history"][-1]["results"]
    errors = check_bench_history(broken)
    assert any("regression gate" in e for e in errors), errors
    # ...and the gate is a gate, not a tripwire for noise: 1.29x passes.
    cell["fused_us_per_step"] = 129.0
    assert check_bench_history(broken) == []
