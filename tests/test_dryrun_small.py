"""Dry-run machinery on a small mesh (8 forced host devices, smoke configs) —
exercises abstract inputs, train/prefill/decode lowering, sharding rules, and
the roofline extraction end-to-end without the production 512-device mesh.
Runs in a subprocess (device count locks at first jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8, timeout: int = 540) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_mesh_constructors():
    out = _run("""
        import jax
        from repro.launch.mesh import make_production_mesh, make_host_mesh
        # 512-device production meshes can't build on 8 devices; host mesh can.
        m = make_host_mesh(model_parallel=2, pods=2)
        assert m.axis_names == ('pod', 'data', 'model')
        assert m.devices.size == 8
        m2 = make_host_mesh(model_parallel=4)
        assert m2.axis_names == ('data', 'model')
        print('MESH OK')
    """)
    assert "MESH OK" in out


@pytest.mark.slow
def test_abstract_lowering_all_kinds():
    out = _run("""
        import dataclasses, jax
        from jax.sharding import Mesh
        import numpy as np
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch.abstracts import (abstract_cache, abstract_train_state,
                                            input_specs, rules_for)
        from repro.launch.dryrun import build_lowered
        from repro.roofline import analyze_compiled

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen2-7b", smoke=True)
        shapes = [InputShape("train", 64, 8, "train"),
                  InputShape("prefill", 64, 8, "prefill"),
                  InputShape("decode", 64, 8, "decode")]
        for shape in shapes:
            lowered, model_flops = build_lowered(cfg, shape, mesh, multi_pod=True)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            assert mem.temp_size_in_bytes >= 0
            rep = analyze_compiled(compiled, arch=cfg.name, shape=shape.name,
                                   mesh_name="test", num_devices=8,
                                   model_flops=model_flops)
            assert rep.t_compute > 0 and rep.t_memory > 0
            assert rep.bottleneck in ("compute", "memory", "collective")
            print(shape.name, "ok", rep.bottleneck)
        print("LOWERING OK")
    """)
    assert "LOWERING OK" in out


@pytest.mark.slow
def test_moe_and_hybrid_cells_lower():
    out = _run("""
        import jax
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch.dryrun import build_lowered

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("granite-moe-1b-a400m", "jamba-1.5-large-398b", "rwkv6-1.6b",
                     "hubert-xlarge"):
            cfg = get_config(arch, smoke=True)
            shape = InputShape("train", 32, 8, "train")
            lowered, _ = build_lowered(cfg, shape, mesh, multi_pod=False)
            lowered.compile()
            print(arch, "train ok")
            if cfg.causal:
                shape = InputShape("decode", 64, 8, "decode")
                lowered, _ = build_lowered(cfg, shape, mesh, multi_pod=False)
                lowered.compile()
                print(arch, "decode ok")
        print("CELLS OK")
    """)
    assert "CELLS OK" in out


def test_collectives_present_in_sharded_train():
    """The multi-axis train step must actually communicate (all-reduce/
    reduce-scatter over data axis; all-gathers from FSDP)."""
    out = _run("""
        import jax
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch.dryrun import build_lowered
        from repro.roofline import hlo_cost

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen2-7b", smoke=True)
        lowered, _ = build_lowered(cfg, InputShape("train", 64, 8, "train"),
                                   mesh, multi_pod=False)
        txt = lowered.compile().as_text()
        cost = hlo_cost.analyze(txt, default_group=8)
        assert cost.wire_bytes > 0, "no collectives found in sharded train step"
        kinds = set(cost.collective_bytes_by_op)
        print("KINDS", sorted(kinds))
        assert kinds & {"all-reduce", "reduce-scatter", "all-gather"}
    """)
    assert "KINDS" in out
