"""Per-kernel allclose vs pure-jnp oracles across shape/dtype sweeps (interpret mode)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane, ising
from repro.core.schedules import geometric
from repro.core.solver import SolverConfig, solve
from repro.kernels import ops, ref
from repro.kernels.bitplane_field import bitplane_field_init as bp_kernel
from repro.kernels.local_field import local_field_init as lf_kernel
from repro.kernels.sweep import mcmc_sweep as sweep_kernel


def _sym(rng, n, dtype=np.float32, integer=False, scale=1.0):
    J = rng.normal(size=(n, n)) * scale
    if integer:
        J = np.rint(J)
    J = np.triu(J, 1)
    return (J + J.T).astype(dtype)


@pytest.mark.parametrize("r,n,br,bn,bk", [
    (8, 256, 8, 128, 128),
    pytest.param(16, 512, 8, 256, 512, marks=pytest.mark.slow),
    (4, 128, 4, 128, 64),
    pytest.param(32, 384, 16, 128, 128, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("sdtype,jdtype", [
    (jnp.int8, jnp.float32),
    (jnp.float32, jnp.float32),
    (jnp.int8, jnp.int8),
    (jnp.bfloat16, jnp.bfloat16),
])
def test_local_field_kernel_shapes_dtypes(r, n, br, bn, bk, sdtype, jdtype):
    rng = np.random.default_rng(r * n)
    s = np.where(rng.random((r, n)) < 0.5, 1, -1)
    J = _sym(rng, n, integer=(jdtype == jnp.int8), scale=3.0)
    h = rng.normal(size=n).astype(np.float32)
    s_j = jnp.asarray(s, sdtype)
    J_j = jnp.asarray(J, jdtype)
    h_j = jnp.asarray(h)
    got = lf_kernel(s_j, J_j, h_j, block_r=br, block_n=bn, block_k=bk, interpret=True)
    want = ref.local_field_init(s_j, J_j, h_j)
    tol = 2e-2 if jdtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol * n)


def test_local_field_kernel_rejects_bad_blocks():
    with pytest.raises(ValueError, match="divisible"):
        lf_kernel(jnp.ones((7, 128), jnp.int8), jnp.zeros((128, 128)),
                  jnp.zeros(128), block_r=4, interpret=True)


@pytest.mark.parametrize("n,b,r", [
    (64, 1, 4), (128, 2, 8),
    pytest.param(256, 8, 8, marks=pytest.mark.slow),
    pytest.param(96, 4, 16, marks=pytest.mark.slow),
])
def test_bitplane_kernel_matches_oracle_and_dense(n, b, r):
    rng = np.random.default_rng(n + b)
    limit = (1 << b) - 1
    J = rng.integers(-limit, limit + 1, size=(n, n))
    J = np.triu(J, 1)
    J = J + J.T
    planes = bitplane.encode_couplings(J, b)
    s = np.where(rng.random((r, n)) < 0.5, 1, -1).astype(np.int8)
    words = bitplane.pack_spins(jnp.asarray(s))
    got = bp_kernel(planes.pos, planes.neg, words, block_r=min(8, r),
                    block_n=min(128, n), interpret=True)
    want = ref.bitplane_field_init(planes.pos, planes.neg, words, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got), s.astype(np.float64) @ J.T, atol=1e-3)


def _sweep_inputs(rng, J, r, n, t):
    s0 = np.where(rng.random((r, n)) < 0.5, 1.0, -1.0).astype(np.float32)
    u0 = (s0 @ J.T).astype(np.float32)
    e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)).astype(np.float32)
    unif = rng.random((t, r, 4)).astype(np.float32)
    temps = np.broadcast_to(np.geomspace(3.0, 0.05, t).astype(np.float32)[:, None],
                            (t, r)).copy()
    return tuple(map(jnp.asarray, (J, u0, s0, e0, unif, temps)))


@pytest.mark.parametrize("mode", ["rsa", "rwa"])
@pytest.mark.parametrize("r,n,t,br", [
    (8, 128, 64, 8),
    pytest.param(16, 64, 128, 4, marks=pytest.mark.slow),
    pytest.param(4, 256, 32, 4, marks=pytest.mark.slow),
])
def test_sweep_kernel_matches_oracle(mode, r, n, t, br):
    rng = np.random.default_rng(r + n + t)
    args = _sweep_inputs(rng, _sym(rng, n), r, n, t)
    got = sweep_kernel(*args, mode=mode, block_r=br, interpret=True)
    want = ref.mcmc_sweep(*args, mode=mode)
    names = ("fields", "spins", "energy", "best_energy", "best_spins", "num_flips")
    for name, a, b in zip(names, got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-3, err_msg=f"{mode}:{name}")


def test_sweep_onehot_gather_matches_dynamic():
    """The opt-in MXU gather heuristic is a pure perf choice — same trajectory."""
    rng = np.random.default_rng(11)
    r, n, t = 8, 64, 32
    args = _sweep_inputs(rng, _sym(rng, n), r, n, t)
    got_dyn = sweep_kernel(*args, mode="rwa", block_r=4, interpret=True)
    got_oh = sweep_kernel(*args, mode="rwa", block_r=4, gather="onehot",
                          interpret=True)
    for name, a, b in zip(("fields", "spins", "energy", "best_energy",
                           "best_spins", "num_flips"), got_dyn, got_oh):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-4, err_msg=name)


def test_sweep_kernel_step_has_no_quadratic_contraction():
    """Acceptance gate for the O(N²)→O(N) gather fix: the default kernel's
    jaxpr must contain no dot_general at all (the one-hot × J contraction was
    the only matmul in the step loop); the opt-in MXU path must contain it."""
    rng = np.random.default_rng(0)
    r, n, t = 4, 128, 8
    args = _sweep_inputs(rng, _sym(rng, n), r, n, t)

    def trace(gather):
        return str(jax.make_jaxpr(
            lambda *a: sweep_kernel(*a, mode="rwa", block_r=4, gather=gather,
                                    interpret=True))(*args))

    assert "dot_general" not in trace("dynamic")
    assert "dot_general" in trace("onehot")


def test_sweep_bitplane_step_has_no_quadratic_contraction():
    """The bit-plane coupling path keeps the O(N)/step contract: its row
    decode is shift-and-mask bit expansion, so the default step jaxpr must
    contain no dot_general either."""
    rng = np.random.default_rng(0)
    r, n, t = 4, 128, 8
    J = _sym(rng, n, integer=True, scale=2.0)
    planes = bitplane.encode_couplings(np.clip(J, -7, 7), 3)
    _, u0, s0, e0, unif, temps = _sweep_inputs(rng, np.clip(J, -7, 7), r, n, t)
    trace = str(jax.make_jaxpr(
        lambda *a: sweep_kernel(planes, *a, mode="rwa", block_r=4,
                                coupling="bitplane", interpret=True))(
        u0, s0, e0, unif, temps))
    assert "dot_general" not in trace


def test_sweep_bitplane_hbm_step_has_no_quadratic_contraction():
    """The HBM-streamed coupling path keeps the O(N)/step contract too: rows
    arrive by DMA and decode through the same shift-and-mask expansion, so
    the step jaxpr must contain no dot_general — and must actually stream
    (the copy primitive appears; the planes never enter a blocked load)."""
    rng = np.random.default_rng(0)
    r, n, t = 4, 128, 8
    J = _sym(rng, n, integer=True, scale=2.0)
    planes = bitplane.encode_couplings(np.clip(J, -7, 7), 3)
    _, u0, s0, e0, unif, temps = _sweep_inputs(rng, np.clip(J, -7, 7), r, n, t)
    trace = str(jax.make_jaxpr(
        lambda *a: sweep_kernel(planes, *a, mode="rwa", block_r=4,
                                coupling="bitplane_hbm", interpret=True))(
        u0, s0, e0, unif, temps))
    assert "dot_general" not in trace
    assert "dma_start" in trace and "dma_wait" in trace


def test_bitplane_field_kernel_clamps_blocks():
    """Non-dividing block_r/block_n fall back to the largest divisors
    (R=12/block_r=8 → 6; N=96/block_n=64 → 48) instead of raising."""
    rng = np.random.default_rng(4)
    n, b, r = 96, 2, 12
    J = rng.integers(-3, 4, size=(n, n))
    J = np.triu(J, 1)
    J = J + J.T
    planes = bitplane.encode_couplings(J, b)
    s = np.where(rng.random((r, n)) < 0.5, 1, -1).astype(np.int8)
    words = bitplane.pack_spins(jnp.asarray(s))
    got = bp_kernel(planes.pos, planes.neg, words, block_r=8, block_n=64,
                    interpret=True)
    want = ref.bitplane_field_init(planes.pos, planes.neg, words, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sweep_handles_zero_temperature_degenerate():
    """T=0 at a local optimum ⇒ W=0 ⇒ fallback path must not flip or NaN."""
    n, r, t = 32, 4, 16
    J = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    s0 = np.ones((r, n), np.float32)
    u0 = (s0 @ J.T).astype(np.float32)
    e0 = (-0.5 * np.einsum("ri,ri->r", s0, s0 @ J.T)).astype(np.float32)
    unif = np.random.default_rng(0).random((t, r, 4)).astype(np.float32)
    temps = np.zeros((t, r), np.float32)
    got = sweep_kernel(*map(jnp.asarray, (J, u0, s0, e0, unif, temps)),
                       mode="rwa", block_r=4, interpret=True)
    assert np.all(np.asarray(got[1]) == 1.0)
    assert np.all(np.isfinite(np.asarray(got[2])))
    assert np.all(np.asarray(got[5]) == 0)  # zero accepted flips tracked


def test_fused_anneal_solves_and_matches_reference_quality():
    """Optimized backend reaches the same ground state as the paper-faithful
    scan driver on a small exhaustible instance."""
    rng = np.random.default_rng(5)
    n = 12
    J = _sym(rng, n, integer=True, scale=2.0)
    prob = ising.IsingProblem.create(J=J)
    e_star, _, _ = ising.brute_force_ground_state(prob)
    cfg = SolverConfig(num_steps=1024, schedule=geometric(6.0, 0.02, 1024),
                       mode="rwa", num_replicas=8)
    fused = ops.fused_anneal(prob, 3, cfg, chunk_steps=256, interpret=True)
    assert float(jnp.min(fused.best_energy)) == pytest.approx(e_star, abs=1e-2)
    # Energy bookkeeping inside the kernel is exact:
    recomputed = np.asarray(ising.energy(prob, fused.best_spins))
    np.testing.assert_allclose(np.asarray(fused.best_energy), recomputed, atol=1e-2)
    # num_flips is tracked (RWA at T>0 flips nearly every step).
    assert np.all(np.asarray(fused.num_flips) > 0)
    baseline = solve(prob, 3, cfg)
    assert float(jnp.min(baseline.best_energy)) == pytest.approx(e_star, abs=1e-2)


def test_pwl_segment_select_matches_gather_exactly():
    """The lane-friendly PWL formulation (ROADMAP item): a branch-free
    compare-and-select sweep over the S segments must agree with the
    per-element two-gather evaluation *bitwise* — eagerly, under one jit
    (where the compiler could fuse differently), and across the RWA-style
    (T, 1) temperature broadcast — so switching formulations per backend can
    never split kernel/oracle parity."""
    from repro.core.pwl import pwl_table
    from repro.kernels import common

    tbl = pwl_table(64, 8.0)
    g = np.random.default_rng(7)
    # Dense z coverage: interior, exact knots, clamp tails, zero, +/-inf-ish.
    de = np.concatenate([g.normal(size=2048) * 30,
                         np.linspace(-8.5, 8.5, 257),
                         [0.0, 1e30, -1e30]]).astype(np.float32)
    de = jnp.asarray(np.broadcast_to(de, (4, de.size)))
    for t in (0.0, 0.25, 1.0, 7.0):
        a = common.flip_probability(de, t, tbl, pwl_select="gather")
        b = common.flip_probability(de, t, tbl, pwl_select="select")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    temps = jnp.asarray([0.0, 0.5, 1.0, 3.0])[:, None]
    np.testing.assert_array_equal(
        np.asarray(common.flip_probability(de, temps, tbl, pwl_select="gather")),
        np.asarray(common.flip_probability(de, temps, tbl, pwl_select="select")))
    fn = jax.jit(lambda d: (
        common.flip_probability(d, 0.7, tbl, pwl_select="gather"),
        common.flip_probability(d, 0.7, tbl, pwl_select="select")))
    a, b = fn(de)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="pwl_select"):
        common.flip_probability(de, 1.0, tbl, pwl_select="nope")
    # Default resolution is deterministic per backend (gather off-TPU), so
    # kernel and oracle always land on the same formulation.
    assert common.default_pwl_select() in ("gather", "select")


def test_sweep_trajectory_invariant_under_pwl_formulation(monkeypatch):
    """End-to-end guard: forcing the select formulation through the fused
    sweep leaves the whole trajectory bit-identical to the gather default."""
    from repro.kernels import common

    rng = np.random.default_rng(3)
    n = 48
    J = _sym(rng, n, integer=True, scale=2.0)
    prob = ising.IsingProblem.create(J=J)
    cfg = SolverConfig(num_steps=128, schedule=geometric(4.0, 0.05, 128),
                       mode="rwa", num_replicas=4, trace_every=32)
    base = ops.fused_anneal(prob, 9, cfg, interpret=True)
    monkeypatch.setattr(common, "default_pwl_select", lambda: "select")
    # Tracing re-resolves the formulation; with trace_every set the chunk
    # plan ignores chunk_steps, so bumping it forces a fresh trace (a cached
    # jit would silently reuse the gather path) without touching cadence.
    forced = ops.fused_anneal(prob, 9, cfg, interpret=True, chunk_steps=257)
    for name in ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy"):
        np.testing.assert_array_equal(np.asarray(getattr(base, name)),
                                      np.asarray(getattr(forced, name)),
                                      err_msg=name)
