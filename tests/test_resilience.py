"""Resilient-solve supervisor: monolithic parity, bit-identical resume,
corrupt-snapshot recovery, budgets, and tier fallback (in-process tiers;
the spin-sharded tier's kill-and-resume runs on a forced mesh in
``test_fault_injection.py``)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ising, schedules
from repro.core.solver import SolverConfig, solve
from repro.core.tempering import TemperingConfig, solve_tempering
from repro.core.resilience import (BudgetConfig, run_resilient,
                                   inject_faults, is_allocation_failure,
                                   next_tier, STOP_COMPLETED, STOP_DEADLINE,
                                   STOP_INTERRUPTED, STOP_MAX_STEPS,
                                   STOP_TARGET)
from repro.checkpoint import snapshot_steps

from fault_injection import (SimulatedCrash, corrupt_snapshot, fake_oom,
                             kill_after_chunk_hook, oom_once_hook)

N = 64
STEPS = 120
TRACE = 20          # -> 6 chunks
REPLICAS = 4
FUSED_FMTS = ("dense", "bitplane", "bitplane_hbm")
RESULT_FIELDS = ("best_energy", "best_spins", "final_energy", "num_flips",
                 "trace_energy")


def _problem():
    g = np.random.default_rng(0)
    J = np.clip(np.rint(g.normal(size=(N, N)) * 1.5), -3, 3)
    J = np.triu(J, 1)
    J = J + J.T
    h = g.normal(size=(N,)).astype(np.float32)
    return ising.IsingProblem.create(J, h, offset=1.5)


@pytest.fixture(scope="module")
def problem():
    return _problem()


def _cfg(mode="rwa", fmt="auto"):
    return SolverConfig(num_steps=STEPS,
                        schedule=schedules.linear(3.0, 0.1, STEPS),
                        mode=mode, num_replicas=REPLICAS, trace_every=TRACE,
                        coupling_format=fmt)


def _tcfg(fmt="auto"):
    return TemperingConfig(num_steps=STEPS, t_min=0.1, t_max=3.0,
                           num_replicas=REPLICAS, swap_every=TRACE,
                           backend="fused", coupling_format=fmt)


def _assert_same_solve(mono, got):
    for field in RESULT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, field)), np.asarray(getattr(got, field)),
            err_msg=field)


def _assert_same_tempering(mono, got):
    for field in ("best_energy", "best_spins", "final_energy",
                  "swap_acceptance", "num_flips"):
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, field)), np.asarray(getattr(got, field)),
            err_msg=field)


def _interrupted_then_resumed(problem, config, tmp_path, boundary, *,
                              seed=7, backend="auto"):
    """Kill a checkpointed run right after snapshot ``boundary``, resume it,
    and return the resumed ResilientResult."""
    run_dir = str(tmp_path / f"run_b{boundary}")
    with pytest.raises(SimulatedCrash):
        run_resilient(problem, seed, config, run_dir=run_dir,
                      backend=backend,
                      on_event=kill_after_chunk_hook(boundary))
    res = run_resilient(problem, seed, config, run_dir=run_dir,
                        backend=backend)
    assert res.resumed_from_chunk == boundary
    assert res.stop_reason == STOP_COMPLETED
    return res


# ---------------------------------------------------------------- parity

@pytest.mark.parametrize("fmt,mode", [("dense", "rsa"), ("dense", "rwa"),
                                      ("bitplane", "rwa"),
                                      ("bitplane_hbm", "rsa")])
def test_resilient_matches_monolithic_fused(problem, fmt, mode):
    cfg = _cfg(mode, fmt)
    mono = solve(problem, 7, cfg, backend="fused")
    res = run_resilient(problem, 7, cfg)
    assert res.stop_reason == STOP_COMPLETED
    assert res.chunks_done == res.total_chunks == STEPS // TRACE
    assert res.steps_done == STEPS
    _assert_same_solve(mono, res.result)


def test_resilient_matches_monolithic_reference(problem):
    cfg = _cfg("rwa", "auto")
    mono = solve(problem, 7, cfg, backend="reference")
    res = run_resilient(problem, 7, cfg, backend="reference")
    assert res.stop_reason == STOP_COMPLETED
    _assert_same_solve(mono, res.result)


@pytest.mark.parametrize("fmt", ["dense", "bitplane"])
def test_resilient_matches_monolithic_tempering(problem, fmt):
    tc = _tcfg(fmt)
    mono = solve_tempering(problem, 7, tc)
    res = run_resilient(problem, 7, tc)
    assert res.stop_reason == STOP_COMPLETED
    _assert_same_tempering(mono, res.result)


def test_untraced_run_covers_remainder_chunk(problem):
    # 120 steps at chunk_steps=50 -> chunks of 50, 50, and a 20-step tail.
    # Chunking is part of the RNG stream layout for untraced runs, so the
    # monolithic oracle must be driven at the same chunk_steps.
    from repro.kernels.ops import fused_anneal
    cfg = SolverConfig(num_steps=STEPS,
                       schedule=schedules.linear(3.0, 0.1, STEPS),
                       num_replicas=REPLICAS)
    mono = fused_anneal(problem, 7, cfg, chunk_steps=50)
    res = run_resilient(problem, 7, cfg, chunk_steps=50)
    assert res.total_chunks == 3 and res.steps_done == STEPS
    _assert_same_solve(mono, res.result)


# ---------------------------------------------------------------- resume

def test_resume_parity_every_boundary(problem, tmp_path):
    """Interrupt at EVERY chunk boundary (bitplane x rwa): the resumed
    trajectory must be bit-identical to the uninterrupted one."""
    cfg = _cfg("rwa", "bitplane")
    mono = solve(problem, 7, cfg, backend="fused")
    for boundary in range(1, STEPS // TRACE):
        res = _interrupted_then_resumed(problem, cfg, tmp_path, boundary)
        _assert_same_solve(mono, res.result)


@pytest.mark.parametrize("fmt,mode", [("dense", "rsa"),
                                      ("bitplane_hbm", "rwa")])
def test_resume_parity_one_boundary(problem, tmp_path, fmt, mode):
    cfg = _cfg(mode, fmt)
    mono = solve(problem, 7, cfg, backend="fused")
    res = _interrupted_then_resumed(problem, cfg, tmp_path, 2)
    _assert_same_solve(mono, res.result)


def test_resume_parity_reference(problem, tmp_path):
    cfg = _cfg("rwa", "auto")
    mono = solve(problem, 7, cfg, backend="reference")
    res = _interrupted_then_resumed(problem, cfg, tmp_path, 3,
                                    backend="reference")
    _assert_same_solve(mono, res.result)


def test_resume_parity_tempering(problem, tmp_path):
    tc = _tcfg("bitplane")
    mono = solve_tempering(problem, 7, tc)
    res = _interrupted_then_resumed(problem, tc, tmp_path, 2)
    _assert_same_tempering(mono, res.result)


@pytest.mark.slow
@pytest.mark.parametrize("fmt", FUSED_FMTS)
@pytest.mark.parametrize("mode", ["rsa", "rwa"])
def test_resume_parity_full_matrix(problem, tmp_path, fmt, mode):
    cfg = _cfg(mode, fmt)
    mono = solve(problem, 7, cfg, backend="fused")
    for boundary in range(1, STEPS // TRACE):
        res = _interrupted_then_resumed(problem, cfg, tmp_path, boundary)
        _assert_same_solve(mono, res.result)


@pytest.mark.slow
@pytest.mark.parametrize("fmt", ["dense", "bitplane", "bitplane_hbm"])
def test_resume_parity_tempering_full(problem, tmp_path, fmt):
    tc = _tcfg(fmt)
    mono = solve_tempering(problem, 7, tc)
    for boundary in range(1, STEPS // TRACE):
        res = _interrupted_then_resumed(problem, tc, tmp_path, boundary)
        _assert_same_tempering(mono, res.result)


# ------------------------------------------------------------ corruption

def test_corrupt_newest_snapshot_falls_back(problem, tmp_path):
    cfg = _cfg("rwa", "bitplane")
    run_dir = str(tmp_path / "run")
    with pytest.raises(SimulatedCrash):
        run_resilient(problem, 7, cfg, run_dir=run_dir, keep=10,
                      on_event=kill_after_chunk_hook(4))
    assert snapshot_steps(run_dir) == [1, 2, 3, 4]
    corrupt_snapshot(run_dir, 4, how="flip")
    events = []
    res = run_resilient(problem, 7, cfg, run_dir=run_dir, keep=10,
                        on_event=lambda k, i: events.append(k))
    assert res.resumed_from_chunk == 3
    assert "snapshot_corrupt" in events
    _assert_same_solve(solve(problem, 7, cfg, backend="fused"), res.result)


@pytest.mark.parametrize("how", ["truncate", "manifest", "legacy_empty"])
def test_all_snapshots_corrupt_restarts_fresh(problem, tmp_path, how):
    cfg = _cfg("rwa", "bitplane")
    run_dir = str(tmp_path / f"run_{how}")
    with pytest.raises(SimulatedCrash):
        run_resilient(problem, 7, cfg, run_dir=run_dir,
                      on_event=kill_after_chunk_hook(3))
    for step in snapshot_steps(run_dir):
        corrupt_snapshot(run_dir, step, how=how)
    res = run_resilient(problem, 7, cfg, run_dir=run_dir)
    assert res.resumed_from_chunk is None
    assert res.stop_reason == STOP_COMPLETED
    _assert_same_solve(solve(problem, 7, cfg, backend="fused"), res.result)


def test_legacy_snapshot_truncated_npz_falls_back(problem, tmp_path):
    """A pre-checksum snapshot (no ``arrays_sha256`` in the manifest) whose
    arrays.npz was torn to zero bytes: ``np.load`` raises ``EOFError`` with
    no checksum gate in front of it, and the newest-first walk must convert
    that into fallback to the next-older snapshot, not crash."""
    cfg = _cfg("rwa", "bitplane")
    run_dir = str(tmp_path / "run")
    with pytest.raises(SimulatedCrash):
        run_resilient(problem, 7, cfg, run_dir=run_dir, keep=10,
                      on_event=kill_after_chunk_hook(4))
    corrupt_snapshot(run_dir, 4, how="legacy_empty")
    events = []
    res = run_resilient(problem, 7, cfg, run_dir=run_dir, keep=10,
                        on_event=lambda k, i: events.append(k))
    assert res.resumed_from_chunk == 3
    assert "snapshot_corrupt" in events
    _assert_same_solve(solve(problem, 7, cfg, backend="fused"), res.result)


def test_mismatched_run_dir_is_refused(problem, tmp_path):
    cfg = _cfg("rwa", "bitplane")
    run_dir = str(tmp_path / "run")
    with pytest.raises(SimulatedCrash):
        run_resilient(problem, 7, cfg, run_dir=run_dir,
                      on_event=kill_after_chunk_hook(2))
    other_cfg = _cfg("rsa", "bitplane")
    with pytest.raises(ValueError, match="signature mismatch"):
        run_resilient(problem, 7, other_cfg, run_dir=run_dir)
    with pytest.raises(ValueError, match="mismatch"):
        run_resilient(problem, 8, cfg, run_dir=run_dir)
    with pytest.raises(ValueError, match="mismatch"):
        run_resilient(_problem_with_offset(2.5), 7, cfg, run_dir=run_dir)


def _problem_with_offset(offset):
    p = _problem()
    return ising.IsingProblem.create(np.asarray(p.couplings),
                                     np.asarray(p.fields), offset=offset)


# --------------------------------------------------------------- budgets

def test_budget_max_steps(problem):
    cfg = _cfg("rwa", "bitplane")
    res = run_resilient(problem, 7, cfg, budget=BudgetConfig(max_steps=40))
    assert res.stop_reason == STOP_MAX_STEPS
    assert res.steps_done == 40 and res.chunks_done == 2
    # The partial result is the best-so-far after exactly those chunks.
    assert np.isfinite(np.asarray(res.result.best_energy)).all()
    assert np.asarray(res.result.trace_energy).shape == (2, REPLICAS)


def test_budget_deadline(problem):
    cfg = _cfg("rwa", "bitplane")
    res = run_resilient(problem, 7, cfg,
                        budget=BudgetConfig(deadline_seconds=0.0))
    assert res.stop_reason == STOP_DEADLINE
    assert res.chunks_done == 0


def test_budget_target_energy(problem):
    cfg = _cfg("rwa", "bitplane")
    # A target above the initial energy is hit immediately...
    res = run_resilient(problem, 7, cfg,
                        budget=BudgetConfig(target_energy=1e9))
    assert res.stop_reason == STOP_TARGET and res.chunks_done == 0
    # ...an unreachable one never fires.
    res = run_resilient(problem, 7, cfg,
                        budget=BudgetConfig(target_energy=-1e9))
    assert res.stop_reason == STOP_COMPLETED


def test_budget_stop_then_resume_to_parity(problem, tmp_path):
    cfg = _cfg("rwa", "bitplane")
    run_dir = str(tmp_path / "run")
    res = run_resilient(problem, 7, cfg, run_dir=run_dir,
                        budget=BudgetConfig(max_steps=60))
    assert res.stop_reason == STOP_MAX_STEPS and res.chunks_done == 3
    res = run_resilient(problem, 7, cfg, run_dir=run_dir)
    assert res.resumed_from_chunk == 3
    assert res.stop_reason == STOP_COMPLETED
    _assert_same_solve(solve(problem, 7, cfg, backend="fused"), res.result)


def test_keyboard_interrupt_returns_best_so_far(problem, tmp_path):
    cfg = _cfg("rwa", "bitplane")
    run_dir = str(tmp_path / "run")

    def interrupt(kind, info):
        if kind == "chunk" and info["chunk"] == 2:
            raise KeyboardInterrupt()

    res = run_resilient(problem, 7, cfg, run_dir=run_dir, on_event=interrupt)
    assert res.stop_reason == STOP_INTERRUPTED
    assert res.chunks_done == 2
    assert np.asarray(res.result.trace_energy).shape == (2, REPLICAS)
    # The interrupt frontier was snapshotted; a follow-up run finishes.
    res = run_resilient(problem, 7, cfg, run_dir=run_dir)
    assert res.resumed_from_chunk == 2
    _assert_same_solve(solve(problem, 7, cfg, backend="fused"), res.result)


# ---------------------------------------------------------- tier fallback

def test_is_allocation_failure_classification():
    assert is_allocation_failure(fake_oom())
    assert is_allocation_failure(MemoryError("x"))
    assert is_allocation_failure(RuntimeError("Failed to allocate 8 bytes"))
    assert not is_allocation_failure(ValueError("J must be symmetric"))


def test_next_tier_ladder(problem):
    assert next_tier("dense", problem, None) == "bitplane"
    assert next_tier("bitplane", problem, None) == "bitplane_hbm"
    assert next_tier("bitplane_hbm", problem, None) is None  # no mesh
    assert next_tier("bitplane_sharded", problem, None) is None
    frac = ising.IsingProblem.create(
        np.array([[0.0, 0.5], [0.5, 0.0]], np.float32))
    assert next_tier("dense", frac, None) is None  # fractional J stays dense


def test_downgrade_chain_on_build_oom(problem):
    cfg = _cfg("rwa", "auto")
    mono = solve(problem, 7, cfg, backend="fused")
    with inject_faults(oom_once_hook("store_build",
                                     fmts=("dense", "bitplane"))):
        res = run_resilient(problem, 7, cfg)
    assert [d[:2] for d in res.downgrades] == [
        ("dense", "bitplane"), ("bitplane", "bitplane_hbm")]
    _assert_same_solve(mono, res.result)   # tiers are trajectory-identical


def test_downgrade_midrun_restores_from_snapshot(problem, tmp_path):
    cfg = _cfg("rwa", "auto")
    mono = solve(problem, 7, cfg, backend="fused")
    run_dir = str(tmp_path / "run")
    events = []
    with inject_faults(oom_once_hook("chunk_start", at_chunk=3)):
        res = run_resilient(problem, 7, cfg, run_dir=run_dir,
                            on_event=lambda k, i: events.append((k, i)))
    assert res.downgrades == (("dense", "bitplane", 3),)
    assert ("tier_downgrade" in [k for k, _ in events])
    # Work before the OOM survived: the post-downgrade attempt resumed.
    assert any(k == "resume" and i["chunk"] == 3 for k, i in events)
    _assert_same_solve(mono, res.result)
    # The recorded downgrade survives in the final snapshot.
    res2 = run_resilient(problem, 7, cfg, run_dir=run_dir)
    assert res2.downgrades == (("dense", "bitplane", 3),)


def test_explicit_format_propagates_oom(problem):
    cfg = _cfg("rwa", "dense")   # not "auto": the ladder is disabled
    with inject_faults(oom_once_hook("store_build", fmts=("dense",))):
        with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
            run_resilient(problem, 7, cfg)


def test_non_alloc_error_propagates(problem):
    cfg = _cfg("rwa", "auto")

    def bad(site, info):
        if site == "chunk_start":
            raise ValueError("some real bug")

    with inject_faults(bad):
        with pytest.raises(ValueError, match="some real bug"):
            run_resilient(problem, 7, cfg)
