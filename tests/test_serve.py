"""The serving layer's contracts (DESIGN.md §Serving layer).

The load-bearing claims, each pinned here:

* **Exactness under padding** — spin-bucketed launches report energies
  identical to the unpadded instance's (padding spins are isolated and
  zero-field, so they contribute exactly zero).
* **Bit-identity of the vmap lane** — a seed-pinned request served in a
  ``solve_many`` batch returns exactly what ``solve`` alone returns for
  that (padded problem, seed, config).
* **Span slicing of the stack lane** — replica-stacked requests get back
  their own contiguous replica span, shaped as if they had launched alone.
* **Cache contracts** — warm-instance solves perform zero re-encodes
  (store LRU on the coupling content hash), and a target-energy request
  already satisfied by the warm-start cache is answered without a launch,
  with spins whose recomputed energy equals the cached energy.
* **Admission** — over-cap instances/steps, full queues, unknown backends
  and capability mismatches are refused at submit with actionable errors.

Planning (``plan_batches``) is tested as pure policy, no kernels.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import coupling, ising, schedules
from repro.core.resilience import BudgetConfig
from repro.core.solver import SolverConfig, solve
from repro.serve import (AdmissionError, LRUStoreCache, ServeConfig,
                         SolveRequest, SolverService, WarmStartCache,
                         bucket_replicas, bucket_spins, coupling_digest,
                         pad_problem, plan_batches)

N = 48
STEPS = 96
REPLICAS = 2


def _problem(seed: int = 0) -> ising.IsingProblem:
    rng = np.random.default_rng(seed)
    J = rng.integers(-3, 4, size=(N, N)).astype(np.float32)
    J = np.round((J + J.T) / 2)
    np.fill_diagonal(J, 0)
    h = rng.integers(-2, 3, size=N).astype(np.float32)
    return ising.IsingProblem.create(J, h)


def _cfg(**kw) -> SolverConfig:
    base = dict(num_steps=STEPS, schedule=schedules.geometric(3.0, 0.1, STEPS),
                mode="rsa", num_replicas=REPLICAS, trace_every=16)
    base.update(kw)
    return SolverConfig(**base)


class TestBuckets:
    def test_spin_buckets_round_up(self):
        assert bucket_spins(1) == 64
        assert bucket_spins(64) == 64
        assert bucket_spins(65) == 128
        assert bucket_spins(300) == 384
        assert bucket_spins(16384) == 16384
        # Past the table: next multiple of the last bucket.
        assert bucket_spins(16385) == 32768
        with pytest.raises(ValueError):
            bucket_spins(0)

    def test_replica_buckets_power_of_two(self):
        assert [bucket_replicas(r) for r in (1, 2, 3, 5, 8, 9)] == \
            [1, 2, 4, 8, 8, 16]
        with pytest.raises(ValueError):
            bucket_replicas(0)


class TestPadding:
    def test_padded_energies_exact(self):
        """Isolated zero-coupling zero-field padding spins contribute zero:
        any spin assignment extended arbitrarily into the pad scores the
        same energy as the original instance."""
        prob = _problem(3)
        padded = pad_problem(prob, 64)
        assert padded.num_spins == 64
        rng = np.random.default_rng(0)
        s = rng.choice(np.asarray([-1.0, 1.0], np.float32), size=N)
        s_pad = np.concatenate([s, rng.choice(
            np.asarray([-1.0, 1.0], np.float32), size=64 - N)])
        np.testing.assert_allclose(float(ising.energy(prob, s)),
                                   float(ising.energy(padded, s_pad)),
                                   rtol=1e-6)

    def test_pad_noop_and_shrink_rejected(self):
        prob = _problem(3)
        assert pad_problem(prob, N) is prob
        with pytest.raises(ValueError, match="pad"):
            pad_problem(prob, N - 1)

    def test_edge_list_padding_stays_dense_j_free(self):
        prob = _problem(4)
        rows, cols = np.nonzero(np.triu(np.asarray(prob.couplings), 1))
        w = np.asarray(prob.couplings)[rows, cols]
        ep = ising.IsingProblem.create_sparse(
            ising.EdgeList.create(rows, cols, w, num_spins=N),
            np.asarray(prob.fields))
        padded = pad_problem(ep, 64)
        assert padded.couplings is None and padded.num_spins == 64
        assert padded.edges.nnz == ep.edges.nnz


class TestPlanBatches:
    @dataclasses.dataclass
    class Req:
        problem_key: str
        config: SolverConfig
        seed: object = None

    def test_seed_free_same_instance_stacks(self):
        cfg = _cfg()
        reqs = [self.Req("p1", cfg) for _ in range(3)]
        plans = plan_batches(reqs)
        assert len(plans) == 1 and plans[0].kind == "stack"
        assert plans[0].spans == ((0, 2), (2, 2), (4, 2))
        assert plans[0].launch_replicas == 8      # 6 -> power-of-two bucket
        assert plans[0].config.num_replicas == 8

    def test_pinned_seeds_take_the_vmap_lane(self):
        cfg = _cfg()
        reqs = [self.Req("p1", cfg, seed=i) for i in range(3)]
        plans = plan_batches(reqs)
        assert len(plans) == 1 and plans[0].kind == "vmap"
        assert len(plans[0].requests) == 3

    def test_distinct_instances_never_mix(self):
        cfg = _cfg()
        reqs = [self.Req("p1", cfg), self.Req("p2", cfg), self.Req("p1", cfg)]
        plans = plan_batches(reqs)
        kinds = sorted(p.kind for p in plans)
        assert kinds == ["single", "stack"]
        stack = next(p for p in plans if p.kind == "stack")
        assert all(r.problem_key == "p1" for r in stack.requests)

    def test_config_mismatch_splits_groups(self):
        reqs = [self.Req("p1", _cfg()), self.Req("p1", _cfg(mode="rwa"))]
        plans = plan_batches(reqs)
        assert sorted(p.kind for p in plans) == ["single", "single"]

    def test_flip_mode_mismatch_never_stacks(self):
        """A colored request and a single-flip request run different kernels
        (different backends, different per-step semantics); the planner must
        keep them in separate launches even on the same instance + schedule."""
        reqs = [self.Req("p1", _cfg()), self.Req("p1", _cfg()),
                self.Req("p1", _cfg(flip_mode="colored")),
                self.Req("p1", _cfg(flip_mode="colored"))]
        plans = plan_batches(reqs)
        assert sorted(p.kind for p in plans) == ["stack", "stack"]
        modes = sorted({p.config.flip_mode for p in plans})
        assert modes == ["colored", "single"]
        for p in plans:
            assert {r.config.flip_mode for r in p.requests} == \
                {p.config.flip_mode}

    def test_stack_cap_splits_launches(self):
        cfg = _cfg(num_replicas=100)
        reqs = [self.Req("p1", cfg) for _ in range(3)]
        plans = plan_batches(reqs, max_stack_replicas=256)
        # 100+100 fits under 256; the third spills to its own launch.
        assert sorted(p.kind for p in plans) == ["single", "stack"]

    def test_lone_pinned_seed_launches_single(self):
        plans = plan_batches([self.Req("p1", _cfg(), seed=5)])
        assert len(plans) == 1 and plans[0].kind == "single"


class TestServiceLanes:
    def test_vmap_lane_bit_identical_to_solo_solve(self):
        prob = _problem(1)
        cfg = _cfg()
        svc = SolverService()
        t1 = svc.submit(SolveRequest(prob, cfg, seed=11))
        t2 = svc.submit(SolveRequest(prob, cfg, seed=12))
        out = svc.drain()
        assert out[t1].batched == "vmap" and out[t2].batched == "vmap"
        padded = pad_problem(prob, bucket_spins(N))
        for ticket, seed in ((t1, 11), (t2, 12)):
            ref = solve(padded, seed, cfg, backend="fused")
            np.testing.assert_array_equal(
                np.asarray(ref.best_energy),
                np.asarray(out[ticket].result.best_energy))
            np.testing.assert_array_equal(
                np.asarray(ref.best_spins)[:, :N],
                np.asarray(out[ticket].result.best_spins))

    def test_stack_lane_slices_spans_to_request_shape(self):
        prob = _problem(1)
        svc = SolverService()
        t1 = svc.submit(SolveRequest(prob, _cfg()))
        t2 = svc.submit(SolveRequest(prob, _cfg(num_replicas=3)))
        out = svc.drain()
        assert out[t1].batched == "stack" and out[t2].batched == "stack"
        assert out[t1].result.best_energy.shape == (REPLICAS,)
        assert out[t1].result.best_spins.shape == (REPLICAS, N)
        assert out[t2].result.best_energy.shape == (3,)
        assert out[t2].result.trace_energy.shape == (STEPS // 16, 3)
        # One launch served both requests.
        assert svc.stats["launches"] == 1
        # Reported energies are exact for the sliced spins.
        e = ising.energy(prob, np.asarray(out[t2].result.best_spins[0]))
        assert abs(float(e) - float(out[t2].result.best_energy[0])) < 1e-3

    def test_batching_off_launches_singly_same_results(self):
        prob = _problem(1)
        cfg = _cfg()
        svc = SolverService(ServeConfig(batching=False))
        t1 = svc.submit(SolveRequest(prob, cfg, seed=11))
        out = svc.drain()
        assert out[t1].batched == "single"
        padded = pad_problem(prob, bucket_spins(N))
        ref = solve(padded, 11, cfg, backend="fused")
        np.testing.assert_array_equal(np.asarray(ref.best_energy),
                                      np.asarray(out[t1].result.best_energy))


class TestServiceCaches:
    def test_warm_instance_solves_reencode_nothing(self, monkeypatch):
        calls = {"n": 0}
        real = coupling.encode_couplings

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)
        monkeypatch.setattr(coupling, "encode_couplings", counting)
        prob = _problem(2)
        cfg = _cfg(coupling_format="bitplane")
        svc = SolverService()
        svc.solve(prob, cfg, seed=1)
        assert calls["n"] == 1
        r = svc.solve(prob, cfg, seed=2)         # same instance, new request
        assert calls["n"] == 1, "warm-instance solve must not re-encode"
        assert r.store_hit
        # A *content-equal* resubmission (fresh arrays) hits too.
        r = svc.solve(_problem(2), cfg, seed=3)
        assert calls["n"] == 1 and r.store_hit

    def test_store_cache_lru_eviction(self):
        cache = LRUStoreCache(capacity=2)
        p1, p2, p3 = _problem(1), _problem(2), _problem(3)
        cache.get_or_build(p1, "bitplane")
        cache.get_or_build(p2, "bitplane")
        _, hit = cache.get_or_build(p1, "bitplane")
        assert hit
        cache.get_or_build(p3, "bitplane")       # evicts p2 (LRU)
        assert cache.evictions == 1
        _, hit = cache.get_or_build(p2, "bitplane")
        assert not hit and len(cache) == 2

    def test_warm_start_cache_answers_met_targets_without_launch(self):
        prob = _problem(2)
        svc = SolverService()
        first = svc.solve(prob, _cfg())
        best = float(np.min(np.asarray(first.result.best_energy)))
        launches = svc.stats["launches"]
        hit = svc.solve(prob, _cfg(),
                        budget=BudgetConfig(target_energy=best + 1.0))
        assert hit.stop_reason == "cached_target" and hit.warm_hit
        assert svc.stats["launches"] == launches, "no launch on a met target"
        # The cached spins really score the cached energy.
        e = ising.energy(prob, np.asarray(hit.result.best_spins[0]))
        assert abs(float(e) - float(hit.result.best_energy[0])) < 1e-3
        # An unmet (lower) target still launches, through the supervisor.
        miss = svc.solve(prob, _cfg(),
                         budget=BudgetConfig(target_energy=best - 1e9))
        assert miss.batched == "budgeted"
        assert svc.stats["launches"] == launches + 1

    def test_warm_cache_folds_min_and_bounds_capacity(self):
        cache = WarmStartCache(capacity=2)

        class R:
            def __init__(self, e, n=4):
                self.best_energy = np.asarray([e], np.float32)
                self.best_spins = np.ones((1, n), np.float32)
        rec = cache.observe("a", R(-5.0))
        assert rec.energy == -5.0
        rec = cache.observe("a", R(-3.0))        # worse: keeps -5
        assert rec.energy == -5.0
        cache.observe("b", R(-1.0))
        cache.observe("c", R(-2.0))              # evicts "a"
        assert cache.lookup("a") is None and len(cache) == 2

    def test_budgeted_request_reports_supervisor_stop_reason(self):
        prob = _problem(2)
        svc = SolverService()
        r = svc.solve(prob, _cfg(), seed=3,
                      budget=BudgetConfig(max_steps=STEPS // 2))
        assert r.batched == "budgeted"
        assert r.stop_reason == "max_steps"


class TestAdmission:
    def test_over_cap_instance_and_steps_rejected(self):
        svc = SolverService(ServeConfig(max_spins=16, max_steps=50))
        with pytest.raises(AdmissionError, match="N=48"):
            svc.submit(SolveRequest(_problem(), _cfg()))
        svc2 = SolverService(ServeConfig(max_steps=50))
        with pytest.raises(AdmissionError, match="num_steps"):
            svc2.submit(SolveRequest(_problem(), _cfg()))

    def test_queue_bound(self):
        svc = SolverService(ServeConfig(max_pending=1))
        svc.submit(SolveRequest(_problem(), _cfg()))
        with pytest.raises(AdmissionError, match="queue"):
            svc.submit(SolveRequest(_problem(), _cfg()))

    def test_unknown_backend_and_capability_mismatch(self):
        svc = SolverService()
        with pytest.raises(ValueError, match="backend"):
            svc.submit(SolveRequest(_problem(), _cfg(), backend="nope"))
        prob = _problem(4)
        rows, cols = np.nonzero(np.triu(np.asarray(prob.couplings), 1))
        w = np.asarray(prob.couplings)[rows, cols]
        ep = ising.IsingProblem.create_sparse(
            ising.EdgeList.create(rows, cols, w, num_spins=N))
        with pytest.raises(AdmissionError, match="edge-list"):
            svc.submit(SolveRequest(ep, _cfg(), backend="reference"))
        with pytest.raises(AdmissionError, match="mesh"):
            svc.submit(SolveRequest(_problem(), _cfg(), backend="sharded"))
        # Nothing half-admitted: the queue is still empty.
        assert svc.drain() == {}

    def test_rejection_counters(self):
        svc = SolverService(ServeConfig(max_spins=16))
        with pytest.raises(AdmissionError):
            svc.submit(SolveRequest(_problem(), _cfg()))
        assert svc.stats["rejected"] == 1 and svc.stats["admitted"] == 0


class TestDigests:
    def test_coupling_digest_is_content_not_identity(self):
        assert coupling_digest(_problem(1)) == coupling_digest(_problem(1))
        assert coupling_digest(_problem(1)) != coupling_digest(_problem(2))

    def test_coupling_digest_separates_dtypes_with_identical_bytes(self):
        """An int32 J and its float32 bit-pattern twin are different
        couplings with identical shape+bytes — their cache keys must differ
        or one tenant is served a store built from the other's matrix.
        (``IsingProblem.create`` canonicalizes to f32, but problems also
        enter as pytrees — ``tree_unflatten`` preserves whatever dtype the
        couplings leaf carries.)"""
        g = np.random.default_rng(0)
        J_i = np.rint(g.normal(size=(N, N)) * 2).astype(np.int32)
        J_i = np.triu(J_i, 1) + np.triu(J_i, 1).T
        J_f = J_i.view(np.float32)          # same bytes, same shape
        assert J_i.tobytes() == J_f.tobytes()
        h = np.zeros(N, np.float32)
        a = ising.IsingProblem(couplings=J_i, fields=h, offset=0.0)
        b = ising.IsingProblem(couplings=J_f, fields=h, offset=0.0)
        assert coupling_digest(a) != coupling_digest(b)

    def test_edge_list_problems_digest_by_canonical_coo(self):
        prob = _problem(4)
        rows, cols = np.nonzero(np.triu(np.asarray(prob.couplings), 1))
        w = np.asarray(prob.couplings)[rows, cols]
        a = ising.IsingProblem.create_sparse(
            ising.EdgeList.create(rows, cols, w, num_spins=N))
        perm = np.random.default_rng(0).permutation(len(rows))
        b = ising.IsingProblem.create_sparse(
            ising.EdgeList.create(rows[perm], cols[perm], w[perm],
                                  num_spins=N))
        assert coupling_digest(a) == coupling_digest(b)
        assert coupling_digest(a).startswith("edges:")
