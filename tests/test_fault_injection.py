"""End-to-end fault injection on the spin-sharded tier: real process
deaths (``os._exit`` mid-run) on a forced 2-device CPU mesh, resumed runs
proving bit-identical recovery.

Tier-1 runs the single kill-and-resume smoke (``-m fault`` selects just
these); the randomized kill/corrupt matrix rides ``-m slow``.
"""
import numpy as np
import pytest

from benchmarks.subproc import run_forced_device_subprocess
from fault_injection import (KILL_EXIT_CODE, corrupt_snapshot, parse_result,
                             resilient_subprocess_code)

pytestmark = pytest.mark.fault


def _run(code, n_devices=2):
    proc = run_forced_device_subprocess(code, n_devices=n_devices)
    return proc


def _digest(proc):
    assert proc.returncode == 0, (
        f"subprocess failed rc={proc.returncode}\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    d = parse_result(proc.stdout)
    d.pop("resumed_from")
    return d


def test_kill_and_resume_sharded_smoke(tmp_path):
    """A hard kill (os._exit, no cleanup) right after snapshot 2 on a
    2-device sharded mesh; the resumed run must land bit-identical to an
    uninterrupted one."""
    clean = _digest(_run(resilient_subprocess_code(
        run_dir=str(tmp_path / "clean"))))

    killed_dir = str(tmp_path / "killed")
    proc = _run(resilient_subprocess_code(run_dir=killed_dir,
                                          kill_after_chunk=2))
    assert proc.returncode == KILL_EXIT_CODE, (
        f"expected injected kill rc={KILL_EXIT_CODE}, got "
        f"{proc.returncode}\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")

    resumed = _digest(_run(resilient_subprocess_code(
        run_dir=killed_dir, expect_resumed_from=2)))
    assert resumed == clean


def test_kill_and_resume_sharded_2d_smoke(tmp_path):
    """Same kill-at-chunk-boundary drill on the 2-D (groups=2, rows=2)
    mesh: the bitplane_sharded_2d tier must also resume bit-identically
    after a hard mid-run death."""
    clean = _digest(_run(resilient_subprocess_code(
        run_dir=str(tmp_path / "clean"), mesh_shape=(2, 2)), n_devices=4))

    killed_dir = str(tmp_path / "killed")
    proc = _run(resilient_subprocess_code(run_dir=killed_dir,
                                          kill_after_chunk=2,
                                          mesh_shape=(2, 2)), n_devices=4)
    assert proc.returncode == KILL_EXIT_CODE, (
        f"expected injected kill rc={KILL_EXIT_CODE}, got "
        f"{proc.returncode}\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")

    resumed = _digest(_run(resilient_subprocess_code(
        run_dir=killed_dir, expect_resumed_from=2, mesh_shape=(2, 2)),
        n_devices=4))
    assert resumed == clean


@pytest.mark.slow
@pytest.mark.parametrize("trial", range(3))
def test_kill_and_resume_randomized(tmp_path, trial):
    """Randomized matrix: seed, kill boundary, and optional post-kill
    snapshot corruption drawn per trial; every combination must recover to
    the uninterrupted trajectory."""
    g = np.random.default_rng(100 + trial)
    seed = int(g.integers(0, 2**16))
    kill_at = int(g.integers(1, 3))          # 60 steps / 20 -> chunks 1..3
    corrupt = bool(g.integers(0, 2))

    clean = _digest(_run(resilient_subprocess_code(
        run_dir=str(tmp_path / "clean"), seed=seed)))

    run_dir = str(tmp_path / "killed")
    proc = _run(resilient_subprocess_code(run_dir=run_dir, seed=seed,
                                          kill_after_chunk=kill_at))
    assert proc.returncode == KILL_EXIT_CODE, proc.stderr

    resume_from = kill_at
    if corrupt and kill_at > 1:
        # Damage the newest snapshot too: recovery must walk back one.
        corrupt_snapshot(run_dir, kill_at,
                         how=("flip", "truncate")[trial % 2])
        resume_from = kill_at - 1

    resumed = _digest(_run(resilient_subprocess_code(
        run_dir=run_dir, seed=seed, expect_resumed_from=resume_from)))
    assert resumed == clean
