"""Unit tier for ``core/placement.py`` and ``core/refine.py`` invariants.

Complements the end-to-end quality checks in test_graphs/test_extensions with
the contracts those tests cannot pin: placement determinism (stateless seeded
solver ⇒ identical assignments), structural validity of the returned
partition, hand-computable ``cut_bytes``/traffic-matrix algebra, and greedy
descent's energy-never-increases + 1-opt-fixpoint contract across batch
shapes. Property tests route through ``hypothesis_compat`` so they skip —
individually — on hypothesis-less hosts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import ising, placement
from repro.core.refine import greedy_descent


def _traffic(seed, e=12, clusters=2):
    g = np.random.default_rng(seed)
    C = g.random((e, e)) * 0.2
    step = e // clusters
    for c in range(clusters):
        C[c * step:(c + 1) * step, c * step:(c + 1) * step] += 3.0
    C = np.triu(C, 1)
    return C + C.T


# ---------------------------------------------------------------- placement

def test_place_is_deterministic_per_seed():
    C = _traffic(3)
    a = placement.place(C, num_devices=2, seed=7, steps=200, replicas=4)
    b = placement.place(C, num_devices=2, seed=7, steps=200, replicas=4)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert a.cut_bytes == b.cut_bytes and a.imbalance == b.imbalance


def test_place_validity_invariants():
    C = _traffic(5, e=16)
    loads = np.ones(16)
    res = placement.place(C, num_devices=4, loads=loads, seed=1, steps=200,
                          replicas=4)
    assert res.assignment.shape == (16,)
    assert res.num_devices == 4
    assert res.assignment.min() >= 0 and res.assignment.max() < 4
    # Recursive bisection with the degenerate-split fallback never empties a
    # device when E >= D.
    assert np.bincount(res.assignment, minlength=4).min() >= 1
    # Reported cut matches the standalone accounting on the same assignment.
    assert res.cut_bytes == placement.cut_bytes(C, res.assignment)
    # Imbalance is max/mean - 1 over device loads.
    dev = np.array([loads[res.assignment == d].sum() for d in range(4)])
    assert res.imbalance == pytest.approx(dev.max() / dev.mean() - 1.0)


def test_place_rejects_non_power_of_two():
    with pytest.raises(ValueError, match="power of two"):
        placement.place(_traffic(0, e=9), num_devices=3)


def test_cut_bytes_hand_example():
    C = np.array([[0.0, 2.0, 3.0],
                  [2.0, 0.0, 5.0],
                  [3.0, 5.0, 0.0]])
    # {0,1} vs {2}: cross edges (0,2)=3 and (1,2)=5.
    assert placement.cut_bytes(C, np.array([0, 0, 1])) == 8.0
    assert placement.cut_bytes(C, np.array([0, 0, 0])) == 0.0
    assert placement.cut_bytes(C, np.array([0, 1, 2])) == 10.0


def test_expert_traffic_matrix_properties():
    g = np.random.default_rng(2)
    probs = g.random((40, 6))
    C = placement.expert_traffic_matrix(probs)
    assert C.shape == (6, 6)
    np.testing.assert_array_equal(np.diag(C), np.zeros(6))
    np.testing.assert_allclose(C, C.T)
    assert (C >= 0).all()
    # Off-diagonals are co-activation inner products.
    assert C[0, 1] == pytest.approx(float(probs[:, 0] @ probs[:, 1]))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_place_partitions_every_expert_exactly_once(seed):
    C = _traffic(seed, e=8)
    res = placement.place(C, num_devices=2, seed=seed % 1000, steps=64,
                          replicas=2)
    assert res.assignment.shape == (8,)
    assert set(np.unique(res.assignment)) <= {0, 1}


# ------------------------------------------------------------------- refine

def _problem(seed, n):
    g = np.random.default_rng(seed)
    J = np.rint(g.normal(size=(n, n)) * 2.0)
    J = np.triu(J, 1)
    return ising.IsingProblem.create(J=(J + J.T).astype(np.float32))


def test_greedy_descent_never_increases_energy_and_is_consistent():
    problem = _problem(11, 24)
    spins = ising.random_spins(jax.random.key(4), (5, 24))
    e0 = np.asarray(ising.energy(problem, spins))
    refined, e1 = greedy_descent(problem, spins)
    e1 = np.asarray(e1)
    assert refined.shape == spins.shape and e1.shape == (5,)
    assert (e1 <= e0 + 1e-5).all()
    np.testing.assert_allclose(e1, np.asarray(ising.energy(problem, refined)),
                               atol=1e-3)
    assert np.isin(np.asarray(refined), (-1, 1)).all()


def test_greedy_descent_is_idempotent():
    """A 1-opt fixpoint must survive a second descent unchanged — the
    energy-never-increases contract composed with local optimality."""
    problem = _problem(12, 16)
    spins = ising.random_spins(jax.random.key(1), (3, 16))
    once, e_once = greedy_descent(problem, spins)
    twice, e_twice = greedy_descent(problem, once)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
    np.testing.assert_array_equal(np.asarray(e_once), np.asarray(e_twice))


def test_greedy_descent_respects_max_flips():
    """With max_flips=0 the input must come back untouched (the cap bounds
    the while_loop, so a zero budget is the identity)."""
    problem = _problem(13, 16)
    spins = ising.random_spins(jax.random.key(2), (2, 16))
    refined, e = greedy_descent(problem, spins, max_flips=0)
    np.testing.assert_array_equal(np.asarray(refined), np.asarray(spins))
    np.testing.assert_allclose(np.asarray(e),
                               np.asarray(ising.energy(problem, spins)),
                               atol=1e-4)


def test_greedy_descent_batch_shapes():
    """The leading batch shape is preserved verbatim (vmapped over a
    flattened replica axis internally)."""
    problem = _problem(14, 12)
    spins = ising.random_spins(jax.random.key(3), (2, 3, 12))
    refined, e = greedy_descent(problem, spins)
    assert refined.shape == (2, 3, 12)
    assert e.shape == (2, 3)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(4, 24))
def test_greedy_descent_monotone_property(seed, n):
    problem = _problem(seed, n)
    spins = ising.random_spins(jax.random.fold_in(jax.random.key(0), seed),
                               (2, n))
    e0 = np.asarray(ising.energy(problem, spins))
    refined, e1 = greedy_descent(problem, spins)
    assert (np.asarray(e1) <= e0 + 1e-5).all()
    de = np.asarray(ising.delta_energies(problem, refined))
    assert (de >= -1e-3).all()  # 1-opt local optimum
